//! Closed-loop optimization: the twin tunes itself.
//!
//! The paper's headline result is an *operating-point trade-off*
//! (Figs. 4–7): raising the coolant setpoint toward 60–70 degC
//! maximizes adsorption-chiller reuse while throttle risk bounds it
//! from above. This subsystem wraps the megabatch fleet evaluator in a
//! search layer so that band comes out as an *output*:
//!
//!  * [`space`] — typed parameter space (setpoint, pump scale, chiller
//!    sizing, facility share), every axis a bounded lattice;
//!  * [`objective`] — scalar lower-is-better score (PUE/ERE/throttle
//!    from `FleetAggregate`, payback from `economics::CostModel`);
//!  * [`eval`] — fingerprint-cached, sharded candidate evaluation on
//!    the fleet path (one candidate = one small fleet run);
//!  * [`driver`] — deterministic search drivers (grid with random
//!    restarts, coordinate descent, cross-entropy), splitmix64-seeded.
//!
//! Surfaces: the `idatacool optimize` CLI subcommand, the `[optimize]`
//! TOML section, `POST /v1/optimize` on the server, and the
//! `idatacool-optimize/1` JSON report — one serializer for all of
//! them, byte for byte.
//!
//! Determinism: for a fixed (base config, space, objective, driver,
//! seed, budget, plants, scenario), the trajectory, the per-generation
//! stats, the winner and the report bytes are bitwise reproducible
//! across runs, shard counts and the CLI/server boundary
//! (`tests/optimize_integration.rs` is the gate). The report carries no
//! wall-clock and no execution-shape fields.

pub mod driver;
pub mod eval;
pub mod objective;
pub mod space;

use anyhow::Result;

use crate::config::{OptimizeSettings, SimConfig};
use crate::economics::CostModel;
use crate::figures::sweep::{self, SetpointRun, SweepOptions};
use crate::fleet::scenario::Scenario;
use crate::util::json::{Json, JsonBuilder};

use driver::{DriverKind, EvalRecord, GenStat};
use eval::Evaluator;
use objective::Weights;
use space::Space;

/// A fully resolved optimization run configuration (TOML/env/flag
/// precedence already applied — see [`OptimizeConfig::from_settings`]).
#[derive(Debug, Clone)]
pub struct OptimizeConfig {
    /// Base plant config candidates derive from.
    pub base: SimConfig,
    pub space: Space,
    pub weights: Weights,
    /// The preset name the weights started from (report field).
    pub objective_name: String,
    pub kind: DriverKind,
    /// Search + fleet seed (one seed reproduces the whole trajectory).
    pub seed: u64,
    /// Physical-evaluation budget.
    pub budget: usize,
    /// Candidates per generation.
    pub gen_size: usize,
    /// Plants per candidate fleet.
    pub n_plants: usize,
    pub scenario: Scenario,
    /// Simulated seconds per candidate evaluation (overrides the base
    /// config's duration for the inner fleet runs). Semantic knob: it
    /// changes the measured physics, so it is part of the canonical
    /// request document — unlike shards/megabatch, which are execution
    /// shape.
    pub eval_duration_s: f64,
    /// Re-measure the winner through the sweep's `evaluate_point` and
    /// attach the result as `best_detail`.
    pub detail: bool,
    pub cost: CostModel,
    /// Execution shape (never in documents or cache keys).
    pub megabatch: bool,
    pub shards: usize,
}

impl OptimizeConfig {
    /// Resolve an [`OptimizeSettings`] (the `[optimize]` TOML section,
    /// possibly env/flag-patched by the CLI) against a base config.
    /// Defaults: `ere` objective, `grid` driver, budget 24, 2 plants,
    /// `mixed` scenario, setpoint axis only, generation size 8, 900 s
    /// eval windows, detail on, seed = the base config's seed.
    pub fn from_settings(base: SimConfig, s: &OptimizeSettings)
                         -> Result<OptimizeConfig> {
        let objective_name =
            s.objective.clone().unwrap_or_else(|| "ere".into());
        let mut weights = Weights::preset(&objective_name)?;
        if let Some(w) = s.w_pue {
            weights.pue = w;
        }
        if let Some(w) = s.w_ere {
            weights.ere = w;
        }
        if let Some(w) = s.w_throttle {
            weights.throttle = w;
        }
        if let Some(w) = s.w_cost {
            weights.cost = w;
        }
        let kind =
            DriverKind::by_name(s.driver.as_deref().unwrap_or("grid"))?;
        let scenario =
            Scenario::by_name(s.scenario.as_deref().unwrap_or("mixed"))?;
        let mut space = Space::default();
        if let Some(axes) = &s.axes {
            space.enable_axes(axes)?;
        }
        let eval_duration_s = s.eval_duration_s.unwrap_or(900.0);
        anyhow::ensure!(
            eval_duration_s > 0.0,
            "optimize eval_duration_s must be positive"
        );
        let cfg = OptimizeConfig {
            seed: base.seed,
            base,
            space,
            weights,
            objective_name,
            kind,
            budget: s.budget.unwrap_or(24),
            gen_size: s.gen_size.unwrap_or(8),
            n_plants: s.plants.unwrap_or(2),
            scenario,
            eval_duration_s,
            detail: s.detail.unwrap_or(true),
            cost: CostModel::default(),
            megabatch: crate::fleet::default_megabatch()?,
            shards: eval::default_opt_shards()?,
        };
        cfg.space.validate()?;
        Ok(cfg)
    }
}

/// A finished optimization: trajectory, per-generation stats, winner.
pub struct OptimizeRun {
    pub records: Vec<EvalRecord>,
    pub gens: Vec<GenStat>,
    /// Index into `records` of the winner.
    pub best: usize,
    /// Physical evaluations spent.
    pub evals: usize,
    pub cache_hits: usize,
    /// The winner re-measured through the sweep's `evaluate_point`
    /// (when `detail` is on and the measurement succeeded).
    pub best_detail: Option<SetpointRun>,
}

/// Run a resolved optimization end to end.
pub fn run_optimize(c: &OptimizeConfig) -> Result<OptimizeRun> {
    let _span = crate::obs::span("optimize");
    let mut base = c.base.clone();
    base.duration_s = c.eval_duration_s;
    let mut ev = Evaluator::new(
        base.clone(),
        c.space.clone(),
        c.weights,
        c.cost.clone(),
        c.n_plants,
        c.scenario,
        c.seed,
        c.megabatch,
        c.shards,
        c.budget,
    )?;
    let outcome = driver::search(c.kind, &mut ev, c.gen_size, c.seed)?;
    let best = outcome.records[outcome.best];
    // Re-measure the winner with the sweep's own instrument: the same
    // evaluate_point behind the figure sweeps, so the optimizer report
    // and the sweep figures can never disagree about what the chosen
    // operating point looks like. SweepOptions::quick() keeps the CLI
    // snappy; the measurement is deterministic either way.
    let best_detail = if c.detail {
        let dcfg = c.space.apply(&base, &best.point);
        match sweep::evaluate_point(&dcfg, best.point.setpoint,
                                    &SweepOptions::quick()) {
            Ok(run) => Some(run),
            Err(e) => {
                eprintln!("optimize: best-point detail measurement \
                           failed: {e:#}");
                None
            }
        }
    } else {
        None
    };
    Ok(OptimizeRun {
        records: outcome.records,
        gens: outcome.gens,
        best: outcome.best,
        evals: ev.evals(),
        cache_hits: ev.cache_hits(),
        best_detail,
    })
}

/// `f64::INFINITY`-safe number: JSON has no `inf`, so non-finite
/// paybacks serialize as `null`.
fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

fn record_json(r: &EvalRecord) -> Json {
    JsonBuilder::new()
        .num("eval", r.eval as f64)
        .num("gen", r.gen as f64)
        .bool("cached", r.cached)
        .bool("failed", r.failed)
        .num("setpoint", r.point.setpoint)
        .num("pump_scale", r.point.pump_scale)
        .num("chiller_scale", r.point.chiller_scale)
        .num("facility_share", r.point.facility_share)
        .num("objective", r.score.total)
        .num("pue", r.score.pue)
        .num("ere", r.score.ere)
        .num("throttle_frac", r.score.throttle_frac)
        .set("payback_years", num_or_null(r.score.payback_years))
        .build()
}

/// The sweep-point detail block (same field names as the sweep's
/// `SweepData::to_json_value` points, same serializer substrate).
fn detail_json(run: &SetpointRun) -> Json {
    let p = &run.point;
    JsonBuilder::new()
        .num("setpoint", p.setpoint)
        .num("t_out_mean", p.t_out.mean())
        .num("t_out_std", p.t_out.std())
        .num("t_tank_mean", p.t_tank.mean())
        .num("sel_core_mean", p.sel_core.mean())
        .num("sel_core_std", p.sel_core.std())
        .num("sel_power_mean", p.sel_power.mean())
        .num("sel_power_std", p.sel_power.std())
        .num("hiw", p.hiw)
        .num("hiw_err", p.hiw_err)
        .num("pd_frac", p.pd_frac)
        .num("cop", p.cop)
        .num("reuse", p.reuse)
        .num("valve_mean", p.valve_mean)
        .num("p_ac_w", p.p_ac)
        .build()
}

impl OptimizeRun {
    /// The `idatacool-optimize/1` document: the resolved request, the
    /// full trajectory, per-generation stats, the winner (plus its
    /// sweep-grade detail when enabled) and the determinism
    /// fingerprint. `util::json` substrate — BTreeMap-stable key order,
    /// shortest-round-trip floats — so the CLI `--json` file and the
    /// `POST /v1/optimize` response body are the same bytes. No
    /// wall-clock, no execution-shape fields (shards/megabatch).
    pub fn to_json_value(&self, cfg: &OptimizeConfig) -> Json {
        let axes: Vec<Json> = cfg
            .space
            .axes()
            .iter()
            .map(|a| {
                JsonBuilder::new()
                    .str("name", a.name)
                    .num("lo", a.lo)
                    .num("hi", a.hi)
                    .num("step", a.step)
                    .bool("frozen", a.frozen)
                    .num("fixed", a.fixed)
                    .build()
            })
            .collect();
        let gens: Vec<Json> = self
            .gens
            .iter()
            .map(|g| {
                JsonBuilder::new()
                    .num("index", g.index as f64)
                    .num("submitted", g.submitted as f64)
                    .num("physical", g.physical as f64)
                    .num("best", g.best)
                    .num("mean", g.mean)
                    .build()
            })
            .collect();
        let trajectory: Vec<Json> =
            self.records.iter().map(record_json).collect();
        JsonBuilder::new()
            .str("schema", "idatacool-optimize/1")
            .str("objective", &cfg.objective_name)
            .set(
                "weights",
                JsonBuilder::new()
                    .num("pue", cfg.weights.pue)
                    .num("ere", cfg.weights.ere)
                    .num("throttle", cfg.weights.throttle)
                    .num("cost", cfg.weights.cost)
                    .build(),
            )
            .str("driver", cfg.kind.name())
            .hex("seed", cfg.seed)
            .num("budget", cfg.budget as f64)
            .num("gen_size", cfg.gen_size as f64)
            .num("evals", self.evals as f64)
            .num("cache_hits", self.cache_hits as f64)
            .num("n_plants", cfg.n_plants as f64)
            .str("scenario", cfg.scenario.name())
            .str("base_config", &cfg.base.name)
            .num("eval_duration_s", cfg.eval_duration_s)
            .arr("space", axes)
            .arr("generations", gens)
            .arr("trajectory", trajectory)
            .set("best", record_json(&self.records[self.best]))
            .set(
                "best_detail",
                self.best_detail
                    .as_ref()
                    .map(detail_json)
                    .unwrap_or(Json::Null),
            )
            .hex("fingerprint", self.fingerprint())
            .build()
    }

    pub fn to_json(&self, cfg: &OptimizeConfig) -> String {
        self.to_json_value(cfg).to_string()
    }

    /// Order-sensitive bitwise fingerprint of the trajectory and the
    /// winner — the determinism gate compares this across runs and
    /// across the CLI/server boundary.
    pub fn fingerprint(&self) -> u64 {
        fn mix(h: u64, v: u64) -> u64 {
            (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
        }
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        for r in &self.records {
            h = mix(h, r.gen as u64);
            for c in r.point.coords() {
                h = mix(h, c.to_bits());
            }
            h = mix(h, r.score.total.to_bits());
            h = mix(h, r.cached as u64);
            h = mix(h, r.failed as u64);
        }
        h = mix(h, self.best as u64);
        h
    }

    /// One-line CLI headline.
    pub fn summary(&self, cfg: &OptimizeConfig) -> String {
        let b = &self.records[self.best];
        format!(
            "optimize [{} / {}]: best objective {:.6} at setpoint \
             {:.1} degC (pump x{:.2}, chiller x{:.2}, share {:.2}) \
             after {} evals (+{} cache hits, {} generations)",
            cfg.objective_name,
            cfg.kind.name(),
            b.score.total,
            b.point.setpoint,
            b.point.pump_scale,
            b.point.chiller_scale,
            b.point.facility_share,
            self.evals,
            self.cache_hits,
            self.gens.len(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_settings_applies_defaults() {
        let base = SimConfig::test_small();
        let c = OptimizeConfig::from_settings(
            base.clone(),
            &OptimizeSettings::default(),
        )
        .unwrap();
        assert_eq!(c.objective_name, "ere");
        assert_eq!(c.kind, DriverKind::Grid);
        assert_eq!(c.budget, 24);
        assert_eq!(c.n_plants, 2);
        assert_eq!(c.scenario.name(), "mixed");
        assert_eq!(c.eval_duration_s, 900.0);
        assert!(c.detail);
        assert_eq!(c.seed, base.seed);
        // default space: only the setpoint axis is free
        assert!(!c.space.setpoint.frozen);
        assert!(c.space.pump.frozen);
    }

    #[test]
    fn from_settings_resolves_presets_axes_and_overrides() {
        let mut s = OptimizeSettings::default();
        s.objective = Some("cost".into());
        s.driver = Some("cem".into());
        s.budget = Some(10);
        s.axes = Some("setpoint,pump".into());
        s.w_throttle = Some(2.0);
        let c = OptimizeConfig::from_settings(SimConfig::test_small(), &s)
            .unwrap();
        assert_eq!(c.kind, DriverKind::Cem);
        assert_eq!(c.weights.cost, 1.0);
        assert_eq!(c.weights.throttle, 2.0); // explicit override wins
        assert!(!c.space.pump.frozen);
        assert!(c.space.chiller.frozen);
        // garbage is rejected
        let mut bad = OptimizeSettings::default();
        bad.objective = Some("speed".into());
        assert!(OptimizeConfig::from_settings(SimConfig::test_small(),
                                              &bad)
            .is_err());
        let mut bad = OptimizeSettings::default();
        bad.eval_duration_s = Some(0.0);
        assert!(OptimizeConfig::from_settings(SimConfig::test_small(),
                                              &bad)
            .is_err());
    }
}
