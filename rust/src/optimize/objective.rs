//! Scalar objective for the closed-loop optimizer.
//!
//! Composes the fleet-level metrics (`FleetAggregate::objective`: PUE,
//! ERE, throttle fraction) with the amortization economics
//! (`economics::CostModel::analyze`) into one lower-is-better score.
//! Presets:
//!
//!  * `ere`  — energy-reuse effectiveness with a strong throttle
//!    penalty (the paper's operating-point question; default);
//!  * `pue`  — facility efficiency with the same throttle penalty;
//!  * `cost` — normalized payback time of the retrofit, throttle
//!    penalized.
//!
//! The `facility_share` axis enters *here*, not in the physics: ERE is
//! PUE minus the credit-per-IT-energy term, so valuing only a share `s`
//! of the facility credit is exactly `s*ERE + (1-s)*PUE` — a
//! reweighting, which keeps candidate evaluation (the expensive part)
//! independent of the share axis.

use anyhow::{bail, Result};

use crate::economics::CostModel;
use crate::fleet::aggregate::ObjectiveWeights;
use crate::fleet::FleetRun;

use super::space::Point;

/// Cap on the payback horizon entering the cost term: paybacks beyond
/// this (including the infinite no-savings case) saturate at 1.0.
pub const PAYBACK_CAP_YEARS: f64 = 20.0;

/// Finite worst-case score assigned to failed candidate evaluations
/// (panic or error under chaos): JSON-safe, orders after every real
/// score, and never NaN-poisons a generation statistic.
pub const WORST_SCORE: f64 = 1e12;

/// Objective weights: the fleet terms plus the economics term.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Weights {
    pub pue: f64,
    pub ere: f64,
    pub throttle: f64,
    /// Weight on the normalized payback time (capped at
    /// [`PAYBACK_CAP_YEARS`], scaled to [0, 1]).
    pub cost: f64,
}

impl Weights {
    /// Resolve a named preset.
    pub fn preset(name: &str) -> Result<Weights> {
        Ok(match name {
            "ere" => Weights { pue: 0.0, ere: 1.0, throttle: 5.0,
                               cost: 0.0 },
            "pue" => Weights { pue: 1.0, ere: 0.0, throttle: 5.0,
                               cost: 0.0 },
            "cost" => Weights { pue: 0.0, ere: 0.0, throttle: 5.0,
                                cost: 1.0 },
            other => bail!(
                "unknown objective preset '{other}' (ere|pue|cost)"
            ),
        })
    }
}

/// One scored candidate: the total plus its components (the trajectory
/// rows carry all of them so a report reader can re-weight offline).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Score {
    /// The weighted total (lower is better).
    pub total: f64,
    pub pue: f64,
    pub ere: f64,
    pub throttle_frac: f64,
    /// Uncapped payback estimate [years] (`f64::INFINITY` when the
    /// operating point never amortizes).
    pub payback_years: f64,
}

impl Score {
    /// The sentinel a failed evaluation is scored with.
    pub fn worst() -> Score {
        Score {
            total: WORST_SCORE,
            pue: 0.0,
            ere: 0.0,
            throttle_frac: 0.0,
            payback_years: f64::INFINITY,
        }
    }
}

/// Score a finished fleet evaluation of one candidate.
///
/// Deterministic: every input is a pure function of the fleet run
/// (itself bitwise reproducible) and the reductions below iterate
/// plants in index order with plain f64 arithmetic.
pub fn score(run: &FleetRun, n_nodes: usize, point: &Point, w: &Weights,
             model: &CostModel) -> Score {
    let agg = &run.aggregate;
    let share = point.facility_share;
    // share-adjusted fleet terms: s*ERE + (1-s)*PUE == PUE - s*credit
    let fleet_w = ObjectiveWeights {
        pue: w.pue + w.ere * (1.0 - share),
        ere: w.ere * share,
        throttle: w.throttle,
    };
    let base = agg.objective(&fleet_w);

    // Economics at the fleet-mean operating point (plant-index order).
    let n_plants = run.plants.len().max(1);
    let mut p_ac = 0.0;
    let mut hiw = 0.0;
    for p in &run.plants {
        p_ac += p.result.energy.mean_p_ac();
        hiw += p.result.energy.heat_in_water_fraction();
    }
    p_ac /= n_plants as f64;
    hiw /= n_plants as f64;
    let p_chilled = if run.facility.seconds > 1e-9 {
        share * (run.facility.e_chilled / run.facility.seconds)
            / n_plants as f64
    } else {
        0.0
    };
    let amort = model.analyze(n_nodes, p_ac, hiw, p_chilled);
    let payback = amort.payback_years;
    let cost_term = (payback.min(PAYBACK_CAP_YEARS) / PAYBACK_CAP_YEARS)
        .min(1.0);

    Score {
        total: base + w.cost * cost_term,
        pue: agg.pue_stats.mean(),
        ere: agg.ere_stats.mean(),
        throttle_frac: agg.throttle_fraction(),
        payback_years: payback,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_garbage_is_rejected() {
        let e = Weights::preset("ere").unwrap();
        assert_eq!(e.ere, 1.0);
        assert_eq!(e.cost, 0.0);
        let p = Weights::preset("pue").unwrap();
        assert_eq!(p.pue, 1.0);
        let c = Weights::preset("cost").unwrap();
        assert_eq!(c.cost, 1.0);
        // every preset keeps the throttle penalty on
        for w in [e, p, c] {
            assert!(w.throttle > 0.0);
        }
        assert!(Weights::preset("speed").is_err());
    }

    #[test]
    fn worst_score_is_finite_and_orders_last() {
        let w = Score::worst();
        assert!(w.total.is_finite());
        assert!(w.total > 1e6);
        assert!(w.payback_years.is_infinite());
    }
}
