//! Deterministic search drivers: random-restart grid, coordinate
//! descent, and a cross-entropy method.
//!
//! Every driver consumes randomness only through one `variability::Rng`
//! seeded by [`search_seed`] (the fleet's splitmix64 convention), draws
//! in a fixed order (canonical axis order within a point, submission
//! order within a generation), and proposes only lattice-snapped
//! points — so a fixed seed replays the identical search trajectory
//! bitwise, including every cache hit.
//!
//! Budget semantics: the budget caps *physical* evaluations (cache hits
//! are free). Drivers stop when the budget is spent, or after three
//! consecutive generations that neither spent budget nor improved — the
//! degenerate case where the whole reachable lattice is already cached
//! (e.g. the 1-D default space under a generous budget) terminates
//! promptly instead of spinning on free lookups.

use anyhow::{bail, Result};

use crate::variability::rng::{splitmix64, Rng};

use super::eval::{EvalOutcome, Evaluator};
use super::objective::Score;
use super::space::Point;

/// Consecutive no-progress generations before a driver gives up.
const STALE_LIMIT: usize = 3;

/// The search-driver catalog.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriverKind {
    /// Full (budget-truncated) lattice scan + seeded random restarts.
    Grid = 0,
    /// Coordinate descent with seeded restarts on stagnation.
    Coordinate = 1,
    /// Cross-entropy method: sample, select elites, refit.
    Cem = 2,
}

impl DriverKind {
    pub fn name(self) -> &'static str {
        match self {
            DriverKind::Grid => "grid",
            DriverKind::Coordinate => "coordinate",
            DriverKind::Cem => "cem",
        }
    }

    pub fn by_name(s: &str) -> Result<DriverKind> {
        Ok(match s {
            "grid" => DriverKind::Grid,
            "coordinate" => DriverKind::Coordinate,
            "cem" => DriverKind::Cem,
            other => bail!(
                "unknown optimize driver '{other}' (grid|coordinate|cem)"
            ),
        })
    }
}

/// Derive the driver's RNG seed from the user seed and the driver kind
/// — the same mix shape as `fleet::plant_seed`, so two drivers under
/// one seed never share a stream.
pub fn search_seed(seed: u64, kind: DriverKind) -> u64 {
    let salt = (kind as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(seed ^ salt).1
}

/// One trajectory row: the i-th evaluation the search requested.
#[derive(Debug, Clone, Copy)]
pub struct EvalRecord {
    /// Position in the trajectory (0-based).
    pub eval: usize,
    /// Generation that requested it (0-based).
    pub gen: usize,
    pub point: Point,
    pub score: Score,
    pub cached: bool,
    pub failed: bool,
}

/// Per-generation statistics.
#[derive(Debug, Clone, Copy)]
pub struct GenStat {
    pub index: usize,
    /// Candidates submitted (cached + physical; budget-skipped excluded).
    pub submitted: usize,
    /// Physical evaluations this generation spent.
    pub physical: usize,
    /// Best (lowest) total this generation, worst-case if empty.
    pub best: f64,
    /// Mean total over the generation's evaluated candidates.
    pub mean: f64,
}

/// A finished search: the full trajectory plus the winner.
pub struct SearchOutcome {
    pub records: Vec<EvalRecord>,
    pub gens: Vec<GenStat>,
    /// Index into `records` of the best candidate (lowest total,
    /// earliest on ties, non-failed preferred).
    pub best: usize,
}

/// Trajectory accumulator shared by the drivers.
struct SearchState {
    records: Vec<EvalRecord>,
    gens: Vec<GenStat>,
}

impl SearchState {
    /// Submit one generation: evaluate, record the trajectory rows (in
    /// submission order) and the generation stat. Returns the raw
    /// outcomes aligned with `points`.
    fn run_gen(&mut self, ev: &mut Evaluator, points: &[Point])
               -> Vec<Option<EvalOutcome>> {
        let _span = crate::obs::span("optimize_generation");
        let gen = self.gens.len();
        let before = ev.evals();
        let outs = ev.eval_batch(points);
        let mut best = f64::INFINITY;
        let mut sum = 0.0;
        let mut n = 0usize;
        for (p, o) in points.iter().zip(&outs) {
            let Some(o) = o else { continue };
            self.records.push(EvalRecord {
                eval: self.records.len(),
                gen,
                point: *p,
                score: o.score,
                cached: o.cached,
                failed: o.failed,
            });
            if o.score.total < best {
                best = o.score.total;
            }
            sum += o.score.total;
            n += 1;
        }
        self.gens.push(GenStat {
            index: gen,
            submitted: n,
            physical: ev.evals() - before,
            best: if n > 0 { best } else { super::objective::WORST_SCORE },
            mean: if n > 0 { sum / n as f64 } else { 0.0 },
        });
        outs
    }
}

/// Run the chosen driver to budget exhaustion (or stagnation) and pick
/// the winner.
pub fn search(kind: DriverKind, ev: &mut Evaluator, gen_size: usize,
              seed: u64) -> Result<SearchOutcome> {
    anyhow::ensure!(gen_size > 0, "optimize gen_size must be positive");
    let mut rng = Rng::new(search_seed(seed, kind));
    let mut st = SearchState { records: Vec::new(), gens: Vec::new() };
    match kind {
        DriverKind::Grid => grid(ev, gen_size, &mut rng, &mut st),
        DriverKind::Coordinate => coordinate(ev, &mut rng, &mut st),
        DriverKind::Cem => cem(ev, gen_size, &mut rng, &mut st),
    }
    if st.records.is_empty() {
        bail!("optimize search produced no evaluations \
               (budget too small?)");
    }
    // Winner: lowest total, earliest on exact ties; a failed
    // (worst-case-scored) row wins only if every row failed.
    let pick = |skip_failed: bool| -> Option<usize> {
        let mut w: Option<(f64, usize)> = None;
        for r in &st.records {
            if skip_failed && r.failed {
                continue;
            }
            if w.is_none() || r.score.total < w.unwrap().0 {
                w = Some((r.score.total, r.eval));
            }
        }
        w.map(|(_, i)| i)
    };
    let best = pick(true).or_else(|| pick(false)).unwrap();
    Ok(SearchOutcome { records: st.records, gens: st.gens, best })
}

/// Random-restart grid: scan the lattice (seeded-shuffled and truncated
/// when it exceeds the budget), then spend any leftover budget on
/// uniform random restarts.
fn grid(ev: &mut Evaluator, gen_size: usize, rng: &mut Rng,
        st: &mut SearchState) {
    let mut lattice = ev.space.grid();
    if lattice.len() > ev.budget {
        rng.shuffle(&mut lattice);
        lattice.truncate(ev.budget);
    }
    for chunk in lattice.chunks(gen_size) {
        st.run_gen(ev, chunk);
        if ev.remaining() == 0 {
            break;
        }
    }
    let mut stale = 0;
    while ev.remaining() > 0 && stale < STALE_LIMIT {
        let pts: Vec<Point> =
            (0..gen_size).map(|_| ev.space.sample(rng)).collect();
        let before = ev.evals();
        st.run_gen(ev, &pts);
        if ev.evals() == before {
            stale += 1;
        } else {
            stale = 0;
        }
    }
}

/// Coordinate descent: from the lattice center, propose +-1 step per
/// free axis each round, move to the best improving neighbor; on
/// stagnation, restart from a seeded random point.
fn coordinate(ev: &mut Evaluator, rng: &mut Rng, st: &mut SearchState) {
    let mut cur = ev.space.snap(ev.space.center());
    let outs = st.run_gen(ev, &[cur]);
    let mut cur_total = match outs.first().and_then(|o| o.as_ref()) {
        Some(o) => o.score.total,
        None => return, // budget < 1 physical eval
    };
    let mut stale = 0;
    let cap = 4 * ev.budget.max(1);
    for _ in 0..cap {
        if ev.remaining() == 0 || stale >= STALE_LIMIT {
            break;
        }
        // neighbors: +-1 lattice step per free axis, canonical order
        let mut props: Vec<Point> = Vec::new();
        for (i, a) in ev.space.axes().iter().enumerate() {
            if a.frozen {
                continue;
            }
            for d in [-1.0, 1.0] {
                let mut c = cur.coords();
                c[i] += d * a.step;
                let p = ev.space.snap(Point::from_coords(c));
                if p != cur && !props.contains(&p) {
                    props.push(p);
                }
            }
        }
        let before = ev.evals();
        let outs = st.run_gen(ev, &props);
        let mut winner: Option<(f64, usize)> = None;
        for (j, o) in outs.iter().enumerate() {
            let Some(o) = o else { continue };
            if winner.is_none() || o.score.total < winner.unwrap().0 {
                winner = Some((o.score.total, j));
            }
        }
        let progressed = ev.evals() > before;
        match winner {
            Some((t, j)) if t < cur_total => {
                cur_total = t;
                cur = props[j];
                stale = 0;
            }
            _ => {
                // stagnation: seeded restart (descend from wherever it
                // lands, even if worse — the global winner is picked
                // from the full trajectory at the end)
                cur = ev.space.sample(rng);
                let outs = st.run_gen(ev, &[cur]);
                match outs.first().and_then(|o| o.as_ref()) {
                    Some(o) => cur_total = o.score.total,
                    None => break,
                }
                if progressed || ev.evals() > before {
                    stale = 0;
                } else {
                    stale += 1;
                }
            }
        }
    }
}

/// Cross-entropy method: sample a population around a per-axis
/// mean/std, refit both to the elite quartile, repeat. Std is floored
/// at half a lattice step so the distribution never collapses below
/// the lattice resolution.
fn cem(ev: &mut Evaluator, gen_size: usize, rng: &mut Rng,
       st: &mut SearchState) {
    let space = ev.space.clone();
    let axes = space.axes();
    let center = space.center().coords();
    let mut mean = center;
    let mut std = [0.0f64; 4];
    for (i, a) in axes.iter().enumerate() {
        std[i] = if a.frozen { 0.0 } else { (a.hi - a.lo) / 4.0 };
    }
    let mut stale = 0;
    while ev.remaining() > 0 && stale < STALE_LIMIT {
        let pop: Vec<Point> = (0..gen_size)
            .map(|_| {
                let mut c = [0.0f64; 4];
                for (i, a) in axes.iter().enumerate() {
                    c[i] = if a.frozen {
                        a.fixed
                    } else {
                        mean[i] + std[i] * rng.normal()
                    };
                }
                space.snap(Point::from_coords(c))
            })
            .collect();
        let before = ev.evals();
        let outs = st.run_gen(ev, &pop);
        let mut scored: Vec<(f64, usize)> = outs
            .iter()
            .enumerate()
            .filter_map(|(j, o)| o.as_ref().map(|o| (o.score.total, j)))
            .collect();
        scored.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        if !scored.is_empty() {
            let n_elite = ((scored.len() + 3) / 4).max(1);
            let elites = &scored[..n_elite];
            for (i, a) in axes.iter().enumerate() {
                if a.frozen {
                    continue;
                }
                let vals: Vec<f64> = elites
                    .iter()
                    .map(|&(_, j)| pop[j].coords()[i])
                    .collect();
                let m = vals.iter().sum::<f64>() / vals.len() as f64;
                let var = vals.iter().map(|v| (v - m) * (v - m))
                    .sum::<f64>() / vals.len() as f64;
                mean[i] = m;
                std[i] = var.sqrt().max(a.step * 0.5);
            }
        }
        if ev.evals() == before {
            stale += 1;
        } else {
            stale = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn driver_names_round_trip() {
        for k in [DriverKind::Grid, DriverKind::Coordinate,
                  DriverKind::Cem] {
            assert_eq!(DriverKind::by_name(k.name()).unwrap(), k);
        }
        assert!(DriverKind::by_name("anneal").is_err());
    }

    #[test]
    fn search_seeds_separate_drivers_and_seeds() {
        let g = search_seed(7, DriverKind::Grid);
        let c = search_seed(7, DriverKind::Coordinate);
        let m = search_seed(7, DriverKind::Cem);
        assert_ne!(g, c);
        assert_ne!(c, m);
        assert_ne!(g, m);
        assert_ne!(search_seed(7, DriverKind::Grid),
                   search_seed(8, DriverKind::Grid));
        assert_eq!(g, search_seed(7, DriverKind::Grid));
    }
}
