//! Candidate evaluation: the megabatch fleet path behind a
//! fingerprint-keyed cache, sharded like `run_sweep_sharded`.
//!
//! Each candidate realizes a `SimConfig` (`Space::apply`), runs a small
//! fleet through `FleetDriver` (megabatch lockstep by default — the
//! same engine the sweep and the server use) and scores the aggregate
//! (`objective::score`). Evaluations are memoized under a key mixing
//! the applied config's fingerprint with the raw point coordinates (the
//! chiller-scale and facility-share axes are invisible to
//! `config_fingerprint`, so the coordinates must enter the key
//! directly), the fleet seed, the plant count and the scenario — a
//! repeated candidate is free, which is what lets grid restarts and
//! coordinate descent revisit points without spending budget.
//!
//! Determinism: a batch shards only its *uncached first-occurrence*
//! jobs across OS threads (contiguous blocks, `util::shard::blocks`),
//! every thread writes its own result slot, and the cache insertion
//! walks jobs in submission order — bitwise identical results for any
//! shard count, same argument as the sweep's.
//!
//! Containment: one candidate is one fault domain. A panicking or
//! erroring evaluation (the `optimize_eval` chaos site, or an organic
//! defect) is scored [`Score::worst`] and logged — the search continues
//! (degraded, never aborted), mirroring the fleet's quarantine story.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};

use anyhow::{bail, Result};

use crate::bench::record::config_fingerprint;
use crate::config::SimConfig;
use crate::economics::CostModel;
use crate::fleet::scenario::Scenario;
use crate::fleet::{FleetConfig, FleetDriver};
use crate::resilience::inject::{self, Site};
use crate::util::shard::blocks;

use super::objective::{self, Score, Weights};
use super::space::{Point, Space};

/// Shard (OS thread) count for a generation's candidate evaluations:
/// every available core, overridable via `IDATACOOL_OPT_SHARDS` with
/// the same strict parse as the sweep's (`env_usize_strict`): garbage
/// is an error, zero is an error, and the count clamps to the job
/// count at batch time.
pub fn default_opt_shards() -> Result<usize> {
    match crate::util::cli::env_usize_strict("IDATACOOL_OPT_SHARDS")? {
        Some(0) => anyhow::bail!(
            "IDATACOOL_OPT_SHARDS must be at least 1 \
             (use 1 for serial evaluation)"
        ),
        Some(k) => Ok(k),
        None => Ok(std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)),
    }
}

/// One evaluated candidate as the driver sees it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalOutcome {
    pub score: Score,
    /// The evaluation panicked or errored and was scored worst-case.
    pub failed: bool,
    /// Served from the cache (no physical evaluation this time).
    pub cached: bool,
}

/// The memoizing, sharded candidate evaluator.
pub struct Evaluator {
    /// Per-candidate base config (eval duration already applied).
    pub base: SimConfig,
    pub space: Space,
    pub weights: Weights,
    pub cost: CostModel,
    pub n_plants: usize,
    pub scenario: Scenario,
    pub fleet_seed: u64,
    pub megabatch: bool,
    pub shards: usize,
    /// Physical-evaluation budget (cache hits are free).
    pub budget: usize,
    physical_evals: usize,
    cache_hits: usize,
    cache: BTreeMap<u64, (Score, bool)>,
}

impl Evaluator {
    #[allow(clippy::too_many_arguments)]
    pub fn new(base: SimConfig, space: Space, weights: Weights,
               cost: CostModel, n_plants: usize, scenario: Scenario,
               fleet_seed: u64, megabatch: bool, shards: usize,
               budget: usize) -> Result<Evaluator> {
        anyhow::ensure!(n_plants > 0, "optimize needs at least one plant");
        anyhow::ensure!(budget > 0, "optimize budget must be positive");
        anyhow::ensure!(shards > 0, "optimize needs at least one shard");
        space.validate()?;
        Ok(Evaluator {
            base,
            space,
            weights,
            cost,
            n_plants,
            scenario,
            fleet_seed,
            megabatch,
            shards,
            budget,
            physical_evals: 0,
            cache_hits: 0,
            cache: BTreeMap::new(),
        })
    }

    /// Physical evaluations spent so far.
    pub fn evals(&self) -> usize {
        self.physical_evals
    }

    pub fn cache_hits(&self) -> usize {
        self.cache_hits
    }

    /// Physical evaluations left in the budget.
    pub fn remaining(&self) -> usize {
        self.budget.saturating_sub(self.physical_evals)
    }

    /// The evaluation-cache key: the applied config's fingerprint mixed
    /// (FNV) with the raw point coordinates, the fleet seed, the plant
    /// count and the scenario name. The coordinates must enter
    /// explicitly — `config_fingerprint` does not cover the chiller
    /// capacity curve, and the facility-share axis never touches the
    /// config at all.
    pub fn key(&self, p: &Point) -> u64 {
        fn mix(h: u64, v: u64) -> u64 {
            (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
        }
        let cfg = self.space.apply(&self.base, p);
        let mut h = config_fingerprint(&cfg);
        for c in p.coords() {
            h = mix(h, c.to_bits());
        }
        h = mix(h, self.fleet_seed);
        h = mix(h, self.n_plants as u64);
        for &b in self.scenario.name().as_bytes() {
            h = mix(h, b as u64);
        }
        h
    }

    /// Evaluate a generation of candidates. Cached candidates are free;
    /// uncached first occurrences run sharded, in submission order, up
    /// to the remaining budget. Returns one slot per input point:
    /// `None` means the budget ran out before that point could be
    /// physically evaluated.
    pub fn eval_batch(&mut self, points: &[Point])
                      -> Vec<Option<EvalOutcome>> {
        let keys: Vec<u64> = points.iter().map(|p| self.key(p)).collect();
        // First-occurrence uncached jobs, budget-capped. `trigger`
        // remembers which input slot caused the physical run so only
        // that slot reports cached=false.
        let mut trigger: BTreeMap<u64, usize> = BTreeMap::new();
        let mut jobs: Vec<(u64, Point)> = Vec::new();
        for (i, p) in points.iter().enumerate() {
            let k = keys[i];
            if self.cache.contains_key(&k) || trigger.contains_key(&k) {
                continue;
            }
            if jobs.len() >= self.remaining() {
                continue;
            }
            trigger.insert(k, i);
            jobs.push((k, *p));
        }

        let mut slots: Vec<Option<(Score, bool)>> = vec![None; jobs.len()];
        if !jobs.is_empty() {
            let shards = self.shards.clamp(1, jobs.len());
            let this = &*self;
            if shards <= 1 {
                for (slot, (_, p)) in jobs.iter().enumerate() {
                    slots[slot] = Some(this.evaluate_candidate(p));
                }
            } else {
                let indexed: Vec<(usize, Point)> = jobs
                    .iter()
                    .enumerate()
                    .map(|(i, (_, p))| (i, *p))
                    .collect();
                let buckets = blocks(indexed, shards);
                std::thread::scope(|scope| {
                    let mut handles = Vec::with_capacity(buckets.len());
                    for bucket in buckets {
                        handles.push(scope.spawn(move || {
                            bucket
                                .into_iter()
                                .map(|(i, p)| {
                                    (i, this.evaluate_candidate(&p))
                                })
                                .collect::<Vec<_>>()
                        }));
                    }
                    for h in handles {
                        // evaluate_candidate contains its own panics; a
                        // dead shard leaves its slots None -> worst.
                        if let Ok(rs) = h.join() {
                            for (i, r) in rs {
                                slots[i] = Some(r);
                            }
                        }
                    }
                });
            }
            // Cache insertion in submission order (determinism).
            for ((k, _), slot) in jobs.iter().zip(slots) {
                let entry = slot.unwrap_or((Score::worst(), true));
                self.cache.insert(*k, entry);
                self.physical_evals += 1;
            }
        }

        points
            .iter()
            .enumerate()
            .map(|(i, _)| {
                let k = keys[i];
                let (score, failed) = *self.cache.get(&k)?;
                let cached = trigger.get(&k) != Some(&i);
                if cached {
                    self.cache_hits += 1;
                }
                Some(EvalOutcome { score, failed, cached })
            })
            .collect()
    }

    /// Run one candidate: apply the point, run the fleet, score it.
    /// Self-contained and panic-proof — a failure is scored worst-case
    /// (`failed = true`), never propagated.
    fn evaluate_candidate(&self, p: &Point) -> (Score, bool) {
        if crate::obs::enabled() {
            crate::obs::metrics::optimize_evals().inc();
        }
        let _span = crate::obs::span("optimize_eval");
        let cfg = self.space.apply(&self.base, p);
        let fc = FleetConfig {
            n_plants: self.n_plants,
            // candidates are the parallel axis; each fleet runs serial
            shards: 1,
            base: cfg,
            fleet_seed: self.fleet_seed,
            scenario: self.scenario,
            megabatch: self.megabatch,
        };
        let n_nodes = self.base.n_nodes;
        let weights = self.weights;
        let cost = self.cost.clone();
        let point = *p;
        let r = catch_unwind(AssertUnwindSafe(move || -> Result<Score> {
            if inject::armed()
                && inject::fire(Site::OptimizeEval, None).is_some()
            {
                bail!("chaos: poisoned candidate evaluation");
            }
            let run = FleetDriver::new(fc)?.run()?;
            Ok(objective::score(&run, n_nodes, &point, &weights, &cost))
        }));
        match r {
            Ok(Ok(score)) => (score, false),
            Ok(Err(e)) => {
                eprintln!(
                    "optimize: candidate (setpoint {:.1}) failed: {e:#}; \
                     scored worst-case",
                    p.setpoint
                );
                (Score::worst(), true)
            }
            Err(_) => {
                eprintln!(
                    "optimize: candidate (setpoint {:.1}) panicked; \
                     scored worst-case",
                    p.setpoint
                );
                (Score::worst(), true)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_evaluator(budget: usize) -> Evaluator {
        let mut base = SimConfig::test_small();
        base.duration_s = 120.0;
        Evaluator::new(
            base,
            Space::default(),
            Weights::preset("ere").unwrap(),
            CostModel::default(),
            1,
            Scenario::by_name("baseline").unwrap(),
            0x0997,
            true,
            1,
            budget,
        )
        .unwrap()
    }

    #[test]
    fn cache_key_separates_points_and_seeds() {
        let ev = tiny_evaluator(4);
        let a = Point { setpoint: 55.0, pump_scale: 1.0,
                        chiller_scale: 1.0, facility_share: 1.0 };
        let b = Point { setpoint: 57.0, ..a };
        // share and chiller scale differ only in the raw coords — the
        // key must still separate them (config_fingerprint cannot).
        let c = Point { facility_share: 0.5, ..a };
        let d = Point { chiller_scale: 2.0, ..a };
        assert_eq!(ev.key(&a), ev.key(&a));
        assert_ne!(ev.key(&a), ev.key(&b));
        assert_ne!(ev.key(&a), ev.key(&c));
        assert_ne!(ev.key(&a), ev.key(&d));
        let mut ev2 = tiny_evaluator(4);
        ev2.fleet_seed = 0x0998;
        assert_ne!(ev.key(&a), ev2.key(&a));
    }

    #[test]
    fn batch_caches_and_respects_budget() {
        let mut ev = tiny_evaluator(2);
        let a = Point { setpoint: 55.0, pump_scale: 1.0,
                        chiller_scale: 1.0, facility_share: 1.0 };
        let b = Point { setpoint: 57.0, ..a };
        let c = Point { setpoint: 59.0, ..a };
        // a twice in one batch: 1 physical + 1 in-batch hit; b: 1 more
        // physical; c: over budget -> None.
        let out = ev.eval_batch(&[a, a, b, c]);
        assert_eq!(ev.evals(), 2);
        assert!(!out[0].as_ref().unwrap().cached);
        assert!(out[1].as_ref().unwrap().cached);
        assert!(!out[2].as_ref().unwrap().cached);
        assert!(out[3].is_none());
        assert_eq!(ev.cache_hits(), 1);
        assert_eq!(ev.remaining(), 0);
        // repeats stay free even with the budget exhausted
        let again = ev.eval_batch(&[a, b]);
        assert_eq!(ev.evals(), 2);
        assert!(again[0].as_ref().unwrap().cached);
        assert!(again[1].as_ref().unwrap().cached);
        assert_eq!(
            again[0].as_ref().unwrap().score,
            out[0].as_ref().unwrap().score
        );
    }
}
