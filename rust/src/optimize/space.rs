//! Typed parameter space for the closed-loop optimizer.
//!
//! Four operating knobs, each a bounded, stepped [`Axis`]:
//!
//!  * `setpoint` — rack-outlet setpoint [degC], the paper's Fig. 4–7
//!    x-axis (the only axis free by default);
//!  * `pump` — pump-curve scale applied to the base config's
//!    `pump_speed`;
//!  * `chiller` — adsorption-chiller sizing scale applied to the
//!    `pc_max` capacity curve;
//!  * `share` — facility share: the fraction of the pooled cooling
//!    credit the objective values (objective-side only, it never
//!    touches the plant physics).
//!
//! Every axis is a finite lattice (`lo + k*step`): candidate points are
//! *snapped* to lattice values before evaluation, so two search paths
//! that propose nearly-equal floats evaluate the identical `SimConfig`
//! and hit the same evaluation-cache key — the property that makes the
//! eval cache effective and the search trajectory bitwise reproducible.

use anyhow::{ensure, Result};

use crate::config::SimConfig;
use crate::variability::rng::Rng;

/// One candidate operating point (always lattice-snapped).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    /// Rack-outlet setpoint [degC].
    pub setpoint: f64,
    /// Scale on the base config's `pump_speed`.
    pub pump_scale: f64,
    /// Scale on the chiller capacity curve (`pc_max_at_57`, `pc_max_cap`).
    pub chiller_scale: f64,
    /// Fraction of the facility cooling credit the objective values.
    pub facility_share: f64,
}

impl Point {
    /// The four coordinates in canonical axis order
    /// (setpoint, pump, chiller, share) — the order every serializer,
    /// fingerprint and driver loop walks.
    pub fn coords(&self) -> [f64; 4] {
        [self.setpoint, self.pump_scale, self.chiller_scale,
         self.facility_share]
    }

    /// Rebuild a point from canonical-order coordinates.
    pub fn from_coords(c: [f64; 4]) -> Point {
        Point {
            setpoint: c[0],
            pump_scale: c[1],
            chiller_scale: c[2],
            facility_share: c[3],
        }
    }
}

/// One bounded, stepped search axis.
#[derive(Debug, Clone)]
pub struct Axis {
    pub name: &'static str,
    pub lo: f64,
    pub hi: f64,
    /// Lattice step; candidate values snap to `lo + k*step`.
    pub step: f64,
    /// A frozen axis contributes its `fixed` value to every candidate.
    pub frozen: bool,
    pub fixed: f64,
}

impl Axis {
    fn new(name: &'static str, lo: f64, hi: f64, step: f64, frozen: bool,
           fixed: f64) -> Axis {
        Axis { name, lo, hi, step, frozen, fixed }
    }

    /// Number of lattice levels (`lo` and `hi` inclusive).
    pub fn levels(&self) -> usize {
        ((self.hi - self.lo) / self.step).round() as usize + 1
    }

    /// The k-th lattice value.
    pub fn level(&self, k: usize) -> f64 {
        self.lo + k as f64 * self.step
    }

    /// Snap a value to the nearest lattice level (frozen axes snap to
    /// their fixed value). Pure f64 arithmetic on the same inputs —
    /// bitwise deterministic.
    pub fn snap(&self, v: f64) -> f64 {
        if self.frozen {
            return self.fixed;
        }
        let k = ((v - self.lo) / self.step).round();
        let k = k.clamp(0.0, (self.levels() - 1) as f64);
        self.level(k as usize)
    }

    fn validate(&self) -> Result<()> {
        ensure!(self.step > 0.0, "axis {}: step must be positive",
                self.name);
        ensure!(self.lo <= self.hi, "axis {}: lo > hi", self.name);
        ensure!(
            (self.lo..=self.hi).contains(&self.fixed),
            "axis {}: fixed value {} outside [{}, {}]",
            self.name, self.fixed, self.lo, self.hi
        );
        Ok(())
    }
}

/// The full parameter space: four axes in canonical order.
#[derive(Debug, Clone)]
pub struct Space {
    pub setpoint: Axis,
    pub pump: Axis,
    pub chiller: Axis,
    pub share: Axis,
}

impl Default for Space {
    /// The paper's operating-point question: only the setpoint is free
    /// (45–75 degC in 2-degree steps — the sweep's familiar grid); the
    /// other axes sit frozen at their neutral scales until
    /// [`Space::enable_axes`] opens them.
    fn default() -> Self {
        Space {
            setpoint: Axis::new("setpoint", 45.0, 75.0, 2.0, false, 67.0),
            pump: Axis::new("pump", 0.6, 1.4, 0.1, true, 1.0),
            chiller: Axis::new("chiller", 0.5, 2.0, 0.25, true, 1.0),
            share: Axis::new("share", 0.0, 1.0, 0.05, true, 1.0),
        }
    }
}

impl Space {
    /// The axes in canonical order (matches [`Point::coords`]).
    pub fn axes(&self) -> [&Axis; 4] {
        [&self.setpoint, &self.pump, &self.chiller, &self.share]
    }

    /// Unfreeze exactly the named axes (comma-separated catalog names:
    /// `setpoint`, `pump`, `chiller`, `share`); all others freeze at
    /// their fixed values.
    pub fn enable_axes(&mut self, csv: &str) -> Result<()> {
        let mut free = [false; 4];
        for name in csv.split(',') {
            let name = name.trim();
            if name.is_empty() {
                continue;
            }
            let i = match name {
                "setpoint" => 0,
                "pump" => 1,
                "chiller" => 2,
                "share" => 3,
                other => anyhow::bail!(
                    "unknown optimize axis '{other}' \
                     (setpoint|pump|chiller|share)"
                ),
            };
            free[i] = true;
        }
        ensure!(free.iter().any(|&f| f),
                "optimize axes '{csv}' enables nothing");
        self.setpoint.frozen = !free[0];
        self.pump.frozen = !free[1];
        self.chiller.frozen = !free[2];
        self.share.frozen = !free[3];
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        for a in self.axes() {
            a.validate()?;
        }
        ensure!(self.axes().iter().any(|a| !a.frozen),
                "optimize space has no free axis");
        // The setpoint axis must stay inside SimConfig's validated
        // operating range, or every candidate would fail to build.
        ensure!(
            self.setpoint.lo > 25.0 && self.setpoint.hi <= 75.0,
            "setpoint axis [{}, {}] outside the plant's operating range \
             (25, 75]",
            self.setpoint.lo, self.setpoint.hi
        );
        ensure!(
            self.pump.lo > 0.0,
            "pump scale axis must stay positive"
        );
        Ok(())
    }

    /// Snap every coordinate to its axis lattice.
    pub fn snap(&self, p: Point) -> Point {
        let axes = self.axes();
        let mut c = p.coords();
        for (i, a) in axes.iter().enumerate() {
            c[i] = a.snap(c[i]);
        }
        Point::from_coords(c)
    }

    /// The lattice-snapped midpoint of every free axis (frozen axes at
    /// their fixed values) — the coordinate-descent start.
    pub fn center(&self) -> Point {
        let mut c = [0.0; 4];
        for (i, a) in self.axes().iter().enumerate() {
            c[i] = a.snap(0.5 * (a.lo + a.hi));
        }
        Point::from_coords(c)
    }

    /// One uniformly random lattice point. Draws exactly one `below`
    /// per **free** axis, in canonical axis order — the draw count is
    /// part of the determinism contract (frozen axes consume nothing,
    /// so the same seed with the same free-axis set replays the same
    /// trajectory).
    pub fn sample(&self, rng: &mut Rng) -> Point {
        let mut c = [0.0; 4];
        for (i, a) in self.axes().iter().enumerate() {
            c[i] = if a.frozen {
                a.fixed
            } else {
                a.level(rng.below(a.levels()))
            };
        }
        Point::from_coords(c)
    }

    /// The full lattice over the free axes, in odometer order with the
    /// setpoint axis outermost (frozen axes contribute their fixed
    /// value). The default space reduces this to the familiar 1-D
    /// setpoint grid — the existing sweep as a degenerate case.
    pub fn grid(&self) -> Vec<Point> {
        let axes = self.axes();
        let levels: Vec<usize> = axes
            .iter()
            .map(|a| if a.frozen { 1 } else { a.levels() })
            .collect();
        let total: usize = levels.iter().product();
        let mut out = Vec::with_capacity(total);
        let mut idx = [0usize; 4];
        for _ in 0..total {
            let mut c = [0.0; 4];
            for (i, a) in axes.iter().enumerate() {
                c[i] = if a.frozen { a.fixed } else { a.level(idx[i]) };
            }
            out.push(Point::from_coords(c));
            // odometer: last axis fastest, setpoint (index 0) outermost
            for i in (0..4).rev() {
                idx[i] += 1;
                if idx[i] < levels[i] {
                    break;
                }
                idx[i] = 0;
            }
        }
        out
    }

    /// Realize a candidate as a runnable config on top of the base.
    /// `facility_share` is objective-side only and deliberately absent:
    /// it weights the cooling credit in the score, not the physics.
    pub fn apply(&self, base: &SimConfig, p: &Point) -> SimConfig {
        let mut c = base.clone();
        c.t_out_setpoint = p.setpoint;
        // warm start near the operating point, same convention as the
        // sweep's evaluate_point
        c.t_water_init = (p.setpoint - 3.0).max(20.0);
        c.pump_speed = (base.pump_speed * p.pump_scale).clamp(0.05, 1.5);
        c.pp.pc_max_at_57 *= p.chiller_scale;
        c.pp.pc_max_cap *= p.chiller_scale;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_space_is_the_sweep_lattice() {
        let s = Space::default();
        s.validate().unwrap();
        let g = s.grid();
        // 45..=75 step 2 -> 16 setpoints, other axes frozen
        assert_eq!(g.len(), 16);
        assert_eq!(g[0].setpoint, 45.0);
        assert_eq!(g[15].setpoint, 75.0);
        for p in &g {
            assert_eq!(p.pump_scale, 1.0);
            assert_eq!(p.chiller_scale, 1.0);
            assert_eq!(p.facility_share, 1.0);
        }
    }

    #[test]
    fn snap_lands_on_lattice_and_respects_bounds() {
        let s = Space::default();
        let p = s.snap(Point {
            setpoint: 61.7,
            pump_scale: 7.0,
            chiller_scale: -1.0,
            facility_share: 0.5,
        });
        assert_eq!(p.setpoint, 61.0);
        // frozen axes snap to fixed regardless of input
        assert_eq!(p.pump_scale, 1.0);
        assert_eq!(p.chiller_scale, 1.0);
        assert_eq!(p.facility_share, 1.0);
        // out-of-bounds free values clamp to the boundary level
        assert_eq!(s.setpoint.snap(1000.0), 75.0);
        assert_eq!(s.setpoint.snap(-1000.0), 45.0);
    }

    #[test]
    fn enable_axes_opens_and_validates() {
        let mut s = Space::default();
        s.enable_axes("setpoint,share").unwrap();
        assert!(!s.setpoint.frozen && !s.share.frozen);
        assert!(s.pump.frozen && s.chiller.frozen);
        // grid now covers the 2-D lattice
        assert_eq!(s.grid().len(), 16 * s.share.levels());
        assert!(s.enable_axes("bogus").is_err());
        assert!(s.enable_axes("").is_err());
    }

    #[test]
    fn sample_is_deterministic_and_in_lattice() {
        let mut s = Space::default();
        s.enable_axes("setpoint,pump").unwrap();
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..64 {
            let pa = s.sample(&mut a);
            let pb = s.sample(&mut b);
            assert_eq!(pa, pb);
            // snapping a sampled point is a no-op
            assert_eq!(s.snap(pa), pa);
        }
    }

    #[test]
    fn apply_realizes_the_point() {
        let base = SimConfig::test_small();
        let s = Space::default();
        let p = Point {
            setpoint: 63.0,
            pump_scale: 1.2,
            chiller_scale: 2.0,
            facility_share: 0.5,
        };
        let cfg = s.apply(&base, &p);
        assert_eq!(cfg.t_out_setpoint, 63.0);
        assert_eq!(cfg.t_water_init, 60.0);
        assert!((cfg.pump_speed - base.pump_speed * 1.2).abs() < 1e-12);
        assert_eq!(cfg.pp.pc_max_at_57, base.pp.pc_max_at_57 * 2.0);
        assert_eq!(cfg.pp.pc_max_cap, base.pp.pc_max_cap * 2.0);
        cfg.validate().unwrap();
    }

    #[test]
    fn grid_points_all_validate() {
        let base = SimConfig::test_small();
        let mut s = Space::default();
        s.enable_axes("setpoint,pump,chiller,share").unwrap();
        // spot-check the extreme corners rather than the full product
        let g = s.grid();
        for p in [g.first().unwrap(), g.last().unwrap()] {
            s.apply(&base, p).validate().unwrap();
        }
    }
}
