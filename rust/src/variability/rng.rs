//! Deterministic RNG mirrored bit-for-bit (integer stream) with
//! `python/compile/params.py::Rng` so both sides draw the identical
//! manufacturing lottery from the same seed.

/// One SplitMix64 step.
#[inline]
pub fn splitmix64(state: u64) -> (u64, u64) {
    let state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    (state, z ^ (z >> 31))
}

/// Deterministic RNG (SplitMix64 + Box-Muller), the Python mirror.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    cached_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed, cached_normal: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let (s, out) = splitmix64(self.state);
        self.state = s;
        out
    }

    /// Uniform in [0, 1) with 53-bit resolution (same as Python mirror).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller (pair-cached, matching Python).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.cached_normal.take() {
            return v;
        }
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Exponentially distributed with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -(1.0 - self.uniform()).ln() / rate
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) ("13 randomly selected nodes").
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx.sort_unstable();
        idx
    }

    /// The full generator state — SplitMix64 counter plus the cached
    /// Box-Muller half-pair. Checkpoint/resume must restore *both* to
    /// keep the normal stream bitwise identical (a resumed run that
    /// dropped the cached half would shift every later draw).
    pub fn state(&self) -> (u64, Option<f64>) {
        (self.state, self.cached_normal)
    }

    /// Restore a state captured by [`Rng::state`].
    pub fn restore(&mut self, state: u64, cached_normal: Option<f64>) {
        self.state = state;
        self.cached_normal = cached_normal;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Known-answer test for seed 0 (standard SplitMix64 vector).
        let (_, v) = splitmix64(0);
        assert_eq!(v, 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn python_mirror_stream() {
        // Golden values from python/compile/params.py::Rng(0x1DA7AC001):
        //   >>> r = Rng(0x1DA7AC001); [r.next_u64() for _ in range(3)]
        let mut r = Rng::new(0x1DA7AC001);
        let a = r.next_u64();
        let b = r.next_u64();
        let c = r.next_u64();
        // Cross-checked against the Python implementation in
        // tests/cross_lottery.rs using the dumped lottery JSON; here we
        // only pin determinism and non-degeneracy.
        assert_ne!(a, b);
        assert_ne!(b, c);
        let mut r2 = Rng::new(0x1DA7AC001);
        assert_eq!(a, r2.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(123);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(99);
        let s = r.sample_indices(216, 13);
        assert_eq!(s.len(), 13);
        for w in s.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(*s.last().unwrap() < 216);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
