//! Manufacturing variability: the "silicon lottery" (Figs. 4b, 5b).
//!
//! The paper attributes the large spreads in core temperature and node
//! power "to the manufacturing process of the chips, not to our
//! liquid-cooling solution". This module draws per-chip/per-core/per-mount
//! multipliers with the exact algorithm and draw order of
//! `python/compile/params.py::draw_chip_lottery`, so the Rust native plant
//! and the AOT-lowered HLO plant see the same silicon.

pub mod rng;

use crate::config::constants::PlantParams;
use crate::plant::layout::{NC, NG};
use rng::Rng;

/// Default lottery seed (shared with aot.py).
pub const DEFAULT_SEED: u64 = 0x1DA7AC001;

/// Per-node variability arrays, node-major.
#[derive(Debug, Clone)]
pub struct ChipLottery {
    pub n_nodes: usize,
    /// 1.0 if core slot exists (E5630 nodes populate 8 of 12 slots).
    pub active: Vec<f32>, // [n, NC]
    /// junction->package conductance 1/R_jc [W/K]
    pub g_jc: Vec<f32>, // [n, NC]
    /// per-core dynamic power at 100 % util [W]
    pub p_dyn: Vec<f32>, // [n, NC]
    /// per-core idle power [W]
    pub p_idle: Vec<f32>, // [n, NC]
    /// pkg->sink conductance per socket [W/K]
    pub g_sp: Vec<f32>, // [n, 2]
    /// sink->water conductance [W/K]
    pub g_sw: Vec<f32>, // [n]
    /// 1.0 for six-core (E5645) nodes — the only ones in the paper's plots
    pub six_core: Vec<f32>, // [n]
    /// Precomputed indices of the six-core nodes (derived from
    /// `six_core` at construction; hot paths iterate this every tick).
    six_idx: Vec<usize>,
}

/// Indices of the six-core entries (`six_core[i] > 0.5`).
fn six_core_index(six_core: &[f32]) -> Vec<usize> {
    six_core
        .iter()
        .enumerate()
        .filter(|(_, &s)| s > 0.5)
        .map(|(i, _)| i)
        .collect()
}

impl ChipLottery {
    /// Draw the lottery; mirrors `params.draw_chip_lottery` exactly.
    pub fn draw(n_nodes: usize, pp: &PlantParams, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // Which nodes are four-core (E5630): scale the paper's 22/216 ratio.
        let n_four =
            ((n_nodes as f64 * 22.0 / 216.0) + 0.5).floor() as usize;
        let mut four_idx = std::collections::BTreeSet::new();
        if n_four > 0 {
            let stride = (n_nodes / n_four).max(1);
            let mut i = 7 % n_nodes;
            while four_idx.len() < n_four {
                four_idx.insert(i % n_nodes);
                i += stride;
            }
        }

        let mut lot = ChipLottery {
            n_nodes,
            active: vec![0.0; n_nodes * NC],
            g_jc: vec![0.0; n_nodes * NC],
            p_dyn: vec![0.0; n_nodes * NC],
            p_idle: vec![0.0; n_nodes * NC],
            g_sp: vec![0.0; n_nodes * 2],
            g_sw: vec![0.0; n_nodes],
            six_core: vec![0.0; n_nodes],
            six_idx: Vec::new(),
        };

        for n in 0..n_nodes {
            let four = four_idx.contains(&n);
            lot.six_core[n] = if four { 0.0 } else { 1.0 };
            let cores_per_chip = if four { 4 } else { 6 };
            for chip in 0..2 {
                let m_r_chip = 1.0 + pp.sigma_r_chip * rng.normal();
                let m_p_chip = 1.0 + pp.sigma_p_chip * rng.normal();
                for c in 0..6 {
                    let slot = n * NC + chip * 6 + c;
                    if c >= cores_per_chip {
                        lot.active[slot] = 0.0;
                        lot.g_jc[slot] = 1e-3;
                        lot.p_dyn[slot] = 0.0;
                        lot.p_idle[slot] = 0.0;
                        // Burn the draws to keep the stream aligned.
                        rng.normal();
                        rng.normal();
                        continue;
                    }
                    let m_r = (m_r_chip
                        * (1.0 + pp.sigma_r_core * rng.normal()))
                    .max(0.35);
                    let m_p = (m_p_chip
                        * (1.0 + pp.sigma_p_core * rng.normal()))
                    .max(0.60);
                    lot.active[slot] = 1.0;
                    lot.g_jc[slot] = (1.0 / (pp.r_jc * m_r)) as f32;
                    lot.p_dyn[slot] = (pp.p_core_dyn * m_p) as f32;
                    lot.p_idle[slot] = (pp.p_core_idle * m_p) as f32;
                }
            }
            let m_sp0 = (1.0 + pp.sigma_mount * rng.normal()).max(0.5);
            let m_sp1 = (1.0 + pp.sigma_mount * rng.normal()).max(0.5);
            let m_sw = (1.0 + pp.sigma_mount * rng.normal()).max(0.5);
            lot.g_sp[n * 2] = (1.0 / (pp.r_sp * m_sp0)) as f32;
            lot.g_sp[n * 2 + 1] = (1.0 / (pp.r_sp * m_sp1)) as f32;
            lot.g_sw[n] = (1.0 / (pp.r_sw * m_sw)) as f32;
        }
        lot.six_idx = six_core_index(&lot.six_core);
        lot
    }

    /// Load a lottery dumped by aot.py (`artifacts/lottery_n{N}.json`)
    /// so the coordinator uses *exactly* the floats the HLO was built with.
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        use anyhow::Context;
        let n_nodes = j
            .get("n_nodes")
            .and_then(|v| v.as_usize())
            .context("lottery: n_nodes")?;
        let mat = |k: &str| -> anyhow::Result<Vec<f32>> {
            let (flat, r, _c) = j
                .get(k)
                .and_then(|v| v.as_mat_f64())
                .with_context(|| format!("lottery: field {k}"))?;
            anyhow::ensure!(r == n_nodes, "lottery: {k} rows {r} != {n_nodes}");
            Ok(flat.into_iter().map(|x| x as f32).collect())
        };
        let vec1 = |k: &str| -> anyhow::Result<Vec<f32>> {
            Ok(j.get(k)
                .and_then(|v| v.as_vec_f64())
                .with_context(|| format!("lottery: field {k}"))?
                .into_iter()
                .map(|x| x as f32)
                .collect())
        };
        let six_core = vec1("six_core")?;
        let six_idx = six_core_index(&six_core);
        Ok(ChipLottery {
            n_nodes,
            active: mat("active")?,
            g_jc: mat("g_jc")?,
            p_dyn: mat("p_dyn")?,
            p_idle: mat("p_idle")?,
            g_sp: mat("g_sp")?,
            g_sw: vec1("g_sw")?,
            six_core,
            six_idx,
        })
    }

    /// Assemble the [n, NG] variable-conductance matrix (kernel input).
    /// Channel `G_ADV` carries the nominal advective conductance.
    pub fn g_var(&self, pp: &PlantParams) -> Vec<f32> {
        let mut g = vec![0.0f32; self.n_nodes * NG];
        for n in 0..self.n_nodes {
            for c in 0..NC {
                g[n * NG + c] = self.g_jc[n * NC + c];
            }
            g[n * NG + NC] = self.g_sp[n * 2];
            g[n * NG + NC + 1] = self.g_sp[n * 2 + 1];
            g[n * NG + NC + 2] = self.g_sw[n];
            g[n * NG + NC + 3] = pp.node_mcp() as f32;
        }
        g
    }

    /// Indices of six-core nodes (the population in the paper's figures).
    /// Precomputed at construction — hot loops borrow it per tick.
    pub fn six_core_nodes(&self) -> &[usize] {
        &self.six_idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::constants::PlantParams;

    #[test]
    fn deterministic() {
        let pp = PlantParams::default();
        let a = ChipLottery::draw(8, &pp, 42);
        let b = ChipLottery::draw(8, &pp, 42);
        assert_eq!(a.g_jc, b.g_jc);
        assert_eq!(a.p_dyn, b.p_dyn);
    }

    #[test]
    fn four_core_ratio_full_cluster() {
        let pp = PlantParams::default();
        let lot = ChipLottery::draw(216, &pp, DEFAULT_SEED);
        let n_four = lot.six_core.iter().filter(|&&s| s == 0.0).count();
        assert_eq!(n_four, 22);
        // Four-core nodes have 8 active slots; six-core have 12.
        for n in 0..216 {
            let act: f32 = lot.active[n * NC..(n + 1) * NC].iter().sum();
            if lot.six_core[n] > 0.5 {
                assert_eq!(act, 12.0);
            } else {
                assert_eq!(act, 8.0);
            }
        }
    }

    #[test]
    fn power_spread_in_band() {
        let pp = PlantParams::default();
        let lot = ChipLottery::draw(216, &pp, DEFAULT_SEED);
        let mut node_p = Vec::new();
        for n in 0..216 {
            if lot.six_core[n] < 0.5 {
                continue;
            }
            let p: f32 = (0..NC)
                .map(|c| lot.p_dyn[n * NC + c] + lot.p_idle[n * NC + c])
                .sum();
            node_p.push(p);
        }
        let mean = node_p.iter().sum::<f32>() / node_p.len() as f32;
        let var = node_p.iter().map(|p| (p - mean) * (p - mean)).sum::<f32>()
            / node_p.len() as f32;
        let sigma = var.sqrt();
        assert!(sigma > 3.5 && sigma < 7.5, "sigma {sigma}");
    }

    #[test]
    fn six_core_index_matches_flags() {
        let pp = PlantParams::default();
        let lot = ChipLottery::draw(50, &pp, DEFAULT_SEED);
        let expect: Vec<usize> =
            (0..50).filter(|&n| lot.six_core[n] > 0.5).collect();
        assert_eq!(lot.six_core_nodes(), expect.as_slice());
        assert!(!lot.six_core_nodes().is_empty());
    }

    #[test]
    fn g_var_layout() {
        let pp = PlantParams::default();
        let lot = ChipLottery::draw(4, &pp, 1);
        let g = lot.g_var(&pp);
        assert_eq!(g.len(), 4 * NG);
        // advection channel = node m*cp
        assert!((g[NG - 1] - pp.node_mcp() as f32).abs() < 1e-4);
    }
}
