//! The `idatacool-ckpt/1` snapshot codec and atomic persistence.
//!
//! A snapshot is a flat little-endian byte stream with bit-exact floats
//! (`f64::to_bits` / `f32::to_bits` — resume must be *bitwise*
//! identical to an uninterrupted run, so no decimal round-trips) and
//! length-prefixed strings/vectors. The stream opens with the
//! [`MAGIC`] tag; readers reject anything else before touching the
//! payload. The fleet driver owns the payload layout (DESIGN.md §8
//! documents it field by field); this module is only the codec plus
//! [`atomic_write`] — write to a sibling `.tmp`, fsync, rename — so a
//! crash mid-checkpoint leaves either the previous complete snapshot or
//! none, never a torn file.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// Version tag; bump the suffix on any layout change.
pub const MAGIC: &str = "idatacool-ckpt/1";

/// Append-only snapshot encoder.
#[derive(Default)]
pub struct SnapWriter {
    buf: Vec<u8>,
}

impl SnapWriter {
    /// Start a snapshot: the magic tag is always the first field.
    pub fn new() -> Self {
        let mut w = SnapWriter { buf: Vec::new() };
        w.str(MAGIC);
        w
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
            None => self.u8(0),
        }
    }

    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn f32s(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.f32(x);
        }
    }

    pub fn f64s(&mut self, xs: &[f64]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.f64(x);
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Sequential snapshot decoder over a borrowed byte buffer.
pub struct SnapReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> SnapReader<'a> {
    /// Open a snapshot; fails unless the stream starts with [`MAGIC`].
    pub fn new(buf: &'a [u8]) -> Result<Self> {
        let mut r = SnapReader { buf, pos: 0 };
        let magic = r.str().context("snapshot magic")?;
        if magic != MAGIC {
            bail!("not an {MAGIC} snapshot (magic `{magic}`)");
        }
        Ok(r)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated snapshot: need {n} bytes at offset {}",
                  self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn opt_f64(&mut self) -> Result<Option<f64>> {
        Ok(match self.u8()? {
            0 => None,
            _ => Some(self.f64()?),
        })
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.usize()?;
        let bytes = self.take(n)?;
        Ok(std::str::from_utf8(bytes)
            .context("snapshot string is not UTF-8")?
            .to_string())
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.usize()?;
        let mut v = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.usize()?;
        let mut v = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            v.push(self.f64()?);
        }
        Ok(v)
    }

    /// True when every byte has been consumed (layout sanity check).
    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Crash-consistent write: the bytes land in `<path>.tmp`, are synced,
/// then renamed over `path`. Readers only ever see a complete snapshot.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(),
                                 path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_round_trips_bit_exact() {
        let mut w = SnapWriter::new();
        w.u8(7);
        w.bool(true);
        w.u64(u64::MAX - 3);
        w.usize(42);
        w.f64(f64::MIN); // peak_pooled_w's initial sentinel
        w.f64(-0.0);
        w.f32(f32::NAN);
        w.opt_f64(Some(1.5e-300));
        w.opt_f64(None);
        w.str("mixed scenario");
        w.f32s(&[1.0, -2.5, f32::INFINITY]);
        w.f64s(&[0.1, 0.2]);
        let bytes = w.into_bytes();

        let mut r = SnapReader::new(&bytes).unwrap();
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.usize().unwrap(), 42);
        assert_eq!(r.f64().unwrap().to_bits(), f64::MIN.to_bits());
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.f32().unwrap().is_nan());
        assert_eq!(r.opt_f64().unwrap(), Some(1.5e-300));
        assert_eq!(r.opt_f64().unwrap(), None);
        assert_eq!(r.str().unwrap(), "mixed scenario");
        let v = r.f32s().unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v[2], f32::INFINITY);
        assert_eq!(r.f64s().unwrap(), vec![0.1, 0.2]);
        assert!(r.done());
    }

    #[test]
    fn reader_rejects_bad_magic_and_truncation() {
        let mut w = SnapWriter::new();
        w.u64(1);
        let mut bytes = w.into_bytes();
        assert!(SnapReader::new(&bytes[..bytes.len() - 1])
            .map(|mut r| r.u64().is_err())
            .unwrap_or(true));
        bytes[10] ^= 0xFF; // corrupt the magic text
        assert!(SnapReader::new(&bytes).is_err());
    }

    #[test]
    fn atomic_write_replaces_whole_file() {
        let dir = std::env::temp_dir().join("idatacool-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("t{}.ckpt", std::process::id()));
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second-longer").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second-longer");
        assert!(!path.with_extension("tmp").exists());
        let _ = std::fs::remove_file(&path);
    }
}
