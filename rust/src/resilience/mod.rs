//! Resilience: fault containment, deterministic chaos injection, and
//! crash-consistent checkpoint/resume.
//!
//! The paper's operational story (Sect. 4) is continuity under degraded
//! cooling — the adsorption chiller can drop out and the plant keeps
//! running inside its thermal envelope. This module gives the *software*
//! stack the same discipline, in three pieces:
//!
//!  * **`inject`** — a seeded, config-driven chaos injector. Fault plans
//!    name a site (plant tick, megabatch sweep, facility step, server
//!    compute) and a kind (`panic`, `stall_ms`, `poison_nan`); the same
//!    seed always fires at the same invocation counts. Unarmed (the
//!    default), every site check is one relaxed atomic load — the same
//!    zero-cost pattern as `obs::enabled()`.
//!  * **`checkpoint`** — the versioned `idatacool-ckpt/1` snapshot codec
//!    (length-prefixed, bit-exact floats) plus atomic tmp+rename
//!    persistence. The fleet driver snapshots every `--checkpoint-every`
//!    ticks and `--resume` continues bitwise-identical to an
//!    uninterrupted run.
//!  * **Quarantine** (lives in `fleet`): a panicking or NaN-poisoned
//!    plant is evicted from the lane arena and recorded in
//!    `FleetAggregate.quarantined`; the survivors complete and the fleet
//!    exits with degraded success instead of aborting.
//!
//! See DESIGN.md §8 for the quarantine contract, the checkpoint format,
//! and the chaos site catalog.

pub mod checkpoint;
pub mod inject;
