//! Deterministic chaos injection: seeded, config-driven fault plans.
//!
//! A *plan* is a semicolon-separated list of rules; each rule is a
//! comma-separated `key=value` list:
//!
//! ```text
//! site=plant_tick,kind=panic,plant=1,tick=7
//! site=megabatch_sweep,kind=stall_ms,arg=50
//! site=plant_tick,kind=poison_nan,plant=0
//! ```
//!
//!  * `site` (required) — where the fault fires; see [`Site`].
//!  * `kind` (required) — `panic`, `stall_ms` (duration via `arg`, ms),
//!    or `poison_nan`.
//!  * `plant` (optional) — restrict to one plant index; omitted = any.
//!  * `tick` (optional) — the 1-based invocation count of the
//!    (site, plant) pair at which the rule fires. Omitted ticks are
//!    derived from the plan seed: rule *i* fires at
//!    `splitmix64(seed ^ (i+1)·GOLDEN) % 40 + 1`, so the same seed
//!    always produces the same fire ticks (the determinism proptest
//!    gates this).
//!
//! Each rule fires **once**. Fired events are appended to an in-memory
//! log (`site=… plant=… tick=… kind=…` lines) that `take_log` drains —
//! the fleet CLI prints it with the quarantine report, and the
//! chaos-determinism proptest compares it across repeated runs.
//!
//! Arming is process-global. The hot-path contract is the same as
//! `obs::enabled()`: call sites guard with `if inject::armed() { … }`,
//! and `armed()` is a single relaxed atomic load — when no plan is
//! armed (the default) that load is the entire cost.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

use anyhow::{anyhow, bail, Result};

use crate::variability::rng::splitmix64;

static ARMED: AtomicBool = AtomicBool::new(false);

/// Is a chaos plan armed? One relaxed load; inlined into every site.
#[inline(always)]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Named injection sites. The catalog is closed on purpose: every site
/// is a place with a containment story (DESIGN.md §8) — a panic at
/// `PlantTick` quarantines one plant, at `MegabatchSweep` the shard's
/// bucket, at `FacilityStep` it forces the post-hoc facility replay,
/// at `ServerCompute` it is absorbed by the worker's catch_unwind
/// into a 500/504 envelope, at `OptimizeEval` the candidate is
/// scored worst-case and the search continues, and at `WorkerTick`
/// (the supervised serve-worker loop, once per popped job; the `plant`
/// selector addresses the worker slot) a panic kills the worker — the
/// supervisor answers the victim and respawns — while a stall trips
/// the monitor's watchdog (DESIGN.md §10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    PlantTick = 0,
    MegabatchSweep = 1,
    FacilityStep = 2,
    ServerCompute = 3,
    OptimizeEval = 4,
    WorkerTick = 5,
}

impl Site {
    pub fn name(self) -> &'static str {
        match self {
            Site::PlantTick => "plant_tick",
            Site::MegabatchSweep => "megabatch_sweep",
            Site::FacilityStep => "facility_step",
            Site::ServerCompute => "server_compute",
            Site::OptimizeEval => "optimize_eval",
            Site::WorkerTick => "worker_tick",
        }
    }

    pub fn by_name(s: &str) -> Option<Site> {
        match s {
            "plant_tick" => Some(Site::PlantTick),
            "megabatch_sweep" => Some(Site::MegabatchSweep),
            "facility_step" => Some(Site::FacilityStep),
            "server_compute" => Some(Site::ServerCompute),
            "optimize_eval" => Some(Site::OptimizeEval),
            "worker_tick" => Some(Site::WorkerTick),
            _ => None,
        }
    }
}

/// What a matched rule does at its site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FaultKind {
    /// `panic!` after logging — the containment layers catch it.
    Panic,
    /// Sleep for the given milliseconds (deadline/timeout testing).
    StallMs(u64),
    /// Ask the caller to poison its own state with NaN.
    PoisonNan,
}

impl FaultKind {
    fn name(self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::StallMs(_) => "stall_ms",
            FaultKind::PoisonNan => "poison_nan",
        }
    }
}

/// Action returned to the call site. Panics and stalls are executed
/// inside [`fire`]; poisoning is the caller's job (only it can reach
/// its lanes), so it comes back as a value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    PoisonNan,
}

#[derive(Clone, Debug)]
struct Rule {
    site: Site,
    kind: FaultKind,
    plant: Option<usize>,
    tick: u64,
    fired: bool,
}

struct ChaosState {
    rules: Vec<Rule>,
    /// Invocation counts per (site, plant) pair; plant-less sites count
    /// under `u64::MAX`.
    counts: BTreeMap<(u8, u64), u64>,
    log: Vec<String>,
}

fn state() -> &'static Mutex<Option<ChaosState>> {
    static S: OnceLock<Mutex<Option<ChaosState>>> = OnceLock::new();
    S.get_or_init(|| Mutex::new(None))
}

fn lock_state() -> MutexGuard<'static, Option<ChaosState>> {
    // An injected panic unwinds while the guard is held; recover the
    // poisoned lock — the state itself is always left consistent.
    state().lock().unwrap_or_else(|e| e.into_inner())
}

fn derive_tick(seed: u64, rule_index: usize) -> u64 {
    let mix = seed ^ (rule_index as u64 + 1)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(mix).1 % 40 + 1
}

fn parse_rule(text: &str, index: usize, seed: u64) -> Result<Rule> {
    let mut site = None;
    let mut kind = None;
    let mut plant = None;
    let mut tick = None;
    let mut arg: Option<u64> = None;
    for field in text.split(',') {
        let field = field.trim();
        if field.is_empty() {
            continue;
        }
        let (k, v) = field
            .split_once('=')
            .ok_or_else(|| anyhow!("chaos rule field `{field}` is not key=value"))?;
        match k.trim() {
            "site" => {
                site = Some(Site::by_name(v.trim()).ok_or_else(|| {
                    anyhow!("unknown chaos site `{}`", v.trim())
                })?)
            }
            "kind" => kind = Some(v.trim().to_string()),
            "plant" => plant = Some(v.trim().parse::<usize>()?),
            "tick" => tick = Some(v.trim().parse::<u64>()?),
            "arg" => arg = Some(v.trim().parse::<u64>()?),
            other => bail!("unknown chaos rule key `{other}`"),
        }
    }
    let site = site.ok_or_else(|| anyhow!("chaos rule `{text}` has no site="))?;
    let kind = match kind.as_deref() {
        Some("panic") => FaultKind::Panic,
        Some("stall_ms") => FaultKind::StallMs(arg.unwrap_or(100)),
        Some("poison_nan") => FaultKind::PoisonNan,
        Some(other) => bail!("unknown chaos kind `{other}`"),
        None => bail!("chaos rule `{text}` has no kind="),
    };
    let tick = match tick {
        Some(t) if t >= 1 => t,
        Some(_) => bail!("chaos tick is 1-based"),
        None => derive_tick(seed, index),
    };
    Ok(Rule { site, kind, plant, tick, fired: false })
}

/// Arm a fault plan. Replaces any armed plan; resets counters and log.
pub fn arm(plan: &str, seed: u64) -> Result<()> {
    let mut rules = Vec::new();
    for (i, text) in plan.split(';').enumerate() {
        let text = text.trim();
        if text.is_empty() {
            continue;
        }
        rules.push(parse_rule(text, i, seed)?);
    }
    if rules.is_empty() {
        bail!("chaos plan `{plan}` contains no rules");
    }
    *lock_state() = Some(ChaosState {
        rules,
        counts: BTreeMap::new(),
        log: Vec::new(),
    });
    ARMED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Arm from a single spec string: an optional leading `seed=N;` segment
/// followed by the plan (`--chaos` / `IDATACOOL_CHAOS` use this form).
pub fn arm_spec(spec: &str) -> Result<()> {
    let spec = spec.trim();
    if let Some(rest) = spec.strip_prefix("seed=") {
        let (seed_text, plan) = rest
            .split_once(';')
            .ok_or_else(|| anyhow!("chaos spec `seed=N` needs a ;plan"))?;
        let seed = seed_text.trim().parse::<u64>()?;
        return arm(plan, seed);
    }
    arm(spec, 0)
}

/// Disarm and drop all chaos state.
pub fn disarm() {
    ARMED.store(false, Ordering::Relaxed);
    *lock_state() = None;
}

/// Drain the injected-event log (armed state is kept).
pub fn take_log() -> Vec<String> {
    match lock_state().as_mut() {
        Some(st) => std::mem::take(&mut st.log),
        None => Vec::new(),
    }
}

/// One site invocation. Counts the (site, plant) pair, fires any due
/// rules (once each), logs them, executes stalls and panics inline, and
/// returns `PoisonNan` for the caller to apply. Only reached behind an
/// `armed()` guard, so the unarmed hot path never touches the mutex.
pub fn fire(site: Site, plant: Option<usize>) -> Option<Action> {
    let mut action = None;
    let mut stall = None;
    let mut do_panic = false;
    {
        let mut guard = lock_state();
        let st = guard.as_mut()?;
        let key = (site as u8, plant.map(|p| p as u64).unwrap_or(u64::MAX));
        let count = st.counts.entry(key).or_insert(0);
        *count += 1;
        let now = *count;
        let mut fired = Vec::new();
        for rule in st.rules.iter_mut() {
            if rule.fired || rule.site != site || rule.tick != now {
                continue;
            }
            if let Some(rp) = rule.plant {
                if plant != Some(rp) {
                    continue;
                }
            }
            rule.fired = true;
            fired.push((rule.kind, rule.plant));
            match rule.kind {
                FaultKind::Panic => do_panic = true,
                FaultKind::StallMs(ms) => stall = Some(ms),
                FaultKind::PoisonNan => action = Some(Action::PoisonNan),
            }
        }
        for (kind, rule_plant) in fired {
            st.log.push(format!(
                "site={} plant={} tick={} kind={}",
                site.name(),
                rule_plant
                    .or(plant)
                    .map(|p| p.to_string())
                    .unwrap_or_else(|| "-".into()),
                now,
                kind.name(),
            ));
        }
    }
    if let Some(ms) = stall {
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
    if do_panic {
        panic!("chaos: injected panic at site {}", site.name());
    }
    action
}

/// Tests that arm the process-global injector serialize on this lock so
/// `cargo test`'s parallel threads cannot interleave plans.
pub fn test_lock() -> MutexGuard<'static, ()> {
    static L: Mutex<()> = Mutex::new(());
    L.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_fire_is_none_and_cheap() {
        let _g = test_lock();
        disarm();
        assert!(!armed());
        assert_eq!(fire(Site::PlantTick, Some(0)), None);
    }

    #[test]
    fn plan_parses_and_fires_once_at_tick() {
        let _g = test_lock();
        arm("site=plant_tick,kind=poison_nan,plant=2,tick=3", 0).unwrap();
        assert!(armed());
        for t in 1..=5u64 {
            let a = fire(Site::PlantTick, Some(2));
            if t == 3 {
                assert_eq!(a, Some(Action::PoisonNan), "tick {t}");
            } else {
                assert_eq!(a, None, "tick {t}");
            }
            // other plants never match
            assert_eq!(fire(Site::PlantTick, Some(1)), None);
        }
        let log = take_log();
        assert_eq!(log,
                   vec!["site=plant_tick plant=2 tick=3 kind=poison_nan"
                       .to_string()]);
        disarm();
    }

    #[test]
    fn derived_ticks_are_seed_deterministic() {
        let _g = test_lock();
        let run = |seed: u64| -> Vec<String> {
            arm("site=plant_tick,kind=poison_nan;\
                 site=facility_step,kind=poison_nan",
                seed)
            .unwrap();
            for _ in 0..64 {
                let _ = fire(Site::PlantTick, Some(0));
                let _ = fire(Site::FacilityStep, None);
            }
            let log = take_log();
            disarm();
            log
        };
        let a = run(7);
        let b = run(7);
        let c = run(8);
        assert_eq!(a.len(), 2, "{a:?}");
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn injected_panic_unwinds_and_state_survives() {
        let _g = test_lock();
        arm("site=megabatch_sweep,kind=panic,tick=1", 0).unwrap();
        let r = std::panic::catch_unwind(|| fire(Site::MegabatchSweep, None));
        assert!(r.is_err());
        // the rule fired once; further invocations are clean
        assert_eq!(fire(Site::MegabatchSweep, None), None);
        assert_eq!(take_log().len(), 1);
        disarm();
    }

    #[test]
    fn site_names_round_trip() {
        for s in [Site::PlantTick, Site::MegabatchSweep,
                  Site::FacilityStep, Site::ServerCompute,
                  Site::OptimizeEval, Site::WorkerTick] {
            assert_eq!(Site::by_name(s.name()), Some(s));
        }
        assert_eq!(Site::by_name("nowhere"), None);
    }

    #[test]
    fn arm_spec_accepts_seed_prefix_and_rejects_garbage() {
        let _g = test_lock();
        arm_spec("seed=9;site=plant_tick,kind=panic").unwrap();
        assert!(armed());
        disarm();
        assert!(arm_spec("site=nowhere,kind=panic").is_err());
        assert!(arm_spec("site=plant_tick").is_err());
        assert!(arm_spec("").is_err());
        assert!(!armed());
    }
}
