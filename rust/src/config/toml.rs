//! Minimal TOML-subset parser for run configuration files.
//!
//! Supports the subset the launcher uses: `[section]` headers, `key = value`
//! pairs with string / float / int / bool values, `#` comments. Nested
//! tables and arrays are intentionally out of scope (configs stay flat).

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat `section.key -> value` map.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    pub values: BTreeMap<String, TomlValue>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(TomlError {
                    line: lineno + 1,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or(TomlError {
                line: lineno + 1,
                msg: "expected key = value".into(),
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(TomlError {
                    line: lineno + 1,
                    msg: "empty key".into(),
                });
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let v = parse_value(value.trim()).ok_or(TomlError {
                line: lineno + 1,
                msg: format!("bad value {:?}", value.trim()),
            })?;
            doc.values.insert(full, v);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(TomlValue::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.f64_or(key, default as f64) as usize
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(TomlValue::as_str).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(TomlValue::as_bool).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<TomlValue> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"')?;
        return Some(TomlValue::Str(inner.to_string()));
    }
    match s {
        "true" => return Some(TomlValue::Bool(true)),
        "false" => return Some(TomlValue::Bool(false)),
        _ => {}
    }
    s.parse::<f64>().ok().map(TomlValue::Num)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            # run config
            name = "prod"
            [cluster]
            nodes = 216        # full system
            backend = "hlo"
            [control]
            setpoint = 67.5
            enabled = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "prod");
        assert_eq!(doc.usize_or("cluster.nodes", 0), 216);
        assert_eq!(doc.str_or("cluster.backend", ""), "hlo");
        assert_eq!(doc.f64_or("control.setpoint", 0.0), 67.5);
        assert!(doc.bool_or("control.enabled", false));
    }

    #[test]
    fn errors_have_line_numbers() {
        let err = TomlDoc::parse("a = 1\nbogus line\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse(r##"k = "a#b""##).unwrap();
        assert_eq!(doc.str_or("k", ""), "a#b");
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.f64_or("x.y", 3.5), 3.5);
        assert_eq!(doc.str_or("x.z", "d"), "d");
    }
}
