//! Plant physical constants — the Rust mirror of
//! `python/compile/params.py::PlantParams`.
//!
//! `PlantParams::default()` must stay numerically identical to the Python
//! dataclass defaults; `tests/cross_params.rs` compares against
//! `artifacts/params.json` (written by aot.py) field by field. When
//! artifacts are present, prefer `PlantParams::from_artifacts` so the
//! native plant runs with *exactly* the constants the HLO was lowered with.

use crate::util::json::Json;

/// All scalar constants of the plant (SI units unless noted).
/// See params.py for the calibration targets each value serves.
#[derive(Debug, Clone, PartialEq)]
pub struct PlantParams {
    // thermal masses [J/K]
    pub c_core: f64,
    pub c_pkg: f64,
    pub c_sink: f64,
    pub c_water: f64,
    pub c_tank: f64,
    pub c_primary: f64,
    pub c_recool: f64,
    // thermal resistances / conductances
    pub r_jc: f64,
    pub r_sp: f64,
    pub r_sw: f64,
    pub ua_node_air: f64,
    // hydraulics
    pub node_flow_lpm: f64,
    pub cp_water: f64,
    pub rho_water: f64,
    pub node_dp_bar: f64,
    pub manifold_dp_bar: f64,
    // power model
    pub p_core_dyn: f64,
    pub p_core_idle: f64,
    pub p_node_base: f64,
    pub leak_frac: f64,
    pub leak_beta: f64,
    pub leak_t0: f64,
    pub psu_efficiency: f64,
    pub p_switches: f64,
    pub t_throttle: f64,
    pub throttle_band: f64,
    // variability
    pub sigma_r_chip: f64,
    pub sigma_r_core: f64,
    pub sigma_p_chip: f64,
    pub sigma_p_core: f64,
    pub sigma_mount: f64,
    // plumbing / insulation
    pub ua_pipe_env: f64,
    pub ua_pipe_cold_frac: f64,
    pub t_room: f64,
    // driving circuit + HX
    pub eps_hx_drive: f64,
    pub eps_hx_primary: f64,
    pub ua_tank_env: f64,
    pub drive_flow_lps: f64,
    // adsorption chiller (InvenSor LTC 09 class)
    pub chiller_t_on: f64,
    pub chiller_t_off: f64,
    pub cop_at_57: f64,
    pub cop_slope: f64,
    pub cop_max: f64,
    pub pc_max_at_57: f64,
    pub pc_max_slope: f64,
    pub pc_max_cap: f64,
    pub cycle_period_s: f64,
    pub cycle_amp: f64,
    pub chiller_min_drive: f64,
    // primary circuit + central cooling
    pub t_primary_support: f64,
    pub ua_cooltrans: f64,
    pub gpu_peak_w: f64,
    // recooler
    pub ua_recool_max: f64,
    pub recool_fan_min: f64,
    // integration
    pub dt_substep: f64,
    pub substeps_per_tick: usize,
}

impl Default for PlantParams {
    fn default() -> Self {
        PlantParams {
            c_core: 18.0,
            c_pkg: 110.0,
            c_sink: 640.0,
            c_water: 270.0,
            c_tank: 800.0 * 4186.0,
            c_primary: 180.0 * 4186.0,
            c_recool: 120.0 * 4186.0,
            r_jc: 0.62,
            r_sp: 0.045,
            r_sw: 0.028,
            ua_node_air: 1.72,
            node_flow_lpm: 0.60,
            cp_water: 4186.0,
            rho_water: 0.988,
            node_dp_bar: 0.095,
            manifold_dp_bar: 0.008,
            p_core_dyn: 11.8,
            p_core_idle: 1.9,
            p_node_base: 44.0,
            leak_frac: 0.13,
            leak_beta: 0.026,
            leak_t0: 80.0,
            psu_efficiency: 0.92,
            p_switches: 2300.0,
            t_throttle: 100.0,
            throttle_band: 2.5,
            sigma_r_chip: 0.24,
            sigma_r_core: 0.15,
            sigma_p_chip: 0.045,
            sigma_p_core: 0.012,
            sigma_mount: 0.20,
            ua_pipe_env: 95.0,
            ua_pipe_cold_frac: 0.35,
            t_room: 26.0,
            eps_hx_drive: 0.92,
            eps_hx_primary: 0.85,
            ua_tank_env: 14.0,
            drive_flow_lps: 0.95,
            chiller_t_on: 55.0,
            chiller_t_off: 53.0,
            cop_at_57: 0.270,
            cop_slope: 0.0187,
            cop_max: 0.560,
            pc_max_at_57: 3600.0,
            pc_max_slope: 430.0,
            pc_max_cap: 10500.0,
            cycle_period_s: 420.0,
            cycle_amp: 0.22,
            chiller_min_drive: 0.0,
            t_primary_support: 20.0,
            ua_cooltrans: 2600.0,
            gpu_peak_w: 12000.0,
            ua_recool_max: 3400.0,
            recool_fan_min: 0.15,
            dt_substep: 0.25,
            substeps_per_tick: 20,
        }
    }
}

impl PlantParams {
    /// Per-node water mass flow [kg/s].
    pub fn node_flow_kgps(&self) -> f64 {
        self.node_flow_lpm / 60.0 * self.rho_water
    }

    /// Per-node advective conductance m_dot * c_p [W/K].
    pub fn node_mcp(&self) -> f64 {
        self.node_flow_kgps() * self.cp_water
    }

    /// Rack-level advective conductance at nominal pump speed [W/K].
    pub fn rack_mcp(&self, n_nodes: usize) -> f64 {
        self.node_mcp() * n_nodes as f64
    }

    /// Chiller COP vs driving temperature (Fig. 6b). Zero in standby.
    pub fn cop(&self, t_drive: f64) -> f64 {
        if t_drive < self.chiller_t_on {
            return 0.0;
        }
        (self.cop_at_57 + self.cop_slope * (t_drive - 57.0))
            .clamp(0.0, self.cop_max)
    }

    /// Max chilled-water capacity [W] vs driving temperature.
    pub fn pc_max(&self, t_drive: f64) -> f64 {
        if t_drive < self.chiller_t_on {
            return 0.0;
        }
        (self.pc_max_at_57 + self.pc_max_slope * (t_drive - 57.0))
            .clamp(0.0, self.pc_max_cap)
    }

    /// Max power removable from the driving circuit (Sect. 3).
    pub fn pd_max(&self, t_drive: f64) -> f64 {
        let c = self.cop(t_drive);
        if c > 0.0 {
            self.pc_max(t_drive) / c
        } else {
            0.0
        }
    }

    /// Load from `artifacts/params.json` (written by aot.py) so the native
    /// plant and the HLO plant share identical constants.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let p = j.get("params").unwrap_or(j);
        let f = |k: &str| -> anyhow::Result<f64> {
            p.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("params.json missing {k}"))
        };
        Ok(PlantParams {
            c_core: f("c_core")?,
            c_pkg: f("c_pkg")?,
            c_sink: f("c_sink")?,
            c_water: f("c_water")?,
            c_tank: f("c_tank")?,
            c_primary: f("c_primary")?,
            c_recool: f("c_recool")?,
            r_jc: f("r_jc")?,
            r_sp: f("r_sp")?,
            r_sw: f("r_sw")?,
            ua_node_air: f("ua_node_air")?,
            node_flow_lpm: f("node_flow_lpm")?,
            cp_water: f("cp_water")?,
            rho_water: f("rho_water")?,
            node_dp_bar: f("node_dp_bar")?,
            manifold_dp_bar: f("manifold_dp_bar")?,
            p_core_dyn: f("p_core_dyn")?,
            p_core_idle: f("p_core_idle")?,
            p_node_base: f("p_node_base")?,
            leak_frac: f("leak_frac")?,
            leak_beta: f("leak_beta")?,
            leak_t0: f("leak_t0")?,
            psu_efficiency: f("psu_efficiency")?,
            p_switches: f("p_switches")?,
            t_throttle: f("t_throttle")?,
            throttle_band: f("throttle_band")?,
            sigma_r_chip: f("sigma_r_chip")?,
            sigma_r_core: f("sigma_r_core")?,
            sigma_p_chip: f("sigma_p_chip")?,
            sigma_p_core: f("sigma_p_core")?,
            sigma_mount: f("sigma_mount")?,
            ua_pipe_env: f("ua_pipe_env")?,
            ua_pipe_cold_frac: f("ua_pipe_cold_frac")?,
            t_room: f("t_room")?,
            eps_hx_drive: f("eps_hx_drive")?,
            eps_hx_primary: f("eps_hx_primary")?,
            ua_tank_env: f("ua_tank_env")?,
            drive_flow_lps: f("drive_flow_lps")?,
            chiller_t_on: f("chiller_t_on")?,
            chiller_t_off: f("chiller_t_off")?,
            cop_at_57: f("cop_at_57")?,
            cop_slope: f("cop_slope")?,
            cop_max: f("cop_max")?,
            pc_max_at_57: f("pc_max_at_57")?,
            pc_max_slope: f("pc_max_slope")?,
            pc_max_cap: f("pc_max_cap")?,
            cycle_period_s: f("cycle_period_s")?,
            cycle_amp: f("cycle_amp")?,
            chiller_min_drive: f("chiller_min_drive")?,
            t_primary_support: f("t_primary_support")?,
            ua_cooltrans: f("ua_cooltrans")?,
            gpu_peak_w: f("gpu_peak_w")?,
            ua_recool_max: f("ua_recool_max")?,
            recool_fan_min: f("recool_fan_min")?,
            dt_substep: f("dt_substep")?,
            substeps_per_tick: f("substeps_per_tick")? as usize,
        })
    }

    /// Convenience: load from `<artifacts>/params.json` if present,
    /// otherwise fall back to the built-in defaults.
    pub fn from_artifacts(dir: &std::path::Path) -> Self {
        let path = dir.join("params.json");
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(j) = Json::parse(&text) {
                if let Ok(pp) = Self::from_json(&j) {
                    return pp;
                }
            }
        }
        Self::default()
    }

    /// The "ideal insulation" ablation of Sect. 5: the paper estimates
    /// that with better thermal insulation "almost 50 % of the energy can
    /// be recovered" — i.e. heat-in-water roughly doubles at 70 degC.
    pub fn with_ideal_insulation(&self) -> Self {
        let mut p = self.clone();
        p.ua_node_air = 0.15;
        p.ua_pipe_env = 8.0;
        p.ua_tank_env = 3.0;
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cop_matches_paper_gain() {
        let pp = PlantParams::default();
        let gain = pp.cop(70.0) / pp.cop(57.0);
        assert!((1.8..=2.0).contains(&gain), "gain {gain}");
        assert_eq!(pp.cop(54.0), 0.0);
    }

    #[test]
    fn pd_max_rises_with_temperature() {
        let pp = PlantParams::default();
        assert!(pp.pd_max(70.0) > pp.pd_max(60.0));
        assert!(pp.pd_max(60.0) > pp.pd_max(57.0));
        // Sect. 3 equilibrium band: slightly below the rack transfer ~19 kW.
        assert!(pp.pd_max(70.0) > 15_000.0 && pp.pd_max(70.0) < 20_000.0);
    }

    #[test]
    fn node_mcp_plausible() {
        let pp = PlantParams::default();
        // 0.6 l/min of water ~ 41 W/K
        let mcp = pp.node_mcp();
        assert!((40.0..44.0).contains(&mcp), "{mcp}");
    }

    #[test]
    fn from_json_roundtrip_defaults() {
        // Build a JSON object mirroring the defaults and re-parse it.
        let pp = PlantParams::default();
        let text = format!(
            r#"{{"params": {{
            "c_core": {}, "c_pkg": {}, "c_sink": {}, "c_water": {},
            "c_tank": {}, "c_primary": {}, "c_recool": {},
            "r_jc": {}, "r_sp": {}, "r_sw": {}, "ua_node_air": {},
            "node_flow_lpm": {}, "cp_water": {}, "rho_water": {},
            "node_dp_bar": {}, "manifold_dp_bar": {},
            "p_core_dyn": {}, "p_core_idle": {}, "p_node_base": {},
            "leak_frac": {}, "leak_beta": {}, "leak_t0": {},
            "psu_efficiency": {}, "p_switches": {}, "t_throttle": {},
            "throttle_band": {}, "sigma_r_chip": {}, "sigma_r_core": {},
            "sigma_p_chip": {}, "sigma_p_core": {}, "sigma_mount": {},
            "ua_pipe_env": {}, "ua_pipe_cold_frac": {}, "t_room": {},
            "eps_hx_drive": {}, "eps_hx_primary": {}, "ua_tank_env": {},
            "drive_flow_lps": {}, "chiller_t_on": {}, "chiller_t_off": {},
            "cop_at_57": {}, "cop_slope": {}, "cop_max": {},
            "pc_max_at_57": {}, "pc_max_slope": {}, "pc_max_cap": {},
            "cycle_period_s": {}, "cycle_amp": {}, "chiller_min_drive": {},
            "t_primary_support": {}, "ua_cooltrans": {}, "gpu_peak_w": {},
            "ua_recool_max": {}, "recool_fan_min": {},
            "dt_substep": {}, "substeps_per_tick": {}
            }}}}"#,
            pp.c_core, pp.c_pkg, pp.c_sink, pp.c_water, pp.c_tank,
            pp.c_primary, pp.c_recool, pp.r_jc, pp.r_sp, pp.r_sw,
            pp.ua_node_air, pp.node_flow_lpm, pp.cp_water, pp.rho_water,
            pp.node_dp_bar, pp.manifold_dp_bar, pp.p_core_dyn,
            pp.p_core_idle, pp.p_node_base, pp.leak_frac, pp.leak_beta,
            pp.leak_t0, pp.psu_efficiency, pp.p_switches, pp.t_throttle,
            pp.throttle_band, pp.sigma_r_chip, pp.sigma_r_core,
            pp.sigma_p_chip, pp.sigma_p_core, pp.sigma_mount,
            pp.ua_pipe_env, pp.ua_pipe_cold_frac, pp.t_room,
            pp.eps_hx_drive, pp.eps_hx_primary, pp.ua_tank_env,
            pp.drive_flow_lps, pp.chiller_t_on, pp.chiller_t_off,
            pp.cop_at_57, pp.cop_slope, pp.cop_max, pp.pc_max_at_57,
            pp.pc_max_slope, pp.pc_max_cap, pp.cycle_period_s, pp.cycle_amp,
            pp.chiller_min_drive, pp.t_primary_support, pp.ua_cooltrans,
            pp.gpu_peak_w, pp.ua_recool_max, pp.recool_fan_min,
            pp.dt_substep, pp.substeps_per_tick,
        );
        let j = Json::parse(&text).unwrap();
        let got = PlantParams::from_json(&j).unwrap();
        assert_eq!(got, pp);
    }

    #[test]
    fn ideal_insulation_reduces_ua() {
        let pp = PlantParams::default();
        let ideal = pp.with_ideal_insulation();
        assert!(ideal.ua_node_air < pp.ua_node_air / 5.0);
        assert!(ideal.ua_pipe_env < pp.ua_pipe_env / 5.0);
    }
}
