//! Run configuration: cluster size, backend, control setpoints, workload
//! mix, fault schedule — assembled from presets and/or TOML files.

pub mod constants;
pub mod toml;

use std::path::{Path, PathBuf};

use constants::PlantParams;
use toml::TomlDoc;

/// Workload selection (Sect. 4: stress on a 13-node subset vs the whole
/// system in production mode).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// `stress` tool on the selected subset, other nodes idle.
    Stress,
    /// Batch-queue production mix (jobs of various sizes).
    Production,
    /// Everything idle.
    Idle,
}

impl std::str::FromStr for WorkloadKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "stress" => Ok(WorkloadKind::Stress),
            "production" => Ok(WorkloadKind::Production),
            "idle" => Ok(WorkloadKind::Idle),
            _ => anyhow::bail!("unknown workload '{s}'"),
        }
    }
}

impl WorkloadKind {
    /// The catalog name (inverse of `FromStr` — canonical request
    /// documents round-trip through it).
    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::Stress => "stress",
            WorkloadKind::Production => "production",
            WorkloadKind::Idle => "idle",
        }
    }
}

/// Full simulation run configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub name: String,
    /// Cluster size (paper: 216; stress subset measurements use 13).
    pub n_nodes: usize,
    /// Backend: "hlo" | "native" | "auto".
    pub backend: String,
    /// Native substep kernel: "soa" | "reference" | "auto" (auto defers
    /// to the `IDATACOOL_KERNEL` env override, then the SoA default).
    pub kernel: String,
    /// Artifacts directory.
    pub artifacts_dir: PathBuf,
    /// Lottery seed (must match aot.py for the HLO backend).
    pub seed: u64,
    /// Initial water temperature [degC].
    pub t_water_init: f64,
    /// Simulated duration [s].
    pub duration_s: f64,
    /// Rack-outlet temperature setpoint for the PID [degC].
    pub t_out_setpoint: f64,
    /// Regulate (PID on valve) or run open-loop with a fixed valve.
    pub regulate: bool,
    pub valve_fixed: f64,
    /// Pump speed (fraction of nominal 0.6 l/min per node).
    pub pump_speed: f64,
    /// Ambient (outside) temperature for the recooler [degC].
    pub t_ambient: f64,
    /// Central cooling circuit supply temperature [degC].
    pub t_central: f64,
    /// GPU cluster load on the primary circuit [W].
    pub gpu_load: f64,
    pub workload: WorkloadKind,
    /// Stress subset size (paper: 13 randomly selected nodes).
    pub stress_nodes: usize,
    /// Background utilization on the non-selected nodes during stress
    /// sweeps (the paper's cluster kept running production around the
    /// 13 measured nodes).
    pub stress_background: f64,
    /// Production mix target utilization (cluster-average).
    pub production_load: f64,
    /// Telemetry sensor noise on/off (paper accuracies when on).
    pub sensor_noise: bool,
    /// Plant constants.
    pub pp: PlantParams,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            name: "default".into(),
            n_nodes: 216,
            backend: "auto".into(),
            kernel: "auto".into(),
            artifacts_dir: PathBuf::from("artifacts"),
            seed: crate::variability::DEFAULT_SEED,
            t_water_init: 20.0,
            duration_s: 3600.0,
            t_out_setpoint: 67.0,
            regulate: true,
            valve_fixed: 0.0,
            pump_speed: 0.75,
            t_ambient: 18.0,
            t_central: 8.0,
            gpu_load: 9000.0,
            workload: WorkloadKind::Production,
            stress_nodes: 13,
            stress_background: 0.0,
            production_load: 0.92,
            sensor_noise: true,
            pp: PlantParams::default(),
        }
    }
}

impl SimConfig {
    /// The paper's full installation in production mode.
    pub fn idatacool_full() -> Self {
        SimConfig::default()
    }

    /// The 13-node stress-measurement setup of Figs. 4(a), 5(a), 6(a).
    /// The full cluster runs, 13 randomly selected nodes under stress.
    pub fn subset13() -> Self {
        SimConfig {
            name: "subset13".into(),
            workload: WorkloadKind::Stress,
            ..SimConfig::default()
        }
    }

    /// Small, fast configuration for tests.
    pub fn test_small() -> Self {
        SimConfig {
            name: "test_small".into(),
            n_nodes: 13,
            backend: "native".into(),
            duration_s: 300.0,
            sensor_noise: false,
            ..SimConfig::default()
        }
    }

    /// Load overrides from a TOML file on top of a preset base.
    pub fn from_toml_file(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Self::from_toml_doc(&TomlDoc::parse(&text)?)
    }

    /// Like `from_toml_file`, from an already-parsed doc (callers that
    /// also consume other sections — e.g. `[serve]` — parse once).
    pub fn from_toml_doc(doc: &TomlDoc) -> anyhow::Result<Self> {
        let base = match doc.str_or("preset", "full") {
            "full" => SimConfig::idatacool_full(),
            "subset13" => SimConfig::subset13(),
            "test_small" => SimConfig::test_small(),
            other => anyhow::bail!("unknown preset '{other}'"),
        };
        base.apply_toml(doc)
    }

    /// Apply TOML overrides (flat `section.key` layout, see configs/*.toml).
    pub fn apply_toml(mut self, doc: &TomlDoc) -> anyhow::Result<Self> {
        self.name = doc.str_or("name", &self.name).to_string();
        self.n_nodes = doc.usize_or("cluster.nodes", self.n_nodes);
        self.backend = doc.str_or("cluster.backend", &self.backend).to_string();
        self.kernel = doc.str_or("cluster.kernel", &self.kernel).to_string();
        if let Some(v) = doc.get("cluster.artifacts_dir") {
            self.artifacts_dir = PathBuf::from(
                v.as_str().ok_or_else(|| anyhow::anyhow!("artifacts_dir"))?,
            );
        }
        self.seed = doc.f64_or("cluster.seed", self.seed as f64) as u64;
        self.t_water_init = doc.f64_or("sim.t_water_init", self.t_water_init);
        self.duration_s = doc.f64_or("sim.duration_s", self.duration_s);
        self.t_out_setpoint =
            doc.f64_or("control.t_out_setpoint", self.t_out_setpoint);
        self.regulate = doc.bool_or("control.regulate", self.regulate);
        self.valve_fixed = doc.f64_or("control.valve_fixed", self.valve_fixed);
        self.pump_speed = doc.f64_or("control.pump_speed", self.pump_speed);
        self.t_ambient = doc.f64_or("env.t_ambient", self.t_ambient);
        self.t_central = doc.f64_or("env.t_central", self.t_central);
        self.gpu_load = doc.f64_or("env.gpu_load", self.gpu_load);
        if let Some(w) = doc.get("workload.kind") {
            self.workload = w
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("workload.kind"))?
                .parse()?;
        }
        self.stress_nodes = doc.usize_or("workload.stress_nodes", self.stress_nodes);
        self.stress_background =
            doc.f64_or("workload.stress_background", self.stress_background);
        self.production_load =
            doc.f64_or("workload.production_load", self.production_load);
        self.sensor_noise = doc.bool_or("telemetry.noise", self.sensor_noise);
        self.validate()?;
        Ok(self)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_nodes > 0, "n_nodes must be positive");
        anyhow::ensure!(
            self.kernel.parse::<crate::plant::PlantKernel>().is_ok(),
            "unknown kernel '{}' (soa|reference|auto)",
            self.kernel
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.valve_fixed),
            "valve_fixed must be in [0,1]"
        );
        anyhow::ensure!(
            self.pump_speed > 0.0 && self.pump_speed <= 1.5,
            "pump_speed out of range"
        );
        anyhow::ensure!(
            self.stress_nodes <= self.n_nodes,
            "stress_nodes > n_nodes"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.production_load),
            "production_load must be in [0,1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.stress_background),
            "stress_background must be in [0,1]"
        );
        anyhow::ensure!(
            self.t_out_setpoint > 25.0 && self.t_out_setpoint <= 75.0,
            "t_out_setpoint outside the plant's operating range"
        );
        Ok(())
    }
}

/// `[serve]` launcher knobs for `idatacool serve`. Kept separate from
/// `SimConfig`: these shape the serving process (threads, cache, bind
/// address), not the physics — they never enter a cache key or a
/// response document.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeConfig {
    /// Bind address (`serve.addr`).
    pub addr: String,
    /// Worker threads (`serve.workers`); simulations are CPU-bound, so
    /// the default is one per available core.
    pub workers: usize,
    /// LRU response-cache entries (`serve.cache_cap`).
    pub cache_cap: usize,
    /// Bounded job-queue capacity (`serve.queue_cap`); overflow sheds
    /// load with a 503.
    pub queue_cap: usize,
    /// Continuous-batching admission window in milliseconds
    /// (`serve.batch_window_ms`). `0` disables batching — every request
    /// computes solo. Execution shape only: batched responses are
    /// bitwise identical to solo runs, so this never enters a cache key
    /// or a response document.
    pub batch_window_ms: usize,
    /// Most plants one batched lane arena packs (`serve.batch_max_plants`);
    /// a round with more pending plants sweeps as several chunks.
    pub batch_max_plants: usize,
    /// Per-request compute budget in milliseconds (`serve.deadline_ms`).
    /// A request that cannot be answered inside the budget gets a 504
    /// `idatacool-error/1` envelope with `Retry-After` instead of
    /// holding the connection. `0` disables the deadline (requests wait
    /// as long as the compute takes) — zero is the off switch, not a
    /// degenerate value, same convention as `batch_window_ms`.
    pub deadline_ms: usize,
    /// Most keep-alive connections the readiness loop holds open at
    /// once (`serve.max_parked`); arrivals beyond it are shed with a
    /// 503 envelope. Strict count: zero is rejected (a server that can
    /// park nothing cannot serve).
    pub max_parked: usize,
    /// Token-bucket refill rate for cost-aware admission control
    /// (`serve.rate_limit`), in request-cost units per second (nominal
    /// ticks × plants — see `server::admit`); the burst capacity is
    /// 4 s of refill. `0` disables the rate limiter (off switch).
    pub rate_limit: usize,
    /// Worker respawns the supervisor may perform over the server's
    /// lifetime (`serve.restart_budget`) — the fuse against a crash
    /// loop. `0` disables respawning (a dead worker stays dark and the
    /// health document says so); zero is the off switch.
    pub restart_budget: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        ServeConfig {
            addr: "127.0.0.1:8080".into(),
            workers,
            cache_cap: 64,
            queue_cap: 4 * workers,
            batch_window_ms: 2,
            batch_max_plants: 16,
            deadline_ms: 0,
            max_parked: 1024,
            rate_limit: 0,
            restart_budget: 16,
        }
    }
}

impl ServeConfig {
    /// Apply `[serve]` overrides from a TOML doc. Counts are strict:
    /// a present-yet-non-integer (or zero) value is an error, matching
    /// the CLI-flag discipline. `batch_window_ms`, `deadline_ms`,
    /// `rate_limit` and `restart_budget` admit zero — zero is their
    /// off switch, not a degenerate value.
    pub fn apply_toml(mut self, doc: &TomlDoc) -> anyhow::Result<Self> {
        self.addr = doc.str_or("serve.addr", &self.addr).to_string();
        self.workers = toml_count(doc, "serve.workers", self.workers)?;
        self.cache_cap = toml_count(doc, "serve.cache_cap", self.cache_cap)?;
        self.queue_cap = toml_count(doc, "serve.queue_cap", self.queue_cap)?;
        self.batch_window_ms =
            toml_count0(doc, "serve.batch_window_ms", self.batch_window_ms)?;
        self.batch_max_plants = toml_count(
            doc,
            "serve.batch_max_plants",
            self.batch_max_plants,
        )?;
        self.deadline_ms =
            toml_count0(doc, "serve.deadline_ms", self.deadline_ms)?;
        self.max_parked =
            toml_count(doc, "serve.max_parked", self.max_parked)?;
        self.rate_limit =
            toml_count0(doc, "serve.rate_limit", self.rate_limit)?;
        self.restart_budget =
            toml_count0(doc, "serve.restart_budget", self.restart_budget)?;
        Ok(self)
    }
}

/// `[chaos]` fault-injection settings — the TOML face of
/// `resilience::inject`. Off unless a plan is present; precedence in
/// the CLI is TOML < `IDATACOOL_CHAOS` env < `--chaos` flag. Execution
/// shape in the ugliest sense (injected faults), so, like `[serve]`,
/// never part of result documents or cache keys — but a run that
/// quarantines plants marks its output via the aggregate's
/// `quarantined` section.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChaosSettings {
    /// Deterministic tick-derivation seed (`chaos.seed`); rules without
    /// an explicit `tick=` fire at a tick derived from this seed — same
    /// seed, same fire ticks, every run.
    pub seed: Option<u64>,
    /// Fault plan (`chaos.plan`), `resilience::inject` grammar:
    /// semicolon-separated `site=…,kind=…[,plant=N][,tick=N][,arg=N]`.
    pub plan: Option<String>,
}

impl ChaosSettings {
    /// Parse the `[chaos]` section. A seed without a plan is an error —
    /// it would silently arm nothing.
    pub fn from_toml(doc: &TomlDoc) -> anyhow::Result<Self> {
        let seed = match doc.get("chaos.seed") {
            None => None,
            Some(v) => {
                let x = v.as_f64().ok_or_else(|| {
                    anyhow::anyhow!("chaos.seed must be an integer")
                })?;
                anyhow::ensure!(
                    x >= 0.0 && x.fract() == 0.0,
                    "chaos.seed must be a non-negative integer, got {x}"
                );
                Some(x as u64)
            }
        };
        let plan = match doc.get("chaos.plan") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| {
                        anyhow::anyhow!("chaos.plan must be a string")
                    })?
                    .to_string(),
            ),
        };
        anyhow::ensure!(
            seed.is_none() || plan.is_some(),
            "chaos.seed without chaos.plan arms nothing; add a plan"
        );
        Ok(ChaosSettings { seed, plan })
    }
}

/// `[fleet]` launcher defaults for `idatacool fleet`. Execution shape
/// only, like `[serve]`: the fleet determinism contract makes results
/// bitwise identical across every plants/shards/megabatch combination,
/// so none of these enter result documents or cache keys. Precedence in
/// the CLI: TOML < `IDATACOOL_FLEET_MEGABATCH` env < flags.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FleetSettings {
    /// Fleet size (`fleet.plants`); `None` leaves the CLI default.
    pub plants: Option<usize>,
    /// Shard (OS thread) count (`fleet.shards`).
    pub shards: Option<usize>,
    /// Lockstep lane-arena execution (`fleet.megabatch`).
    pub megabatch: Option<bool>,
}

impl FleetSettings {
    /// Parse the `[fleet]` section. Counts are strict positive
    /// integers, `megabatch` a strict boolean — a present-yet-malformed
    /// value is an error, matching the CLI-flag discipline.
    pub fn from_toml(doc: &TomlDoc) -> anyhow::Result<Self> {
        let count_opt = |key: &str| -> anyhow::Result<Option<usize>> {
            match doc.get(key) {
                None => Ok(None),
                Some(_) => toml_count(doc, key, 1).map(Some),
            }
        };
        let megabatch = match doc.get("fleet.megabatch") {
            None => None,
            Some(v) => Some(v.as_bool().ok_or_else(|| {
                anyhow::anyhow!("fleet.megabatch must be a boolean")
            })?),
        };
        Ok(FleetSettings {
            plants: count_opt("fleet.plants")?,
            shards: count_opt("fleet.shards")?,
            megabatch,
        })
    }
}

/// `[optimize]` settings for `idatacool optimize` — the TOML face of
/// the `optimize` subsystem. Every field is optional (the subsystem's
/// defaults apply, see `optimize::OptimizeConfig::from_settings`);
/// precedence in the CLI is TOML < `IDATACOOL_OPT_*` env < flags.
/// Unlike `[fleet]`, most of these are *semantic*: objective, driver,
/// budget, plants, scenario, axes, generation size and eval duration
/// all change the report document, so the server's canonical request
/// carries their resolved values.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OptimizeSettings {
    /// Objective preset (`optimize.objective`): `ere` | `pue` | `cost`.
    pub objective: Option<String>,
    /// Search driver (`optimize.driver`): `grid` | `coordinate` | `cem`.
    pub driver: Option<String>,
    /// Physical-evaluation budget (`optimize.budget`).
    pub budget: Option<usize>,
    /// Plants per candidate fleet (`optimize.plants`).
    pub plants: Option<usize>,
    /// Fleet scenario for candidate evaluation (`optimize.scenario`).
    pub scenario: Option<String>,
    /// Free axes, comma-separated (`optimize.axes`):
    /// `setpoint|pump|chiller|share`.
    pub axes: Option<String>,
    /// Candidates per generation (`optimize.gen_size`).
    pub gen_size: Option<usize>,
    /// Simulated seconds per candidate evaluation
    /// (`optimize.eval_duration_s`).
    pub eval_duration_s: Option<f64>,
    /// Re-measure the winner through the sweep instrument
    /// (`optimize.detail`).
    pub detail: Option<bool>,
    /// Explicit weight overrides on top of the preset
    /// (`optimize.w_pue` …).
    pub w_pue: Option<f64>,
    pub w_ere: Option<f64>,
    pub w_throttle: Option<f64>,
    pub w_cost: Option<f64>,
}

impl OptimizeSettings {
    /// Parse the `[optimize]` section. Counts are strict positive
    /// integers, `detail` a strict boolean, `eval_duration_s` a strict
    /// positive number — a present-yet-malformed value is an error,
    /// matching the CLI-flag discipline. Name fields are validated
    /// downstream where the catalogs live
    /// (`Weights::preset`, `DriverKind::by_name`, `Scenario::by_name`,
    /// `Space::enable_axes`).
    pub fn from_toml(doc: &TomlDoc) -> anyhow::Result<Self> {
        let count_opt = |key: &str| -> anyhow::Result<Option<usize>> {
            match doc.get(key) {
                None => Ok(None),
                Some(_) => toml_count(doc, key, 1).map(Some),
            }
        };
        let str_opt = |key: &str| -> anyhow::Result<Option<String>> {
            match doc.get(key) {
                None => Ok(None),
                Some(v) => Ok(Some(
                    v.as_str()
                        .ok_or_else(|| {
                            anyhow::anyhow!("{key} must be a string")
                        })?
                        .to_string(),
                )),
            }
        };
        let f64_opt = |key: &str| -> anyhow::Result<Option<f64>> {
            match doc.get(key) {
                None => Ok(None),
                Some(v) => Ok(Some(v.as_f64().ok_or_else(|| {
                    anyhow::anyhow!("{key} must be a number")
                })?)),
            }
        };
        let eval_duration_s = match f64_opt("optimize.eval_duration_s")? {
            Some(d) if d <= 0.0 => anyhow::bail!(
                "optimize.eval_duration_s must be positive, got {d}"
            ),
            other => other,
        };
        let detail = match doc.get("optimize.detail") {
            None => None,
            Some(v) => Some(v.as_bool().ok_or_else(|| {
                anyhow::anyhow!("optimize.detail must be a boolean")
            })?),
        };
        Ok(OptimizeSettings {
            objective: str_opt("optimize.objective")?,
            driver: str_opt("optimize.driver")?,
            budget: count_opt("optimize.budget")?,
            plants: count_opt("optimize.plants")?,
            scenario: str_opt("optimize.scenario")?,
            axes: str_opt("optimize.axes")?,
            gen_size: count_opt("optimize.gen_size")?,
            eval_duration_s,
            detail,
            w_pue: f64_opt("optimize.w_pue")?,
            w_ere: f64_opt("optimize.w_ere")?,
            w_throttle: f64_opt("optimize.w_throttle")?,
            w_cost: f64_opt("optimize.w_cost")?,
        })
    }
}

/// A strictly-parsed positive integer TOML value.
fn toml_count(doc: &TomlDoc, key: &str, default: usize)
              -> anyhow::Result<usize> {
    match doc.get(key) {
        None => Ok(default),
        Some(v) => {
            let x = v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("{key} must be a positive integer")
            })?;
            anyhow::ensure!(
                x >= 1.0 && x.fract() == 0.0,
                "{key} must be a positive integer, got {x}"
            );
            Ok(x as usize)
        }
    }
}

/// A strictly-parsed non-negative integer TOML value (zero allowed —
/// for knobs where zero means "off").
fn toml_count0(doc: &TomlDoc, key: &str, default: usize)
               -> anyhow::Result<usize> {
    match doc.get(key) {
        None => Ok(default),
        Some(v) => {
            let x = v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("{key} must be a non-negative integer")
            })?;
            anyhow::ensure!(
                x >= 0.0 && x.fract() == 0.0,
                "{key} must be a non-negative integer, got {x}"
            );
            Ok(x as usize)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        SimConfig::idatacool_full().validate().unwrap();
        SimConfig::subset13().validate().unwrap();
        SimConfig::test_small().validate().unwrap();
    }

    #[test]
    fn toml_overrides() {
        let doc = TomlDoc::parse(
            r#"
            name = "exp1"
            [cluster]
            nodes = 13
            backend = "native"
            kernel = "reference"
            [control]
            t_out_setpoint = 49
            [workload]
            kind = "stress"
            "#,
        )
        .unwrap();
        let cfg = SimConfig::default().apply_toml(&doc).unwrap();
        assert_eq!(cfg.name, "exp1");
        assert_eq!(cfg.n_nodes, 13);
        assert_eq!(cfg.workload, WorkloadKind::Stress);
        assert_eq!(cfg.t_out_setpoint, 49.0);
        assert_eq!(cfg.kernel, "reference");
    }

    #[test]
    fn invalid_config_rejected() {
        let doc = TomlDoc::parse("[control]\nt_out_setpoint = 150\n").unwrap();
        assert!(SimConfig::default().apply_toml(&doc).is_err());
        let doc = TomlDoc::parse("[workload]\nkind = \"bogus\"\n").unwrap();
        assert!(SimConfig::default().apply_toml(&doc).is_err());
        let doc = TomlDoc::parse("[cluster]\nkernel = \"bogus\"\n").unwrap();
        assert!(SimConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn workload_names_round_trip() {
        for w in [WorkloadKind::Stress, WorkloadKind::Production,
                  WorkloadKind::Idle] {
            assert_eq!(w.name().parse::<WorkloadKind>().unwrap(), w);
        }
    }

    #[test]
    fn serve_section_overrides() {
        let doc = TomlDoc::parse(
            "[serve]\naddr = \"0.0.0.0:9000\"\nworkers = 3\n\
             cache_cap = 16\nqueue_cap = 12\n\
             batch_window_ms = 5\nbatch_max_plants = 32\n\
             max_parked = 256\nrate_limit = 500\nrestart_budget = 4\n",
        )
        .unwrap();
        let sc = ServeConfig::default().apply_toml(&doc).unwrap();
        assert_eq!(sc.addr, "0.0.0.0:9000");
        assert_eq!(sc.workers, 3);
        assert_eq!(sc.cache_cap, 16);
        assert_eq!(sc.queue_cap, 12);
        assert_eq!(sc.batch_window_ms, 5);
        assert_eq!(sc.batch_max_plants, 32);
        assert_eq!(sc.max_parked, 256);
        assert_eq!(sc.rate_limit, 500);
        assert_eq!(sc.restart_budget, 4);
        // zero is the batching off switch, not an error
        let doc =
            TomlDoc::parse("[serve]\nbatch_window_ms = 0\n").unwrap();
        let sc = ServeConfig::default().apply_toml(&doc).unwrap();
        assert_eq!(sc.batch_window_ms, 0);
        // defaults survive an empty doc
        let sc = ServeConfig::default()
            .apply_toml(&TomlDoc::parse("").unwrap())
            .unwrap();
        assert!(sc.workers >= 1 && sc.cache_cap >= 1);
        assert_eq!(sc.batch_window_ms, 2);
        assert_eq!(sc.batch_max_plants, 16);
        assert_eq!(sc.deadline_ms, 0);
        assert_eq!(sc.max_parked, 1024);
        assert_eq!(sc.rate_limit, 0);
        assert_eq!(sc.restart_budget, 16);
        // deadline: zero = off, positive = budget, garbage rejected
        let doc = TomlDoc::parse("[serve]\ndeadline_ms = 250\n").unwrap();
        let sc = ServeConfig::default().apply_toml(&doc).unwrap();
        assert_eq!(sc.deadline_ms, 250);
        let doc = TomlDoc::parse("[serve]\ndeadline_ms = -5\n").unwrap();
        assert!(ServeConfig::default().apply_toml(&doc).is_err());
        // rate_limit and restart_budget: zero is the off switch
        let doc = TomlDoc::parse(
            "[serve]\nrate_limit = 0\nrestart_budget = 0\n",
        )
        .unwrap();
        let sc = ServeConfig::default().apply_toml(&doc).unwrap();
        assert_eq!(sc.rate_limit, 0);
        assert_eq!(sc.restart_budget, 0);
        // max_parked is strict: zero is rejected, not an off switch
        let doc = TomlDoc::parse("[serve]\nmax_parked = 0\n").unwrap();
        assert!(ServeConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn chaos_section_parses_and_is_strict() {
        let doc = TomlDoc::parse(
            "[chaos]\nseed = 7\nplan = \"site=plant_tick,kind=panic\"\n",
        )
        .unwrap();
        let cs = ChaosSettings::from_toml(&doc).unwrap();
        assert_eq!(cs.seed, Some(7));
        assert_eq!(cs.plan.as_deref(), Some("site=plant_tick,kind=panic"));
        // absent section: chaos stays off
        let cs = ChaosSettings::from_toml(&TomlDoc::parse("").unwrap())
            .unwrap();
        assert_eq!(cs, ChaosSettings::default());
        // seed without a plan arms nothing — rejected
        let doc = TomlDoc::parse("[chaos]\nseed = 7\n").unwrap();
        assert!(ChaosSettings::from_toml(&doc).is_err());
        // malformed values rejected
        for bad in ["seed = -1", "seed = 1.5", "plan = 3"] {
            let doc = TomlDoc::parse(&format!("[chaos]\n{bad}\n")).unwrap();
            assert!(
                ChaosSettings::from_toml(&doc).is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn fleet_section_overrides() {
        let doc = TomlDoc::parse(
            "[fleet]\nplants = 8\nshards = 2\nmegabatch = false\n",
        )
        .unwrap();
        let fs = FleetSettings::from_toml(&doc).unwrap();
        assert_eq!(fs.plants, Some(8));
        assert_eq!(fs.shards, Some(2));
        assert_eq!(fs.megabatch, Some(false));
        // absent section leaves everything to the CLI defaults
        let fs = FleetSettings::from_toml(&TomlDoc::parse("").unwrap())
            .unwrap();
        assert_eq!(fs, FleetSettings::default());
    }

    #[test]
    fn fleet_section_is_strict() {
        for bad in ["plants = 0", "plants = 2.5", "shards = \"two\"",
                    "megabatch = \"yes\"", "megabatch = 1"] {
            let doc = TomlDoc::parse(&format!("[fleet]\n{bad}\n")).unwrap();
            assert!(
                FleetSettings::from_toml(&doc).is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn optimize_section_overrides() {
        let doc = TomlDoc::parse(
            "[optimize]\nobjective = \"pue\"\ndriver = \"cem\"\n\
             budget = 40\nplants = 4\nscenario = \"baseline\"\n\
             axes = \"setpoint,pump\"\ngen_size = 6\n\
             eval_duration_s = 600\ndetail = false\nw_throttle = 2.5\n",
        )
        .unwrap();
        let os = OptimizeSettings::from_toml(&doc).unwrap();
        assert_eq!(os.objective.as_deref(), Some("pue"));
        assert_eq!(os.driver.as_deref(), Some("cem"));
        assert_eq!(os.budget, Some(40));
        assert_eq!(os.plants, Some(4));
        assert_eq!(os.scenario.as_deref(), Some("baseline"));
        assert_eq!(os.axes.as_deref(), Some("setpoint,pump"));
        assert_eq!(os.gen_size, Some(6));
        assert_eq!(os.eval_duration_s, Some(600.0));
        assert_eq!(os.detail, Some(false));
        assert_eq!(os.w_throttle, Some(2.5));
        assert_eq!(os.w_pue, None);
        // absent section leaves everything to the subsystem defaults
        let os = OptimizeSettings::from_toml(&TomlDoc::parse("").unwrap())
            .unwrap();
        assert_eq!(os, OptimizeSettings::default());
    }

    #[test]
    fn optimize_section_is_strict() {
        for bad in ["budget = 0", "budget = 2.5", "plants = -1",
                    "gen_size = \"six\"", "detail = \"yes\"",
                    "detail = 1", "eval_duration_s = 0",
                    "eval_duration_s = -5", "objective = 3",
                    "w_ere = \"one\""] {
            let doc =
                TomlDoc::parse(&format!("[optimize]\n{bad}\n")).unwrap();
            assert!(
                OptimizeSettings::from_toml(&doc).is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn serve_section_counts_are_strict() {
        for bad in ["workers = 0", "workers = 2.5", "workers = \"four\"",
                    "cache_cap = 0", "queue_cap = -1",
                    "batch_max_plants = 0", "batch_window_ms = -1",
                    "batch_window_ms = 1.5", "max_parked = 0",
                    "max_parked = -3", "rate_limit = 1.5",
                    "restart_budget = \"many\""] {
            let doc = TomlDoc::parse(&format!("[serve]\n{bad}\n")).unwrap();
            assert!(
                ServeConfig::default().apply_toml(&doc).is_err(),
                "{bad} must be rejected"
            );
        }
    }
}
