//! Synthetic production job mix.
//!
//! Job classes model the Regensburg QCD-flavored mix the paper alludes to:
//! wide long-running MPI jobs, medium multi-node jobs, small single-node
//! jobs, and short bursty tasks — with distinct compute intensities
//! (utilization levels) and durations. Arrivals are Poisson.

use crate::variability::rng::Rng;

/// A job class template.
#[derive(Debug, Clone)]
pub struct JobClass {
    pub name: &'static str,
    /// Nodes requested (min..=max, uniform).
    pub nodes_min: usize,
    pub nodes_max: usize,
    /// Runtime [s] (exponential with this mean).
    pub mean_runtime_s: f64,
    /// Per-core utilization while running (compute intensity).
    pub util: f32,
    /// Relative arrival weight.
    pub weight: f64,
}

/// The default mix. Weights tuned so a 216-node cluster settles around
/// 80-85 % allocated in steady state (the paper's production histograms
/// show a small idle population, Fig. 4b).
pub const DEFAULT_MIX: &[JobClass] = &[
    JobClass { name: "wide-mpi", nodes_min: 32, nodes_max: 96,
               mean_runtime_s: 14_400.0, util: 1.0, weight: 0.08 },
    JobClass { name: "multi-node", nodes_min: 8, nodes_max: 24,
               mean_runtime_s: 7_200.0, util: 0.99, weight: 0.25 },
    JobClass { name: "single-node", nodes_min: 1, nodes_max: 2,
               mean_runtime_s: 3_600.0, util: 0.98, weight: 0.45 },
    JobClass { name: "io-bound", nodes_min: 1, nodes_max: 4,
               mean_runtime_s: 1_800.0, util: 0.65, weight: 0.12 },
    JobClass { name: "burst", nodes_min: 1, nodes_max: 8,
               mean_runtime_s: 600.0, util: 1.0, weight: 0.10 },
];

/// A concrete job instance.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    pub class: usize,
    pub nodes: usize,
    pub runtime_s: f64,
    pub util: f32,
    pub submit_s: f64,
    pub start_s: Option<f64>,
}

impl Job {
    /// Checkpoint encoding (field order is the `idatacool-ckpt/1`
    /// contract; see DESIGN.md §8).
    pub fn save(&self, w: &mut crate::resilience::checkpoint::SnapWriter) {
        w.u64(self.id);
        w.usize(self.class);
        w.usize(self.nodes);
        w.f64(self.runtime_s);
        w.f32(self.util);
        w.f64(self.submit_s);
        w.opt_f64(self.start_s);
    }

    /// Decode a job written by [`Job::save`].
    pub fn load(r: &mut crate::resilience::checkpoint::SnapReader)
                -> anyhow::Result<Job> {
        Ok(Job {
            id: r.u64()?,
            class: r.usize()?,
            nodes: r.usize()?,
            runtime_s: r.f64()?,
            util: r.f32()?,
            submit_s: r.f64()?,
            start_s: r.opt_f64()?,
        })
    }
}

/// Poisson job generator over a class mix.
#[derive(Debug)]
pub struct JobGenerator {
    pub mix: Vec<JobClass>,
    rng: Rng,
    next_id: u64,
    /// Mean inter-arrival time [s].
    pub mean_interarrival_s: f64,
    next_arrival_s: f64,
}

impl JobGenerator {
    /// `target_load` is the desired steady-state allocated fraction; the
    /// arrival rate is derived from Little's law over the mix.
    pub fn new(n_nodes: usize, target_load: f64, seed: u64) -> Self {
        let mix: Vec<JobClass> = DEFAULT_MIX.to_vec();
        let wsum: f64 = mix.iter().map(|c| c.weight).sum();
        // E[nodes * runtime] per arrival:
        let mean_node_seconds: f64 = mix
            .iter()
            .map(|c| {
                let mean_nodes = (c.nodes_min + c.nodes_max) as f64 / 2.0;
                c.weight / wsum * mean_nodes * c.mean_runtime_s
            })
            .sum();
        // Little: allocated_nodes = arrival_rate * mean_node_seconds
        let arrival_rate =
            (n_nodes as f64 * target_load).max(1e-9) / mean_node_seconds;
        let mut rng = Rng::new(seed ^ 0x9E3779B97F4A7C15);
        let first = rng.exponential(arrival_rate);
        JobGenerator {
            mix,
            rng,
            next_id: 1,
            mean_interarrival_s: 1.0 / arrival_rate,
            next_arrival_s: first,
        }
    }

    /// Jobs arriving in the window [t, t + dt).
    pub fn arrivals(&mut self, t: f64, dt: f64) -> Vec<Job> {
        let mut out = Vec::new();
        while self.next_arrival_s < t + dt {
            let submit = self.next_arrival_s;
            self.next_arrival_s +=
                self.rng.exponential(1.0 / self.mean_interarrival_s);
            let class = self.pick_class();
            let c = &self.mix[class];
            let nodes = c.nodes_min
                + self.rng.below(c.nodes_max - c.nodes_min + 1);
            let runtime = self
                .rng
                .exponential(1.0 / c.mean_runtime_s)
                .clamp(60.0, 10.0 * c.mean_runtime_s);
            out.push(Job {
                id: self.next_id,
                class,
                nodes,
                runtime_s: runtime,
                util: c.util,
                submit_s: submit,
                start_s: None,
            });
            self.next_id += 1;
        }
        out
    }

    /// Serialize the generator's dynamic state (RNG stream, id counter,
    /// pending arrival). The mix and rate are configuration — the resume
    /// path reconstructs them from the same `(n_nodes, target_load)`.
    pub fn save_state(&self, w: &mut crate::resilience::checkpoint::SnapWriter) {
        let (state, cached) = self.rng.state();
        w.u64(state);
        w.opt_f64(cached);
        w.u64(self.next_id);
        w.f64(self.next_arrival_s);
    }

    /// Restore state written by [`JobGenerator::save_state`].
    pub fn load_state(&mut self,
                      r: &mut crate::resilience::checkpoint::SnapReader)
                      -> anyhow::Result<()> {
        let state = r.u64()?;
        let cached = r.opt_f64()?;
        self.rng.restore(state, cached);
        self.next_id = r.u64()?;
        self.next_arrival_s = r.f64()?;
        Ok(())
    }

    fn pick_class(&mut self) -> usize {
        let wsum: f64 = self.mix.iter().map(|c| c.weight).sum();
        let mut x = self.rng.uniform() * wsum;
        for (i, c) in self.mix.iter().enumerate() {
            if x < c.weight {
                return i;
            }
            x -= c.weight;
        }
        self.mix.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_rate_tracks_target_load() {
        let mut gen = JobGenerator::new(216, 0.8, 1);
        let mut node_seconds = 0.0;
        // Long horizon: the wide-MPI class is rare and heavy-tailed, so
        // the implied load converges slowly.
        let horizon = 3_000_000.0;
        for j in gen.arrivals(0.0, horizon) {
            node_seconds += j.nodes as f64 * j.runtime_s;
        }
        let implied_load = node_seconds / (216.0 * horizon);
        assert!((implied_load - 0.8).abs() < 0.15, "load {implied_load}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = JobGenerator::new(216, 0.8, 7);
        let mut b = JobGenerator::new(216, 0.8, 7);
        let ja = a.arrivals(0.0, 50_000.0);
        let jb = b.arrivals(0.0, 50_000.0);
        assert_eq!(ja.len(), jb.len());
        for (x, y) in ja.iter().zip(&jb) {
            assert_eq!(x.nodes, y.nodes);
            assert_eq!(x.runtime_s, y.runtime_s);
        }
    }

    #[test]
    fn job_sizes_within_class_bounds() {
        let mut gen = JobGenerator::new(216, 0.9, 3);
        for j in gen.arrivals(0.0, 100_000.0) {
            let c = &gen.mix[j.class];
            assert!(j.nodes >= c.nodes_min && j.nodes <= c.nodes_max);
            assert!(j.runtime_s >= 60.0);
        }
    }
}
