//! Batch-queue scheduler: FIFO with conservative backfill.
//!
//! The paper's production measurements run under "the batch queueing
//! system"; this is the equivalent substrate. Jobs request whole nodes
//! (the iDataCool queue was node-exclusive); the scheduler keeps a FIFO
//! head but backfills smaller jobs that fit the current holes without
//! delaying the head job's earliest start.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::resilience::checkpoint::{SnapReader, SnapWriter};

use super::jobs::{Job, JobGenerator};
use super::{UtilPlan, WorkloadSource};

/// A running job occupying concrete nodes.
#[derive(Debug, Clone)]
struct Running {
    job: Job,
    nodes: Vec<usize>,
    end_s: f64,
}

/// FIFO + backfill node-exclusive scheduler.
pub struct BatchScheduler {
    n_nodes: usize,
    free: Vec<bool>,
    queue: VecDeque<Job>,
    running: Vec<Running>,
    gen: JobGenerator,
    now_s: f64,
    // telemetry
    pub started: u64,
    pub finished: u64,
    pub backfilled: u64,
    pub wait_time_sum: f64,
    pub node_seconds: f64,
}

impl BatchScheduler {
    pub fn new(n_nodes: usize, target_load: f64, seed: u64) -> Self {
        BatchScheduler {
            n_nodes,
            free: vec![true; n_nodes],
            queue: VecDeque::new(),
            running: Vec::new(),
            gen: JobGenerator::new(n_nodes, target_load, seed),
            now_s: 0.0,
            started: 0,
            finished: 0,
            backfilled: 0,
            wait_time_sum: 0.0,
            node_seconds: 0.0,
        }
    }

    pub fn allocated_nodes(&self) -> usize {
        self.free.iter().filter(|&&f| !f).count()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn utilization(&self) -> f64 {
        self.allocated_nodes() as f64 / self.n_nodes as f64
    }

    fn free_count(&self) -> usize {
        self.free.iter().filter(|&&f| f).count()
    }

    fn take_nodes(&mut self, k: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(k);
        for (i, f) in self.free.iter_mut().enumerate() {
            if *f {
                *f = false;
                out.push(i);
                if out.len() == k {
                    break;
                }
            }
        }
        debug_assert_eq!(out.len(), k);
        out
    }

    /// Earliest time the FIFO head could start, given running jobs' ends.
    fn head_earliest_start(&self, head_nodes: usize) -> f64 {
        let mut frees = self.free_count();
        if frees >= head_nodes {
            return self.now_s;
        }
        let mut ends: Vec<(f64, usize)> = self
            .running
            .iter()
            .map(|r| (r.end_s, r.nodes.len()))
            .collect();
        ends.sort_by(|a, b| a.0.total_cmp(&b.0));
        for (end, k) in ends {
            frees += k;
            if frees >= head_nodes {
                return end;
            }
        }
        f64::INFINITY
    }

    /// One scheduling pass: start the head while it fits, then backfill.
    fn schedule(&mut self) {
        // FIFO head
        while let Some(head) = self.queue.front() {
            if head.nodes <= self.free_count() {
                let mut job = self.queue.pop_front().unwrap();
                job.start_s = Some(self.now_s);
                self.wait_time_sum += self.now_s - job.submit_s;
                let nodes = self.take_nodes(job.nodes);
                self.started += 1;
                self.running.push(Running {
                    end_s: self.now_s + job.runtime_s,
                    nodes,
                    job,
                });
            } else {
                break;
            }
        }
        // Conservative backfill: a queued job may jump ahead only if it
        // finishes before the head's earliest possible start.
        if let Some(head) = self.queue.front() {
            let head_start = self.head_earliest_start(head.nodes);
            let mut i = 1;
            while i < self.queue.len() {
                let fits = {
                    let j = &self.queue[i];
                    j.nodes <= self.free_count()
                        && self.now_s + j.runtime_s <= head_start
                };
                if fits {
                    let mut job = self.queue.remove(i).unwrap();
                    job.start_s = Some(self.now_s);
                    self.wait_time_sum += self.now_s - job.submit_s;
                    let nodes = self.take_nodes(job.nodes);
                    self.started += 1;
                    self.backfilled += 1;
                    self.running.push(Running {
                        end_s: self.now_s + job.runtime_s,
                        nodes,
                        job,
                    });
                } else {
                    i += 1;
                }
            }
        }
    }

    pub fn mean_wait_s(&self) -> f64 {
        if self.started == 0 {
            0.0
        } else {
            self.wait_time_sum / self.started as f64
        }
    }
}

impl WorkloadSource for BatchScheduler {
    fn advance(&mut self, dt: f64, plan: &mut UtilPlan) {
        // arrivals
        for j in self.gen.arrivals(self.now_s, dt) {
            self.queue.push_back(j);
        }
        self.now_s += dt;
        // completions
        let now = self.now_s;
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].end_s <= now {
                let r = self.running.swap_remove(i);
                for n in &r.nodes {
                    self.free[*n] = true;
                }
                self.node_seconds += r.nodes.len() as f64 * r.job.runtime_s;
                self.finished += 1;
            } else {
                i += 1;
            }
        }
        self.schedule();
        // build the utilization plan
        for u in plan.util.iter_mut() {
            *u = 0.0;
        }
        for r in &self.running {
            for &n in &r.nodes {
                plan.set_node(n, r.job.util);
            }
        }
    }

    fn stats(&self) -> String {
        format!(
            "jobs: started={} finished={} backfilled={} queued={} \
             running={} alloc={:.1}% mean_wait={:.0}s",
            self.started,
            self.finished,
            self.backfilled,
            self.queue_len(),
            self.running_len(),
            100.0 * self.utilization(),
            self.mean_wait_s()
        )
    }

    /// The scheduler is the stateful workload: free map, queue, running
    /// set, generator stream, clock, and counters all cross ticks.
    fn save_state(&self, w: &mut SnapWriter) {
        w.usize(self.n_nodes);
        w.u64(self.free.len() as u64);
        for &f in &self.free {
            w.bool(f);
        }
        w.u64(self.queue.len() as u64);
        for j in &self.queue {
            j.save(w);
        }
        w.u64(self.running.len() as u64);
        for r in &self.running {
            r.job.save(w);
            w.u64(r.nodes.len() as u64);
            for &n in &r.nodes {
                w.u64(n as u64);
            }
            w.f64(r.end_s);
        }
        self.gen.save_state(w);
        w.f64(self.now_s);
        w.u64(self.started);
        w.u64(self.finished);
        w.u64(self.backfilled);
        w.f64(self.wait_time_sum);
        w.f64(self.node_seconds);
    }

    fn load_state(&mut self, r: &mut SnapReader) -> Result<()> {
        let n_nodes = r.usize()?;
        if n_nodes != self.n_nodes {
            bail!("checkpointed scheduler has {n_nodes} nodes, \
                   config has {}", self.n_nodes);
        }
        let n_free = r.usize()?;
        if n_free != self.free.len() {
            bail!("checkpointed free map has {n_free} entries");
        }
        for f in self.free.iter_mut() {
            *f = r.bool()?;
        }
        self.queue.clear();
        for _ in 0..r.usize()? {
            self.queue.push_back(Job::load(r)?);
        }
        self.running.clear();
        for _ in 0..r.usize()? {
            let job = Job::load(r)?;
            let mut nodes = Vec::new();
            for _ in 0..r.usize()? {
                nodes.push(r.u64()? as usize);
            }
            let end_s = r.f64()?;
            self.running.push(Running { job, nodes, end_s });
        }
        self.gen.load_state(r)?;
        self.now_s = r.f64()?;
        self.started = r.u64()?;
        self.finished = r.u64()?;
        self.backfilled = r.u64()?;
        self.wait_time_sum = r.f64()?;
        self.node_seconds = r.f64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_oversubscribes() {
        let mut s = BatchScheduler::new(64, 0.95, 2);
        let mut plan = UtilPlan::idle(64);
        for _ in 0..2000 {
            s.advance(30.0, &mut plan);
            assert!(s.allocated_nodes() <= 64);
            // every running job's nodes are distinct
            let mut seen = vec![false; 64];
            for r in &s.running {
                for &n in &r.nodes {
                    assert!(!seen[n], "node {n} double-booked");
                    seen[n] = true;
                }
            }
        }
    }

    #[test]
    fn reaches_target_load() {
        let mut s = BatchScheduler::new(216, 0.82, 3);
        let mut plan = UtilPlan::idle(216);
        // warm up 1 simulated day, then measure
        for _ in 0..2880 {
            s.advance(30.0, &mut plan);
        }
        let mut acc = 0.0;
        let ticks = 2880;
        for _ in 0..ticks {
            s.advance(30.0, &mut plan);
            acc += s.utilization();
        }
        let mean = acc / ticks as f64;
        assert!((0.60..=1.0).contains(&mean), "mean load {mean}");
    }

    #[test]
    fn backfill_happens() {
        let mut s = BatchScheduler::new(216, 0.95, 4);
        let mut plan = UtilPlan::idle(216);
        for _ in 0..20_000 {
            s.advance(30.0, &mut plan);
        }
        assert!(s.backfilled > 0, "no backfill in a busy queue");
    }

    #[test]
    fn checkpoint_roundtrip_resumes_identically() {
        use crate::resilience::checkpoint::{SnapReader, SnapWriter};
        let mut a = BatchScheduler::new(64, 0.9, 11);
        let mut plan = UtilPlan::idle(64);
        for _ in 0..500 {
            a.advance(30.0, &mut plan);
        }
        let mut w = SnapWriter::new();
        a.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut b = BatchScheduler::new(64, 0.9, 11);
        let mut r = SnapReader::new(&bytes).unwrap();
        b.load_state(&mut r).unwrap();
        assert!(r.done());
        let mut pa = UtilPlan::idle(64);
        let mut pb = UtilPlan::idle(64);
        for _ in 0..500 {
            a.advance(30.0, &mut pa);
            b.advance(30.0, &mut pb);
            for (x, y) in pa.util.iter().zip(&pb.util) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(a.started, b.started);
        assert_eq!(a.finished, b.finished);
        assert_eq!(a.wait_time_sum.to_bits(), b.wait_time_sum.to_bits());
    }

    #[test]
    fn plan_reflects_running_jobs() {
        let mut s = BatchScheduler::new(32, 0.9, 5);
        let mut plan = UtilPlan::idle(32);
        for _ in 0..400 {
            s.advance(60.0, &mut plan);
        }
        let allocated = s.allocated_nodes();
        let busy_nodes =
            (0..32).filter(|&n| plan.node_mean(n) > 0.0).count();
        assert_eq!(allocated, busy_nodes);
    }
}
