//! Workload substrate: the cluster's job load.
//!
//! The paper measures under two regimes (Sect. 4): (i) the `stress` tool
//! pinning all cores of 13 randomly selected nodes, and (ii) "production
//! mode, i.e., various jobs of different sizes and with different
//! computing and communication requirements are scheduled and executed by
//! the batch queueing system". This module provides both: a stress
//! generator and a batch-queue scheduler (FIFO + backfill) fed by a
//! synthetic production job mix.

pub mod jobs;
pub mod scheduler;
pub mod stress;

use crate::plant::layout::NC;

/// A utilization plan: per-core utilization for every (padded) node slot.
#[derive(Debug, Clone)]
pub struct UtilPlan {
    pub n_padded: usize,
    pub util: Vec<f32>, // [n_padded * NC]
}

impl UtilPlan {
    pub fn idle(n_padded: usize) -> Self {
        UtilPlan { n_padded, util: vec![0.0; n_padded * NC] }
    }

    pub fn set_node(&mut self, node: usize, u: f32) {
        for c in 0..NC {
            self.util[node * NC + c] = u;
        }
    }

    pub fn node_mean(&self, node: usize) -> f32 {
        self.util[node * NC..(node + 1) * NC].iter().sum::<f32>() / NC as f32
    }
}

/// Something that produces per-tick utilization plans.
///
/// `Send` is a supertrait so a boxed workload (and with it the whole
/// `SimulationDriver`) can move across the fleet engine's shard threads.
pub trait WorkloadSource: Send {
    /// Advance simulated time by `dt` seconds and refresh `plan`.
    fn advance(&mut self, dt: f64, plan: &mut UtilPlan);
    /// Human-readable stats line for the run report.
    fn stats(&self) -> String;
    /// Serialize cross-tick state into a checkpoint snapshot. Sources
    /// whose `advance` is a pure function of construction parameters
    /// (stress, idle) keep the default and write nothing.
    fn save_state(&self, _w: &mut crate::resilience::checkpoint::SnapWriter) {
    }
    /// Restore state written by `save_state` onto a freshly constructed
    /// source of the same configuration (the resume path rebuilds the
    /// source from config first, then overlays the dynamic state).
    fn load_state(&mut self,
                  _r: &mut crate::resilience::checkpoint::SnapReader)
                  -> anyhow::Result<()> {
        Ok(())
    }
}
