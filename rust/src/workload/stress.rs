//! The `stress` workload of Sect. 4: "a subset of 13 randomly selected
//! nodes (six-core E5645 processors ...) running a well-defined load (the
//! standard stress tool)". All cores of the selected nodes pinned at
//! 100 % utilization; the rest of the cluster idles (or runs a background
//! load for the production variants).

use super::{UtilPlan, WorkloadSource};
use crate::variability::rng::Rng;

/// Stress on a random subset of six-core nodes.
pub struct StressWorkload {
    pub selected: Vec<usize>,
    pub util: f32,
    pub background_util: f32,
    n_nodes: usize,
}

impl StressWorkload {
    /// Select `k` random *six-core* nodes (the paper's figures only
    /// include E5645 processors).
    pub fn new(
        lot: &crate::variability::ChipLottery,
        k: usize,
        seed: u64,
    ) -> Self {
        let six = lot.six_core_nodes();
        let mut rng = Rng::new(seed ^ 0x5757_5757);
        let picks = rng.sample_indices(six.len(), k);
        let selected: Vec<usize> = picks.into_iter().map(|i| six[i]).collect();
        StressWorkload {
            selected,
            util: 1.0,
            background_util: 0.0,
            n_nodes: lot.n_nodes,
        }
    }

    /// All nodes under stress (cluster-wide maximum load, Sect. 3's
    /// equilibrium scenario).
    pub fn full(n_nodes: usize) -> Self {
        StressWorkload {
            selected: (0..n_nodes).collect(),
            util: 1.0,
            background_util: 0.0,
            n_nodes,
        }
    }

    /// Whole cluster idle.
    pub fn idle(n_nodes: usize) -> Self {
        StressWorkload {
            selected: Vec::new(),
            util: 0.0,
            background_util: 0.0,
            n_nodes,
        }
    }
}

impl WorkloadSource for StressWorkload {
    fn advance(&mut self, _dt: f64, plan: &mut UtilPlan) {
        for u in plan.util.iter_mut() {
            *u = 0.0;
        }
        // background on all real nodes
        if self.background_util > 0.0 {
            for n in 0..self.n_nodes {
                plan.set_node(n, self.background_util);
            }
        }
        for &n in &self.selected {
            plan.set_node(n, self.util);
        }
    }

    fn stats(&self) -> String {
        format!(
            "stress: {} nodes @ util={:.2} (background {:.2})",
            self.selected.len(),
            self.util,
            self.background_util
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::constants::PlantParams;
    use crate::variability::ChipLottery;

    #[test]
    fn selects_only_six_core_nodes() {
        let pp = PlantParams::default();
        let lot = ChipLottery::draw(216, &pp, 1);
        let w = StressWorkload::new(&lot, 13, 42);
        assert_eq!(w.selected.len(), 13);
        for &n in &w.selected {
            assert!(lot.six_core[n] > 0.5, "node {n} is four-core");
        }
    }

    #[test]
    fn plan_has_exactly_selected_nodes_busy() {
        let pp = PlantParams::default();
        let lot = ChipLottery::draw(216, &pp, 1);
        let mut w = StressWorkload::new(&lot, 13, 42);
        let mut plan = UtilPlan::idle(256);
        w.advance(5.0, &mut plan);
        let busy: Vec<usize> =
            (0..256).filter(|&n| plan.node_mean(n) > 0.0).collect();
        assert_eq!(busy, w.selected);
    }

    #[test]
    fn deterministic_selection() {
        let pp = PlantParams::default();
        let lot = ChipLottery::draw(216, &pp, 1);
        let a = StressWorkload::new(&lot, 13, 42);
        let b = StressWorkload::new(&lot, 13, 42);
        assert_eq!(a.selected, b.selected);
        let c = StressWorkload::new(&lot, 13, 43);
        assert_ne!(a.selected, c.selected);
    }
}
