//! The shared facility loop: pooled heat recovery + aggregate adsorption
//! chiller.
//!
//! The paper's energy-reuse path (Sect. 3/4): hot water from the racks
//! drives an InvenSor adsorption chiller whose chilled-water output cools
//! *other parts of the computing center*. A fleet of iDataCool plants
//! shares one such facility: every tick the per-plant recovered heat
//! (the power transferred into the driving circuits, P_d) is pooled, the
//! aggregate chiller converts it with the paper's Sect.-4 COP-vs-return-
//! temperature curve (Fig. 6b) subject to a fleet-scaled capacity cap
//! (Fig. 6b's P_c^max curve x number of chiller units), and the chilled
//! output is fed back as a facility-side cooling credit, split across
//! plants pro rata to their heat contribution.
//!
//! The model is pure accounting over the plants' tick traces: it never
//! perturbs plant physics, so plant runs stay embarrassingly parallel and
//! the facility pass is bitwise deterministic in plant-index order
//! regardless of shard count.
//!
//! Two callers feed it, through one conversion (`fleet::plant_tick_of`):
//! the post-hoc replay over finished traces (`fleet::run_facility`) and
//! the per-tick stream of a 1-shard megabatch run
//! (`fleet::megabatch::LockstepFleet::run`), where the whole fleet
//! advances in tick lockstep and each tick's samples are pooled as they
//! are produced. `pool_tick` is incremental either way — identical
//! inputs in identical order, so both feeds produce bitwise-identical
//! reports.

use anyhow::{bail, Result};

use crate::config::constants::PlantParams;
use crate::util::json::{Json, JsonBuilder};

/// Facility-side chiller parameters: the paper's Sect.-4 curves (owned by
/// `PlantParams` — the single source of truth) scaled to a fleet of
/// `units` chiller installations.
#[derive(Debug, Clone)]
pub struct FacilityParams {
    /// Plant constants carrying the Sect.-4 chiller curves.
    pub pp: PlantParams,
    /// Number of chiller units backing the facility loop.
    pub units: usize,
}

impl FacilityParams {
    /// Derive from the plant constants, one chiller unit per plant.
    pub fn from_plant(pp: &PlantParams, n_plants: usize) -> Self {
        FacilityParams { pp: pp.clone(), units: n_plants.max(1) }
    }

    /// COP vs driving (return) temperature — Fig. 6b. Zero in standby.
    pub fn cop(&self, t_drive: f64) -> f64 {
        self.pp.cop(t_drive)
    }

    /// Chilled-water capacity of one unit [W] vs driving temperature.
    pub fn pc_max_unit(&self, t_drive: f64) -> f64 {
        self.pp.pc_max(t_drive)
    }

    /// Aggregate chilled-water capacity [W] of the facility.
    pub fn capacity_w(&self, t_drive: f64) -> f64 {
        self.units as f64 * self.pc_max_unit(t_drive)
    }
}

/// One plant's contribution to the facility loop at one tick.
#[derive(Debug, Clone, Copy)]
pub struct PlantTick {
    /// Heat recovered into the plant's driving circuit (P_d) [W].
    pub p_heat_w: f64,
    /// The plant's return (rack outlet = driving) temperature [degC].
    pub t_return: f64,
    /// The plant's electrical input (P_AC) [W].
    pub p_ac_w: f64,
}

/// The facility's response at one tick.
#[derive(Debug, Clone)]
pub struct FacilityTick {
    /// Pooled recovered heat (sum of plant contributions, signed) [W].
    pub pooled_w: f64,
    /// Heat-weighted fleet return temperature driving the chiller [degC].
    pub t_drive: f64,
    /// Aggregate COP at the driving temperature.
    pub cop: f64,
    /// Chilled-water output delivered to the rest of the center [W].
    pub p_chilled_w: f64,
    /// Per-plant cooling credit (sums to `p_chilled_w`) [W].
    pub credits_w: Vec<f64>,
}

/// Tick-integrating facility model.
#[derive(Debug, Clone)]
pub struct FacilityModel {
    pub params: FacilityParams,
    /// Integrated pooled recovered heat (signed sum) [J].
    pub e_pooled: f64,
    /// Integrated positive (chiller-driving) heat [J].
    pub e_driven: f64,
    /// Integrated chilled-water output [J].
    pub e_chilled: f64,
    /// Integrated fleet electrical input [J].
    pub e_ac: f64,
    pub seconds: f64,
    pub ticks: u64,
    pub peak_pooled_w: f64,
    t_drive_sum: f64,
    plant_credit_j: Vec<f64>,
}

/// Frozen summary of a finished facility pass.
#[derive(Debug, Clone)]
pub struct FacilityReport {
    pub e_pooled: f64,
    pub e_driven: f64,
    pub e_chilled: f64,
    pub e_ac: f64,
    pub seconds: f64,
    pub ticks: u64,
    pub peak_pooled_w: f64,
    /// Time-mean driving temperature [degC].
    pub t_drive_mean: f64,
    /// Integrated cooling credit per plant [J]; sums to `e_chilled`.
    pub plant_credit_j: Vec<f64>,
    pub units: usize,
}

impl FacilityReport {
    /// The headline: facility energy-reuse fraction — chilled water
    /// delivered to the rest of the center per unit of fleet electricity.
    pub fn reuse_fraction(&self) -> f64 {
        if self.e_ac > 1e-9 {
            self.e_chilled / self.e_ac
        } else {
            0.0
        }
    }

    /// Effective time-averaged COP of the facility chiller (chilled
    /// output per unit of *driving* heat — negative contributions from
    /// heat-absorbing plants are excluded, so this never exceeds the
    /// curve's `cop_max`).
    pub fn mean_cop(&self) -> f64 {
        if self.e_driven > 1e-9 {
            self.e_chilled / self.e_driven
        } else {
            0.0
        }
    }

    /// Machine-readable view (`util::json`, BTreeMap-stable key order)
    /// — the `facility` block of the fleet JSON document. Integrals and
    /// the per-plant credit vector only; no wall-clock fields.
    pub fn to_json_value(&self) -> Json {
        JsonBuilder::new()
            .num("e_pooled_j", self.e_pooled)
            .num("e_driven_j", self.e_driven)
            .num("e_chilled_j", self.e_chilled)
            .num("e_ac_j", self.e_ac)
            .num("seconds", self.seconds)
            .num("ticks", self.ticks as f64)
            .num("peak_pooled_w", self.peak_pooled_w)
            .num("t_drive_mean", self.t_drive_mean)
            .num("mean_cop", self.mean_cop())
            .num("reuse_fraction", self.reuse_fraction())
            .num("units", self.units as f64)
            .arr(
                "plant_credit_j",
                self.plant_credit_j.iter().map(|&j| Json::Num(j)).collect(),
            )
            .build()
    }

    pub fn summary(&self) -> String {
        format!(
            "facility: pooled {:.1} kWh over {:.0} s (peak {:.1} kW, mean \
             T_drive {:.1} degC, {} chiller units) -> chilled {:.1} kWh \
             (mean COP {:.3}); energy-reuse fraction {:.1}%",
            self.e_pooled / 3.6e6,
            self.seconds,
            self.peak_pooled_w / 1e3,
            self.t_drive_mean,
            self.units,
            self.e_chilled / 3.6e6,
            self.mean_cop(),
            100.0 * self.reuse_fraction(),
        )
    }
}

impl FacilityModel {
    pub fn new(params: FacilityParams, n_plants: usize) -> Self {
        FacilityModel {
            params,
            e_pooled: 0.0,
            e_driven: 0.0,
            e_chilled: 0.0,
            e_ac: 0.0,
            seconds: 0.0,
            ticks: 0,
            peak_pooled_w: f64::MIN,
            t_drive_sum: 0.0,
            plant_credit_j: vec![0.0; n_plants],
        }
    }

    /// Pool one tick of per-plant contributions (plant-index order) and
    /// advance the integrals by `dt` seconds.
    ///
    /// Invariant (tested): `pooled_w` equals the plain sum of the inputs'
    /// `p_heat_w`, and `credits_w` sums to `p_chilled_w`.
    pub fn pool_tick(&mut self, inputs: &[PlantTick], dt: f64) -> FacilityTick {
        let pooled: f64 = inputs.iter().map(|p| p.p_heat_w).sum();
        // Only positive contributions drive the chiller (a plant with a
        // cold tank transiently *absorbs* heat; it cannot be un-pooled).
        let heat_pos: f64 = inputs.iter().map(|p| p.p_heat_w.max(0.0)).sum();
        let t_drive = if heat_pos > 1.0 {
            inputs
                .iter()
                .map(|p| p.p_heat_w.max(0.0) * p.t_return)
                .sum::<f64>()
                / heat_pos
        } else if !inputs.is_empty() {
            inputs.iter().map(|p| p.t_return).sum::<f64>()
                / inputs.len() as f64
        } else {
            0.0
        };
        let cop = self.params.cop(t_drive);
        let p_chilled = (heat_pos * cop).min(self.params.capacity_w(t_drive));
        let credits_w: Vec<f64> = if p_chilled > 0.0 && heat_pos > 0.0 {
            inputs
                .iter()
                .map(|p| p_chilled * p.p_heat_w.max(0.0) / heat_pos)
                .collect()
        } else {
            vec![0.0; inputs.len()]
        };

        self.e_pooled += pooled * dt;
        self.e_driven += heat_pos * dt;
        self.e_chilled += p_chilled * dt;
        self.e_ac += inputs.iter().map(|p| p.p_ac_w).sum::<f64>() * dt;
        self.seconds += dt;
        self.ticks += 1;
        self.peak_pooled_w = self.peak_pooled_w.max(pooled);
        self.t_drive_sum += t_drive;
        for (c, j) in credits_w.iter().zip(self.plant_credit_j.iter_mut()) {
            *j += c * dt;
        }

        FacilityTick { pooled_w: pooled, t_drive, cop, p_chilled_w: p_chilled, credits_w }
    }

    /// Checkpoint encoding of the streamed integrals (field order is
    /// the `idatacool-ckpt/1` contract; DESIGN.md §8). `params` is
    /// configuration — the resume path reconstructs it and overlays
    /// this state. The `f64::MIN` peak sentinel round-trips bit-exactly
    /// (`to_bits` codec).
    pub fn save_state(&self,
                      w: &mut crate::resilience::checkpoint::SnapWriter) {
        w.f64(self.e_pooled);
        w.f64(self.e_driven);
        w.f64(self.e_chilled);
        w.f64(self.e_ac);
        w.f64(self.seconds);
        w.u64(self.ticks);
        w.f64(self.peak_pooled_w);
        w.f64(self.t_drive_sum);
        w.f64s(&self.plant_credit_j);
    }

    /// Restore state written by [`FacilityModel::save_state`] onto a
    /// model freshly built for the same fleet shape.
    pub fn restore_state(&mut self,
                         r: &mut crate::resilience::checkpoint::SnapReader)
                         -> Result<()> {
        self.e_pooled = r.f64()?;
        self.e_driven = r.f64()?;
        self.e_chilled = r.f64()?;
        self.e_ac = r.f64()?;
        self.seconds = r.f64()?;
        self.ticks = r.u64()?;
        self.peak_pooled_w = r.f64()?;
        self.t_drive_sum = r.f64()?;
        let credits = r.f64s()?;
        if credits.len() != self.plant_credit_j.len() {
            bail!("checkpointed facility has {} plant credits, fleet has {}",
                  credits.len(), self.plant_credit_j.len());
        }
        self.plant_credit_j = credits;
        Ok(())
    }

    pub fn into_report(self) -> FacilityReport {
        FacilityReport {
            e_pooled: self.e_pooled,
            e_driven: self.e_driven,
            e_chilled: self.e_chilled,
            e_ac: self.e_ac,
            seconds: self.seconds,
            t_drive_mean: if self.ticks > 0 {
                self.t_drive_sum / self.ticks as f64
            } else {
                0.0
            },
            peak_pooled_w: if self.ticks > 0 { self.peak_pooled_w } else { 0.0 },
            ticks: self.ticks,
            plant_credit_j: self.plant_credit_j,
            units: self.params.units,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(units: usize) -> FacilityParams {
        FacilityParams::from_plant(&PlantParams::default(), units)
    }

    fn tick(p: f64, t: f64) -> PlantTick {
        PlantTick { p_heat_w: p, t_return: t, p_ac_w: 50_000.0 }
    }

    #[test]
    fn cop_curve_matches_plant_curve() {
        let pp = PlantParams::default();
        let fp = params(4);
        for t in [40.0, 55.0, 57.0, 63.0, 70.0, 90.0] {
            assert_eq!(fp.cop(t), pp.cop(t), "t={t}");
            assert_eq!(fp.pc_max_unit(t), pp.pc_max(t), "t={t}");
        }
        assert_eq!(fp.capacity_w(70.0), 4.0 * pp.pc_max(70.0));
    }

    #[test]
    fn pooling_conserves_heat() {
        let mut m = FacilityModel::new(params(3), 3);
        let inputs = vec![tick(12_000.0, 66.0), tick(9_000.0, 64.0),
                          tick(15_000.0, 68.0)];
        let expect: f64 = inputs.iter().map(|p| p.p_heat_w).sum();
        let out = m.pool_tick(&inputs, 5.0);
        assert_eq!(out.pooled_w, expect);
        assert_eq!(m.e_pooled, expect * 5.0);
        let credit_sum: f64 = out.credits_w.iter().sum();
        assert!((credit_sum - out.p_chilled_w).abs() < 1e-6,
                "{credit_sum} vs {}", out.p_chilled_w);
    }

    #[test]
    fn standby_below_threshold() {
        let mut m = FacilityModel::new(params(2), 2);
        let out = m.pool_tick(&[tick(10_000.0, 45.0), tick(10_000.0, 50.0)],
                              5.0);
        assert_eq!(out.cop, 0.0);
        assert_eq!(out.p_chilled_w, 0.0);
        assert!(out.credits_w.iter().all(|&c| c == 0.0));
        // pooled heat is still accounted even in standby
        assert_eq!(out.pooled_w, 20_000.0);
    }

    #[test]
    fn capacity_caps_chilled_output() {
        let expected = params(1).capacity_w(70.0);
        let mut m = FacilityModel::new(params(1), 1);
        // enormous pooled heat: output must clip at the unit capacity
        let out = m.pool_tick(&[tick(10_000_000.0, 70.0)], 1.0);
        assert_eq!(out.p_chilled_w, expected);
    }

    #[test]
    fn negative_contribution_reduces_pool_not_credits() {
        let mut m = FacilityModel::new(params(2), 2);
        let out = m.pool_tick(&[tick(20_000.0, 66.0), tick(-3_000.0, 30.0)],
                              5.0);
        assert_eq!(out.pooled_w, 17_000.0);
        // the absorbing plant gets no credit
        assert_eq!(out.credits_w[1], 0.0);
        assert!(out.credits_w[0] > 0.0);
        // drive temperature is that of the contributing plant
        assert!((out.t_drive - 66.0).abs() < 1e-9);
    }

    #[test]
    fn report_json_round_trips() {
        let mut m = FacilityModel::new(params(2), 2);
        m.pool_tick(&[tick(12_000.0, 66.0), tick(8_000.0, 66.0)], 5.0);
        let r = m.into_report();
        let j = r.to_json_value();
        assert_eq!(j.get("units").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("ticks").unwrap().as_f64(), Some(1.0));
        let credits = j.get("plant_credit_j").unwrap().as_vec_f64().unwrap();
        assert_eq!(credits.len(), 2);
        // serialized text re-parses (key order is builder-stable)
        let text = j.to_string();
        assert_eq!(crate::util::json::Json::parse(&text).unwrap(), j);
        assert!(text.starts_with("{\"e_ac_j\":"), "{text}");
    }

    #[test]
    fn facility_state_round_trips_bit_exact() {
        use crate::resilience::checkpoint::{SnapReader, SnapWriter};
        let mut a = FacilityModel::new(params(2), 2);
        for _ in 0..7 {
            a.pool_tick(&[tick(12_000.0, 66.0), tick(8_000.0, 64.0)], 5.0);
        }
        let mut w = SnapWriter::new();
        a.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut b = FacilityModel::new(params(2), 2);
        let mut r = SnapReader::new(&bytes).unwrap();
        b.restore_state(&mut r).unwrap();
        assert!(r.done());
        // wrong fleet shape is rejected
        let mut c = FacilityModel::new(params(3), 3);
        let mut r = SnapReader::new(&bytes).unwrap();
        assert!(c.restore_state(&mut r).is_err());
        // continue both in lockstep; the reports must match bitwise
        for m in [&mut a, &mut b] {
            m.pool_tick(&[tick(9_000.0, 67.0), tick(7_000.0, 65.0)], 5.0);
        }
        let (ra, rb) = (a.into_report(), b.into_report());
        assert_eq!(ra.e_chilled.to_bits(), rb.e_chilled.to_bits());
        assert_eq!(ra.t_drive_mean.to_bits(), rb.t_drive_mean.to_bits());
        assert_eq!(ra.peak_pooled_w.to_bits(), rb.peak_pooled_w.to_bits());
        for (x, y) in ra.plant_credit_j.iter().zip(&rb.plant_credit_j) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // a never-ticked model round-trips its f64::MIN peak sentinel
        let empty = FacilityModel::new(params(1), 1);
        let mut w = SnapWriter::new();
        empty.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut back = FacilityModel::new(params(1), 1);
        back.restore_state(&mut SnapReader::new(&bytes).unwrap()).unwrap();
        assert_eq!(back.peak_pooled_w.to_bits(), f64::MIN.to_bits());
    }

    #[test]
    fn report_integrates_and_sums_credits() {
        let mut m = FacilityModel::new(params(2), 2);
        for _ in 0..10 {
            m.pool_tick(&[tick(12_000.0, 66.0), tick(8_000.0, 66.0)], 5.0);
        }
        let r = m.into_report();
        assert_eq!(r.ticks, 10);
        assert!((r.seconds - 50.0).abs() < 1e-12);
        let credit_sum: f64 = r.plant_credit_j.iter().sum();
        assert!((credit_sum - r.e_chilled).abs() < 1e-6 * r.e_chilled.max(1.0));
        assert!(r.reuse_fraction() > 0.0 && r.reuse_fraction() < 1.0);
        assert!((r.t_drive_mean - 66.0).abs() < 1e-9);
        assert!(r.summary().contains("energy-reuse"));
    }
}
