//! Declarative scenario catalog for fleet runs.
//!
//! A scenario is a *typed* recipe that turns the fleet's base `SimConfig`
//! into one concrete per-plant configuration plus a timed `Fault` schedule
//! (routed through the existing `Supervisor`). Everything is a pure
//! function of `(scenario, plant index, fleet size, base config)` so a
//! fleet run is reproducible regardless of how plants are sharded across
//! threads.
//!
//! Catalog (see the paper's Sect. 3 redundancy narrative and the
//! energy-aware-operation regimes of arXiv:2411.16204):
//!  * `baseline`          homogeneous production fleet, no faults
//!  * `heatwave`          ambient ramp staggered across the fleet
//!  * `chiller-outage`    adsorption-chiller failures on half the plants
//!  * `pump-degradation`  progressive pump derating + one pump failure
//!  * `load-surge`        staggered GPU-cluster load surges at high load
//!  * `mixed`             stress / production / idle thirds

use crate::config::{SimConfig, WorkloadKind};
use crate::coordinator::supervisor::Fault;

/// Scenario identity (the catalog key).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioKind {
    Baseline,
    Heatwave,
    ChillerOutage,
    PumpDegradation,
    LoadSurge,
    Mixed,
}

/// A catalog entry, resolvable by name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Scenario {
    pub kind: ScenarioKind,
}

/// One plant's fully resolved run recipe.
#[derive(Debug, Clone)]
pub struct PlantSpec {
    pub index: usize,
    pub label: String,
    pub seed: u64,
    pub cfg: SimConfig,
    pub faults: Vec<Fault>,
}

impl Scenario {
    /// The catalog: `(name, kind, description)`.
    pub const CATALOG: &[(&str, ScenarioKind, &str)] = &[
        (
            "baseline",
            ScenarioKind::Baseline,
            "homogeneous fleet on the base workload, no faults",
        ),
        (
            "heatwave",
            ScenarioKind::Heatwave,
            "ambient ramp: +8..+16 degC staggered across the fleet at \
             high production load",
        ),
        (
            "chiller-outage",
            ScenarioKind::ChillerOutage,
            "adsorption-chiller failure windows, staggered over every \
             second plant (Sect. 3 failover path)",
        ),
        (
            "pump-degradation",
            ScenarioKind::PumpDegradation,
            "progressive rack-pump derating across the fleet; the worst \
             plant additionally suffers a pump failure window",
        ),
        (
            "load-surge",
            ScenarioKind::LoadSurge,
            "staggered GPU-cluster load surges on the primary circuit at \
             98% production load",
        ),
        (
            "mixed",
            ScenarioKind::Mixed,
            "mixed fleet: stress / production / idle thirds",
        ),
    ];

    /// Resolve a scenario by its catalog name.
    pub fn by_name(name: &str) -> anyhow::Result<Scenario> {
        for (n, kind, _) in Self::CATALOG {
            if *n == name {
                return Ok(Scenario { kind: *kind });
            }
        }
        anyhow::bail!(
            "unknown scenario '{name}' (have: {})",
            Self::names().join(", ")
        )
    }

    /// All catalog names, in catalog order.
    pub fn names() -> Vec<&'static str> {
        Self::CATALOG.iter().map(|(n, _, _)| *n).collect()
    }

    pub fn name(&self) -> &'static str {
        Self::CATALOG
            .iter()
            .find(|(_, k, _)| *k == self.kind)
            .map(|(n, _, _)| *n)
            .expect("scenario kind missing from catalog")
    }

    pub fn description(&self) -> &'static str {
        Self::CATALOG
            .iter()
            .find(|(_, k, _)| *k == self.kind)
            .map(|(_, _, d)| *d)
            .expect("scenario kind missing from catalog")
    }

    /// Resolve plant `index` of `n_plants` against the base config.
    ///
    /// Overrides are deliberately conservative: every produced config must
    /// pass `SimConfig::validate` for any base config that does.
    ///
    /// Lockstep invariant (megabatch eligibility): scenarios override
    /// workloads, setpoints, faults and environment — never the plant
    /// constants (`pp`), the cluster size, the backend/kernel selection,
    /// or the run duration. Every spec derived from one base therefore
    /// shares the substep count, tick length and tick count, which is
    /// what lets `fleet::megabatch` advance a whole shard over one lane
    /// arena (`specs_stay_lockstep_uniform` pins this).
    pub fn plant_spec(
        &self,
        index: usize,
        n_plants: usize,
        base: &SimConfig,
        seed: u64,
    ) -> PlantSpec {
        let mut cfg = base.clone();
        let mut faults = Vec::new();
        // Position of this plant in the fleet, in [0, 1].
        let frac = if n_plants > 1 {
            index as f64 / (n_plants - 1) as f64
        } else {
            0.0
        };
        let dur = cfg.duration_s;

        match self.kind {
            // Baseline keeps the base workload (so --workload/--preset
            // flow through); the other scenarios define the load shape as
            // part of the scenario itself.
            ScenarioKind::Baseline => {}
            ScenarioKind::Heatwave => {
                cfg.workload = WorkloadKind::Production;
                cfg.production_load = base.production_load.max(0.95);
                cfg.t_ambient = base.t_ambient + 8.0 + 8.0 * frac;
            }
            ScenarioKind::ChillerOutage => {
                cfg.workload = WorkloadKind::Production;
                if index % 2 == 0 {
                    let start = (0.2 + 0.05 * index as f64).min(0.6) * dur;
                    let end = (start + 0.25 * dur).min(0.95 * dur);
                    faults.push(Fault::ChillerFailure {
                        start_s: start,
                        end_s: end,
                    });
                }
            }
            ScenarioKind::PumpDegradation => {
                cfg.workload = WorkloadKind::Production;
                cfg.pump_speed = (base.pump_speed * (1.0 - 0.35 * frac)).max(0.3);
                if index + 1 == n_plants && n_plants > 1 {
                    faults.push(Fault::PumpFailure {
                        start_s: 0.4 * dur,
                        end_s: 0.5 * dur,
                    });
                }
            }
            ScenarioKind::LoadSurge => {
                cfg.workload = WorkloadKind::Production;
                cfg.production_load = 0.98;
                let start = (0.1 + 0.7 * frac) * dur;
                faults.push(Fault::GpuSurge {
                    start_s: start,
                    end_s: (start + 0.15 * dur).min(dur),
                    load_w: cfg.pp.gpu_peak_w,
                });
            }
            ScenarioKind::Mixed => match index % 3 {
                0 => {
                    cfg.workload = WorkloadKind::Stress;
                    cfg.stress_nodes = cfg.n_nodes;
                    cfg.stress_background = 0.25;
                }
                1 => {
                    cfg.workload = WorkloadKind::Production;
                }
                _ => {
                    cfg.workload = WorkloadKind::Idle;
                }
            },
        }

        // Fleet runs study the coupled operating point, not the multi-hour
        // warm-up: start each plant near the paper's production band so
        // short runs already exercise the facility chiller.
        cfg.t_water_init = base.t_water_init.max(62.0);

        let label = format!("{}/p{index:02}", self.name());
        cfg.name = label.clone();
        PlantSpec { index, label, seed, cfg, faults }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_resolves_by_name() {
        for name in Scenario::names() {
            let s = Scenario::by_name(name).unwrap();
            assert_eq!(s.name(), name);
            assert!(!s.description().is_empty());
        }
        assert!(Scenario::by_name("nope").is_err());
    }

    #[test]
    fn specs_validate_for_every_catalog_entry() {
        let base = SimConfig::test_small();
        for name in Scenario::names() {
            let s = Scenario::by_name(name).unwrap();
            for n_plants in [1usize, 2, 5, 8] {
                for i in 0..n_plants {
                    let spec = s.plant_spec(i, n_plants, &base, 42 + i as u64);
                    spec.cfg.validate().unwrap_or_else(|e| {
                        panic!("{name} plant {i}/{n_plants}: {e}")
                    });
                    for f in &spec.faults {
                        let (a, b) = match *f {
                            Fault::ChillerFailure { start_s, end_s }
                            | Fault::PumpFailure { start_s, end_s }
                            | Fault::GpuSurge { start_s, end_s, .. } => {
                                (start_s, end_s)
                            }
                        };
                        assert!(a < b, "{name}: empty fault window");
                        assert!(b <= spec.cfg.duration_s + 1e-9);
                    }
                }
            }
        }
    }

    #[test]
    fn specs_are_deterministic() {
        let base = SimConfig::test_small();
        let s = Scenario::by_name("heatwave").unwrap();
        let a = s.plant_spec(3, 8, &base, 7);
        let b = s.plant_spec(3, 8, &base, 7);
        assert_eq!(a.cfg.t_ambient, b.cfg.t_ambient);
        assert_eq!(a.label, b.label);
        assert_eq!(a.faults, b.faults);
    }

    #[test]
    fn specs_stay_lockstep_uniform() {
        // Megabatch eligibility: every catalog entry must keep the
        // plant constants, cluster size, backend/kernel and duration of
        // the base config, so a shard's plants share one arena and one
        // tick grid (see plant_spec's lockstep invariant).
        let base = SimConfig::test_small();
        for name in Scenario::names() {
            let s = Scenario::by_name(name).unwrap();
            for i in 0..6 {
                let spec = s.plant_spec(i, 6, &base, 7 + i as u64);
                assert_eq!(spec.cfg.pp, base.pp, "{name} plant {i}: pp");
                assert_eq!(spec.cfg.n_nodes, base.n_nodes, "{name}");
                assert_eq!(spec.cfg.backend, base.backend, "{name}");
                assert_eq!(spec.cfg.kernel, base.kernel, "{name}");
                assert_eq!(spec.cfg.duration_s, base.duration_s, "{name}");
            }
        }
    }

    #[test]
    fn mixed_fleet_rotates_workloads() {
        let base = SimConfig::test_small();
        let s = Scenario::by_name("mixed").unwrap();
        let kinds: Vec<WorkloadKind> = (0..6)
            .map(|i| s.plant_spec(i, 6, &base, 0).cfg.workload)
            .collect();
        assert_eq!(kinds[0], WorkloadKind::Stress);
        assert_eq!(kinds[1], WorkloadKind::Production);
        assert_eq!(kinds[2], WorkloadKind::Idle);
        assert_eq!(kinds[3], WorkloadKind::Stress);
    }
}
