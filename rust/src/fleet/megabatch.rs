//! Fleet megabatch: tick-lockstep execution of a shard's plants over
//! one shared SoA lane arena.
//!
//! The per-plant path (`run_bucket` with megabatch off) runs each plant
//! to completion as its own kernel instance — N small working sets, N
//! sets of loop/dispatch overhead per tick. The megabatch path packs
//! every plant assigned to a shard into one `[slot][n_total]` lane
//! arena (`SoaState::new_arena`; per-plant `LaneRange`s, tile-padded so
//! each starts on a vector-width boundary) and advances all of them in
//! tick lockstep: per substep, one `soa_substep_ranges` sweep over the
//! whole contiguous working set replaces N kernel calls — amortizing
//! dispatch, keeping small plants' lanes hot in cache, and letting a
//! single-shard fleet feed the shared facility loop **per tick** instead
//! of replaying traces post-hoc.
//!
//! Determinism: the engine reproduces `SimulationDriver::step` exactly —
//! `control_phase` → plant physics → `sample_phase` per plant, in plant
//! order — and the arena kernel is bitwise identical to per-plant SoA
//! substeps (elementwise lane ops plus per-range reductions in node
//! order; see `plant::soa`). A K-shard megabatch run therefore produces
//! byte-identical `idatacool-fleet/1` output to the 1-shard, megabatch-
//! off reference (`tests/fleet_integration.rs` gates it).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::constants::PlantParams;
use crate::coordinator::energy::EnergyAccount;
use crate::coordinator::{RunResult, SimulationDriver, TraceSample};
use crate::plant::circuits;
use crate::plant::layout::*;
use crate::plant::soa::{self, SoaState};
use crate::plant::{PlantKernel, TickOutput};
use crate::resilience::checkpoint::{SnapReader, SnapWriter};
use crate::resilience::inject::{self, Action, Site};

use super::facility::{FacilityModel, FacilityReport};
use super::scenario::PlantSpec;
use super::{note_quarantine, plant_tick_of, PlantRun, QuarantineEntry};

/// One plant's identity plus its ready-to-run driver (the unit the
/// lockstep engine and the sequential fallback share).
pub struct PlantCtx {
    pub index: usize,
    pub label: String,
    pub seed: u64,
    pub tick_s: f64,
    pub driver: SimulationDriver,
}

/// Config-level lockstep eligibility, checkable **before** any driver
/// exists: the base must resolve to the native backend with the SoA
/// kernel. Callers use it to decide whether to construct a whole
/// bucket's drivers up front for the arena (`build_ctxs` +
/// `LockstepFleet::new`) or to keep the per-plant one-driver-at-a-time
/// memory profile — a fleet with `kernel = "reference"` or a pinned
/// `hlo` backend must not pay an all-drivers-resident peak just to
/// discover it cannot lockstep. `LockstepFleet::new`'s deep per-plant
/// check remains the authority; this is the cheap gate in front of it.
pub fn precheck(base: &crate::config::SimConfig) -> bool {
    use crate::runtime::BackendKind;
    // `auto` resolves by artifact presence through the same shared rule
    // PlantBackend::create_with_kernel applies.
    let native = base
        .backend
        .parse::<BackendKind>()
        .is_ok_and(|k| {
            k.resolve_auto(&base.artifacts_dir) == BackendKind::Native
        });
    native
        && PlantKernel::resolve(&base.kernel)
            .is_ok_and(|k| k == PlantKernel::Soa)
}

/// Construct the drivers for a bucket of plant specs, in spec order.
pub fn build_ctxs(bucket: Vec<PlantSpec>) -> Result<Vec<PlantCtx>> {
    let mut ctxs = Vec::with_capacity(bucket.len());
    for spec in bucket {
        let PlantSpec { index, label, seed, cfg, faults } = spec;
        let mut driver = SimulationDriver::from_prebuilt(cfg, seed, faults)?;
        // Chaos rules with a plant= filter target the fleet index.
        driver.chaos_plant = Some(index);
        let tick_s = driver.backend.tick_seconds(&driver.cfg.pp);
        ctxs.push(PlantCtx { index, label, seed, tick_s, driver });
    }
    Ok(ctxs)
}

/// Run a bucket the per-plant way (each plant's driver owns its full
/// tick loop) — the megabatch-off path and the lockstep fallback.
///
/// Each plant is its own fault domain: a panic, a run error, or a
/// non-finite energy integral evicts that plant into the quarantine
/// list; the rest of the bucket completes untouched.
pub fn run_ctxs_sequential(ctxs: Vec<PlantCtx>)
                           -> Result<(Vec<PlantRun>, Vec<QuarantineEntry>)> {
    let mut out = Vec::with_capacity(ctxs.len());
    let mut quarantined = Vec::new();
    for ctx in ctxs {
        let PlantCtx { index, label, seed, tick_s, mut driver } = ctx;
        // sample_every = 1: the facility pass needs every tick.
        match catch_unwind(AssertUnwindSafe(|| driver.run(1))) {
            Ok(Ok(result)) => {
                if result.energy.e_ac.is_finite()
                    && result.energy.e_dc.is_finite()
                {
                    out.push(PlantRun { index, label, seed, tick_s, result });
                } else {
                    note_quarantine(&mut quarantined, index,
                                    "non-finite energy integral");
                }
            }
            Ok(Err(e)) => {
                note_quarantine(&mut quarantined, index,
                                &format!("run error: {e:#}"));
            }
            Err(_) => {
                note_quarantine(&mut quarantined, index,
                                "panic in plant run");
            }
        }
    }
    Ok((out, quarantined))
}

/// The lockstep engine: a shard's plants resident in one lane arena.
pub struct LockstepFleet {
    ctxs: Vec<PlantCtx>,
    soa: SoaState,
    ranges: Vec<LaneRange>,
    outs: Vec<TickOutput>,
    ctrl: Vec<[f32; CT]>,
    last_flow: Vec<Option<f32>>,
    sums: Vec<(f64, f32)>,
    traces: Vec<Vec<TraceSample>>,
    energies: Vec<EnergyAccount>,
    pp: PlantParams,
    inv_c_w: f32,
    substeps: usize,
    tick_s: f64,
    ticks_total: u64,
    ticks_done: u64,
    /// Per-plant liveness: `false` after quarantine. Dead plants take no
    /// further part in any phase; their lanes stay in the arena, where
    /// elementwise ops and per-range reductions confine them
    /// (`plant::soa::tests::poison_is_confined_to_its_range`).
    alive: Vec<bool>,
    /// Plants evicted so far, in eviction order.
    quarantined: Vec<QuarantineEntry>,
    /// Wall-clock spent in the arena physics (substeps + epilogue),
    /// the lockstep analogue of `RunResult::plant_wall_s`.
    plant_wall_s: f64,
    /// Span label for the arena physics window, carrying the shard
    /// index (`megabatch_sweep/shard=K`) — see `set_shard`.
    sweep_label: std::sync::Arc<str>,
}

impl LockstepFleet {
    /// Build the arena over a bucket of constructed plants.
    ///
    /// `Err` hands the contexts back untouched when the bucket is not
    /// lockstep-eligible — any non-native backend, a non-SoA kernel, or
    /// plants that disagree on plant constants / substep count / tick
    /// length / tick count (scenarios never produce that, but a TOML
    /// base config pinning `backend = "hlo"` or `kernel = "reference"`
    /// legitimately does). The caller falls back to the per-plant path,
    /// which is bitwise identical anyway.
    pub fn new(mut ctxs: Vec<PlantCtx>)
               -> std::result::Result<LockstepFleet, Vec<PlantCtx>> {
        if ctxs.is_empty() {
            return Err(ctxs);
        }
        let eligible = |ctx: &PlantCtx| -> bool {
            ctx.driver
                .backend
                .native()
                .is_some_and(|np| np.kernel == PlantKernel::Soa)
        };
        if !ctxs.iter().all(eligible) {
            return Err(ctxs);
        }
        let (pp, substeps) = {
            let np = ctxs[0].driver.backend.native().expect("checked");
            (np.pp.clone(), np.substeps)
        };
        let tick_s = ctxs[0].tick_s;
        let ticks_of = |ctx: &PlantCtx| -> u64 {
            (ctx.driver.cfg.duration_s / ctx.tick_s).ceil() as u64
        };
        let ticks_total = ticks_of(&ctxs[0]);
        let uniform = ctxs.iter().all(|ctx| {
            let np = ctx.driver.backend.native().expect("checked");
            np.pp == pp
                && np.substeps == substeps
                && ctx.tick_s == tick_s
                && ticks_of(ctx) == ticks_total
        });
        if !uniform {
            return Err(ctxs);
        }

        // One contiguous arena over every plant's statics, in plant
        // order (identical ops: Operators::build is a pure function of
        // the shared plant constants).
        let (mut soa, ranges) = {
            let statics: Vec<&crate::plant::PlantStatic> = ctxs
                .iter()
                .map(|c| &c.driver.backend.native().expect("checked").st)
                .collect();
            let ops = &ctxs[0].driver.backend.native().expect("checked").ops;
            SoaState::new_arena(&statics, ops, &pp)
        };
        let inv_c_w = ctxs[0]
            .driver
            .backend
            .native()
            .expect("checked")
            .ops
            .inv_c[IDX_WATER];
        // Warm-up load: each plant's node-major state enters its lane
        // slice once; the lanes are resident for the rest of the run.
        for (ctx, r) in ctxs.iter_mut().zip(&ranges) {
            let np = ctx.driver.backend.native_mut().expect("checked");
            soa.load_state_range(np.node_state(), *r);
        }

        let n = ctxs.len();
        let outs = ctxs
            .iter()
            .map(|c| TickOutput::new(c.driver.backend.n_padded()))
            .collect();
        Ok(LockstepFleet {
            soa,
            ranges,
            outs,
            ctrl: vec![[0.0; CT]; n],
            last_flow: vec![None; n],
            sums: vec![(0.0, 0.0); n],
            traces: vec![Vec::new(); n],
            energies: (0..n).map(|_| EnergyAccount::new()).collect(),
            pp,
            inv_c_w,
            substeps,
            tick_s,
            ticks_total,
            ticks_done: 0,
            alive: vec![true; n],
            quarantined: Vec::new(),
            plant_wall_s: 0.0,
            sweep_label: std::sync::Arc::from("megabatch_sweep/shard=0"),
            ctxs,
        })
    }

    /// Tag this arena's trace spans with its shard index. Purely an
    /// observability label; never enters results.
    pub fn set_shard(&mut self, shard: usize) {
        self.sweep_label =
            std::sync::Arc::from(format!("megabatch_sweep/shard={shard}").as_str());
    }

    /// Number of plants in the arena.
    pub fn len(&self) -> usize {
        self.ctxs.len()
    }

    /// Drop the per-plant trace history accumulated so far. Bench
    /// harnesses price `tick()` in a loop without ever building
    /// `PlantRun`s; clearing between iterations (capacity is kept, so
    /// no reallocation re-enters the timed window) bounds their memory.
    /// Not meaningful around `run`, which needs the full history.
    pub fn discard_history(&mut self) {
        for trace in &mut self.traces {
            trace.clear();
        }
    }

    pub fn is_empty(&self) -> bool {
        self.ctxs.is_empty()
    }

    /// Advance every plant by one tick, in lockstep. Mirrors
    /// `SimulationDriver::step` phase for phase; the plant physics of
    /// all plants runs as one arena sweep per substep.
    pub fn tick(&mut self) {
        let tick_s = self.tick_s;
        // Phase 1 (per plant, plant order): workload + control — the
        // coordinator-side work SimulationDriver::step also excludes
        // from its plant_wall_s. Each plant's control phase is its own
        // fault domain: a panic (organic or chaos-injected) evicts that
        // plant only. The chaos `plant_tick` site fires here, mirroring
        // the sequential path's hook in SimulationDriver::step.
        for p in 0..self.ctxs.len() {
            if !self.alive[p] {
                continue;
            }
            let r = self.ranges[p];
            let (ctxs, outs, soa) =
                (&mut self.ctxs, &self.outs, &mut self.soa);
            let res = catch_unwind(AssertUnwindSafe(|| {
                if inject::armed() {
                    let ctx = &mut ctxs[p];
                    if let Some(Action::PoisonNan) =
                        inject::fire(Site::PlantTick, ctx.driver.chaos_plant)
                    {
                        soa.poison_state_range(r);
                        ctx.driver
                            .backend
                            .native_mut()
                            .expect("lockstep plant")
                            .circuit_state
                            .fill(f32::NAN);
                    }
                }
                ctxs[p].driver.control_phase(tick_s, &outs[p]);
            }));
            match res {
                Ok(()) => self.ctrl[p]
                    .copy_from_slice(self.ctxs[p].driver.controls()),
                Err(_) => self.quarantine(p, "panic in control phase"),
            }
        }
        // Whole-sweep chaos site: a panic here unwinds out of tick()
        // and the fleet driver quarantines the entire bucket (shard
        // containment, not plant containment).
        if inject::armed() {
            inject::fire(Site::MegabatchSweep, None);
        }
        // Everything from here through the observe epilogue is the
        // lockstep analogue of `backend.tick`, which the sequential
        // path's plant_wall_s times — including the per-tick
        // utilization transpose-in and the flow-cached advection
        // rescale, so the two execution modes report comparable plant
        // wall clocks.
        let t0 = Instant::now();
        let _sweep_span = crate::obs::span_dyn(&self.sweep_label);
        for (p, ctx) in self.ctxs.iter().enumerate() {
            if !self.alive[p] {
                continue;
            }
            let r = self.ranges[p];
            self.soa.load_util_range(&ctx.driver.plan.util, r);
            // Shared definition with NativePlant::tick — the bitwise
            // contract needs both paths to derive the flow identically.
            let flow = crate::plant::native::effective_flow(&self.ctrl[p]);
            if self.last_flow[p] != Some(flow) {
                self.soa.set_flow_range(flow, r);
                self.last_flow[p] = Some(flow);
            }
        }
        // Phase 2: K fused substeps, one contiguous sweep each. The
        // inlet forcing and the circuit step stay per plant (each plant
        // owns its circuit state), exactly as NativePlant::tick orders
        // them. The sweep still covers dead plants' ranges (skipping
        // them would change nothing for survivors and cost a ranges
        // rebuild); their reductions are simply discarded. The numeric
        // integrity guard promotes a freshly non-finite reduction to
        // quarantine on the spot.
        let _substep_span = crate::obs::span("soa_substep");
        for _ in 0..self.substeps {
            for (p, ctx) in self.ctxs.iter().enumerate() {
                if !self.alive[p] {
                    continue;
                }
                let t_in = ctx.driver.backend.circuit_state()[C_T_RACK_IN];
                self.soa.set_inlet_range(t_in, self.inv_c_w, self.ranges[p]);
            }
            soa::soa_substep_ranges(&mut self.soa, &self.pp, &self.ranges,
                                    &mut self.sums);
            for p in 0..self.ctxs.len() {
                if !self.alive[p] {
                    continue;
                }
                let (p_dc, t_out_sum) = self.sums[p];
                if !p_dc.is_finite() || !t_out_sum.is_finite() {
                    self.quarantine(p, "non-finite substep reduction");
                    continue;
                }
                let r = self.ranges[p];
                let t_out_raw = t_out_sum / r.n_valid as f32;
                let ctrl = self.ctrl[p];
                let np = self.ctxs[p]
                    .driver
                    .backend
                    .native_mut()
                    .expect("lockstep plant");
                circuits::circuit_substep(&mut np.circuit_state, &ctrl,
                                          t_out_raw, p_dc, r.n_valid,
                                          &self.pp);
            }
        }
        drop(_substep_span);
        // Phase 3 (per plant): fused observe epilogue from the resident
        // lanes + the scalar block — still plant physics, so it stays
        // inside the plant_wall_s window.
        let obs_span = crate::obs::span("observe");
        for p in 0..self.ctxs.len() {
            if !self.alive[p] {
                continue;
            }
            let r = self.ranges[p];
            let (p_dc, throttling, core_max) = soa::soa_observe_range(
                &mut self.soa, &self.pp, r, &mut self.outs[p].node_obs);
            if !p_dc.is_finite() || !throttling.is_finite()
                || !core_max.is_finite()
            {
                self.quarantine(p, "non-finite observation");
                continue;
            }
            let ctrl = self.ctrl[p];
            let np = self.ctxs[p]
                .driver
                .backend
                .native_mut()
                .expect("lockstep plant");
            np.fill_scalars(&ctrl, p_dc, throttling, core_max,
                            &mut self.outs[p]);
        }
        drop(obs_span);
        drop(_sweep_span);
        self.plant_wall_s += t0.elapsed().as_secs_f64();
        // Phase 4 (per plant): telemetry sample + accounting — the
        // coordinator-side work SimulationDriver::step also excludes
        // from its plant_wall_s.
        for (p, ctx) in self.ctxs.iter_mut().enumerate() {
            if !self.alive[p] {
                continue;
            }
            let sample = ctx.driver.sample_phase(tick_s, &self.outs[p]);
            self.energies[p].push(&self.outs[p].scalars, tick_s);
            self.traces[p].push(sample);
        }
        self.ticks_done += 1;
    }

    /// Evict plant `p` from the arena: it takes no further part in any
    /// phase, its partial trace is dropped at run end, and its fleet
    /// index lands in the quarantine report.
    fn quarantine(&mut self, p: usize, reason: &str) {
        self.alive[p] = false;
        note_quarantine(&mut self.quarantined, self.ctxs[p].index, reason);
    }

    /// Run the configured duration. With `facility` set (the shard
    /// covers the whole fleet, i.e. a 1-shard run), the shared facility
    /// loop is fed per tick from the freshly sampled traces — same
    /// inputs in the same plant order as the post-hoc replay
    /// (`fleet::run_facility`), so the report is bitwise identical.
    ///
    /// Quarantined plants are dropped from the returned runs and listed
    /// in the third tuple element. The first quarantine also drops the
    /// streamed facility model (its integral consumed the dead plant's
    /// earlier ticks): the report comes back `None` and the fleet
    /// driver replays the facility pass over the survivors post hoc —
    /// so survivors match a fault-free run of the same spec subset.
    pub fn run(self, facility: Option<FacilityModel>)
               -> Result<(Vec<PlantRun>, Option<FacilityReport>,
                          Vec<QuarantineEntry>)> {
        self.run_with(facility, 0, |_, _| Ok(()))
    }

    /// `run`, invoking `save` every `checkpoint_every` ticks (0 = never)
    /// with the engine and the streamed facility model — the fleet
    /// driver's checkpoint hook. The callback runs *between* ticks, so
    /// a snapshot taken there resumes bitwise-identically.
    pub fn run_with(
        mut self,
        mut facility: Option<FacilityModel>,
        checkpoint_every: u64,
        mut save: impl FnMut(&mut LockstepFleet, Option<&FacilityModel>)
                             -> Result<()>,
    ) -> Result<(Vec<PlantRun>, Option<FacilityReport>,
                 Vec<QuarantineEntry>)> {
        let start = Instant::now();
        let mut inputs = Vec::with_capacity(self.ctxs.len());
        // Ticks already advanced through `tick()` (e.g. by a bench
        // harness or a checkpoint restore) count toward the configured
        // duration.
        while self.ticks_done < self.ticks_total {
            self.tick();
            // A quarantine invalidates the streamed facility integral;
            // the caller recomputes it over the survivors post hoc.
            if !self.quarantined.is_empty() {
                facility = None;
            }
            if let Some(model) = facility.as_mut() {
                let _span = crate::obs::span("facility");
                inputs.clear();
                for trace in &self.traces {
                    let s = trace.last().expect("tick just pushed a sample");
                    inputs.push(plant_tick_of(s));
                }
                model.pool_tick(&inputs, self.tick_s);
            }
            if checkpoint_every > 0
                && self.ticks_done % checkpoint_every == 0
                && self.ticks_done < self.ticks_total
            {
                save(&mut self, facility.as_ref())?;
            }
        }
        let total_wall_s = start.elapsed().as_secs_f64();
        let report = facility.map(FacilityModel::into_report);

        // Hand each surviving plant its final arena slice back: the
        // lockstep run drove the shared arena, so the drivers' own
        // node-major buffers still hold the warm-up fill — one
        // transpose per plant at run end keeps any later consumer of a
        // driver honest. Dead plants' (possibly NaN) slices stay in the
        // arena.
        let mut node_scratch = Vec::new();
        for (p, ctx) in self.ctxs.iter_mut().enumerate() {
            if !self.alive[p] {
                continue;
            }
            let r = self.ranges[p];
            node_scratch.resize(r.npad * S, 0.0);
            self.soa.materialize_range(r, &mut node_scratch);
            ctx.driver
                .backend
                .native_mut()
                .expect("lockstep plant")
                .adopt_node_state(&node_scratch);
        }

        let LockstepFleet {
            ctxs, traces, energies, ticks_total, plant_wall_s, alive,
            quarantined, ..
        } = self;
        let mut plants = Vec::with_capacity(ctxs.len());
        for (p, ((ctx, trace), energy)) in
            ctxs.into_iter().zip(traces).zip(energies).enumerate()
        {
            if !alive[p] {
                continue;
            }
            let PlantCtx { index, label, seed, tick_s, mut driver } = ctx;
            let result = RunResult {
                trace,
                energy,
                events: std::mem::take(&mut driver.supervisor.events),
                workload_stats: driver.workload.stats(),
                backend: driver.backend.kind_name(),
                // Wall clocks are shared across the lockstep bucket
                // (the plants ran together); they never enter result
                // documents.
                plant_wall_s,
                total_wall_s,
                ticks: ticks_total,
            };
            plants.push(PlantRun { index, label, seed, tick_s, result });
        }
        Ok((plants, report, quarantined))
    }

    /// Serialize the arena's full cross-tick state — per plant: the
    /// node-major thermal state, circuit state, previous tick's scalar
    /// block, coordinator state (`SimulationDriver::save_state`), energy
    /// integrals and the trace so far — plus the tick cursor and the
    /// quarantine list. Field order is the `idatacool-ckpt/1` contract
    /// (DESIGN.md §8). The fleet driver prepends a config-identity
    /// header before handing the bytes to `checkpoint::atomic_write`.
    pub fn save_state(&self, w: &mut SnapWriter) {
        w.u64(self.ticks_done);
        w.u64(self.ticks_total);
        w.u64(self.ctxs.len() as u64);
        let mut node_scratch = Vec::new();
        for p in 0..self.ctxs.len() {
            w.bool(self.alive[p]);
            let r = self.ranges[p];
            node_scratch.resize(r.npad * S, 0.0);
            self.soa.materialize_range(r, &mut node_scratch);
            w.f32s(&node_scratch);
            let np =
                self.ctxs[p].driver.backend.native().expect("lockstep plant");
            w.f32s(&np.circuit_state);
            w.f32s(&self.outs[p].scalars);
            self.ctxs[p].driver.save_state(w);
            self.energies[p].save(w);
            w.u64(self.traces[p].len() as u64);
            for s in &self.traces[p] {
                s.save(w);
            }
        }
        w.u64(self.quarantined.len() as u64);
        for q in &self.quarantined {
            w.u64(q.index as u64);
            w.str(&q.reason);
        }
    }

    /// Restore state written by [`LockstepFleet::save_state`] onto an
    /// engine freshly built from the same specs. `last_flow` stays
    /// `None` on purpose: the first resumed tick re-derives the flow
    /// and rewrites bitwise-identical `g_eff` lanes.
    pub fn restore_state(&mut self, r: &mut SnapReader) -> Result<()> {
        self.ticks_done = r.u64()?;
        let total = r.u64()?;
        if total != self.ticks_total {
            bail!("checkpoint spans {total} ticks, run configures {}",
                  self.ticks_total);
        }
        let n = r.usize()?;
        if n != self.ctxs.len() {
            bail!("checkpoint has {n} plants, fleet has {}",
                  self.ctxs.len());
        }
        for p in 0..n {
            self.alive[p] = r.bool()?;
            let range = self.ranges[p];
            let node = r.f32s()?;
            if node.len() != range.npad * S {
                bail!("plant {p}: checkpointed node state has {} entries, \
                       expected {}", node.len(), range.npad * S);
            }
            self.soa.load_state_range(&node, range);
            let circ = r.f32s()?;
            {
                let np = self.ctxs[p]
                    .driver
                    .backend
                    .native_mut()
                    .expect("lockstep plant");
                if circ.len() != np.circuit_state.len() {
                    bail!("plant {p}: checkpointed circuit state has {} \
                           entries", circ.len());
                }
                np.circuit_state.copy_from_slice(&circ);
            }
            let scalars = r.f32s()?;
            if scalars.len() != NS {
                bail!("plant {p}: checkpointed scalar block has {} entries",
                      scalars.len());
            }
            self.outs[p].scalars.copy_from_slice(&scalars);
            self.ctxs[p].driver.restore_state(r)?;
            self.energies[p] = EnergyAccount::load(r)?;
            let n_samples = r.usize()?;
            self.traces[p].clear();
            for _ in 0..n_samples {
                self.traces[p].push(TraceSample::load(r)?);
            }
        }
        self.quarantined.clear();
        for _ in 0..r.usize()? {
            let index = r.usize()?;
            let reason = r.str()?;
            self.quarantined.push(QuarantineEntry { index, reason });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::fleet::scenario::Scenario;
    use crate::fleet::plant_seed;

    fn specs(n_plants: usize, scenario: &str, base: &SimConfig)
             -> Vec<PlantSpec> {
        let s = Scenario::by_name(scenario).unwrap();
        (0..n_plants)
            .map(|i| s.plant_spec(i, n_plants, base,
                                  plant_seed(base.seed, i)))
            .collect()
    }

    fn small_base() -> SimConfig {
        let mut c = SimConfig::test_small();
        c.duration_s = 60.0;
        c
    }

    #[test]
    fn lockstep_matches_sequential_bitwise() {
        // Bitwise comparisons must not race a concurrently armed chaos
        // plan from another test in this binary.
        let _guard = inject::test_lock();
        let base = small_base();
        let ctxs = build_ctxs(specs(3, "mixed", &base)).unwrap();
        let ls = LockstepFleet::new(ctxs).ok().expect("eligible bucket");
        assert_eq!(ls.len(), 3);
        let (a, report, q) = ls.run(None).unwrap();
        assert!(report.is_none());
        assert!(q.is_empty());
        let (b, qb) = run_ctxs_sequential(
            build_ctxs(specs(3, "mixed", &base)).unwrap()).unwrap();
        assert!(qb.is_empty());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.index, y.index);
            assert_eq!(x.result.ticks, y.result.ticks);
            assert_eq!(x.result.trace.len(), y.result.trace.len());
            for (s, t) in x.result.trace.iter().zip(&y.result.trace) {
                assert_eq!(s.t_rack_out.to_bits(), t.t_rack_out.to_bits());
                assert_eq!(s.p_d.to_bits(), t.p_d.to_bits());
                assert_eq!(s.p_ac.to_bits(), t.p_ac.to_bits());
                assert_eq!(s.core_max.to_bits(), t.core_max.to_bits());
                assert_eq!(s.throttling, t.throttling);
            }
            assert_eq!(x.result.energy.e_ac.to_bits(),
                       y.result.energy.e_ac.to_bits());
            assert_eq!(x.result.energy.e_drive.to_bits(),
                       y.result.energy.e_drive.to_bits());
        }
    }

    #[test]
    fn precheck_follows_backend_and_kernel() {
        // test_small pins the native backend; kernel "auto" resolves
        // through the env, so only assert the positive case when the
        // env leaves the SoA default in place.
        if std::env::var_os("IDATACOOL_KERNEL").is_none() {
            assert!(precheck(&small_base()));
        }
        let mut b = small_base();
        b.kernel = "reference".into();
        assert!(!precheck(&b));
        let mut b = small_base();
        b.backend = "hlo".into();
        assert!(!precheck(&b));
    }

    #[test]
    fn non_soa_bucket_is_handed_back() {
        let _guard = inject::test_lock();
        let mut base = small_base();
        base.kernel = "reference".into();
        let ctxs = build_ctxs(specs(2, "baseline", &base)).unwrap();
        let back = match LockstepFleet::new(ctxs) {
            Err(back) => back,
            Ok(_) => panic!("reference-kernel bucket must not lockstep"),
        };
        assert_eq!(back.len(), 2);
        // the handed-back contexts still run fine sequentially
        let (runs, q) = run_ctxs_sequential(back).unwrap();
        assert_eq!(runs.len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn quarantined_plant_is_evicted_and_survivors_match() {
        let _guard = inject::test_lock();
        let base = small_base();
        // Poison plant 1's lanes on tick 3; plants 0 and 2 must finish
        // and match a chaos-free run of just those two specs bitwise.
        inject::arm("site=plant_tick,kind=poison_nan,plant=1,tick=3", 0)
            .unwrap();
        let ctxs = build_ctxs(specs(3, "mixed", &base)).unwrap();
        let ls = LockstepFleet::new(ctxs).ok().expect("eligible bucket");
        let out = ls.run(None);
        inject::disarm();
        let (runs, report, q) = out.unwrap();
        assert!(report.is_none());
        assert_eq!(q.len(), 1, "exactly one plant quarantined: {q:?}");
        assert_eq!(q[0].index, 1);
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].index, 0);
        assert_eq!(runs[1].index, 2);

        // Fault-free reference over the surviving specs only.
        let survivors: Vec<PlantSpec> = specs(3, "mixed", &base)
            .into_iter()
            .filter(|s| s.index != 1)
            .collect();
        let (clean, qc) =
            run_ctxs_sequential(build_ctxs(survivors).unwrap()).unwrap();
        assert!(qc.is_empty());
        assert_eq!(clean.len(), 2);
        for (x, y) in runs.iter().zip(&clean) {
            assert_eq!(x.index, y.index);
            assert_eq!(x.result.trace.len(), y.result.trace.len());
            for (s, t) in x.result.trace.iter().zip(&y.result.trace) {
                assert_eq!(s.t_rack_out.to_bits(), t.t_rack_out.to_bits());
                assert_eq!(s.p_ac.to_bits(), t.p_ac.to_bits());
                assert_eq!(s.core_max.to_bits(), t.core_max.to_bits());
            }
            assert_eq!(x.result.energy.e_ac.to_bits(),
                       y.result.energy.e_ac.to_bits());
        }
    }

    #[test]
    fn lockstep_checkpoint_resumes_bitwise() {
        let _guard = inject::test_lock();
        let base = small_base();
        // Uninterrupted reference run.
        let ls = LockstepFleet::new(
            build_ctxs(specs(3, "mixed", &base)).unwrap())
            .ok().expect("eligible bucket");
        let (full, _, q) = ls.run(None).unwrap();
        assert!(q.is_empty());

        // Interrupted run: advance 5 ticks, snapshot, throw the engine
        // away, restore into a fresh one, finish.
        let mut first = LockstepFleet::new(
            build_ctxs(specs(3, "mixed", &base)).unwrap())
            .ok().expect("eligible bucket");
        for _ in 0..5 {
            first.tick();
        }
        let mut w = SnapWriter::new();
        first.save_state(&mut w);
        let bytes = w.into_bytes();
        drop(first);

        let mut resumed = LockstepFleet::new(
            build_ctxs(specs(3, "mixed", &base)).unwrap())
            .ok().expect("eligible bucket");
        let mut r = SnapReader::new(&bytes).unwrap();
        resumed.restore_state(&mut r).unwrap();
        assert!(r.done(), "snapshot fully consumed");
        let (cont, _, qc) = resumed.run(None).unwrap();
        assert!(qc.is_empty());

        assert_eq!(full.len(), cont.len());
        for (x, y) in full.iter().zip(&cont) {
            assert_eq!(x.result.trace.len(), y.result.trace.len());
            for (s, t) in x.result.trace.iter().zip(&y.result.trace) {
                assert_eq!(s.t_rack_out.to_bits(), t.t_rack_out.to_bits());
                assert_eq!(s.t_rack_in.to_bits(), t.t_rack_in.to_bits());
                assert_eq!(s.p_d.to_bits(), t.p_d.to_bits());
                assert_eq!(s.p_ac.to_bits(), t.p_ac.to_bits());
                assert_eq!(s.core_max.to_bits(), t.core_max.to_bits());
                assert_eq!(s.valve.to_bits(), t.valve.to_bits());
                assert_eq!(s.utilization.to_bits(), t.utilization.to_bits());
            }
            assert_eq!(x.result.energy.e_ac.to_bits(),
                       y.result.energy.e_ac.to_bits());
            assert_eq!(x.result.energy.e_chilled.to_bits(),
                       y.result.energy.e_chilled.to_bits());
        }
    }
}
