//! Fleet megabatch: tick-lockstep execution of a shard's plants over
//! one shared SoA lane arena.
//!
//! The per-plant path (`run_bucket` with megabatch off) runs each plant
//! to completion as its own kernel instance — N small working sets, N
//! sets of loop/dispatch overhead per tick. The megabatch path packs
//! every plant assigned to a shard into one `[slot][n_total]` lane
//! arena (`SoaState::new_arena`; per-plant `LaneRange`s, tile-padded so
//! each starts on a vector-width boundary) and advances all of them in
//! tick lockstep: per substep, one `soa_substep_ranges` sweep over the
//! whole contiguous working set replaces N kernel calls — amortizing
//! dispatch, keeping small plants' lanes hot in cache, and letting a
//! single-shard fleet feed the shared facility loop **per tick** instead
//! of replaying traces post-hoc.
//!
//! Determinism: the engine reproduces `SimulationDriver::step` exactly —
//! `control_phase` → plant physics → `sample_phase` per plant, in plant
//! order — and the arena kernel is bitwise identical to per-plant SoA
//! substeps (elementwise lane ops plus per-range reductions in node
//! order; see `plant::soa`). A K-shard megabatch run therefore produces
//! byte-identical `idatacool-fleet/1` output to the 1-shard, megabatch-
//! off reference (`tests/fleet_integration.rs` gates it).

use std::time::Instant;

use anyhow::Result;

use crate::config::constants::PlantParams;
use crate::coordinator::energy::EnergyAccount;
use crate::coordinator::{RunResult, SimulationDriver, TraceSample};
use crate::plant::circuits;
use crate::plant::layout::*;
use crate::plant::soa::{self, SoaState};
use crate::plant::{PlantKernel, TickOutput};

use super::facility::{FacilityModel, FacilityReport};
use super::scenario::PlantSpec;
use super::{plant_tick_of, PlantRun};

/// One plant's identity plus its ready-to-run driver (the unit the
/// lockstep engine and the sequential fallback share).
pub struct PlantCtx {
    pub index: usize,
    pub label: String,
    pub seed: u64,
    pub tick_s: f64,
    pub driver: SimulationDriver,
}

/// Config-level lockstep eligibility, checkable **before** any driver
/// exists: the base must resolve to the native backend with the SoA
/// kernel. Callers use it to decide whether to construct a whole
/// bucket's drivers up front for the arena (`build_ctxs` +
/// `LockstepFleet::new`) or to keep the per-plant one-driver-at-a-time
/// memory profile — a fleet with `kernel = "reference"` or a pinned
/// `hlo` backend must not pay an all-drivers-resident peak just to
/// discover it cannot lockstep. `LockstepFleet::new`'s deep per-plant
/// check remains the authority; this is the cheap gate in front of it.
pub fn precheck(base: &crate::config::SimConfig) -> bool {
    use crate::runtime::BackendKind;
    // `auto` resolves by artifact presence through the same shared rule
    // PlantBackend::create_with_kernel applies.
    let native = base
        .backend
        .parse::<BackendKind>()
        .is_ok_and(|k| {
            k.resolve_auto(&base.artifacts_dir) == BackendKind::Native
        });
    native
        && PlantKernel::resolve(&base.kernel)
            .is_ok_and(|k| k == PlantKernel::Soa)
}

/// Construct the drivers for a bucket of plant specs, in spec order.
pub fn build_ctxs(bucket: Vec<PlantSpec>) -> Result<Vec<PlantCtx>> {
    let mut ctxs = Vec::with_capacity(bucket.len());
    for spec in bucket {
        let PlantSpec { index, label, seed, cfg, faults } = spec;
        let driver = SimulationDriver::from_prebuilt(cfg, seed, faults)?;
        let tick_s = driver.backend.tick_seconds(&driver.cfg.pp);
        ctxs.push(PlantCtx { index, label, seed, tick_s, driver });
    }
    Ok(ctxs)
}

/// Run a bucket the per-plant way (each plant's driver owns its full
/// tick loop) — the megabatch-off path and the lockstep fallback.
pub fn run_ctxs_sequential(ctxs: Vec<PlantCtx>) -> Result<Vec<PlantRun>> {
    let mut out = Vec::with_capacity(ctxs.len());
    for ctx in ctxs {
        let PlantCtx { index, label, seed, tick_s, mut driver } = ctx;
        // sample_every = 1: the facility pass needs every tick.
        let result = driver.run(1)?;
        out.push(PlantRun { index, label, seed, tick_s, result });
    }
    Ok(out)
}

/// The lockstep engine: a shard's plants resident in one lane arena.
pub struct LockstepFleet {
    ctxs: Vec<PlantCtx>,
    soa: SoaState,
    ranges: Vec<LaneRange>,
    outs: Vec<TickOutput>,
    ctrl: Vec<[f32; CT]>,
    last_flow: Vec<Option<f32>>,
    sums: Vec<(f64, f32)>,
    traces: Vec<Vec<TraceSample>>,
    energies: Vec<EnergyAccount>,
    pp: PlantParams,
    inv_c_w: f32,
    substeps: usize,
    tick_s: f64,
    ticks_total: u64,
    ticks_done: u64,
    /// Wall-clock spent in the arena physics (substeps + epilogue),
    /// the lockstep analogue of `RunResult::plant_wall_s`.
    plant_wall_s: f64,
    /// Span label for the arena physics window, carrying the shard
    /// index (`megabatch_sweep/shard=K`) — see `set_shard`.
    sweep_label: std::sync::Arc<str>,
}

impl LockstepFleet {
    /// Build the arena over a bucket of constructed plants.
    ///
    /// `Err` hands the contexts back untouched when the bucket is not
    /// lockstep-eligible — any non-native backend, a non-SoA kernel, or
    /// plants that disagree on plant constants / substep count / tick
    /// length / tick count (scenarios never produce that, but a TOML
    /// base config pinning `backend = "hlo"` or `kernel = "reference"`
    /// legitimately does). The caller falls back to the per-plant path,
    /// which is bitwise identical anyway.
    pub fn new(mut ctxs: Vec<PlantCtx>)
               -> std::result::Result<LockstepFleet, Vec<PlantCtx>> {
        if ctxs.is_empty() {
            return Err(ctxs);
        }
        let eligible = |ctx: &PlantCtx| -> bool {
            ctx.driver
                .backend
                .native()
                .is_some_and(|np| np.kernel == PlantKernel::Soa)
        };
        if !ctxs.iter().all(eligible) {
            return Err(ctxs);
        }
        let (pp, substeps) = {
            let np = ctxs[0].driver.backend.native().expect("checked");
            (np.pp.clone(), np.substeps)
        };
        let tick_s = ctxs[0].tick_s;
        let ticks_of = |ctx: &PlantCtx| -> u64 {
            (ctx.driver.cfg.duration_s / ctx.tick_s).ceil() as u64
        };
        let ticks_total = ticks_of(&ctxs[0]);
        let uniform = ctxs.iter().all(|ctx| {
            let np = ctx.driver.backend.native().expect("checked");
            np.pp == pp
                && np.substeps == substeps
                && ctx.tick_s == tick_s
                && ticks_of(ctx) == ticks_total
        });
        if !uniform {
            return Err(ctxs);
        }

        // One contiguous arena over every plant's statics, in plant
        // order (identical ops: Operators::build is a pure function of
        // the shared plant constants).
        let (mut soa, ranges) = {
            let statics: Vec<&crate::plant::PlantStatic> = ctxs
                .iter()
                .map(|c| &c.driver.backend.native().expect("checked").st)
                .collect();
            let ops = &ctxs[0].driver.backend.native().expect("checked").ops;
            SoaState::new_arena(&statics, ops, &pp)
        };
        let inv_c_w = ctxs[0]
            .driver
            .backend
            .native()
            .expect("checked")
            .ops
            .inv_c[IDX_WATER];
        // Warm-up load: each plant's node-major state enters its lane
        // slice once; the lanes are resident for the rest of the run.
        for (ctx, r) in ctxs.iter_mut().zip(&ranges) {
            let np = ctx.driver.backend.native_mut().expect("checked");
            soa.load_state_range(np.node_state(), *r);
        }

        let n = ctxs.len();
        let outs = ctxs
            .iter()
            .map(|c| TickOutput::new(c.driver.backend.n_padded()))
            .collect();
        Ok(LockstepFleet {
            soa,
            ranges,
            outs,
            ctrl: vec![[0.0; CT]; n],
            last_flow: vec![None; n],
            sums: vec![(0.0, 0.0); n],
            traces: vec![Vec::new(); n],
            energies: (0..n).map(|_| EnergyAccount::new()).collect(),
            pp,
            inv_c_w,
            substeps,
            tick_s,
            ticks_total,
            ticks_done: 0,
            plant_wall_s: 0.0,
            sweep_label: std::sync::Arc::from("megabatch_sweep/shard=0"),
            ctxs,
        })
    }

    /// Tag this arena's trace spans with its shard index. Purely an
    /// observability label; never enters results.
    pub fn set_shard(&mut self, shard: usize) {
        self.sweep_label =
            std::sync::Arc::from(format!("megabatch_sweep/shard={shard}").as_str());
    }

    /// Number of plants in the arena.
    pub fn len(&self) -> usize {
        self.ctxs.len()
    }

    /// Drop the per-plant trace history accumulated so far. Bench
    /// harnesses price `tick()` in a loop without ever building
    /// `PlantRun`s; clearing between iterations (capacity is kept, so
    /// no reallocation re-enters the timed window) bounds their memory.
    /// Not meaningful around `run`, which needs the full history.
    pub fn discard_history(&mut self) {
        for trace in &mut self.traces {
            trace.clear();
        }
    }

    pub fn is_empty(&self) -> bool {
        self.ctxs.is_empty()
    }

    /// Advance every plant by one tick, in lockstep. Mirrors
    /// `SimulationDriver::step` phase for phase; the plant physics of
    /// all plants runs as one arena sweep per substep.
    pub fn tick(&mut self) {
        let tick_s = self.tick_s;
        // Phase 1 (per plant, plant order): workload + control — the
        // coordinator-side work SimulationDriver::step also excludes
        // from its plant_wall_s.
        for (p, ctx) in self.ctxs.iter_mut().enumerate() {
            ctx.driver.control_phase(tick_s, &self.outs[p]);
            self.ctrl[p].copy_from_slice(ctx.driver.controls());
        }
        // Everything from here through the observe epilogue is the
        // lockstep analogue of `backend.tick`, which the sequential
        // path's plant_wall_s times — including the per-tick
        // utilization transpose-in and the flow-cached advection
        // rescale, so the two execution modes report comparable plant
        // wall clocks.
        let t0 = Instant::now();
        let _sweep_span = crate::obs::span_dyn(&self.sweep_label);
        for (p, ctx) in self.ctxs.iter().enumerate() {
            let r = self.ranges[p];
            self.soa.load_util_range(&ctx.driver.plan.util, r);
            // Shared definition with NativePlant::tick — the bitwise
            // contract needs both paths to derive the flow identically.
            let flow = crate::plant::native::effective_flow(&self.ctrl[p]);
            if self.last_flow[p] != Some(flow) {
                self.soa.set_flow_range(flow, r);
                self.last_flow[p] = Some(flow);
            }
        }
        // Phase 2: K fused substeps, one contiguous sweep each. The
        // inlet forcing and the circuit step stay per plant (each plant
        // owns its circuit state), exactly as NativePlant::tick orders
        // them.
        let _substep_span = crate::obs::span("soa_substep");
        for _ in 0..self.substeps {
            for (p, ctx) in self.ctxs.iter().enumerate() {
                let t_in = ctx.driver.backend.circuit_state()[C_T_RACK_IN];
                self.soa.set_inlet_range(t_in, self.inv_c_w, self.ranges[p]);
            }
            soa::soa_substep_ranges(&mut self.soa, &self.pp, &self.ranges,
                                    &mut self.sums);
            for (p, ctx) in self.ctxs.iter_mut().enumerate() {
                let (p_dc, t_out_sum) = self.sums[p];
                let r = self.ranges[p];
                let t_out_raw = t_out_sum / r.n_valid as f32;
                let np =
                    ctx.driver.backend.native_mut().expect("lockstep plant");
                circuits::circuit_substep(&mut np.circuit_state,
                                          &self.ctrl[p], t_out_raw, p_dc,
                                          r.n_valid, &self.pp);
            }
        }
        drop(_substep_span);
        // Phase 3 (per plant): fused observe epilogue from the resident
        // lanes + the scalar block — still plant physics, so it stays
        // inside the plant_wall_s window.
        let obs_span = crate::obs::span("observe");
        for (p, ctx) in self.ctxs.iter_mut().enumerate() {
            let r = self.ranges[p];
            let (p_dc, throttling, core_max) = soa::soa_observe_range(
                &mut self.soa, &self.pp, r, &mut self.outs[p].node_obs);
            let np = ctx.driver.backend.native_mut().expect("lockstep plant");
            np.fill_scalars(&self.ctrl[p], p_dc, throttling, core_max,
                            &mut self.outs[p]);
        }
        drop(obs_span);
        drop(_sweep_span);
        self.plant_wall_s += t0.elapsed().as_secs_f64();
        // Phase 4 (per plant): telemetry sample + accounting — the
        // coordinator-side work SimulationDriver::step also excludes
        // from its plant_wall_s.
        for (p, ctx) in self.ctxs.iter_mut().enumerate() {
            let sample = ctx.driver.sample_phase(tick_s, &self.outs[p]);
            self.energies[p].push(&self.outs[p].scalars, tick_s);
            self.traces[p].push(sample);
        }
        self.ticks_done += 1;
    }

    /// Run the configured duration. With `facility` set (the shard
    /// covers the whole fleet, i.e. a 1-shard run), the shared facility
    /// loop is fed per tick from the freshly sampled traces — same
    /// inputs in the same plant order as the post-hoc replay
    /// (`fleet::run_facility`), so the report is bitwise identical.
    pub fn run(mut self, mut facility: Option<FacilityModel>)
               -> Result<(Vec<PlantRun>, Option<FacilityReport>)> {
        let start = Instant::now();
        let mut inputs = Vec::with_capacity(self.ctxs.len());
        // Ticks already advanced through `tick()` (e.g. by a bench
        // harness) count toward the configured duration.
        while self.ticks_done < self.ticks_total {
            self.tick();
            if let Some(model) = facility.as_mut() {
                let _span = crate::obs::span("facility");
                inputs.clear();
                for trace in &self.traces {
                    let s = trace.last().expect("tick just pushed a sample");
                    inputs.push(plant_tick_of(s));
                }
                model.pool_tick(&inputs, self.tick_s);
            }
        }
        let total_wall_s = start.elapsed().as_secs_f64();
        let report = facility.map(FacilityModel::into_report);

        // Hand each plant its final arena slice back: the lockstep run
        // drove the shared arena, so the drivers' own node-major
        // buffers still hold the warm-up fill — one transpose per plant
        // at run end keeps any later consumer of a driver honest.
        let mut node_scratch = Vec::new();
        for (p, ctx) in self.ctxs.iter_mut().enumerate() {
            let r = self.ranges[p];
            node_scratch.resize(r.npad * S, 0.0);
            self.soa.materialize_range(r, &mut node_scratch);
            ctx.driver
                .backend
                .native_mut()
                .expect("lockstep plant")
                .adopt_node_state(&node_scratch);
        }

        let LockstepFleet {
            ctxs, traces, energies, ticks_total, plant_wall_s, ..
        } = self;
        let mut plants = Vec::with_capacity(ctxs.len());
        for ((ctx, trace), energy) in
            ctxs.into_iter().zip(traces).zip(energies)
        {
            let PlantCtx { index, label, seed, tick_s, mut driver } = ctx;
            let result = RunResult {
                trace,
                energy,
                events: std::mem::take(&mut driver.supervisor.events),
                workload_stats: driver.workload.stats(),
                backend: driver.backend.kind_name(),
                // Wall clocks are shared across the lockstep bucket
                // (the plants ran together); they never enter result
                // documents.
                plant_wall_s,
                total_wall_s,
                ticks: ticks_total,
            };
            plants.push(PlantRun { index, label, seed, tick_s, result });
        }
        Ok((plants, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::fleet::scenario::Scenario;
    use crate::fleet::plant_seed;

    fn specs(n_plants: usize, scenario: &str, base: &SimConfig)
             -> Vec<PlantSpec> {
        let s = Scenario::by_name(scenario).unwrap();
        (0..n_plants)
            .map(|i| s.plant_spec(i, n_plants, base,
                                  plant_seed(base.seed, i)))
            .collect()
    }

    fn small_base() -> SimConfig {
        let mut c = SimConfig::test_small();
        c.duration_s = 60.0;
        c
    }

    #[test]
    fn lockstep_matches_sequential_bitwise() {
        let base = small_base();
        let ctxs = build_ctxs(specs(3, "mixed", &base)).unwrap();
        let ls = LockstepFleet::new(ctxs).ok().expect("eligible bucket");
        assert_eq!(ls.len(), 3);
        let (a, report) = ls.run(None).unwrap();
        assert!(report.is_none());
        let b = run_ctxs_sequential(
            build_ctxs(specs(3, "mixed", &base)).unwrap()).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.index, y.index);
            assert_eq!(x.result.ticks, y.result.ticks);
            assert_eq!(x.result.trace.len(), y.result.trace.len());
            for (s, t) in x.result.trace.iter().zip(&y.result.trace) {
                assert_eq!(s.t_rack_out.to_bits(), t.t_rack_out.to_bits());
                assert_eq!(s.p_d.to_bits(), t.p_d.to_bits());
                assert_eq!(s.p_ac.to_bits(), t.p_ac.to_bits());
                assert_eq!(s.core_max.to_bits(), t.core_max.to_bits());
                assert_eq!(s.throttling, t.throttling);
            }
            assert_eq!(x.result.energy.e_ac.to_bits(),
                       y.result.energy.e_ac.to_bits());
            assert_eq!(x.result.energy.e_drive.to_bits(),
                       y.result.energy.e_drive.to_bits());
        }
    }

    #[test]
    fn precheck_follows_backend_and_kernel() {
        // test_small pins the native backend; kernel "auto" resolves
        // through the env, so only assert the positive case when the
        // env leaves the SoA default in place.
        if std::env::var_os("IDATACOOL_KERNEL").is_none() {
            assert!(precheck(&small_base()));
        }
        let mut b = small_base();
        b.kernel = "reference".into();
        assert!(!precheck(&b));
        let mut b = small_base();
        b.backend = "hlo".into();
        assert!(!precheck(&b));
    }

    #[test]
    fn non_soa_bucket_is_handed_back() {
        let mut base = small_base();
        base.kernel = "reference".into();
        let ctxs = build_ctxs(specs(2, "baseline", &base)).unwrap();
        let back = match LockstepFleet::new(ctxs) {
            Err(back) => back,
            Ok(_) => panic!("reference-kernel bucket must not lockstep"),
        };
        assert_eq!(back.len(), 2);
        // the handed-back contexts still run fine sequentially
        let runs = run_ctxs_sequential(back).unwrap();
        assert_eq!(runs.len(), 2);
    }
}
