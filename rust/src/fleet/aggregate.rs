//! Cross-plant aggregation: fleet PUE/ERE distributions, worst-case
//! throttling, and the facility energy-reuse headline, rendered through
//! the `report` substrate.
//!
//! Definitions (per plant, over the run's energy account):
//!  * PUE  = E_AC / E_DC — facility electrical input per unit of IT
//!    (DC-side) energy; >= 1, lower is better.
//!  * ERE  = (E_AC - E_credit) / E_DC — PUE with the facility-side
//!    cooling credit (this plant's share of the pooled chiller output)
//!    subtracted, the energy-reuse-effectiveness analogue.
//!
//! Every reduction iterates plants in index order with plain f64
//! arithmetic, so fleet aggregates are bitwise identical across shard
//! counts (the determinism acceptance gate).

use crate::report::Series;
use crate::stats::histogram::Histogram;
use crate::stats::Running;
use crate::util::json::{Json, JsonBuilder};

use super::facility::FacilityReport;
use super::{PlantRun, QuarantineEntry};

/// Per-plant derived metrics.
#[derive(Debug, Clone)]
pub struct PlantMetrics {
    pub index: usize,
    pub label: String,
    pub seed: u64,
    pub pue: f64,
    pub ere: f64,
    /// The plant's own chiller reuse fraction (E_c / E_AC).
    pub reuse_local: f64,
    /// Facility cooling credit per unit of electrical input.
    pub credit_frac: f64,
    /// Ticks with at least one core in the throttle band.
    pub throttle_ticks: u64,
    /// Ticks with the adsorption chiller off (outage windows included).
    pub chiller_off_ticks: u64,
    /// Ticks inside a supervisor pump-failure window.
    pub pump_fail_ticks: u64,
    pub t_out_mean: f64,
    pub mean_p_ac_w: f64,
}

/// Fleet-level aggregate: distributions + headline numbers.
#[derive(Debug, Clone)]
pub struct FleetAggregate {
    pub per_plant: Vec<PlantMetrics>,
    pub pue_stats: Running,
    pub ere_stats: Running,
    pub pue_hist: Histogram,
    pub ere_hist: Histogram,
    /// Chilled water delivered by the shared facility per unit of fleet
    /// electrical input — the fleet's headline reuse number.
    pub facility_reuse_fraction: f64,
    pub worst_throttle_plant: Option<usize>,
    pub worst_throttle_ticks: u64,
    /// Fleet-wide domain-event totals (sums of the per-plant tick
    /// counts) — deterministic, derived from sim state, never wall-clock.
    pub fleet_throttle_ticks: u64,
    pub fleet_chiller_off_ticks: u64,
    pub fleet_pump_fail_ticks: u64,
    pub fleet_e_ac: f64,
    pub fleet_e_dc: f64,
    /// Total trace samples across the surviving plants — the
    /// denominator of the fleet throttle fraction in [`Self::objective`].
    /// Derived bookkeeping: deliberately absent from the JSON document
    /// and the fingerprint (it adds no information beyond the per-plant
    /// traces, and the fleet document's bytes predate it).
    pub fleet_trace_ticks: u64,
    /// Plants evicted by fault containment, in index order. A non-empty
    /// list marks the document as a degraded run: the per-plant metrics
    /// above cover the survivors only, and the entries are mixed into
    /// the fingerprint so a degraded fingerprint can never collide with
    /// the clean run's.
    pub quarantined: Vec<QuarantineEntry>,
}

fn safe_div(a: f64, b: f64) -> f64 {
    if b.abs() < 1e-9 {
        0.0
    } else {
        a / b
    }
}

/// Weights for the scalar fleet objective ([`FleetAggregate::objective`]).
/// Lower is better for every term (PUE and ERE are >= "ideal 1.0 minus
/// credit" scales, throttle is a fraction), so the weighted sum is a
/// *minimization* objective — the convention the `optimize` subsystem
/// inherits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ObjectiveWeights {
    /// Weight on the fleet-mean PUE.
    pub pue: f64,
    /// Weight on the fleet-mean ERE.
    pub ere: f64,
    /// Weight on the fleet throttle fraction (throttling ticks per
    /// trace tick) — the penalty that bounds hot setpoints from above.
    pub throttle: f64,
}

impl ObjectiveWeights {
    /// Pure energy-reuse objective with a strong throttle penalty —
    /// the default the optimizer uses to recover the paper's band.
    pub fn ere() -> Self {
        ObjectiveWeights { pue: 0.0, ere: 1.0, throttle: 5.0 }
    }
}

impl FleetAggregate {
    /// Reduce finished plant runs + the facility report (plants must be in
    /// index order; the fleet driver guarantees it). `quarantined` is
    /// re-sorted by plant index so the document is independent of
    /// eviction order (which shard finished first is execution shape).
    pub fn build(plants: &[PlantRun], facility: &FacilityReport,
                 mut quarantined: Vec<QuarantineEntry>) -> Self {
        quarantined.sort_by_key(|q| q.index);
        let mut per_plant = Vec::with_capacity(plants.len());
        let mut pue_stats = Running::new();
        let mut ere_stats = Running::new();
        let mut pue_hist = Histogram::new(1.0, 1.6, 24);
        let mut ere_hist = Histogram::new(0.6, 1.6, 40);
        let mut fleet_e_ac = 0.0;
        let mut fleet_e_dc = 0.0;
        let mut worst: Option<(usize, u64)> = None;

        for (i, p) in plants.iter().enumerate() {
            let e = &p.result.energy;
            let credit_j = facility.plant_credit_j.get(i).copied().unwrap_or(0.0);
            let pue = safe_div(e.e_ac, e.e_dc);
            let ere = safe_div(e.e_ac - credit_j, e.e_dc);
            let mut t_out = Running::new();
            for s in &p.result.trace {
                t_out.push(s.t_rack_out);
            }
            let throttle_ticks = p
                .result
                .trace
                .iter()
                .filter(|s| s.throttling > 0)
                .count() as u64;
            let chiller_off_ticks = p
                .result
                .trace
                .iter()
                .filter(|s| !s.chiller_on)
                .count() as u64;
            let pump_fail_ticks = p
                .result
                .trace
                .iter()
                .filter(|s| s.pump_fail)
                .count() as u64;
            let is_worse = match worst {
                None => true,
                Some((_, w)) => throttle_ticks > w,
            };
            if is_worse {
                worst = Some((p.index, throttle_ticks));
            }
            pue_stats.push(pue);
            ere_stats.push(ere);
            pue_hist.push(pue);
            ere_hist.push(ere);
            fleet_e_ac += e.e_ac;
            fleet_e_dc += e.e_dc;
            per_plant.push(PlantMetrics {
                index: p.index,
                label: p.label.clone(),
                seed: p.seed,
                pue,
                ere,
                reuse_local: e.reuse_fraction(),
                credit_frac: safe_div(credit_j, e.e_ac),
                throttle_ticks,
                chiller_off_ticks,
                pump_fail_ticks,
                t_out_mean: t_out.mean(),
                mean_p_ac_w: e.mean_p_ac(),
            });
        }

        let fleet_trace_ticks =
            plants.iter().map(|p| p.result.trace.len() as u64).sum();
        let fleet_throttle_ticks =
            per_plant.iter().map(|m| m.throttle_ticks).sum();
        let fleet_chiller_off_ticks =
            per_plant.iter().map(|m| m.chiller_off_ticks).sum();
        let fleet_pump_fail_ticks =
            per_plant.iter().map(|m| m.pump_fail_ticks).sum();
        FleetAggregate {
            fleet_trace_ticks,
            fleet_throttle_ticks,
            fleet_chiller_off_ticks,
            fleet_pump_fail_ticks,
            per_plant,
            pue_stats,
            ere_stats,
            pue_hist,
            ere_hist,
            facility_reuse_fraction: facility.reuse_fraction(),
            worst_throttle_plant: worst.map(|(i, _)| i),
            worst_throttle_ticks: worst.map(|(_, w)| w).unwrap_or(0),
            fleet_e_ac,
            fleet_e_dc,
            quarantined,
        }
    }

    /// Render the aggregate as report series (per-plant table + PUE/ERE
    /// distribution histograms).
    pub fn series(&self) -> Vec<Series> {
        let mut plants = Series::new(
            "fleet_plants",
            "Per-plant fleet metrics",
            &["plant", "pue", "ere", "reuse_local", "credit_frac",
              "throttle_ticks", "t_out_mean", "p_ac_kw"],
        );
        for m in &self.per_plant {
            plants.push(vec![
                m.index as f64,
                m.pue,
                m.ere,
                m.reuse_local,
                m.credit_frac,
                m.throttle_ticks as f64,
                m.t_out_mean,
                m.mean_p_ac_w / 1e3,
            ]);
        }
        for m in &self.per_plant {
            plants.note(format!("plant {}: {} (seed {:#x})",
                                m.index, m.label, m.seed));
        }

        let mut pue = Series::new(
            "fleet_pue_hist",
            "Fleet PUE distribution (E_AC / E_DC)",
            &["pue", "density"],
        );
        for (x, d) in self.pue_hist.centers().into_iter()
            .zip(self.pue_hist.densities())
        {
            pue.push(vec![x, d]);
        }
        pue.note(format!("mean {:.4} +- {:.4} over {} plants",
                         self.pue_stats.mean(), self.pue_stats.std(),
                         self.per_plant.len()));

        let mut ere = Series::new(
            "fleet_ere_hist",
            "Fleet ERE distribution ((E_AC - E_credit) / E_DC)",
            &["ere", "density"],
        );
        for (x, d) in self.ere_hist.centers().into_iter()
            .zip(self.ere_hist.densities())
        {
            ere.push(vec![x, d]);
        }
        ere.note(format!("mean {:.4} +- {:.4}; facility reuse {:.1}%",
                         self.ere_stats.mean(), self.ere_stats.std(),
                         100.0 * self.facility_reuse_fraction));

        vec![plants, pue, ere]
    }

    /// Machine-readable view (`util::json`, BTreeMap-stable key order):
    /// per-plant metrics plus the PUE/ERE aggregates — the `aggregate`
    /// block of the fleet JSON document.
    pub fn to_json_value(&self) -> Json {
        let per_plant: Vec<Json> = self
            .per_plant
            .iter()
            .map(|m| {
                JsonBuilder::new()
                    .num("index", m.index as f64)
                    .str("label", &m.label)
                    .hex("seed", m.seed)
                    .num("pue", m.pue)
                    .num("ere", m.ere)
                    .num("reuse_local", m.reuse_local)
                    .num("credit_frac", m.credit_frac)
                    .num("throttle_ticks", m.throttle_ticks as f64)
                    .num("chiller_off_ticks", m.chiller_off_ticks as f64)
                    .num("pump_fail_ticks", m.pump_fail_ticks as f64)
                    .num("t_out_mean", m.t_out_mean)
                    .num("mean_p_ac_w", m.mean_p_ac_w)
                    .build()
            })
            .collect();
        let stats = |r: &Running| {
            JsonBuilder::new()
                .num("mean", r.mean())
                .num("std", r.std())
                .num("min", r.min())
                .num("max", r.max())
                .build()
        };
        JsonBuilder::new()
            .set("plants", Json::Arr(per_plant))
            .set("pue", stats(&self.pue_stats))
            .set("ere", stats(&self.ere_stats))
            .num("facility_reuse_fraction", self.facility_reuse_fraction)
            .set(
                "worst_throttle_plant",
                self.worst_throttle_plant
                    .map(|i| Json::Num(i as f64))
                    .unwrap_or(Json::Null),
            )
            .num("worst_throttle_ticks", self.worst_throttle_ticks as f64)
            .set(
                "domain_events",
                JsonBuilder::new()
                    .num("throttle_ticks", self.fleet_throttle_ticks as f64)
                    .num(
                        "chiller_outage_ticks",
                        self.fleet_chiller_off_ticks as f64,
                    )
                    .num(
                        "pump_degradation_ticks",
                        self.fleet_pump_fail_ticks as f64,
                    )
                    .build(),
            )
            .num("fleet_e_ac_j", self.fleet_e_ac)
            .num("fleet_e_dc_j", self.fleet_e_dc)
            .set(
                "quarantined",
                Json::Arr(
                    self.quarantined
                        .iter()
                        .map(|q| {
                            JsonBuilder::new()
                                .num("index", q.index as f64)
                                .str("reason", &q.reason)
                                .build()
                        })
                        .collect(),
                ),
            )
            .build()
    }

    /// Scalar minimization objective: `w.pue * mean(PUE) + w.ere *
    /// mean(ERE) + w.throttle * throttle_fraction`.
    ///
    /// NaN-free by construction: `Running::mean()` is 0.0 on an empty
    /// accumulator (every plant quarantined) and the throttle fraction
    /// goes through `safe_div`, so even a fully degraded aggregate
    /// yields a finite score — a prerequisite for the optimizer's
    /// worst-case-scoring chaos containment.
    pub fn objective(&self, w: &ObjectiveWeights) -> f64 {
        let throttle_frac = safe_div(
            self.fleet_throttle_ticks as f64,
            self.fleet_trace_ticks as f64,
        );
        w.pue * self.pue_stats.mean()
            + w.ere * self.ere_stats.mean()
            + w.throttle * throttle_frac
    }

    /// The fleet throttle fraction the objective's penalty term uses.
    pub fn throttle_fraction(&self) -> f64 {
        safe_div(
            self.fleet_throttle_ticks as f64,
            self.fleet_trace_ticks as f64,
        )
    }

    /// One-paragraph headline for the CLI.
    pub fn summary(&self) -> String {
        let degraded = if self.quarantined.is_empty() {
            String::new()
        } else {
            format!("; {} plant(s) QUARANTINED", self.quarantined.len())
        };
        format!(
            "fleet aggregate: {} plants; PUE {:.4} +- {:.4} \
             [{:.4}..{:.4}]; ERE {:.4} +- {:.4}; worst throttling {} ticks \
             (plant {}); fleet E_AC {:.1} kWh; facility energy-reuse \
             fraction {:.1}%{degraded}",
            self.per_plant.len(),
            self.pue_stats.mean(),
            self.pue_stats.std(),
            self.pue_stats.min(),
            self.pue_stats.max(),
            self.ere_stats.mean(),
            self.ere_stats.std(),
            self.worst_throttle_ticks,
            self.worst_throttle_plant
                .map(|i| i.to_string())
                .unwrap_or_else(|| "-".into()),
            self.fleet_e_ac / 3.6e6,
            100.0 * self.facility_reuse_fraction,
        )
    }

    /// Order-sensitive bitwise fingerprint of every aggregate number —
    /// the determinism gate compares this across shard counts.
    pub fn fingerprint(&self) -> u64 {
        fn mix(h: u64, v: f64) -> u64 {
            (h ^ v.to_bits()).wrapping_mul(0x0000_0100_0000_01B3)
        }
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        for m in &self.per_plant {
            h = mix(h, m.pue);
            h = mix(h, m.ere);
            h = mix(h, m.reuse_local);
            h = mix(h, m.credit_frac);
            h = mix(h, m.throttle_ticks as f64);
            h = mix(h, m.chiller_off_ticks as f64);
            h = mix(h, m.pump_fail_ticks as f64);
            h = mix(h, m.t_out_mean);
            h = mix(h, m.mean_p_ac_w);
        }
        h = mix(h, self.facility_reuse_fraction);
        h = mix(h, self.fleet_e_ac);
        h = mix(h, self.fleet_e_dc);
        // Quarantine is part of the result identity: a degraded run must
        // never fingerprint-collide with the clean run, and two degraded
        // runs differing in *why* a plant left must differ too.
        for q in &self.quarantined {
            h = mix(h, q.index as f64);
            for &b in q.reason.as_bytes() {
                h = mix(h, b as f64);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic aggregate (no plant runs needed — every field is
    /// public by design).
    fn agg(pues: &[f64], eres: &[f64], throttle_ticks: u64,
           trace_ticks: u64) -> FleetAggregate {
        let mut pue_stats = Running::new();
        let mut ere_stats = Running::new();
        for &p in pues {
            pue_stats.push(p);
        }
        for &e in eres {
            ere_stats.push(e);
        }
        FleetAggregate {
            per_plant: Vec::new(),
            pue_stats,
            ere_stats,
            pue_hist: Histogram::new(1.0, 1.6, 24),
            ere_hist: Histogram::new(0.6, 1.6, 40),
            facility_reuse_fraction: 0.0,
            worst_throttle_plant: None,
            worst_throttle_ticks: 0,
            fleet_throttle_ticks: throttle_ticks,
            fleet_chiller_off_ticks: 0,
            fleet_pump_fail_ticks: 0,
            fleet_e_ac: 0.0,
            fleet_e_dc: 0.0,
            fleet_trace_ticks: trace_ticks,
            quarantined: Vec::new(),
        }
    }

    #[test]
    fn zero_weights_zero_objective() {
        let a = agg(&[1.2, 1.3], &[0.9, 1.0], 50, 100);
        let w = ObjectiveWeights { pue: 0.0, ere: 0.0, throttle: 0.0 };
        assert_eq!(a.objective(&w), 0.0);
    }

    #[test]
    fn single_term_weights_recover_the_components() {
        let a = agg(&[1.2, 1.4], &[0.8, 1.0], 25, 100);
        let pue_only = ObjectiveWeights { pue: 1.0, ere: 0.0, throttle: 0.0 };
        let ere_only = ObjectiveWeights { pue: 0.0, ere: 1.0, throttle: 0.0 };
        let thr_only = ObjectiveWeights { pue: 0.0, ere: 0.0, throttle: 1.0 };
        assert!((a.objective(&pue_only) - 1.3).abs() < 1e-12);
        assert!((a.objective(&ere_only) - 0.9).abs() < 1e-12);
        assert!((a.objective(&thr_only) - 0.25).abs() < 1e-12);
        assert_eq!(a.throttle_fraction(), 0.25);
    }

    #[test]
    fn throttle_dominated_weights_order_by_throttling() {
        // The cool plant has worse (higher) ERE but never throttles; the
        // hot plant has great ERE but throttles a quarter of the time.
        // With a throttle-dominated weighting, cool must win (score
        // lower) — this is the mechanism that bounds hot setpoints.
        let cool = agg(&[1.3], &[1.1], 0, 100);
        let hot = agg(&[1.1], &[0.8], 25, 100);
        let w = ObjectiveWeights::ere(); // ere + 5x throttle
        assert!(cool.objective(&w) < hot.objective(&w),
                "cool {} !< hot {}", cool.objective(&w),
                hot.objective(&w));
        // and with the throttle term off, hot wins on raw ERE
        let raw = ObjectiveWeights { pue: 0.0, ere: 1.0, throttle: 0.0 };
        assert!(hot.objective(&raw) < cool.objective(&raw));
    }

    #[test]
    fn objective_is_nan_free_when_everything_quarantined() {
        // Empty stats (all plants evicted) and zero trace ticks: every
        // term degrades to 0.0, never NaN.
        let mut a = agg(&[], &[], 0, 0);
        a.quarantined.push(QuarantineEntry {
            index: 0,
            reason: "panic in plant run".into(),
        });
        let w = ObjectiveWeights::ere();
        let v = a.objective(&w);
        assert!(v.is_finite(), "objective {v} not finite");
        assert_eq!(v, 0.0);
        assert_eq!(a.throttle_fraction(), 0.0);
    }
}
