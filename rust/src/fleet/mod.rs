//! Fleet engine: sharded multi-plant simulation against one shared
//! facility loop.
//!
//! The single-plant twin reproduces one iDataCool installation; the fleet
//! engine scales it *out*: N independent `SimulationDriver` instances —
//! one per plant, each with its own `PlantBackend`, workload, telemetry
//! and fault schedule — sharded in contiguous index blocks across OS
//! threads (`std::thread::scope`, one shard per core by default;
//! `util::shard::blocks` — block assignment decorrelates shard load from
//! the index-modulo patterns scenarios use, e.g. `mixed`'s
//! stress/production/idle thirds, which round-robin sharding used to
//! pile onto single shards). Within a shard, plants either run to
//! completion one at a time, or — the **megabatch** default
//! (`FleetConfig::megabatch`, `IDATACOOL_FLEET_MEGABATCH`) — advance in
//! tick lockstep over one shared SoA lane arena (`megabatch`), one
//! kernel sweep per substep for the whole shard. The shared facility
//! pass (`facility`) pools the per-tick recovered heat in plant-index
//! order, drives the aggregate adsorption chiller, and feeds the cooling
//! credit back into each plant's energy account — per tick during the
//! run for a 1-shard megabatch, by post-hoc trace replay otherwise
//! (identical inputs in identical order, so bitwise the same report);
//! `aggregate` reduces the fleet to PUE/ERE distributions and the
//! facility energy-reuse headline.
//!
//! Determinism: per-plant seeds are a pure function of the fleet seed and
//! the plant index (`plant_seed`), plant simulations are self-contained,
//! every cross-plant reduction runs in plant-index order, and the
//! megabatch arena is bitwise identical to per-plant stepping — so any
//! (shard count, megabatch) combination produces byte-identical
//! `idatacool-fleet/1` output (`tests/fleet_integration.rs`).

pub mod aggregate;
pub mod facility;
pub mod megabatch;
pub mod scenario;

use std::time::Instant;

use anyhow::Result;

use crate::config::SimConfig;
use crate::coordinator::{RunResult, SimulationDriver, TraceSample};
use crate::util::json::{Json, JsonBuilder};
use crate::util::shard::blocks;
use crate::variability::rng::splitmix64;

use aggregate::FleetAggregate;
use facility::{FacilityModel, FacilityParams, FacilityReport, PlantTick};
use megabatch::LockstepFleet;
use scenario::{PlantSpec, Scenario};

/// Fleet-level run configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of plants in the fleet.
    pub n_plants: usize,
    /// Shard (OS thread) count; clamped to the plant count.
    pub shards: usize,
    /// Base per-plant configuration the scenario derives from.
    pub base: SimConfig,
    /// Fleet seed; per-plant seeds derive from it via `plant_seed`.
    pub fleet_seed: u64,
    pub scenario: Scenario,
    /// Advance each shard's plants in tick lockstep over one shared SoA
    /// lane arena instead of running them as N independent kernel
    /// instances. Execution shape only — results are bitwise identical
    /// either way — so it never enters result documents or cache keys.
    /// Default: `default_megabatch()` (on, unless
    /// `IDATACOOL_FLEET_MEGABATCH=0`).
    pub megabatch: bool,
}

/// Resolve the `IDATACOOL_FLEET_MEGABATCH` environment override
/// (strictly `0|1|true|false`; garbage is an error, not a silent
/// fall-back). Unset means **on**: the megabatch path is bitwise
/// identical to per-plant stepping, so it is the default execution
/// shape.
pub fn default_megabatch() -> Result<bool> {
    Ok(crate::util::cli::env_bool_strict("IDATACOOL_FLEET_MEGABATCH")?
        .unwrap_or(true))
}

/// One plant's finished run plus its fleet identity.
pub struct PlantRun {
    pub index: usize,
    pub label: String,
    pub seed: u64,
    /// Simulated seconds per tick (identical across the fleet).
    pub tick_s: f64,
    pub result: RunResult,
}

/// The whole fleet outcome.
pub struct FleetRun {
    pub plants: Vec<PlantRun>,
    pub facility: FacilityReport,
    pub aggregate: FleetAggregate,
    pub shards: usize,
    pub wall_s: f64,
}

impl FleetRun {
    /// The `idatacool-fleet/1` document: PUE/ERE aggregates, per-plant
    /// metrics and facility credits, and the determinism fingerprint —
    /// rendered through `util::json`, so key order is BTreeMap-stable.
    ///
    /// This is both the `idatacool fleet --json` file and the server's
    /// `POST /fleet` response body (one serializer, byte for byte). It
    /// carries **no wall-clock and no execution-shape fields** (shard
    /// count included): for a given scenario/seed/base the document is
    /// bitwise reproducible across runs, shard counts, hosts, and the
    /// CLI/server boundary.
    pub fn to_json_value(&self, cfg: &FleetConfig) -> Json {
        JsonBuilder::new()
            .str("schema", "idatacool-fleet/1")
            .str("scenario", cfg.scenario.name())
            .str("base_config", &cfg.base.name)
            .num("n_plants", self.plants.len() as f64)
            .hex("fleet_seed", cfg.fleet_seed)
            .hex("fingerprint", self.aggregate.fingerprint())
            .set("aggregate", self.aggregate.to_json_value())
            .set("facility", self.facility.to_json_value())
            .build()
    }

    pub fn to_json(&self, cfg: &FleetConfig) -> String {
        self.to_json_value(cfg).to_string()
    }
}

/// Deterministic per-plant seed: a SplitMix64 mix of the fleet seed and
/// the plant index — independent of shard assignment and shard count.
pub fn plant_seed(fleet_seed: u64, plant: usize) -> u64 {
    let salt = (plant as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let (_, z) = splitmix64(fleet_seed ^ salt);
    z
}

/// Runs a fleet to completion.
pub struct FleetDriver {
    pub cfg: FleetConfig,
}

impl FleetDriver {
    pub fn new(cfg: FleetConfig) -> Result<Self> {
        anyhow::ensure!(cfg.n_plants > 0, "fleet needs at least one plant");
        anyhow::ensure!(cfg.shards > 0, "fleet needs at least one shard");
        cfg.base.validate()?;
        Ok(FleetDriver { cfg })
    }

    /// The per-plant run recipes (scenario overrides + derived seeds),
    /// in plant-index order.
    pub fn specs(&self) -> Vec<PlantSpec> {
        (0..self.cfg.n_plants)
            .map(|i| {
                self.cfg.scenario.plant_spec(
                    i,
                    self.cfg.n_plants,
                    &self.cfg.base,
                    plant_seed(self.cfg.fleet_seed, i),
                )
            })
            .collect()
    }

    /// Run every plant (sharded across threads), then the facility pass
    /// and the fleet aggregation.
    pub fn run(&self) -> Result<FleetRun> {
        let start = Instant::now();
        let specs = self.specs();
        let n_plants = specs.len();
        let shards = self.cfg.shards.clamp(1, n_plants);
        let params =
            FacilityParams::from_plant(&self.cfg.base.pp, self.cfg.n_plants);
        // Config-level precheck: a base that cannot lockstep (pinned
        // hlo backend / reference kernel) keeps the per-plant path's
        // one-driver-at-a-time memory profile instead of constructing a
        // whole bucket of drivers just to be handed them back.
        let lockstep = self.cfg.megabatch && megabatch::precheck(&self.cfg.base);

        // Single-shard megabatch: the whole fleet advances in tick
        // lockstep, so the shared facility loop is fed per tick instead
        // of replaying traces post-hoc (same inputs, same plant order —
        // bitwise the same report).
        if lockstep && shards == 1 {
            match LockstepFleet::new(megabatch::build_ctxs(specs)?) {
                Ok(mut ls) => {
                    ls.set_shard(0);
                    let model = FacilityModel::new(params, n_plants);
                    let (plants, facility) = ls.run(Some(model))?;
                    let facility =
                        facility.expect("streamed facility report");
                    return Ok(assemble(plants, facility, shards, start));
                }
                // Not lockstep-eligible on the deep per-plant check:
                // fall through to the per-plant path with the
                // already-built drivers.
                Err(ctxs) => {
                    let plants = megabatch::run_ctxs_sequential(ctxs)?;
                    let facility = run_facility(&plants, params);
                    return Ok(assemble(plants, facility, shards, start));
                }
            }
        }

        // Contiguous block sharding: plant order inside a shard equals
        // fleet order, and shard sizes differ by at most one for any
        // n_plants % shards. Assignment is order-independent for
        // results — every cross-plant reduction runs in plant-index
        // order regardless of which shard ran a plant.
        let buckets = blocks(specs, shards);

        let mut slots: Vec<Option<PlantRun>> =
            (0..n_plants).map(|_| None).collect();
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(buckets.len());
            for (shard, bucket) in buckets.into_iter().enumerate() {
                handles.push(
                    scope.spawn(move || run_bucket(bucket, lockstep, shard)),
                );
            }
            for h in handles {
                let shard_runs = h
                    .join()
                    .map_err(|_| anyhow::anyhow!("fleet shard panicked"))??;
                for run in shard_runs {
                    let i = run.index;
                    slots[i] = Some(run);
                }
            }
            Ok(())
        })?;
        let plants: Vec<PlantRun> = slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                s.ok_or_else(|| anyhow::anyhow!("plant {i} produced no run"))
            })
            .collect::<Result<_>>()?;

        // Facility pass + aggregation, both in plant-index order.
        let facility = run_facility(&plants, params);
        Ok(assemble(plants, facility, shards, start))
    }
}

/// The one place a `FleetRun` is put together — every execution path
/// (streamed-facility lockstep, lockstep fallback, sharded) funnels
/// through here so the assembly cannot drift between them.
fn assemble(plants: Vec<PlantRun>, facility: FacilityReport, shards: usize,
            start: Instant) -> FleetRun {
    let aggregate = FleetAggregate::build(&plants, &facility);
    FleetRun {
        plants,
        facility,
        aggregate,
        shards,
        wall_s: start.elapsed().as_secs_f64(),
    }
}

/// Run one shard's plants: in tick lockstep over one shared lane arena
/// (megabatch, config-prechecked by the caller), or sequentially, each
/// plant owning its full driver.
fn run_bucket(bucket: Vec<PlantSpec>, lockstep: bool, shard: usize)
              -> Result<Vec<PlantRun>> {
    if lockstep {
        return match LockstepFleet::new(megabatch::build_ctxs(bucket)?) {
            Ok(mut ls) => {
                ls.set_shard(shard);
                ls.run(None).map(|(plants, _)| plants)
            }
            Err(ctxs) => megabatch::run_ctxs_sequential(ctxs),
        };
    }
    // Megabatch off (or not lockstep-capable): one plant at a time —
    // only one driver alive per shard at any moment.
    let mut out = Vec::with_capacity(bucket.len());
    for spec in bucket {
        let PlantSpec { index, label, seed, cfg, faults } = spec;
        let mut driver = SimulationDriver::from_prebuilt(cfg, seed, faults)?;
        let tick_s = driver.backend.tick_seconds(&driver.cfg.pp);
        // sample_every = 1: the facility pass needs every tick.
        let result = driver.run(1)?;
        out.push(PlantRun { index, label, seed, tick_s, result });
    }
    Ok(out)
}

/// One trace sample's contribution to the facility loop — the single
/// conversion both facility feeds (post-hoc replay here, per-tick
/// streaming in `megabatch::LockstepFleet::run`) share, so they cannot
/// drift.
pub(crate) fn plant_tick_of(s: &TraceSample) -> PlantTick {
    PlantTick {
        p_heat_w: s.p_d,
        t_return: s.t_rack_out,
        p_ac_w: s.p_ac,
    }
}

/// Replay the finished plant traces through the shared facility loop,
/// tick-aligned and in plant-index order.
pub fn run_facility(plants: &[PlantRun], params: FacilityParams)
                    -> FacilityReport {
    let _span = crate::obs::span("facility");
    let mut model = FacilityModel::new(params, plants.len());
    let n_ticks = plants
        .iter()
        .map(|p| p.result.trace.len())
        .min()
        .unwrap_or(0);
    let dt = plants.first().map(|p| p.tick_s).unwrap_or(0.0);
    let mut inputs = Vec::with_capacity(plants.len());
    for t in 0..n_ticks {
        inputs.clear();
        for p in plants {
            inputs.push(plant_tick_of(&p.result.trace[t]));
        }
        model.pool_tick(&inputs, dt);
    }
    model.into_report()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plant_seeds_are_stable_and_distinct() {
        let s: Vec<u64> = (0..32).map(|i| plant_seed(0x1DA7, i)).collect();
        let again: Vec<u64> = (0..32).map(|i| plant_seed(0x1DA7, i)).collect();
        assert_eq!(s, again);
        for (i, a) in s.iter().enumerate() {
            for b in &s[i + 1..] {
                assert_ne!(a, b, "seed collision");
            }
        }
        // and the fleet seed matters
        assert_ne!(plant_seed(1, 0), plant_seed(2, 0));
    }

    #[test]
    fn driver_rejects_degenerate_configs() {
        let base = SimConfig::test_small();
        let scenario = Scenario::by_name("baseline").unwrap();
        let bad = FleetConfig {
            n_plants: 0,
            shards: 1,
            base: base.clone(),
            fleet_seed: 1,
            scenario,
            megabatch: true,
        };
        assert!(FleetDriver::new(bad).is_err());
        let bad = FleetConfig {
            n_plants: 2,
            shards: 0,
            base,
            fleet_seed: 1,
            scenario,
            megabatch: true,
        };
        assert!(FleetDriver::new(bad).is_err());
    }

    #[test]
    fn megabatch_defaults_on_without_env() {
        // The parse half is covered by util::cli; here: the unset-env
        // default is on (tests must not mutate process-global env).
        if std::env::var_os("IDATACOOL_FLEET_MEGABATCH").is_none() {
            assert!(default_megabatch().unwrap());
        }
    }

    #[test]
    fn specs_cover_every_plant_in_order() {
        let base = SimConfig::test_small();
        let cfg = FleetConfig {
            n_plants: 5,
            shards: 2,
            base,
            fleet_seed: 9,
            scenario: Scenario::by_name("mixed").unwrap(),
            megabatch: true,
        };
        let d = FleetDriver::new(cfg).unwrap();
        let specs = d.specs();
        assert_eq!(specs.len(), 5);
        for (i, s) in specs.iter().enumerate() {
            assert_eq!(s.index, i);
            assert_eq!(s.seed, plant_seed(9, i));
        }
    }
}
