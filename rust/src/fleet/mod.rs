//! Fleet engine: sharded multi-plant simulation against one shared
//! facility loop.
//!
//! The single-plant twin reproduces one iDataCool installation; the fleet
//! engine scales it *out*: N independent `SimulationDriver` instances —
//! one per plant, each with its own `PlantBackend`, workload, telemetry
//! and fault schedule — sharded round-robin across OS threads
//! (`std::thread::scope`, one shard per core by default). After the plant
//! runs finish, the shared facility pass (`facility`) pools the per-tick
//! recovered heat in plant-index order, drives the aggregate adsorption
//! chiller, and feeds the cooling credit back into each plant's energy
//! account; `aggregate` reduces the fleet to PUE/ERE distributions and the
//! facility energy-reuse headline.
//!
//! Determinism: per-plant seeds are a pure function of the fleet seed and
//! the plant index (`plant_seed`), plant simulations are self-contained,
//! and every cross-plant reduction runs in plant-index order — so a
//! K-shard run is bitwise identical to a 1-shard run with the same seeds.

pub mod aggregate;
pub mod facility;
pub mod scenario;

use std::time::Instant;

use anyhow::Result;

use crate::config::SimConfig;
use crate::coordinator::{RunResult, SimulationDriver};
use crate::util::json::{Json, JsonBuilder};
use crate::util::shard::round_robin;
use crate::variability::rng::splitmix64;

use aggregate::FleetAggregate;
use facility::{FacilityModel, FacilityParams, FacilityReport, PlantTick};
use scenario::{PlantSpec, Scenario};

/// Fleet-level run configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of plants in the fleet.
    pub n_plants: usize,
    /// Shard (OS thread) count; clamped to the plant count.
    pub shards: usize,
    /// Base per-plant configuration the scenario derives from.
    pub base: SimConfig,
    /// Fleet seed; per-plant seeds derive from it via `plant_seed`.
    pub fleet_seed: u64,
    pub scenario: Scenario,
}

/// One plant's finished run plus its fleet identity.
pub struct PlantRun {
    pub index: usize,
    pub label: String,
    pub seed: u64,
    /// Simulated seconds per tick (identical across the fleet).
    pub tick_s: f64,
    pub result: RunResult,
}

/// The whole fleet outcome.
pub struct FleetRun {
    pub plants: Vec<PlantRun>,
    pub facility: FacilityReport,
    pub aggregate: FleetAggregate,
    pub shards: usize,
    pub wall_s: f64,
}

impl FleetRun {
    /// The `idatacool-fleet/1` document: PUE/ERE aggregates, per-plant
    /// metrics and facility credits, and the determinism fingerprint —
    /// rendered through `util::json`, so key order is BTreeMap-stable.
    ///
    /// This is both the `idatacool fleet --json` file and the server's
    /// `POST /fleet` response body (one serializer, byte for byte). It
    /// carries **no wall-clock and no execution-shape fields** (shard
    /// count included): for a given scenario/seed/base the document is
    /// bitwise reproducible across runs, shard counts, hosts, and the
    /// CLI/server boundary.
    pub fn to_json_value(&self, cfg: &FleetConfig) -> Json {
        JsonBuilder::new()
            .str("schema", "idatacool-fleet/1")
            .str("scenario", cfg.scenario.name())
            .str("base_config", &cfg.base.name)
            .num("n_plants", self.plants.len() as f64)
            .hex("fleet_seed", cfg.fleet_seed)
            .hex("fingerprint", self.aggregate.fingerprint())
            .set("aggregate", self.aggregate.to_json_value())
            .set("facility", self.facility.to_json_value())
            .build()
    }

    pub fn to_json(&self, cfg: &FleetConfig) -> String {
        self.to_json_value(cfg).to_string()
    }
}

/// Deterministic per-plant seed: a SplitMix64 mix of the fleet seed and
/// the plant index — independent of shard assignment and shard count.
pub fn plant_seed(fleet_seed: u64, plant: usize) -> u64 {
    let salt = (plant as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let (_, z) = splitmix64(fleet_seed ^ salt);
    z
}

/// Runs a fleet to completion.
pub struct FleetDriver {
    pub cfg: FleetConfig,
}

impl FleetDriver {
    pub fn new(cfg: FleetConfig) -> Result<Self> {
        anyhow::ensure!(cfg.n_plants > 0, "fleet needs at least one plant");
        anyhow::ensure!(cfg.shards > 0, "fleet needs at least one shard");
        cfg.base.validate()?;
        Ok(FleetDriver { cfg })
    }

    /// The per-plant run recipes (scenario overrides + derived seeds),
    /// in plant-index order.
    pub fn specs(&self) -> Vec<PlantSpec> {
        (0..self.cfg.n_plants)
            .map(|i| {
                self.cfg.scenario.plant_spec(
                    i,
                    self.cfg.n_plants,
                    &self.cfg.base,
                    plant_seed(self.cfg.fleet_seed, i),
                )
            })
            .collect()
    }

    /// Run every plant (sharded across threads), then the facility pass
    /// and the fleet aggregation.
    pub fn run(&self) -> Result<FleetRun> {
        let start = Instant::now();
        let specs = self.specs();
        let n_plants = specs.len();
        let shards = self.cfg.shards.clamp(1, n_plants);

        // Round-robin shard assignment: plant i -> shard i % K (shared
        // with the parallel setpoint sweep, util::shard).
        let buckets = round_robin(specs, shards);

        let mut slots: Vec<Option<PlantRun>> =
            (0..n_plants).map(|_| None).collect();
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(buckets.len());
            for bucket in buckets {
                handles.push(scope.spawn(move || run_bucket(bucket)));
            }
            for h in handles {
                let shard_runs = h
                    .join()
                    .map_err(|_| anyhow::anyhow!("fleet shard panicked"))??;
                for run in shard_runs {
                    let i = run.index;
                    slots[i] = Some(run);
                }
            }
            Ok(())
        })?;
        let plants: Vec<PlantRun> = slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                s.ok_or_else(|| anyhow::anyhow!("plant {i} produced no run"))
            })
            .collect::<Result<_>>()?;

        // Facility pass + aggregation, both in plant-index order.
        let params =
            FacilityParams::from_plant(&self.cfg.base.pp, self.cfg.n_plants);
        let facility = run_facility(&plants, params);
        let aggregate = FleetAggregate::build(&plants, &facility);

        Ok(FleetRun {
            plants,
            facility,
            aggregate,
            shards,
            wall_s: start.elapsed().as_secs_f64(),
        })
    }
}

/// Run one shard's plants sequentially (each plant owns its full driver).
fn run_bucket(bucket: Vec<PlantSpec>) -> Result<Vec<PlantRun>> {
    let mut out = Vec::with_capacity(bucket.len());
    for spec in bucket {
        let PlantSpec { index, label, seed, cfg, faults } = spec;
        let mut driver = SimulationDriver::from_prebuilt(cfg, seed, faults)?;
        let tick_s = driver.backend.tick_seconds(&driver.cfg.pp);
        // sample_every = 1: the facility pass needs every tick.
        let result = driver.run(1)?;
        out.push(PlantRun { index, label, seed, tick_s, result });
    }
    Ok(out)
}

/// Replay the finished plant traces through the shared facility loop,
/// tick-aligned and in plant-index order.
pub fn run_facility(plants: &[PlantRun], params: FacilityParams)
                    -> FacilityReport {
    let mut model = FacilityModel::new(params, plants.len());
    let n_ticks = plants
        .iter()
        .map(|p| p.result.trace.len())
        .min()
        .unwrap_or(0);
    let dt = plants.first().map(|p| p.tick_s).unwrap_or(0.0);
    let mut inputs = Vec::with_capacity(plants.len());
    for t in 0..n_ticks {
        inputs.clear();
        for p in plants {
            let s = &p.result.trace[t];
            inputs.push(PlantTick {
                p_heat_w: s.p_d,
                t_return: s.t_rack_out,
                p_ac_w: s.p_ac,
            });
        }
        model.pool_tick(&inputs, dt);
    }
    model.into_report()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plant_seeds_are_stable_and_distinct() {
        let s: Vec<u64> = (0..32).map(|i| plant_seed(0x1DA7, i)).collect();
        let again: Vec<u64> = (0..32).map(|i| plant_seed(0x1DA7, i)).collect();
        assert_eq!(s, again);
        for (i, a) in s.iter().enumerate() {
            for b in &s[i + 1..] {
                assert_ne!(a, b, "seed collision");
            }
        }
        // and the fleet seed matters
        assert_ne!(plant_seed(1, 0), plant_seed(2, 0));
    }

    #[test]
    fn driver_rejects_degenerate_configs() {
        let base = SimConfig::test_small();
        let scenario = Scenario::by_name("baseline").unwrap();
        let bad = FleetConfig {
            n_plants: 0,
            shards: 1,
            base: base.clone(),
            fleet_seed: 1,
            scenario,
        };
        assert!(FleetDriver::new(bad).is_err());
        let bad = FleetConfig {
            n_plants: 2,
            shards: 0,
            base,
            fleet_seed: 1,
            scenario,
        };
        assert!(FleetDriver::new(bad).is_err());
    }

    #[test]
    fn specs_cover_every_plant_in_order() {
        let base = SimConfig::test_small();
        let cfg = FleetConfig {
            n_plants: 5,
            shards: 2,
            base,
            fleet_seed: 9,
            scenario: Scenario::by_name("mixed").unwrap(),
        };
        let d = FleetDriver::new(cfg).unwrap();
        let specs = d.specs();
        assert_eq!(specs.len(), 5);
        for (i, s) in specs.iter().enumerate() {
            assert_eq!(s.index, i);
            assert_eq!(s.seed, plant_seed(9, i));
        }
    }
}
