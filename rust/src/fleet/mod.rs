//! Fleet engine: sharded multi-plant simulation against one shared
//! facility loop.
//!
//! The single-plant twin reproduces one iDataCool installation; the fleet
//! engine scales it *out*: N independent `SimulationDriver` instances —
//! one per plant, each with its own `PlantBackend`, workload, telemetry
//! and fault schedule — sharded in contiguous index blocks across OS
//! threads (`std::thread::scope`, one shard per core by default;
//! `util::shard::blocks` — block assignment decorrelates shard load from
//! the index-modulo patterns scenarios use, e.g. `mixed`'s
//! stress/production/idle thirds, which round-robin sharding used to
//! pile onto single shards). Within a shard, plants either run to
//! completion one at a time, or — the **megabatch** default
//! (`FleetConfig::megabatch`, `IDATACOOL_FLEET_MEGABATCH`) — advance in
//! tick lockstep over one shared SoA lane arena (`megabatch`), one
//! kernel sweep per substep for the whole shard. The shared facility
//! pass (`facility`) pools the per-tick recovered heat in plant-index
//! order, drives the aggregate adsorption chiller, and feeds the cooling
//! credit back into each plant's energy account — per tick during the
//! run for a 1-shard megabatch, by post-hoc trace replay otherwise
//! (identical inputs in identical order, so bitwise the same report);
//! `aggregate` reduces the fleet to PUE/ERE distributions and the
//! facility energy-reuse headline.
//!
//! Determinism: per-plant seeds are a pure function of the fleet seed and
//! the plant index (`plant_seed`), plant simulations are self-contained,
//! every cross-plant reduction runs in plant-index order, and the
//! megabatch arena is bitwise identical to per-plant stepping — so any
//! (shard count, megabatch) combination produces byte-identical
//! `idatacool-fleet/1` output (`tests/fleet_integration.rs`).

pub mod aggregate;
pub mod facility;
pub mod megabatch;
pub mod scenario;

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::SimConfig;
use crate::coordinator::{RunResult, SimulationDriver, TraceSample};
use crate::resilience::checkpoint::{self, SnapReader, SnapWriter};
use crate::resilience::inject::{self, Site};
use crate::util::json::{Json, JsonBuilder};
use crate::util::shard::blocks;
use crate::variability::rng::splitmix64;

use aggregate::FleetAggregate;
use facility::{FacilityModel, FacilityParams, FacilityReport, PlantTick};
use megabatch::LockstepFleet;
use scenario::{PlantSpec, Scenario};

/// One evicted plant: its fleet index and why it left the run.
///
/// Quarantine is the fleet's fault-containment verdict — a plant that
/// panicked, went numerically non-finite, or rode a shard that died is
/// dropped from the run while the rest of the fleet completes
/// (degraded success, never abort). Entries land in
/// [`FleetAggregate::quarantined`] and are mixed into the determinism
/// fingerprint, so a degraded document can never masquerade as a clean
/// one.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuarantineEntry {
    pub index: usize,
    pub reason: String,
}

/// The one funnel every containment path (lockstep eviction, sequential
/// fallback, shard death) records evictions through — the obs counter
/// and the report cannot drift apart.
pub(crate) fn note_quarantine(q: &mut Vec<QuarantineEntry>, index: usize,
                              reason: &str) {
    if crate::obs::enabled() {
        crate::obs::metrics::quarantined_plants().inc();
    }
    q.push(QuarantineEntry { index, reason: reason.to_string() });
}

/// Crash-consistency settings for a fleet run: write a snapshot to
/// `path` every `every` ticks. Deliberately **outside** `FleetConfig` —
/// like shard count, checkpointing is execution shape, and it must not
/// enter result documents or server cache keys.
#[derive(Debug, Clone)]
pub struct CheckpointSpec {
    pub path: PathBuf,
    pub every: u64,
}

/// Fleet-level run configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of plants in the fleet.
    pub n_plants: usize,
    /// Shard (OS thread) count; clamped to the plant count.
    pub shards: usize,
    /// Base per-plant configuration the scenario derives from.
    pub base: SimConfig,
    /// Fleet seed; per-plant seeds derive from it via `plant_seed`.
    pub fleet_seed: u64,
    pub scenario: Scenario,
    /// Advance each shard's plants in tick lockstep over one shared SoA
    /// lane arena instead of running them as N independent kernel
    /// instances. Execution shape only — results are bitwise identical
    /// either way — so it never enters result documents or cache keys.
    /// Default: `default_megabatch()` (on, unless
    /// `IDATACOOL_FLEET_MEGABATCH=0`).
    pub megabatch: bool,
}

/// Resolve the `IDATACOOL_FLEET_MEGABATCH` environment override
/// (strictly `0|1|true|false`; garbage is an error, not a silent
/// fall-back). Unset means **on**: the megabatch path is bitwise
/// identical to per-plant stepping, so it is the default execution
/// shape.
pub fn default_megabatch() -> Result<bool> {
    Ok(crate::util::cli::env_bool_strict("IDATACOOL_FLEET_MEGABATCH")?
        .unwrap_or(true))
}

/// One plant's finished run plus its fleet identity.
pub struct PlantRun {
    pub index: usize,
    pub label: String,
    pub seed: u64,
    /// Simulated seconds per tick (identical across the fleet).
    pub tick_s: f64,
    pub result: RunResult,
}

/// The whole fleet outcome.
pub struct FleetRun {
    pub plants: Vec<PlantRun>,
    pub facility: FacilityReport,
    pub aggregate: FleetAggregate,
    pub shards: usize,
    pub wall_s: f64,
}

impl FleetRun {
    /// The `idatacool-fleet/1` document: PUE/ERE aggregates, per-plant
    /// metrics and facility credits, and the determinism fingerprint —
    /// rendered through `util::json`, so key order is BTreeMap-stable.
    ///
    /// This is both the `idatacool fleet --json` file and the server's
    /// `POST /fleet` response body (one serializer, byte for byte). It
    /// carries **no wall-clock and no execution-shape fields** (shard
    /// count included): for a given scenario/seed/base the document is
    /// bitwise reproducible across runs, shard counts, hosts, and the
    /// CLI/server boundary.
    pub fn to_json_value(&self, cfg: &FleetConfig) -> Json {
        JsonBuilder::new()
            .str("schema", "idatacool-fleet/1")
            .str("scenario", cfg.scenario.name())
            .str("base_config", &cfg.base.name)
            .num("n_plants", self.plants.len() as f64)
            .hex("fleet_seed", cfg.fleet_seed)
            .hex("fingerprint", self.aggregate.fingerprint())
            .set("aggregate", self.aggregate.to_json_value())
            .set("facility", self.facility.to_json_value())
            .build()
    }

    pub fn to_json(&self, cfg: &FleetConfig) -> String {
        self.to_json_value(cfg).to_string()
    }
}

/// Deterministic per-plant seed: a SplitMix64 mix of the fleet seed and
/// the plant index — independent of shard assignment and shard count.
pub fn plant_seed(fleet_seed: u64, plant: usize) -> u64 {
    let salt = (plant as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let (_, z) = splitmix64(fleet_seed ^ salt);
    z
}

/// Runs a fleet to completion.
pub struct FleetDriver {
    pub cfg: FleetConfig,
}

impl FleetDriver {
    pub fn new(cfg: FleetConfig) -> Result<Self> {
        anyhow::ensure!(cfg.n_plants > 0, "fleet needs at least one plant");
        anyhow::ensure!(cfg.shards > 0, "fleet needs at least one shard");
        cfg.base.validate()?;
        Ok(FleetDriver { cfg })
    }

    /// The per-plant run recipes (scenario overrides + derived seeds),
    /// in plant-index order.
    pub fn specs(&self) -> Vec<PlantSpec> {
        (0..self.cfg.n_plants)
            .map(|i| {
                self.cfg.scenario.plant_spec(
                    i,
                    self.cfg.n_plants,
                    &self.cfg.base,
                    plant_seed(self.cfg.fleet_seed, i),
                )
            })
            .collect()
    }

    /// Run every plant (sharded across threads), then the facility pass
    /// and the fleet aggregation.
    pub fn run(&self) -> Result<FleetRun> {
        self.run_resilient(None, None)
    }

    /// `run` with crash consistency: optionally write a snapshot every
    /// `checkpoint.every` ticks, and/or start from a snapshot at
    /// `resume`. A resumed run produces the same fingerprint and
    /// byte-identical `--json` output as the uninterrupted run.
    ///
    /// Both options force the single-shard lockstep shape — the one
    /// whose results every other (shard count, megabatch) combination
    /// must match bitwise anyway, so the forcing changes nothing
    /// observable — and require a lockstep-capable base config.
    pub fn run_resilient(&self, ckpt: Option<&CheckpointSpec>,
                         resume: Option<&Path>) -> Result<FleetRun> {
        let start = Instant::now();
        let specs = self.specs();
        let n_plants = specs.len();
        let mut shards = self.cfg.shards.clamp(1, n_plants);
        let params =
            FacilityParams::from_plant(&self.cfg.base.pp, self.cfg.n_plants);
        // Config-level precheck: a base that cannot lockstep (pinned
        // hlo backend / reference kernel) keeps the per-plant path's
        // one-driver-at-a-time memory profile instead of constructing a
        // whole bucket of drivers just to be handed them back.
        let lockstep = self.cfg.megabatch && megabatch::precheck(&self.cfg.base);
        let resilient = ckpt.is_some() || resume.is_some();
        if resilient {
            if !lockstep {
                bail!("checkpoint/resume needs the lockstep execution \
                       path: enable megabatch and use the native backend \
                       with the SoA kernel");
            }
            shards = 1;
        }

        // Single-shard megabatch: the whole fleet advances in tick
        // lockstep, so the shared facility loop is fed per tick instead
        // of replaying traces post-hoc (same inputs, same plant order —
        // bitwise the same report).
        if lockstep && shards == 1 {
            match LockstepFleet::new(megabatch::build_ctxs(specs)?) {
                Ok(mut ls) => {
                    ls.set_shard(0);
                    let mut facility =
                        Some(FacilityModel::new(params.clone(), n_plants));
                    if let Some(path) = resume {
                        facility = self.load_checkpoint(path, &mut ls,
                                                        &params)?;
                    }
                    let every = ckpt.map(|c| c.every).unwrap_or(0);
                    let (plants, report, quarantined) = ls.run_with(
                        facility,
                        every,
                        |ls, fac| {
                            let spec = ckpt.expect("every > 0 needs a spec");
                            self.write_checkpoint(&spec.path, ls, fac)
                        },
                    )?;
                    // A quarantine dropped the streamed model; replay
                    // over the survivors so they match a fault-free run
                    // of the same spec subset.
                    let facility = match report {
                        Some(r) => r,
                        None => run_facility(&plants, params),
                    };
                    return assemble(plants, facility, quarantined, shards,
                                    start);
                }
                // Not lockstep-eligible on the deep per-plant check:
                // fall through to the per-plant path with the
                // already-built drivers.
                Err(ctxs) => {
                    if resilient {
                        bail!("checkpoint/resume: plant bucket is not \
                               lockstep-eligible");
                    }
                    let (plants, quarantined) =
                        megabatch::run_ctxs_sequential(ctxs)?;
                    let facility = run_facility(&plants, params);
                    return assemble(plants, facility, quarantined, shards,
                                    start);
                }
            }
        }

        // Contiguous block sharding: plant order inside a shard equals
        // fleet order, and shard sizes differ by at most one for any
        // n_plants % shards. Assignment is order-independent for
        // results — every cross-plant reduction runs in plant-index
        // order regardless of which shard ran a plant.
        let buckets = blocks(specs, shards);
        // Remember which plants rode which shard: a shard that dies
        // (panic past the per-plant containment, or a setup error)
        // quarantines its whole bucket instead of aborting the fleet.
        let bucket_indices: Vec<Vec<usize>> = buckets
            .iter()
            .map(|b| b.iter().map(|s| s.index).collect())
            .collect();

        let mut quarantined: Vec<QuarantineEntry> = Vec::new();
        let mut slots: Vec<Option<PlantRun>> =
            (0..n_plants).map(|_| None).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(buckets.len());
            for (shard, bucket) in buckets.into_iter().enumerate() {
                handles.push(
                    scope.spawn(move || run_bucket(bucket, lockstep, shard)),
                );
            }
            for (shard, h) in handles.into_iter().enumerate() {
                match h.join() {
                    Ok(Ok((shard_runs, q))) => {
                        for run in shard_runs {
                            let i = run.index;
                            slots[i] = Some(run);
                        }
                        quarantined.extend(q);
                    }
                    Ok(Err(e)) => {
                        for &i in &bucket_indices[shard] {
                            note_quarantine(&mut quarantined, i,
                                            &format!("shard error: {e:#}"));
                        }
                    }
                    Err(_) => {
                        for &i in &bucket_indices[shard] {
                            note_quarantine(&mut quarantined, i,
                                            "shard panicked");
                        }
                    }
                }
            }
        });
        let mut plants = Vec::with_capacity(n_plants);
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(run) => plants.push(run),
                None => {
                    if !quarantined.iter().any(|q| q.index == i) {
                        note_quarantine(&mut quarantined, i,
                                        "no result from shard");
                    }
                }
            }
        }

        // Facility pass + aggregation, both in plant-index order.
        let facility = run_facility(&plants, params);
        assemble(plants, facility, quarantined, shards, start)
    }

    /// `idatacool-ckpt/1` header: the run identity a snapshot belongs
    /// to. The resume path refuses a checkpoint whose scenario, fleet
    /// shape, seed, or base-config fingerprint disagrees with the
    /// current invocation — resuming under a different config would
    /// silently produce a chimera document.
    fn save_header(&self, w: &mut SnapWriter) {
        w.str(self.cfg.scenario.name());
        w.u64(self.cfg.n_plants as u64);
        w.u64(self.cfg.fleet_seed);
        w.u64(crate::bench::record::config_fingerprint(&self.cfg.base));
    }

    fn write_checkpoint(&self, path: &Path, ls: &LockstepFleet,
                        facility: Option<&FacilityModel>) -> Result<()> {
        let _span = crate::obs::span("checkpoint");
        let mut w = SnapWriter::new();
        self.save_header(&mut w);
        ls.save_state(&mut w);
        match facility {
            Some(model) => {
                w.bool(true);
                model.save_state(&mut w);
            }
            None => w.bool(false),
        }
        checkpoint::atomic_write(path, &w.into_bytes())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    /// Validate + restore a snapshot into a freshly built engine.
    /// Returns the streamed facility model mid-integral (`None` when
    /// the snapshot predates no facility — i.e. a quarantine had
    /// already dropped it).
    fn load_checkpoint(&self, path: &Path, ls: &mut LockstepFleet,
                       params: &FacilityParams)
                       -> Result<Option<FacilityModel>> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}",
                                     path.display()))?;
        let mut r = SnapReader::new(&bytes)?;
        let scenario = r.str()?;
        if scenario != self.cfg.scenario.name() {
            bail!("checkpoint was taken under scenario '{scenario}', this \
                   run uses '{}'", self.cfg.scenario.name());
        }
        let n = r.u64()? as usize;
        if n != self.cfg.n_plants {
            bail!("checkpoint covers {n} plants, this run configures {}",
                  self.cfg.n_plants);
        }
        let seed = r.u64()?;
        if seed != self.cfg.fleet_seed {
            bail!("checkpoint fleet seed {seed:#x} != configured {:#x}",
                  self.cfg.fleet_seed);
        }
        let fp = r.u64()?;
        let want = crate::bench::record::config_fingerprint(&self.cfg.base);
        if fp != want {
            bail!("checkpoint base-config fingerprint {fp:#018x} != \
                   configured {want:#018x}");
        }
        ls.restore_state(&mut r)?;
        let facility = if r.bool()? {
            let mut model =
                FacilityModel::new(params.clone(), self.cfg.n_plants);
            model.restore_state(&mut r)?;
            Some(model)
        } else {
            None
        };
        if !r.done() {
            bail!("trailing bytes after checkpoint payload");
        }
        Ok(facility)
    }
}

/// The one place a `FleetRun` is put together — every execution path
/// (streamed-facility lockstep, lockstep fallback, sharded) funnels
/// through here so the assembly cannot drift between them. A fleet
/// whose every plant quarantined has no result to degrade into — that
/// (and only that) is still an error.
fn assemble(plants: Vec<PlantRun>, facility: FacilityReport,
            quarantined: Vec<QuarantineEntry>, shards: usize,
            start: Instant) -> Result<FleetRun> {
    if plants.is_empty() {
        let reasons: Vec<String> = quarantined
            .iter()
            .map(|q| format!("plant {}: {}", q.index, q.reason))
            .collect();
        bail!("every plant quarantined: {}", reasons.join("; "));
    }
    let aggregate = FleetAggregate::build(&plants, &facility, quarantined);
    Ok(FleetRun {
        plants,
        facility,
        aggregate,
        shards,
        wall_s: start.elapsed().as_secs_f64(),
    })
}

/// Run one shard's plants: in tick lockstep over one shared lane arena
/// (megabatch, config-prechecked by the caller), or sequentially, each
/// plant owning its full driver. Either way the bucket reports its own
/// evictions; an `Err` (or a panic past the per-plant containment)
/// quarantines the whole bucket in the caller.
fn run_bucket(bucket: Vec<PlantSpec>, lockstep: bool, shard: usize)
              -> Result<(Vec<PlantRun>, Vec<QuarantineEntry>)> {
    if lockstep {
        return match LockstepFleet::new(megabatch::build_ctxs(bucket)?) {
            Ok(mut ls) => {
                ls.set_shard(shard);
                ls.run(None).map(|(plants, _, q)| (plants, q))
            }
            Err(ctxs) => megabatch::run_ctxs_sequential(ctxs),
        };
    }
    // Megabatch off (or not lockstep-capable): one plant at a time —
    // only one driver alive per shard at any moment. Each plant is its
    // own fault domain, exactly like the sequential megabatch fallback.
    let mut out = Vec::with_capacity(bucket.len());
    let mut quarantined = Vec::new();
    for spec in bucket {
        let PlantSpec { index, label, seed, cfg, faults } = spec;
        let mut driver = match SimulationDriver::from_prebuilt(cfg, seed,
                                                               faults) {
            Ok(d) => d,
            Err(e) => {
                note_quarantine(&mut quarantined, index,
                                &format!("driver build error: {e:#}"));
                continue;
            }
        };
        driver.chaos_plant = Some(index);
        let tick_s = driver.backend.tick_seconds(&driver.cfg.pp);
        // sample_every = 1: the facility pass needs every tick.
        match catch_unwind(AssertUnwindSafe(|| driver.run(1))) {
            Ok(Ok(result)) => {
                out.push(PlantRun { index, label, seed, tick_s, result });
            }
            Ok(Err(e)) => {
                note_quarantine(&mut quarantined, index,
                                &format!("run error: {e:#}"));
            }
            Err(_) => {
                note_quarantine(&mut quarantined, index,
                                "panic in plant run");
            }
        }
    }
    Ok((out, quarantined))
}

/// One trace sample's contribution to the facility loop — the single
/// conversion both facility feeds (post-hoc replay here, per-tick
/// streaming in `megabatch::LockstepFleet::run`) share, so they cannot
/// drift.
pub(crate) fn plant_tick_of(s: &TraceSample) -> PlantTick {
    PlantTick {
        p_heat_w: s.p_d,
        t_return: s.t_rack_out,
        p_ac_w: s.p_ac,
    }
}

/// Replay the finished plant traces through the shared facility loop,
/// tick-aligned and in plant-index order.
///
/// The replay is a pure function of finished traces, so a panic — the
/// chaos `facility_step` site, or an organic defect — is recoverable by
/// retrying once: chaos rules fire exactly once, and a deterministic
/// organic panic simply repeats and propagates on the second attempt.
pub fn run_facility(plants: &[PlantRun], params: FacilityParams)
                    -> FacilityReport {
    match catch_unwind(AssertUnwindSafe(|| {
        replay_facility(plants, params.clone())
    })) {
        Ok(report) => report,
        Err(_) => replay_facility(plants, params),
    }
}

fn replay_facility(plants: &[PlantRun], params: FacilityParams)
                   -> FacilityReport {
    let _span = crate::obs::span("facility");
    let mut model = FacilityModel::new(params, plants.len());
    let n_ticks = plants
        .iter()
        .map(|p| p.result.trace.len())
        .min()
        .unwrap_or(0);
    let dt = plants.first().map(|p| p.tick_s).unwrap_or(0.0);
    let mut inputs = Vec::with_capacity(plants.len());
    for t in 0..n_ticks {
        if inject::armed() {
            inject::fire(Site::FacilityStep, None);
        }
        inputs.clear();
        for p in plants {
            inputs.push(plant_tick_of(&p.result.trace[t]));
        }
        model.pool_tick(&inputs, dt);
    }
    model.into_report()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plant_seeds_are_stable_and_distinct() {
        let s: Vec<u64> = (0..32).map(|i| plant_seed(0x1DA7, i)).collect();
        let again: Vec<u64> = (0..32).map(|i| plant_seed(0x1DA7, i)).collect();
        assert_eq!(s, again);
        for (i, a) in s.iter().enumerate() {
            for b in &s[i + 1..] {
                assert_ne!(a, b, "seed collision");
            }
        }
        // and the fleet seed matters
        assert_ne!(plant_seed(1, 0), plant_seed(2, 0));
    }

    #[test]
    fn driver_rejects_degenerate_configs() {
        let base = SimConfig::test_small();
        let scenario = Scenario::by_name("baseline").unwrap();
        let bad = FleetConfig {
            n_plants: 0,
            shards: 1,
            base: base.clone(),
            fleet_seed: 1,
            scenario,
            megabatch: true,
        };
        assert!(FleetDriver::new(bad).is_err());
        let bad = FleetConfig {
            n_plants: 2,
            shards: 0,
            base,
            fleet_seed: 1,
            scenario,
            megabatch: true,
        };
        assert!(FleetDriver::new(bad).is_err());
    }

    #[test]
    fn megabatch_defaults_on_without_env() {
        // The parse half is covered by util::cli; here: the unset-env
        // default is on (tests must not mutate process-global env).
        if std::env::var_os("IDATACOOL_FLEET_MEGABATCH").is_none() {
            assert!(default_megabatch().unwrap());
        }
    }

    #[test]
    fn specs_cover_every_plant_in_order() {
        let base = SimConfig::test_small();
        let cfg = FleetConfig {
            n_plants: 5,
            shards: 2,
            base,
            fleet_seed: 9,
            scenario: Scenario::by_name("mixed").unwrap(),
            megabatch: true,
        };
        let d = FleetDriver::new(cfg).unwrap();
        let specs = d.specs();
        assert_eq!(specs.len(), 5);
        for (i, s) in specs.iter().enumerate() {
            assert_eq!(s.index, i);
            assert_eq!(s.seed, plant_seed(9, i));
        }
    }
}
