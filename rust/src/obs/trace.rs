//! Thread-local span recorders and the Chrome `trace_event` writer.
//!
//! Each thread that records a span lazily allocates one ring buffer and
//! registers it (once) in a global list. Recording locks only the
//! thread's own buffer — uncontended except during a flush — and the
//! buffer is bounded: when full, the oldest event is dropped and
//! counted, so a long capture keeps the most recent window instead of
//! growing without bound. A parallel cumulative per-name aggregate is
//! kept outside the ring, so phase totals (used for bench breakdowns)
//! are exact even after eviction.

use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Per-thread event capacity. At roughly five spans per plant-tick this
/// holds on the order of an hour of simulated time per thread; beyond
/// that the oldest events are evicted (and counted in `dropped`).
const RING_CAP: usize = 1 << 18;

/// Span name: either a `&'static` phase label or an owned label built
/// at runtime (e.g. `megabatch_sweep/shard=3`).
#[derive(Clone, Debug)]
pub enum Name {
    Static(&'static str),
    Owned(Arc<str>),
}

impl Name {
    pub fn as_str(&self) -> &str {
        match self {
            Name::Static(s) => s,
            Name::Owned(s) => s,
        }
    }
}

/// One completed span, timestamped in microseconds since the process
/// trace epoch.
#[derive(Clone, Debug)]
pub struct Event {
    pub name: Name,
    pub ts_us: f64,
    pub dur_us: f64,
}

struct RingBuf {
    tid: u64,
    events: VecDeque<Event>,
    dropped: u64,
    /// Cumulative per-name (count, total µs), never evicted.
    totals: BTreeMap<String, (u64, f64)>,
}

impl RingBuf {
    fn new(tid: u64) -> Self {
        RingBuf { tid, events: VecDeque::new(), dropped: 0, totals: BTreeMap::new() }
    }
}

fn registry() -> &'static Mutex<Vec<Arc<Mutex<RingBuf>>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<Mutex<RingBuf>>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

thread_local! {
    static LOCAL: RefCell<Option<Arc<Mutex<RingBuf>>>> = const { RefCell::new(None) };
}

fn local_ring() -> Arc<Mutex<RingBuf>> {
    LOCAL.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some(ring) = slot.as_ref() {
            return ring.clone();
        }
        static NEXT_TID: AtomicU64 = AtomicU64::new(1);
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let ring = Arc::new(Mutex::new(RingBuf::new(tid)));
        registry()
            .lock()
            .expect("trace registry poisoned")
            .push(ring.clone());
        *slot = Some(ring.clone());
        ring
    })
}

/// Record one completed span. Only called from an enabled `SpanGuard`
/// drop, so the disabled path never reaches here.
pub(crate) fn record(name: Name, start: Instant) {
    let end = Instant::now();
    let e = epoch();
    let ts_us = start.duration_since(e).as_secs_f64() * 1e6;
    let dur_us = end.duration_since(start).as_secs_f64() * 1e6;
    let ring = local_ring();
    let mut buf = ring.lock().expect("trace ring poisoned");
    let t = buf.totals.entry(name.as_str().to_string()).or_insert((0, 0.0));
    t.0 += 1;
    t.1 += dur_us;
    if buf.events.len() >= RING_CAP {
        buf.events.pop_front();
        buf.dropped += 1;
    }
    buf.events.push_back(Event { name, ts_us, dur_us });
}

/// Clear every registered buffer (events, drop counts, and cumulative
/// totals). Call before starting a fresh capture.
pub fn reset() {
    let rings = registry().lock().expect("trace registry poisoned").clone();
    for ring in rings {
        let mut buf = ring.lock().expect("trace ring poisoned");
        buf.events.clear();
        buf.dropped = 0;
        buf.totals.clear();
    }
}

/// Copy out every thread's buffered events: `(tid, events, dropped)`.
pub fn snapshot() -> Vec<(u64, Vec<Event>, u64)> {
    let rings = registry().lock().expect("trace registry poisoned").clone();
    let mut out = Vec::with_capacity(rings.len());
    for ring in rings {
        let buf = ring.lock().expect("trace ring poisoned");
        out.push((buf.tid, buf.events.iter().cloned().collect(), buf.dropped));
    }
    out.sort_by_key(|(tid, _, _)| *tid);
    out
}

/// Cumulative per-span-name `(count, total µs)` across all threads,
/// summed from the eviction-proof aggregates. Deltas of two calls give
/// an exact phase attribution for the interval between them.
pub fn phase_totals() -> BTreeMap<String, (u64, f64)> {
    let rings = registry().lock().expect("trace registry poisoned").clone();
    let mut out: BTreeMap<String, (u64, f64)> = BTreeMap::new();
    for ring in rings {
        let buf = ring.lock().expect("trace ring poisoned");
        for (name, (n, us)) in &buf.totals {
            let t = out.entry(name.clone()).or_insert((0, 0.0));
            t.0 += *n;
            t.1 += *us;
        }
    }
    out
}

/// Render every buffered span as Chrome `trace_event` JSON — the
/// `{"traceEvents": [...]}` object format that Perfetto and
/// `chrome://tracing` load directly. Events are complete (`"ph": "X"`)
/// spans sorted by `(tid, ts, -dur)` so parents precede children.
pub fn chrome_trace_json() -> String {
    let mut all: Vec<(u64, Event)> = Vec::new();
    let mut dropped_total = 0u64;
    for (tid, events, dropped) in snapshot() {
        dropped_total += dropped;
        for e in events {
            all.push((tid, e));
        }
    }
    all.sort_by(|a, b| {
        a.0.cmp(&b.0)
            .then(a.1.ts_us.total_cmp(&b.1.ts_us))
            .then(b.1.dur_us.total_cmp(&a.1.dur_us))
    });
    let mut out = String::with_capacity(64 + all.len() * 96);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"droppedEvents\":");
    out.push_str(&dropped_total.to_string());
    out.push_str(",\"traceEvents\":[");
    for (i, (tid, e)) in all.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"cat\":\"idatacool\",\"dur\":{},\"name\":{:?},\"ph\":\"X\",\"pid\":1,\"tid\":{},\"ts\":{}}}",
            e.dur_us,
            e.name.as_str(),
            tid,
            e.ts_us
        ));
    }
    out.push_str("]}");
    out
}

/// Write [`chrome_trace_json`] to `path`.
pub fn write_chrome_trace(path: &std::path::Path) -> anyhow::Result<()> {
    std::fs::write(path, chrome_trace_json())
        .map_err(|e| anyhow::anyhow!("writing trace to {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    // The enable flag is process-global and unit tests run in parallel,
    // so tests that toggle it serialize on this lock.
    fn flag_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _g = flag_lock();
        crate::obs::disable();
        reset();
        {
            let _s = crate::obs::span("unit_test_disabled");
        }
        let totals = phase_totals();
        assert!(!totals.contains_key("unit_test_disabled"));
    }

    #[test]
    fn enabled_span_lands_in_ring_and_totals() {
        let _g = flag_lock();
        crate::obs::enable();
        reset();
        {
            let _s = crate::obs::span("unit_test_enabled");
        }
        crate::obs::disable();
        let totals = phase_totals();
        let (n, us) = totals.get("unit_test_enabled").copied().expect("span recorded");
        assert_eq!(n, 1);
        assert!(us >= 0.0);
        let json = chrome_trace_json();
        assert!(json.contains("\"unit_test_enabled\""));
        assert!(json.contains("\"ph\":\"X\""));
    }

    #[test]
    fn dyn_span_uses_owned_name() {
        let _g = flag_lock();
        crate::obs::enable();
        reset();
        let label: Arc<str> = Arc::from("unit_test_dyn/shard=7");
        {
            let _s = crate::obs::span_dyn(&label);
        }
        crate::obs::disable();
        assert!(phase_totals().contains_key("unit_test_dyn/shard=7"));
    }
}
