//! Named counters / gauges / histograms with Prometheus text exposition.
//!
//! Everything here is lock-free on the update path: counters and gauges
//! are single `AtomicU64`s, histograms are per-shard `AtomicU64` bin
//! arrays (one shard per server worker) that are only merged into a
//! [`crate::stats::Histogram`] at scrape time. Registries hand out
//! `Arc`s so hot paths hold direct references and never touch the
//! registry lock after setup.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::stats::histogram::Histogram;

/// Monotonic event counter.
pub struct Counter {
    name: &'static str,
    help: &'static str,
    v: AtomicU64,
}

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Point-in-time value; `record_max` keeps a high-water mark.
pub struct Gauge {
    name: &'static str,
    help: &'static str,
    v: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_max(&self, v: u64) {
        self.v.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Counter family over one label dimension with a fixed value catalog
/// (e.g. per-endpoint request counts).
pub struct CounterVec {
    name: &'static str,
    help: &'static str,
    label: &'static str,
    labels: &'static [&'static str],
    values: Vec<AtomicU64>,
}

impl CounterVec {
    #[inline]
    pub fn inc(&self, i: usize) {
        self.values[i].fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self, i: usize) -> u64 {
        self.values[i].load(Ordering::Relaxed)
    }

    pub fn labels(&self) -> &'static [&'static str] {
        self.labels
    }
}

struct AtomicBins {
    bins: Vec<AtomicU64>,
    underflow: AtomicU64,
    overflow: AtomicU64,
}

/// Histogram sharded into independent atomic-bin arrays — one shard per
/// writer (server worker) — so pushes never contend. Shards are summed
/// into a plain [`Histogram`] only at scrape time.
pub struct ShardedHistogram {
    name: &'static str,
    help: &'static str,
    lo: f64,
    hi: f64,
    /// When true, stored values are log10 and exposition quantiles are
    /// mapped back through `10^q` (the server records log10-milliseconds).
    log10: bool,
    shards: Vec<AtomicBins>,
}

impl ShardedHistogram {
    /// Record `x` into shard `shard % n_shards`. Lock-free.
    ///
    /// NaN convention (see DESIGN.md §8): a non-finite sample is *data*
    /// arriving at a sink — it is counted (as underflow) so totals stay
    /// honest, never silently dropped and never allowed to poison bins.
    #[inline]
    pub fn push(&self, shard: usize, x: f64) {
        let s = &self.shards[shard % self.shards.len()];
        if !x.is_finite() || x < self.lo {
            s.underflow.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if x >= self.hi {
            s.overflow.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let n = s.bins.len();
        let idx = (((x - self.lo) / (self.hi - self.lo)) * n as f64) as usize;
        s.bins[idx.min(n - 1)].fetch_add(1, Ordering::Relaxed);
    }

    /// Sum every shard into one [`Histogram`] snapshot.
    pub fn merged(&self) -> Histogram {
        let bins = self.shards[0].bins.len();
        let mut h = Histogram::new(self.lo, self.hi, bins);
        for s in &self.shards {
            for (i, b) in s.bins.iter().enumerate() {
                let n = b.load(Ordering::Relaxed);
                h.counts[i] += n;
                h.total += n;
            }
            let u = s.underflow.load(Ordering::Relaxed);
            let o = s.overflow.load(Ordering::Relaxed);
            h.underflow += u;
            h.overflow += o;
            h.total += u + o;
        }
        h
    }

    fn expo_quantile(&self, h: &Histogram, q: f64) -> f64 {
        let v = h.quantile(q);
        // NaN convention: a quantile of an empty histogram is a
        // *derived* statistic, reported as the neutral 0 (DESIGN.md §8).
        if v.is_nan() {
            return 0.0;
        }
        if self.log10 { 10f64.powf(v) } else { v }
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    CounterVec(Arc<CounterVec>),
    Histogram(Arc<ShardedHistogram>),
}

impl Metric {
    fn name(&self) -> &'static str {
        match self {
            Metric::Counter(c) => c.name,
            Metric::Gauge(g) => g.name,
            Metric::CounterVec(c) => c.name,
            Metric::Histogram(h) => h.name,
        }
    }

    fn render(&self, out: &mut String) {
        match self {
            Metric::Counter(c) => {
                header(out, c.name, c.help, "counter");
                out.push_str(&format!("{} {}\n", c.name, c.get()));
            }
            Metric::Gauge(g) => {
                header(out, g.name, g.help, "gauge");
                out.push_str(&format!("{} {}\n", g.name, g.get()));
            }
            Metric::CounterVec(c) => {
                header(out, c.name, c.help, "counter");
                for (i, l) in c.labels.iter().enumerate() {
                    out.push_str(&format!("{}{{{}=\"{}\"}} {}\n", c.name, c.label, l, c.get(i)));
                }
            }
            Metric::Histogram(hist) => {
                header(out, hist.name, hist.help, "summary");
                let h = hist.merged();
                for q in [0.5, 0.9, 0.99] {
                    out.push_str(&format!(
                        "{}{{quantile=\"{}\"}} {}\n",
                        hist.name,
                        q,
                        hist.expo_quantile(&h, q)
                    ));
                }
                out.push_str(&format!("{}_count {}\n", hist.name, h.total));
            }
        }
    }
}

fn header(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

/// A set of named metrics rendered together. The server owns one per
/// instance; sim-domain counters live in the process-wide [`global`]
/// registry.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<Vec<Metric>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &'static str, help: &'static str) -> Arc<Counter> {
        let mut m = self.metrics.lock().expect("metrics registry poisoned");
        for existing in m.iter() {
            if existing.name() == name {
                match existing {
                    Metric::Counter(c) => return c.clone(),
                    _ => panic!("metric {name} already registered with a different kind"),
                }
            }
        }
        let c = Arc::new(Counter { name, help, v: AtomicU64::new(0) });
        m.push(Metric::Counter(c.clone()));
        c
    }

    pub fn gauge(&self, name: &'static str, help: &'static str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().expect("metrics registry poisoned");
        for existing in m.iter() {
            if existing.name() == name {
                match existing {
                    Metric::Gauge(g) => return g.clone(),
                    _ => panic!("metric {name} already registered with a different kind"),
                }
            }
        }
        let g = Arc::new(Gauge { name, help, v: AtomicU64::new(0) });
        m.push(Metric::Gauge(g.clone()));
        g
    }

    pub fn counter_vec(
        &self,
        name: &'static str,
        help: &'static str,
        label: &'static str,
        labels: &'static [&'static str],
    ) -> Arc<CounterVec> {
        assert!(!labels.is_empty(), "counter_vec needs at least one label value");
        let mut m = self.metrics.lock().expect("metrics registry poisoned");
        for existing in m.iter() {
            if existing.name() == name {
                match existing {
                    Metric::CounterVec(c) => return c.clone(),
                    _ => panic!("metric {name} already registered with a different kind"),
                }
            }
        }
        let values = (0..labels.len()).map(|_| AtomicU64::new(0)).collect();
        let c = Arc::new(CounterVec { name, help, label, labels, values });
        m.push(Metric::CounterVec(c.clone()));
        c
    }

    /// Register a sharded histogram over `[lo, hi)` with `bins` bins and
    /// `shards` independent writer slots. `log10` marks the stored
    /// values as log10 for exposition (quantiles mapped through `10^q`).
    #[allow(clippy::too_many_arguments)]
    pub fn histogram(
        &self,
        name: &'static str,
        help: &'static str,
        lo: f64,
        hi: f64,
        bins: usize,
        shards: usize,
        log10: bool,
    ) -> Arc<ShardedHistogram> {
        assert!(hi > lo && bins > 0 && shards > 0, "degenerate histogram spec");
        let mut m = self.metrics.lock().expect("metrics registry poisoned");
        for existing in m.iter() {
            if existing.name() == name {
                match existing {
                    Metric::Histogram(h) => return h.clone(),
                    _ => panic!("metric {name} already registered with a different kind"),
                }
            }
        }
        let mk = || AtomicBins {
            bins: (0..bins).map(|_| AtomicU64::new(0)).collect(),
            underflow: AtomicU64::new(0),
            overflow: AtomicU64::new(0),
        };
        let h = Arc::new(ShardedHistogram {
            name,
            help,
            lo,
            hi,
            log10,
            shards: (0..shards).map(|_| mk()).collect(),
        });
        m.push(Metric::Histogram(h.clone()));
        h
    }

    /// Prometheus text exposition (format version 0.0.4) of every
    /// registered metric, sorted by metric name.
    pub fn to_prometheus(&self) -> String {
        let m = self.metrics.lock().expect("metrics registry poisoned");
        let mut order: Vec<&Metric> = m.iter().collect();
        order.sort_by_key(|x| x.name());
        let mut out = String::new();
        for metric in order {
            metric.render(&mut out);
        }
        out
    }
}

/// Process-wide registry for sim-domain counters. Updates are gated on
/// [`crate::obs::enabled`] at the call sites, so disabled runs never
/// touch these.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Ticks observed with at least one throttling node (counted once per
/// sampled tick, per plant).
pub fn throttle_events() -> &'static Counter {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| {
        global().counter(
            "idatacool_throttle_events_total",
            "Sim ticks observed with at least one throttling node",
        )
    })
}

/// Non-finite values caught by the numeric integrity sentinels over the
/// per-plant kernel reductions (`plant::soa` epilogues). One increment
/// per poisoned reduction observed, not per NaN lane entry.
pub fn numeric_faults() -> &'static Counter {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| {
        global().counter(
            "idatacool_numeric_faults_total",
            "Non-finite per-plant kernel reductions caught by the \
             integrity sentinels",
        )
    })
}

/// Plants evicted from a fleet run by the quarantine sweep (panic or
/// non-finite state); see DESIGN.md §8.
pub fn quarantined_plants() -> &'static Counter {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| {
        global().counter(
            "idatacool_quarantined_plants_total",
            "Plants evicted from fleet runs by the quarantine sweep",
        )
    })
}

/// Serve workers respawned by the supervisor after a panic or a
/// condemned stall (bounded by the restart budget); see DESIGN.md §10.
pub fn worker_restarts() -> &'static Counter {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| {
        global().counter(
            "idatacool_worker_restarts_total",
            "Serve workers respawned by the supervisor",
        )
    })
}

/// Queued requests answered 504 without compute because the client
/// deadline expired while the job was parked in the queue.
pub fn deadline_drops() -> &'static Counter {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| {
        global().counter(
            "idatacool_deadline_drops_total",
            "Queued requests dropped 504 after their deadline expired",
        )
    })
}

/// Lane-state synchronizations in the SoA plant backend: node-major
/// loads into lanes plus lane-major materializations back out.
pub fn lane_sync_transitions() -> &'static Counter {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| {
        global().counter(
            "idatacool_lane_sync_transitions_total",
            "SoA lane-state loads and node-major materializations",
        )
    })
}

/// Candidate evaluations executed by the optimize subsystem (physical
/// fleet runs only — cache hits never reach the counter).
pub fn optimize_evals() -> &'static Counter {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| {
        global().counter(
            "idatacool_optimize_evals_total",
            "Candidate evaluations executed by the optimize subsystem",
        )
    })
}

/// Writer shards for the serve-layer batch histograms below: their
/// writers are batch-round leaders (one push per round), so a small
/// fixed shard count is plenty — callers pass `worker % BATCH_SHARDS`.
pub const BATCH_SHARDS: usize = 8;

/// Plants packed per batched arena sweep (1 = a request that found no
/// companions in its admission window). Unlike the sim-domain counters
/// above, the serving layer records this unconditionally — it is
/// operational telemetry, not tracing.
pub fn batch_occupancy() -> &'static ShardedHistogram {
    static H: OnceLock<Arc<ShardedHistogram>> = OnceLock::new();
    H.get_or_init(|| {
        global().histogram(
            "idatacool_batch_occupancy",
            "Plants packed per batched lane-arena sweep",
            0.0,
            65.0,
            65,
            BATCH_SHARDS,
            false,
        )
    })
}

/// Milliseconds a request waited in the batch admission window before
/// its sweep started (log10 ms, like the request-latency histogram).
pub fn batch_window_wait_ms() -> &'static ShardedHistogram {
    static H: OnceLock<Arc<ShardedHistogram>> = OnceLock::new();
    H.get_or_init(|| {
        global().histogram(
            "idatacool_batch_window_wait_ms",
            "Batch admission-window wait per request (ms)",
            -3.0,
            5.0,
            160,
            BATCH_SHARDS,
            true,
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render_prometheus() {
        let r = Registry::new();
        let c = r.counter("t_requests_total", "requests");
        let g = r.gauge("t_queue_hwm", "queue high-water");
        c.add(3);
        g.record_max(7);
        g.record_max(4);
        let text = r.to_prometheus();
        assert!(text.contains("# TYPE t_queue_hwm gauge"));
        assert!(text.contains("# TYPE t_requests_total counter"));
        assert!(text.contains("t_requests_total 3\n"));
        assert!(text.contains("t_queue_hwm 7\n"));
    }

    #[test]
    fn counter_vec_renders_labels() {
        let r = Registry::new();
        let v = r.counter_vec("t_by_endpoint_total", "per endpoint", "endpoint", &["a", "b"]);
        v.inc(1);
        v.inc(1);
        let text = r.to_prometheus();
        assert!(text.contains("t_by_endpoint_total{endpoint=\"a\"} 0\n"));
        assert!(text.contains("t_by_endpoint_total{endpoint=\"b\"} 2\n"));
    }

    #[test]
    fn sharded_histogram_merges_and_maps_log_quantiles() {
        let r = Registry::new();
        let h = r.histogram("t_latency_ms", "latency", -3.0, 5.0, 160, 4, true);
        // Push the same value from every shard; the merged median must
        // land on it after the 10^q mapping.
        for shard in 0..4 {
            for _ in 0..10 {
                h.push(shard, 1.0); // log10(10 ms)
            }
        }
        let merged = h.merged();
        assert_eq!(merged.total, 40);
        let text = r.to_prometheus();
        assert!(text.contains("# TYPE t_latency_ms summary"));
        assert!(text.contains("t_latency_ms_count 40\n"));
        // quantile lines are in ms-space, near 10.0
        let q50 = 10f64.powf(merged.quantile(0.5));
        assert!((q50 - 10.0).abs() / 10.0 < 0.1, "q50 = {q50}");
    }

    #[test]
    fn empty_histogram_exposes_zero_quantiles() {
        let r = Registry::new();
        let h = r.histogram("t_empty_ms", "latency", -3.0, 5.0, 160, 2, true);
        let _ = h; // registered but never pushed
        let text = r.to_prometheus();
        assert!(text.contains("t_empty_ms{quantile=\"0.5\"} 0\n"));
        assert!(text.contains("t_empty_ms_count 0\n"));
    }

    #[test]
    fn registry_dedups_by_name() {
        let r = Registry::new();
        let a = r.counter("t_dedup_total", "x");
        let b = r.counter("t_dedup_total", "x");
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2);
    }

    #[test]
    fn global_domain_counters_are_stable() {
        let c1 = throttle_events() as *const _;
        let c2 = throttle_events() as *const _;
        assert_eq!(c1, c2);
        let _ = lane_sync_transitions();
        let _ = optimize_evals();
    }
}
