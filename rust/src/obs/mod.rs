//! Flight recorder: crate-wide tracing spans and a metrics registry.
//!
//! The subsystem is built around one invariant: **when disabled (the
//! default), instrumented code pays a single relaxed atomic load** — no
//! locks, no allocations, no clock reads. Every `span()` call site first
//! checks the global flag; a disabled guard carries `None` and its `Drop`
//! is a no-op. Wall-clock time therefore only ever flows into trace and
//! metrics *output*, never into simulation results — the determinism
//! contract checked by `prop_tracing_is_invisible`.
//!
//! Two halves:
//!
//! * [`trace`] — thread-local ring-buffer span recorders flushed into
//!   Chrome `trace_event` JSON (loadable in Perfetto / `chrome://tracing`).
//! * [`metrics`] — named counters / gauges / sharded histograms with
//!   Prometheus text exposition, used by the HTTP server and for
//!   sim-domain event counters.

pub mod metrics;
pub mod trace;

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Global master switch. All span recording and domain-counter updates
/// are gated on this flag; server request metrics are always on (they
/// are part of the serving contract, not the sim hot path).
static ENABLED: AtomicBool = AtomicBool::new(false);

/// One relaxed load. This is the entire disabled-path cost of a span.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the flight recorder on. Typically paired with
/// [`trace::reset`] so the capture starts from a clean buffer.
pub fn enable() {
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn the flight recorder off. Buffered events stay readable until
/// the next [`trace::reset`].
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// RAII span: records one complete (`ph: "X"`) trace event on drop.
///
/// Obtained from [`span`] (static name) or [`span_dyn`] (owned name,
/// e.g. `megabatch_sweep/shard=3`). When the recorder is disabled the
/// guard holds `None` and dropping it does nothing.
pub struct SpanGuard {
    start: Option<Instant>,
    name: trace::Name,
}

/// Open a span with a `&'static` name. Disabled cost: one relaxed load.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if enabled() {
        SpanGuard { start: Some(Instant::now()), name: trace::Name::Static(name) }
    } else {
        SpanGuard { start: None, name: trace::Name::Static("") }
    }
}

/// Open a span with a dynamic name. The `Arc` is only cloned when the
/// recorder is enabled, so disabled callers pay no refcount traffic.
#[inline]
pub fn span_dyn(name: &std::sync::Arc<str>) -> SpanGuard {
    if enabled() {
        SpanGuard { start: Some(Instant::now()), name: trace::Name::Owned(name.clone()) }
    } else {
        SpanGuard { start: None, name: trace::Name::Static("") }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let name = std::mem::replace(&mut self.name, trace::Name::Static(""));
            trace::record(name, t0);
        }
    }
}
