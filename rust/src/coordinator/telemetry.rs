//! Telemetry: the paper's sensing and monitoring stack (Sect. 4).
//!
//! "we estimate the node-level temperature sensors to be accurate to about
//! 1 degC, while the cluster-level temperature sensors ... have an accuracy
//! of 0.2 degC. The ultrasonic flow meter for the rack cooling circuit is
//! specified to have an accuracy of 1 %, while the flow meters for the
//! other circuits are ... only about 10 % accurate."
//!
//! Sampled quantities get the corresponding noise model (plus quantization
//! for the BMC core-temperature registers, which report whole degrees).

use crate::variability::rng::Rng;

/// Sensor accuracy configuration (paper values by default).
#[derive(Debug, Clone)]
pub struct SensorSpec {
    /// Node-level temperature sensors (core, node water est.) [K, 1 sigma].
    pub node_temp_sigma: f64,
    /// BMC quantization step for core temperatures [K].
    pub core_temp_quantum: f64,
    /// Cluster-level water temperature sensors [K, 1 sigma].
    pub cluster_temp_sigma: f64,
    /// Rack-circuit ultrasonic flow meter (relative, 1 sigma).
    pub rack_flow_rel: f64,
    /// Other circuits' simple flow meters (relative, 1 sigma).
    pub other_flow_rel: f64,
    /// Node DC power measurement (relative).
    pub power_rel: f64,
    pub enabled: bool,
}

impl Default for SensorSpec {
    fn default() -> Self {
        SensorSpec {
            node_temp_sigma: 0.5,   // "accurate to about 1 degC" (2 sigma)
            core_temp_quantum: 1.0, // BMC registers report whole degrees
            cluster_temp_sigma: 0.1, // "accuracy of 0.2 degC" (2 sigma)
            rack_flow_rel: 0.005,   // 1 % (2 sigma)
            other_flow_rel: 0.05,   // 10 % (2 sigma)
            power_rel: 0.01,
            enabled: true,
        }
    }
}

impl SensorSpec {
    pub fn noiseless() -> Self {
        SensorSpec { enabled: false, ..SensorSpec::default() }
    }
}

/// Stateful sampler applying the sensor models.
pub struct Telemetry {
    pub spec: SensorSpec,
    rng: Rng,
}

impl Telemetry {
    pub fn new(spec: SensorSpec, seed: u64) -> Self {
        Telemetry { spec, rng: Rng::new(seed ^ 0x7E1E_4E7E) }
    }

    /// Sampler RNG state for checkpointing (see `resilience`).
    pub fn rng_state(&self) -> (u64, Option<f64>) {
        self.rng.state()
    }

    /// Restore a state captured by [`Telemetry::rng_state`].
    pub fn restore_rng(&mut self, state: u64, cached_normal: Option<f64>) {
        self.rng.restore(state, cached_normal);
    }

    /// Core temperature as reported by the chip-internal sensor via BMC:
    /// Gaussian noise + integer quantization.
    pub fn core_temp(&mut self, true_t: f64) -> f64 {
        if !self.spec.enabled {
            return true_t;
        }
        let noisy = true_t + self.spec.node_temp_sigma * self.rng.normal();
        (noisy / self.spec.core_temp_quantum).round()
            * self.spec.core_temp_quantum
    }

    /// Node in/outlet water estimate (original air-flow sensors attached
    /// to the copper pipe — node-level accuracy class).
    pub fn node_water_temp(&mut self, true_t: f64) -> f64 {
        if !self.spec.enabled {
            return true_t;
        }
        true_t + self.spec.node_temp_sigma * self.rng.normal()
    }

    /// Cluster-level water temperature (direct-contact sensors).
    pub fn cluster_temp(&mut self, true_t: f64) -> f64 {
        if !self.spec.enabled {
            return true_t;
        }
        true_t + self.spec.cluster_temp_sigma * self.rng.normal()
    }

    /// Rack-circuit flow (1 % ultrasonic meter) — relative noise.
    pub fn rack_flow(&mut self, true_q: f64) -> f64 {
        if !self.spec.enabled {
            return true_q;
        }
        true_q * (1.0 + self.spec.rack_flow_rel * self.rng.normal())
    }

    /// Other circuits' flows (10 % meters) — the dominant error bar of
    /// Figs. 6(b) and 7(b).
    pub fn other_flow(&mut self, true_q: f64) -> f64 {
        if !self.spec.enabled {
            return true_q;
        }
        true_q * (1.0 + self.spec.other_flow_rel * self.rng.normal())
    }

    /// Node DC power measurement.
    pub fn node_power(&mut self, true_p: f64) -> f64 {
        if !self.spec.enabled {
            return true_p;
        }
        true_p * (1.0 + self.spec.power_rel * self.rng.normal())
    }

    /// Power derived from a 10 % flow meter and two cluster-temp sensors
    /// (e.g. P_d, P_c): propagate both error sources.
    pub fn derived_power(&mut self, true_p: f64, dt_true: f64) -> f64 {
        if !self.spec.enabled {
            return true_p;
        }
        let flow_factor = 1.0 + self.spec.other_flow_rel * self.rng.normal();
        let dt_err = self.spec.cluster_temp_sigma
            * (self.rng.normal() - self.rng.normal());
        let dt_factor = if dt_true.abs() > 1e-6 {
            (dt_true + dt_err) / dt_true
        } else {
            1.0
        };
        true_p * flow_factor * dt_factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noiseless_passthrough() {
        let mut t = Telemetry::new(SensorSpec::noiseless(), 1);
        assert_eq!(t.core_temp(83.4), 83.4);
        assert_eq!(t.rack_flow(43.2), 43.2);
        assert_eq!(t.derived_power(18_000.0, 4.0), 18_000.0);
    }

    #[test]
    fn core_temp_quantized_to_whole_degrees() {
        let mut t = Telemetry::new(SensorSpec::default(), 2);
        for _ in 0..100 {
            let v = t.core_temp(83.4);
            assert!((v - v.round()).abs() < 1e-9);
        }
    }

    #[test]
    fn noise_is_unbiased_and_scaled() {
        let mut t = Telemetry::new(SensorSpec::default(), 3);
        let n = 40_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let v = t.cluster_temp(67.0) - 67.0;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let sigma = (sq / n as f64 - mean * mean).sqrt();
        assert!(mean.abs() < 0.01, "bias {mean}");
        assert!((sigma - 0.1).abs() < 0.01, "sigma {sigma}");
    }

    #[test]
    fn flow_meters_have_relative_error() {
        let mut t = Telemetry::new(SensorSpec::default(), 4);
        let n = 40_000;
        let mut sq = 0.0;
        for _ in 0..n {
            let rel = t.other_flow(100.0) / 100.0 - 1.0;
            sq += rel * rel;
        }
        let sigma = (sq / n as f64).sqrt();
        assert!((sigma - 0.05).abs() < 0.005, "sigma {sigma}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Telemetry::new(SensorSpec::default(), 7);
        let mut b = Telemetry::new(SensorSpec::default(), 7);
        for _ in 0..50 {
            assert_eq!(a.core_temp(80.0), b.core_temp(80.0));
        }
    }
}
