//! The L3 coordinator: the data-center control plane.
//!
//! Owns the event loop. Every tick (5 s simulated) it:
//!  1. advances the batch scheduler / workload to get per-core utilization,
//!  2. lets the PID + supervisor set the control vector (3-way valve,
//!     chiller enable, pump, GPU load, ambient),
//!  3. executes the plant (AOT HLO via PJRT, or the native mirror),
//!  4. samples telemetry with the paper's sensor-noise models,
//!  5. integrates the energy account and appends to the trace.
//!
//! Python never runs here — the plant executable was lowered once by
//! `make artifacts`.

pub mod energy;
pub mod pid;
pub mod supervisor;
pub mod telemetry;

use anyhow::Result;

use crate::config::{SimConfig, WorkloadKind};
use crate::plant::layout::*;
use crate::plant::TickOutput;
use crate::runtime::{BackendKind, PlantBackend};
use crate::variability::ChipLottery;
use crate::workload::scheduler::BatchScheduler;
use crate::workload::stress::StressWorkload;
use crate::workload::{UtilPlan, WorkloadSource};
use energy::EnergyAccount;
use pid::Pid;
use supervisor::{Fault, Supervisor};
use telemetry::{SensorSpec, Telemetry};

/// One trace sample (telemetry view — what the paper's loggers record).
#[derive(Debug, Clone, Copy, Default)]
pub struct TraceSample {
    pub t_s: f64,
    pub t_rack_in: f64,
    pub t_rack_out: f64,
    pub t_tank: f64,
    pub t_primary: f64,
    pub p_ac: f64,
    pub p_dc: f64,
    pub p_r: f64,
    pub p_d: f64,
    pub p_c: f64,
    pub p_add: f64,
    pub valve: f64,
    pub chiller_on: bool,
    /// True while the supervisor holds the pump in a failure window
    /// (`Fault::PumpFailure`); the fleet aggregate counts these ticks.
    pub pump_fail: bool,
    pub core_max: f64,
    pub throttling: u32,
    pub utilization: f64,
}

impl TraceSample {
    /// Checkpoint encoding (field order is the `idatacool-ckpt/1`
    /// contract; see DESIGN.md §8).
    pub fn save(&self, w: &mut crate::resilience::checkpoint::SnapWriter) {
        w.f64(self.t_s);
        w.f64(self.t_rack_in);
        w.f64(self.t_rack_out);
        w.f64(self.t_tank);
        w.f64(self.t_primary);
        w.f64(self.p_ac);
        w.f64(self.p_dc);
        w.f64(self.p_r);
        w.f64(self.p_d);
        w.f64(self.p_c);
        w.f64(self.p_add);
        w.f64(self.valve);
        w.bool(self.chiller_on);
        w.bool(self.pump_fail);
        w.f64(self.core_max);
        w.u32(self.throttling);
        w.f64(self.utilization);
    }

    /// Decode a sample written by [`TraceSample::save`].
    pub fn load(r: &mut crate::resilience::checkpoint::SnapReader)
                -> Result<TraceSample> {
        Ok(TraceSample {
            t_s: r.f64()?,
            t_rack_in: r.f64()?,
            t_rack_out: r.f64()?,
            t_tank: r.f64()?,
            t_primary: r.f64()?,
            p_ac: r.f64()?,
            p_dc: r.f64()?,
            p_r: r.f64()?,
            p_d: r.f64()?,
            p_c: r.f64()?,
            p_add: r.f64()?,
            valve: r.f64()?,
            chiller_on: r.bool()?,
            pump_fail: r.bool()?,
            core_max: r.f64()?,
            throttling: r.u32()?,
            utilization: r.f64()?,
        })
    }
}

/// Result of a full simulation run.
pub struct RunResult {
    pub trace: Vec<TraceSample>,
    pub energy: EnergyAccount,
    pub events: Vec<supervisor::SupervisorEvent>,
    pub workload_stats: String,
    pub backend: &'static str,
    /// Wall-clock seconds spent inside PlantBackend::tick.
    pub plant_wall_s: f64,
    /// Total wall-clock for the run loop.
    pub total_wall_s: f64,
    pub ticks: u64,
}

impl RunResult {
    /// Simulated seconds per wall second (the coordinator's throughput).
    pub fn speedup(&self, tick_seconds: f64) -> f64 {
        if self.total_wall_s <= 0.0 {
            return 0.0;
        }
        self.ticks as f64 * tick_seconds / self.total_wall_s
    }
}

/// The coordinator event loop.
pub struct SimulationDriver {
    pub cfg: SimConfig,
    pub backend: PlantBackend,
    pub lottery: ChipLottery,
    pub workload: Box<dyn WorkloadSource>,
    pub telemetry: Telemetry,
    pub pid: Pid,
    pub supervisor: Supervisor,
    pub plan: UtilPlan,
    /// Fleet plant index for chaos-injection targeting (`None` outside
    /// a fleet run); see `resilience::inject`.
    pub chaos_plant: Option<usize>,
    controls: Vec<f32>,
    now_s: f64,
}

impl SimulationDriver {
    pub fn new(cfg: SimConfig) -> Result<Self> {
        Self::with_faults(cfg, Vec::new())
    }

    /// Construct from a prebuilt config with an explicit seed override and
    /// fault schedule. This is the fleet engine's entry point: the fleet
    /// driver builds one config per plant (scenario overrides applied) and
    /// derives a deterministic per-plant seed, independent of which shard
    /// thread ends up running the plant.
    pub fn from_prebuilt(
        mut cfg: SimConfig,
        seed: u64,
        faults: Vec<Fault>,
    ) -> Result<Self> {
        cfg.seed = seed;
        Self::with_faults(cfg, faults)
    }

    pub fn with_faults(cfg: SimConfig, faults: Vec<Fault>) -> Result<Self> {
        let kind: BackendKind = cfg.backend.parse()?;
        let kernel = crate::plant::PlantKernel::resolve(&cfg.kernel)?;
        let backend = PlantBackend::create_with_kernel(
            kind,
            kernel,
            &cfg.artifacts_dir,
            cfg.n_nodes,
            &cfg.pp,
            cfg.seed,
            cfg.t_water_init as f32,
        )?;
        let lottery = ChipLottery::draw(cfg.n_nodes, &cfg.pp, cfg.seed);
        let n_padded = backend.n_padded();
        let workload: Box<dyn WorkloadSource> = match cfg.workload {
            WorkloadKind::Stress => {
                let mut w =
                    StressWorkload::new(&lottery, cfg.stress_nodes, cfg.seed);
                w.background_util = cfg.stress_background as f32;
                Box::new(w)
            }
            WorkloadKind::Production => {
                let mut s = BatchScheduler::new(
                    cfg.n_nodes,
                    cfg.production_load,
                    cfg.seed,
                );
                // Warm the queue to steady state (the paper's system had
                // been in production for months; an empty queue would bias
                // the first hours of every run toward idle).
                let mut scratch = UtilPlan::idle(n_padded);
                for _ in 0..5760 {
                    s.advance(30.0, &mut scratch);
                }
                Box::new(s)
            }
            WorkloadKind::Idle => Box::new(StressWorkload::idle(cfg.n_nodes)),
        };
        let spec = if cfg.sensor_noise {
            SensorSpec::default()
        } else {
            SensorSpec::noiseless()
        };
        let mut controls = vec![0.0f32; CT];
        controls[U_VALVE] = cfg.valve_fixed as f32;
        controls[U_CHILLER_EN] = 1.0;
        controls[U_T_AMBIENT] = cfg.t_ambient as f32;
        controls[U_T_CENTRAL] = cfg.t_central as f32;
        controls[U_GPU_LOAD] = cfg.gpu_load as f32;
        controls[U_FLOW_SCALE] = cfg.pump_speed as f32;
        Ok(SimulationDriver {
            plan: UtilPlan::idle(n_padded),
            telemetry: Telemetry::new(spec, cfg.seed),
            pid: Pid::valve_default(),
            supervisor: Supervisor::new(faults),
            workload,
            backend,
            lottery,
            chaos_plant: None,
            controls,
            cfg,
            now_s: 0.0,
        })
    }

    /// Current simulated time [s].
    pub fn now(&self) -> f64 {
        self.now_s
    }

    /// Run for the configured duration; sample the trace every
    /// `sample_every` ticks (1 = every tick).
    pub fn run(&mut self, sample_every: usize) -> Result<RunResult> {
        let tick_s = self.backend.tick_seconds(&self.cfg.pp);
        let ticks = (self.cfg.duration_s / tick_s).ceil() as u64;
        self.run_ticks(ticks, sample_every)
    }

    /// Like `run`, into a caller-owned `TickOutput` (hot-path variant:
    /// the serve path keeps one buffer per worker and reuses it across
    /// requests instead of allocating per request).
    pub fn run_into(&mut self, sample_every: usize, out: &mut TickOutput)
                    -> Result<RunResult> {
        let tick_s = self.backend.tick_seconds(&self.cfg.pp);
        let ticks = (self.cfg.duration_s / tick_s).ceil() as u64;
        self.run_ticks_into(ticks, sample_every, out)
    }

    /// Run an explicit number of ticks.
    pub fn run_ticks(&mut self, ticks: u64, sample_every: usize)
                     -> Result<RunResult> {
        let mut out = TickOutput::new(self.backend.n_padded());
        self.run_ticks_into(ticks, sample_every, &mut out)
    }

    /// `run_ticks` into a caller-owned `TickOutput`. The buffer is
    /// reset first (sized + zeroed), so a reused buffer behaves exactly
    /// like the fresh one `run_ticks` used to allocate — in particular
    /// the supervisor sees zero scalars on the first tick of every run
    /// segment.
    pub fn run_ticks_into(&mut self, ticks: u64, sample_every: usize,
                          out: &mut TickOutput) -> Result<RunResult> {
        let tick_s = self.backend.tick_seconds(&self.cfg.pp);
        out.reset(self.backend.n_padded());
        let mut trace = Vec::new();
        let mut energy = EnergyAccount::new();
        let mut plant_wall = 0.0f64;
        let start = std::time::Instant::now();

        for i in 0..ticks {
            let sample = self.step(tick_s, out, &mut plant_wall)?;
            energy.push(&out.scalars, tick_s);
            if sample_every > 0 && (i as usize) % sample_every == 0 {
                trace.push(sample);
            }
        }

        Ok(RunResult {
            trace,
            energy,
            events: std::mem::take(&mut self.supervisor.events),
            workload_stats: self.workload.stats(),
            backend: self.backend.kind_name(),
            plant_wall_s: plant_wall,
            total_wall_s: start.elapsed().as_secs_f64(),
            ticks,
        })
    }

    /// One tick of the control loop; returns the telemetry-noised sample.
    ///
    /// Split into `control_phase` → plant tick → `sample_phase` so the
    /// fleet megabatch engine (`fleet::megabatch`) can interleave the
    /// control and sample phases of many plants around one shared
    /// arena sweep while reproducing this loop exactly.
    fn step(&mut self, tick_s: f64, out: &mut TickOutput,
            plant_wall: &mut f64) -> Result<TraceSample> {
        let _tick_span = crate::obs::span("tick");
        self.control_phase(tick_s, out);
        // Chaos site `plant_tick` (sequential path; the lockstep engine
        // fires it per plant in its control phase). One relaxed load
        // when unarmed.
        if crate::resilience::inject::armed() {
            use crate::resilience::inject::{fire, Action, Site};
            if let Some(Action::PoisonNan) =
                fire(Site::PlantTick, self.chaos_plant)
            {
                if let Some(np) = self.backend.native_mut() {
                    np.poison_state();
                }
            }
        }
        let t0 = std::time::Instant::now();
        self.backend.tick(&self.controls, &self.plan.util, out)?;
        *plant_wall += t0.elapsed().as_secs_f64();
        Ok(self.sample_phase(tick_s, out))
    }

    /// Pre-plant phase: advance the workload, run the PID on the
    /// measured rack outlet, let the supervisor set the control vector
    /// (`prev` carries the previous tick's scalars for its
    /// over-temperature checks).
    pub(crate) fn control_phase(&mut self, tick_s: f64, prev: &TickOutput) {
        let _span = crate::obs::span("control");
        // 1. workload
        self.workload.advance(tick_s, &mut self.plan);

        // 2. control: PID on the measured rack outlet temperature
        let t_out_meas = self
            .telemetry
            .cluster_temp(self.backend.circuit_state()[C_T_RACK_OUT] as f64);
        let pid_valve = if self.cfg.regulate {
            self.pid.update(t_out_meas - self.cfg.t_out_setpoint, tick_s)
        } else {
            self.cfg.valve_fixed
        };
        self.supervisor.apply(
            self.now_s,
            &prev.scalars,
            &mut self.controls,
            pid_valve,
            self.cfg.gpu_load,
        );
    }

    /// Post-plant phase: advance simulated time and build the
    /// telemetry-noised trace sample from the plant outputs.
    pub(crate) fn sample_phase(&mut self, tick_s: f64, out: &TickOutput)
                               -> TraceSample {
        let _span = crate::obs::span("sample");
        self.now_s += tick_s;

        // 4. telemetry view
        let sc = &out.scalars;
        let dt_rack = (sc[SC_T_RACK_OUT] - sc[SC_T_RACK_IN]) as f64;
        let util_mean = {
            let n = self.backend.n_nodes();
            (0..n).map(|i| self.plan.node_mean(i) as f64).sum::<f64>()
                / n as f64
        };
        let sample = TraceSample {
            t_s: self.now_s,
            t_rack_in: self.telemetry.cluster_temp(sc[SC_T_RACK_IN] as f64),
            t_rack_out: self.telemetry.cluster_temp(sc[SC_T_RACK_OUT] as f64),
            t_tank: self.telemetry.cluster_temp(sc[SC_T_TANK] as f64),
            t_primary: self.telemetry.cluster_temp(sc[SC_T_PRIMARY] as f64),
            p_ac: sc[SC_P_AC] as f64,
            p_dc: sc[SC_P_DC] as f64,
            p_r: self.telemetry.rack_flow(sc[SC_P_R] as f64),
            p_d: self.telemetry.derived_power(sc[SC_P_D] as f64, dt_rack),
            p_c: self.telemetry.derived_power(sc[SC_P_C] as f64, dt_rack),
            p_add: sc[SC_P_ADD] as f64,
            valve: self.controls[U_VALVE] as f64,
            chiller_on: sc[SC_CHILLER_ON] > 0.5,
            pump_fail: self.controls[U_PUMP_FAIL] > 0.5,
            core_max: sc[SC_CORE_MAX] as f64,
            throttling: sc[SC_THROTTLE] as u32,
            utilization: util_mean,
        };
        if crate::obs::enabled() && sample.throttling > 0 {
            crate::obs::metrics::throttle_events().inc();
        }
        sample
    }

    /// The current control vector `[CT]` (the megabatch engine copies
    /// it out between the control and plant phases).
    pub(crate) fn controls(&self) -> &[f32] {
        &self.controls
    }

    /// Per-node observation view with node-level sensor noise applied.
    /// Returns (node_power, core_mean, core_max, water_out) per node.
    pub fn node_observations(&mut self, out: &TickOutput)
                             -> Vec<[f64; OBS_N]> {
        let mut v = Vec::new();
        self.node_observations_into(out, &mut v);
        v
    }

    /// `node_observations` into a caller-owned buffer (hot-path variant:
    /// measurement loops reuse one buffer across ticks instead of
    /// allocating per tick). Telemetry draws are identical to
    /// `node_observations`, so both variants produce the same samples.
    pub fn node_observations_into(&mut self, out: &TickOutput,
                                  buf: &mut Vec<[f64; OBS_N]>) {
        let n = self.backend.n_nodes();
        buf.clear();
        buf.reserve(n);
        for i in 0..n {
            let o = out.node(i);
            buf.push([
                self.telemetry.node_power(o[O_NODE_POWER] as f64),
                self.telemetry.core_temp(o[O_CORE_MEAN] as f64),
                self.telemetry.core_temp(o[O_CORE_MAX] as f64),
                self.telemetry.node_water_temp(o[O_WATER_OUT] as f64),
            ]);
        }
    }

    /// Per-core temperatures (BMC-sampled) of the valid nodes — the raw
    /// population of Fig. 4(b).
    pub fn core_temperatures(&mut self) -> Vec<f64> {
        let n = self.backend.n_nodes();
        let state = self.backend.node_state().to_vec();
        let mut temps = Vec::new();
        for node in 0..n {
            for c in 0..NC {
                if self.lottery.active[node * NC + c] > 0.5 {
                    temps.push(
                        self.telemetry.core_temp(state[node * S + c] as f64),
                    );
                }
            }
        }
        temps
    }

    /// Advance one tick, writing the plant outputs into a caller-owned
    /// `TickOutput` (hot-path variant of `tick_once`: measurement loops
    /// reuse one buffer across ticks instead of allocating per tick).
    ///
    /// The scalars are zeroed first: `step` hands them to the supervisor
    /// *before* the plant tick (over-temperature checks), and `tick_once`
    /// always supplied a fresh zeroed buffer there — a reused buffer must
    /// not change that. Both backends fully overwrite `node_obs`, so the
    /// rest of the buffer needs no reset.
    pub fn tick_into(&mut self, out: &mut TickOutput)
                     -> Result<TraceSample> {
        out.scalars = [0.0; NS];
        let tick_s = self.backend.tick_seconds(&self.cfg.pp);
        let mut wall = 0.0;
        self.step(tick_s, out, &mut wall)
    }

    /// Expose one TickOutput-sized buffer (convenience for callers that
    /// need direct access between run segments).
    pub fn tick_once(&mut self) -> Result<(TickOutput, TraceSample)> {
        let mut out = TickOutput::new(self.backend.n_padded());
        let sample = self.tick_into(&mut out)?;
        Ok((out, sample))
    }

    /// Serialize the coordinator's cross-tick state for a checkpoint:
    /// clock, control vector, PID, supervisor state machine + event
    /// log, telemetry RNG stream, and the workload source. Plant state
    /// (node lanes, circuit) is serialized separately by the fleet
    /// engine, which owns the arena.
    pub fn save_state(&self,
                      w: &mut crate::resilience::checkpoint::SnapWriter) {
        w.f64(self.now_s);
        w.f32s(&self.controls);
        let (integral, last_error) = self.pid.state();
        w.f64(integral);
        w.opt_f64(last_error);
        w.u8(match self.supervisor.state {
            supervisor::SupervisorState::Normal => 0,
            supervisor::SupervisorState::OverTemp => 1,
            supervisor::SupervisorState::ChillerDown => 2,
            supervisor::SupervisorState::PumpDown => 3,
        });
        w.u64(self.supervisor.events.len() as u64);
        for e in &self.supervisor.events {
            w.f64(e.t_s);
            w.str(&e.msg);
        }
        let (rng_state, cached) = self.telemetry.rng_state();
        w.u64(rng_state);
        w.opt_f64(cached);
        self.workload.save_state(w);
    }

    /// Restore state written by [`SimulationDriver::save_state`] onto a
    /// driver freshly built from the same config (the resume path).
    pub fn restore_state(&mut self,
                         r: &mut crate::resilience::checkpoint::SnapReader)
                         -> Result<()> {
        self.now_s = r.f64()?;
        let controls = r.f32s()?;
        if controls.len() != self.controls.len() {
            anyhow::bail!("checkpointed control vector has {} entries, \
                           expected {}", controls.len(),
                          self.controls.len());
        }
        self.controls = controls;
        let integral = r.f64()?;
        let last_error = r.opt_f64()?;
        self.pid.restore(integral, last_error);
        self.supervisor.state = match r.u8()? {
            0 => supervisor::SupervisorState::Normal,
            1 => supervisor::SupervisorState::OverTemp,
            2 => supervisor::SupervisorState::ChillerDown,
            3 => supervisor::SupervisorState::PumpDown,
            t => anyhow::bail!("unknown supervisor state tag {t}"),
        };
        self.supervisor.events.clear();
        for _ in 0..r.usize()? {
            let t_s = r.f64()?;
            let msg = r.str()?;
            self.supervisor
                .events
                .push(supervisor::SupervisorEvent { t_s, msg });
        }
        let rng_state = r.u64()?;
        let cached = r.opt_f64()?;
        self.telemetry.restore_rng(rng_state, cached);
        self.workload.load_state(r)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The fleet engine moves whole drivers across `std::thread::scope`
    /// shard threads; keep this a compile-time guarantee.
    #[test]
    fn simulation_driver_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<SimulationDriver>();
        assert_send::<RunResult>();
    }

    #[test]
    fn from_prebuilt_overrides_seed() {
        let mut cfg = SimConfig::test_small();
        cfg.duration_s = 60.0;
        cfg.seed = 1;
        let driver =
            SimulationDriver::from_prebuilt(cfg, 0xBEEF, Vec::new()).unwrap();
        assert_eq!(driver.cfg.seed, 0xBEEF);
    }
}
