//! PID controller for the 3-way valve.
//!
//! Paper, Sect. 3: "The heat transfer to primary and driving circuit is
//! continuously regulated by a 3-way valve. The valve is automatically
//! operated by a PID controller that determines the rack inlet
//! temperature."
//!
//! We regulate the rack *outlet* temperature (the paper's energy-reuse
//! variable) by actuating the valve that adjusts the inlet: opening the
//! valve routes more heat to the primary circuit, lowering the inlet and
//! hence the outlet. Includes anti-windup (conditional integration) and
//! output clamping.

/// PID with clamped output and conditional-integration anti-windup.
#[derive(Debug, Clone)]
pub struct Pid {
    pub kp: f64,
    pub ki: f64,
    pub kd: f64,
    pub out_min: f64,
    pub out_max: f64,
    integral: f64,
    last_error: Option<f64>,
}

impl Pid {
    pub fn new(kp: f64, ki: f64, kd: f64, out_min: f64, out_max: f64) -> Self {
        Pid { kp, ki, kd, out_min, out_max, integral: 0.0, last_error: None }
    }

    /// Gains tuned for the iDataCool valve loop (error in K, output in
    /// valve fraction; plant gain ~ -0.05 K per % valve at 216 nodes).
    pub fn valve_default() -> Self {
        Pid::new(0.12, 0.004, 0.35, 0.0, 1.0)
    }

    pub fn reset(&mut self) {
        self.integral = 0.0;
        self.last_error = None;
    }

    /// One update. `error` = measurement - setpoint (positive = too hot,
    /// which must *open* the valve, so the sign convention is direct).
    pub fn update(&mut self, error: f64, dt: f64) -> f64 {
        let d = match self.last_error {
            Some(prev) if dt > 0.0 => (error - prev) / dt,
            _ => 0.0,
        };
        self.last_error = Some(error);

        let unsat =
            self.kp * error + self.ki * (self.integral + error * dt) + self.kd * d;
        // Conditional integration: only integrate when not pushing further
        // into saturation.
        let saturated_high = unsat > self.out_max && error > 0.0;
        let saturated_low = unsat < self.out_min && error < 0.0;
        if !(saturated_high || saturated_low) {
            self.integral += error * dt;
        }
        (self.kp * error + self.ki * self.integral + self.kd * d)
            .clamp(self.out_min, self.out_max)
    }

    pub fn integral(&self) -> f64 {
        self.integral
    }

    /// Full controller state (integral + previous error) for
    /// checkpointing; gains are configuration, not state.
    pub fn state(&self) -> (f64, Option<f64>) {
        (self.integral, self.last_error)
    }

    /// Restore a state captured by [`Pid::state`].
    pub fn restore(&mut self, integral: f64, last_error: Option<f64>) {
        self.integral = integral;
        self.last_error = last_error;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// First-order plant: y' = (-y + k*u_inv)/tau with u lowering y.
    fn simulate(pid: &mut Pid, setpoint: f64, steps: usize) -> Vec<f64> {
        let mut y = 75.0f64; // starts hot
        let mut out = Vec::new();
        let dt = 5.0;
        for _ in 0..steps {
            let u = pid.update(y - setpoint, dt);
            // valve u in [0,1] cools the plant; heat input pushes toward 78
            let target = 78.0 - 14.0 * u;
            y += (target - y) * (dt / 120.0);
            out.push(y);
        }
        out
    }

    #[test]
    fn converges_to_setpoint() {
        let mut pid = Pid::valve_default();
        let ys = simulate(&mut pid, 67.0, 4000);
        let tail = &ys[ys.len() - 200..];
        let mean: f64 = tail.iter().sum::<f64>() / tail.len() as f64;
        assert!((mean - 67.0).abs() < 0.5, "settled at {mean}");
    }

    #[test]
    fn output_always_clamped() {
        let mut pid = Pid::valve_default();
        for e in [-50.0, -5.0, 0.0, 5.0, 50.0, 500.0] {
            let u = pid.update(e, 5.0);
            assert!((0.0..=1.0).contains(&u), "u={u} for e={e}");
        }
    }

    #[test]
    fn anti_windup_bounds_integral() {
        let mut pid = Pid::valve_default();
        // Long saturation episode: error stays large positive.
        for _ in 0..10_000 {
            pid.update(30.0, 5.0);
        }
        let after_sat = pid.integral();
        // Windup protection: integral must not grow unboundedly
        assert!(after_sat * pid.ki < 5.0, "integral {after_sat}");
        // and recovery must be quick once error flips
        let mut u = 1.0;
        let mut steps = 0;
        while u > 0.5 && steps < 400 {
            u = pid.update(-2.0, 5.0);
            steps += 1;
        }
        assert!(steps < 400, "controller stuck saturated");
    }

    #[test]
    fn reset_clears_state() {
        let mut pid = Pid::valve_default();
        pid.update(10.0, 5.0);
        pid.update(10.0, 5.0);
        pid.reset();
        assert_eq!(pid.integral(), 0.0);
    }

    #[test]
    fn derivative_damps_oscillation() {
        // With kd = 0 the loop oscillates more than with the default kd.
        let measure = |kd: f64| {
            let mut pid = Pid::new(0.12, 0.004, kd, 0.0, 1.0);
            let ys = simulate(&mut pid, 67.0, 3000);
            let tail = &ys[1500..];
            let mean: f64 = tail.iter().sum::<f64>() / tail.len() as f64;
            tail.iter().map(|y| (y - mean).abs()).sum::<f64>() / tail.len() as f64
        };
        assert!(measure(0.35) <= measure(0.0) + 1e-9);
    }
}
