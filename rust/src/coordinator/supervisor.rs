//! Plant supervisor: chiller management, fault injection, failover.
//!
//! Sect. 3's redundancy narrative: "(i) Should the adsorption chiller fail
//! to absorb all the heat from the iDataCool cluster, additional cooling
//! is provided by the primary cooling circuit, which may be supported by
//! the central cooling circuit. (ii) Should the adsorption chiller fail to
//! provide enough cooling power to the GPU cluster, again the central
//! cooling circuit comes to the rescue."
//!
//! The supervisor watches the (telemetry-sampled) plant state, enables or
//! disables the chiller, forces the valve open on over-temperature, and
//! applies the scheduled fault injections.

use crate::plant::layout::*;

/// A scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// Adsorption chiller refuses to absorb heat (standby stuck).
    ChillerFailure { start_s: f64, end_s: f64 },
    /// Rack circulation pump failure.
    PumpFailure { start_s: f64, end_s: f64 },
    /// GPU-cluster load surge on the primary circuit [W].
    GpuSurge { start_s: f64, end_s: f64, load_w: f64 },
}

/// Supervisor state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SupervisorState {
    /// Normal operation: PID regulates, chiller enabled.
    Normal,
    /// Over-temperature: valve forced open, chiller still enabled.
    OverTemp,
    /// Chiller faulted: all heat to the primary/central path.
    ChillerDown,
    /// Pump down: emergency — loads should be shed (cores will throttle).
    PumpDown,
}

/// Events the supervisor emits for the run log.
#[derive(Debug, Clone)]
pub struct SupervisorEvent {
    pub t_s: f64,
    pub msg: String,
}

/// Watches the plant and owns the safety overrides.
pub struct Supervisor {
    pub faults: Vec<Fault>,
    pub state: SupervisorState,
    pub events: Vec<SupervisorEvent>,
    /// Over-temperature threshold on the hottest core [degC].
    pub core_max_limit: f64,
    /// Rack-outlet hard limit [degC] (the paper runs T_out <= 70).
    pub t_out_limit: f64,
}

impl Supervisor {
    pub fn new(faults: Vec<Fault>) -> Self {
        Supervisor {
            faults,
            state: SupervisorState::Normal,
            events: Vec::new(),
            core_max_limit: 98.0,
            t_out_limit: 71.5,
        }
    }

    fn log(&mut self, t_s: f64, msg: impl Into<String>) {
        self.events.push(SupervisorEvent { t_s, msg: msg.into() });
    }

    /// Active faults at time t.
    fn chiller_failed(&self, t: f64) -> bool {
        self.faults.iter().any(|f| matches!(f,
            Fault::ChillerFailure { start_s, end_s } if (*start_s..*end_s).contains(&t)))
    }

    fn pump_failed(&self, t: f64) -> bool {
        self.faults.iter().any(|f| matches!(f,
            Fault::PumpFailure { start_s, end_s } if (*start_s..*end_s).contains(&t)))
    }

    fn gpu_surge(&self, t: f64) -> Option<f64> {
        self.faults.iter().find_map(|f| match f {
            Fault::GpuSurge { start_s, end_s, load_w }
                if (*start_s..*end_s).contains(&t) =>
            {
                Some(*load_w)
            }
            _ => None,
        })
    }

    /// Apply supervision: mutate the control vector after the PID has set
    /// the valve. Returns the (possibly overridden) valve command.
    pub fn apply(
        &mut self,
        t_s: f64,
        scalars: &[f32; NS],
        controls: &mut [f32],
        pid_valve: f64,
        gpu_load_nominal: f64,
    ) -> f64 {
        let chiller_failed = self.chiller_failed(t_s);
        let pump_failed = self.pump_failed(t_s);
        let core_max = scalars[SC_CORE_MAX] as f64;
        let t_out = scalars[SC_T_RACK_OUT] as f64;

        let new_state = if pump_failed {
            SupervisorState::PumpDown
        } else if chiller_failed {
            SupervisorState::ChillerDown
        } else if core_max > self.core_max_limit || t_out > self.t_out_limit {
            SupervisorState::OverTemp
        } else {
            SupervisorState::Normal
        };
        if new_state != self.state {
            self.log(
                t_s,
                format!(
                    "state {:?} -> {:?} (core_max={core_max:.1}, t_out={t_out:.1})",
                    self.state, new_state
                ),
            );
            self.state = new_state;
        }

        controls[U_CHILLER_EN] = if chiller_failed { 0.0 } else { 1.0 };
        controls[U_PUMP_FAIL] = if pump_failed { 1.0 } else { 0.0 };
        controls[U_GPU_LOAD] =
            self.gpu_surge(t_s).unwrap_or(gpu_load_nominal) as f32;

        // Failover: with the chiller down or over-temp, the 3-way valve
        // routes everything to the primary circuit (backed by central).
        let valve = match self.state {
            SupervisorState::Normal => pid_valve,
            SupervisorState::OverTemp | SupervisorState::ChillerDown => 1.0,
            SupervisorState::PumpDown => 1.0,
        };
        controls[U_VALVE] = valve as f32;
        valve
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scalars(core_max: f32, t_out: f32) -> [f32; NS] {
        let mut s = [0.0f32; NS];
        s[SC_CORE_MAX] = core_max;
        s[SC_T_RACK_OUT] = t_out;
        s
    }

    fn controls() -> Vec<f32> {
        vec![0.0, 1.0, 18.0, 8.0, 9000.0, 0.75, 0.0, 0.0]
    }

    #[test]
    fn normal_passes_pid_valve_through() {
        let mut sup = Supervisor::new(vec![]);
        let mut ctl = controls();
        let v = sup.apply(100.0, &scalars(85.0, 67.0), &mut ctl, 0.3, 9000.0);
        assert_eq!(v, 0.3);
        assert_eq!(sup.state, SupervisorState::Normal);
        assert_eq!(ctl[U_CHILLER_EN], 1.0);
    }

    #[test]
    fn over_temperature_forces_valve_open() {
        let mut sup = Supervisor::new(vec![]);
        let mut ctl = controls();
        let v = sup.apply(100.0, &scalars(99.0, 67.0), &mut ctl, 0.1, 9000.0);
        assert_eq!(v, 1.0);
        assert_eq!(sup.state, SupervisorState::OverTemp);
        assert!(!sup.events.is_empty());
    }

    #[test]
    fn chiller_fault_window() {
        let mut sup = Supervisor::new(vec![Fault::ChillerFailure {
            start_s: 50.0,
            end_s: 150.0,
        }]);
        let mut ctl = controls();
        sup.apply(40.0, &scalars(85.0, 67.0), &mut ctl, 0.2, 9000.0);
        assert_eq!(ctl[U_CHILLER_EN], 1.0);
        sup.apply(100.0, &scalars(85.0, 67.0), &mut ctl, 0.2, 9000.0);
        assert_eq!(ctl[U_CHILLER_EN], 0.0);
        assert_eq!(sup.state, SupervisorState::ChillerDown);
        assert_eq!(ctl[U_VALVE], 1.0);
        sup.apply(200.0, &scalars(85.0, 67.0), &mut ctl, 0.2, 9000.0);
        assert_eq!(ctl[U_CHILLER_EN], 1.0);
        assert_eq!(sup.state, SupervisorState::Normal);
    }

    #[test]
    fn gpu_surge_overrides_load() {
        let mut sup = Supervisor::new(vec![Fault::GpuSurge {
            start_s: 0.0,
            end_s: 100.0,
            load_w: 12_000.0,
        }]);
        let mut ctl = controls();
        sup.apply(50.0, &scalars(85.0, 67.0), &mut ctl, 0.2, 9000.0);
        assert_eq!(ctl[U_GPU_LOAD], 12_000.0);
        sup.apply(150.0, &scalars(85.0, 67.0), &mut ctl, 0.2, 9000.0);
        assert_eq!(ctl[U_GPU_LOAD], 9_000.0);
    }

    #[test]
    fn pump_failure_flag_set() {
        let mut sup = Supervisor::new(vec![Fault::PumpFailure {
            start_s: 0.0,
            end_s: 10.0,
        }]);
        let mut ctl = controls();
        sup.apply(5.0, &scalars(85.0, 67.0), &mut ctl, 0.2, 9000.0);
        assert_eq!(ctl[U_PUMP_FAIL], 1.0);
        assert_eq!(sup.state, SupervisorState::PumpDown);
    }
}
