//! Energy accounting: the paper's headline metrics.
//!
//! Integrates electrical input, heat-in-water, driving-circuit transfer,
//! chilled-water output and losses over a run, and derives
//!   * heat-in-water fraction  P_r / P_AC          (Fig. 7a)
//!   * transferred fraction    P_d / P_AC          (Fig. 7b)
//!   * chiller COP             P_c / P_d           (Fig. 6b)
//!   * energy-reuse fraction   P_c / P_AC          (~25 % at 60-70 degC;
//!     equivalently COP x heat-in-water when the chiller absorbs all of
//!     P_d — Sect. 4's multiplication of Figs. 6b and 7a)

use crate::plant::layout::*;

/// Time-integrated energies [J] plus instantaneous views.
#[derive(Debug, Clone, Default)]
pub struct EnergyAccount {
    pub e_ac: f64,
    pub e_dc: f64,
    pub e_water: f64,
    pub e_drive: f64,
    pub e_chilled: f64,
    pub e_add: f64,
    pub e_loss_plumbing: f64,
    pub e_central: f64,
    pub seconds: f64,
    pub ticks: u64,
}

impl EnergyAccount {
    pub fn new() -> Self {
        Self::default()
    }

    /// Integrate one tick of scalar observations over `dt` seconds.
    pub fn push(&mut self, scalars: &[f32; NS], dt: f64) {
        self.e_ac += scalars[SC_P_AC] as f64 * dt;
        self.e_dc += scalars[SC_P_DC] as f64 * dt;
        self.e_water += scalars[SC_P_R] as f64 * dt;
        self.e_drive += scalars[SC_P_D] as f64 * dt;
        self.e_chilled += scalars[SC_P_C] as f64 * dt;
        self.e_add += scalars[SC_P_ADD] as f64 * dt;
        self.e_loss_plumbing += scalars[SC_P_LOSS] as f64 * dt;
        self.e_central += scalars[SC_P_CENTRAL] as f64 * dt;
        self.seconds += dt;
        self.ticks += 1;
    }

    /// Checkpoint encoding (field order is the `idatacool-ckpt/1`
    /// contract; see DESIGN.md §8).
    pub fn save(&self, w: &mut crate::resilience::checkpoint::SnapWriter) {
        w.f64(self.e_ac);
        w.f64(self.e_dc);
        w.f64(self.e_water);
        w.f64(self.e_drive);
        w.f64(self.e_chilled);
        w.f64(self.e_add);
        w.f64(self.e_loss_plumbing);
        w.f64(self.e_central);
        w.f64(self.seconds);
        w.u64(self.ticks);
    }

    /// Decode an account written by [`EnergyAccount::save`].
    pub fn load(r: &mut crate::resilience::checkpoint::SnapReader)
                -> anyhow::Result<EnergyAccount> {
        Ok(EnergyAccount {
            e_ac: r.f64()?,
            e_dc: r.f64()?,
            e_water: r.f64()?,
            e_drive: r.f64()?,
            e_chilled: r.f64()?,
            e_add: r.f64()?,
            e_loss_plumbing: r.f64()?,
            e_central: r.f64()?,
            seconds: r.f64()?,
            ticks: r.u64()?,
        })
    }

    /// Heat-in-water fraction (Fig. 7a).
    pub fn heat_in_water_fraction(&self) -> f64 {
        safe_div(self.e_water, self.e_ac)
    }

    /// Transferred-power fraction (Fig. 7b).
    pub fn transferred_fraction(&self) -> f64 {
        safe_div(self.e_drive, self.e_ac)
    }

    /// Time-averaged chiller COP (Fig. 6b).
    pub fn cop(&self) -> f64 {
        safe_div(self.e_chilled, self.e_drive)
    }

    /// Energy-reuse fraction: chilled water out per electrical in.
    pub fn reuse_fraction(&self) -> f64 {
        safe_div(self.e_chilled, self.e_ac)
    }

    /// The paper's estimate: what reuse *would be* if the chiller could
    /// absorb all heat in water (Fig. 6b x Fig. 7a).
    pub fn reuse_potential(&self) -> f64 {
        self.cop() * self.heat_in_water_fraction()
    }

    /// Mean electrical power [W].
    pub fn mean_p_ac(&self) -> f64 {
        safe_div(self.e_ac, self.seconds)
    }

    pub fn summary(&self) -> String {
        format!(
            "energy over {:.0} s: AC={:.1} kWh, heat-in-water={:.1}% , \
             transferred={:.1}%, COP={:.3}, reuse={:.1}% (potential {:.1}%)",
            self.seconds,
            self.e_ac / 3.6e6,
            100.0 * self.heat_in_water_fraction(),
            100.0 * self.transferred_fraction(),
            self.cop(),
            100.0 * self.reuse_fraction(),
            100.0 * self.reuse_potential(),
        )
    }
}

fn safe_div(a: f64, b: f64) -> f64 {
    if b.abs() < 1e-9 {
        0.0
    } else {
        a / b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scalars(p_ac: f32, p_r: f32, p_d: f32, p_c: f32) -> [f32; NS] {
        let mut s = [0.0f32; NS];
        s[SC_P_AC] = p_ac;
        s[SC_P_R] = p_r;
        s[SC_P_D] = p_d;
        s[SC_P_C] = p_c;
        s
    }

    #[test]
    fn fractions_computed() {
        let mut acc = EnergyAccount::new();
        acc.push(&scalars(50_000.0, 24_000.0, 18_000.0, 9_000.0), 5.0);
        acc.push(&scalars(50_000.0, 24_000.0, 18_000.0, 9_000.0), 5.0);
        assert!((acc.heat_in_water_fraction() - 0.48).abs() < 1e-9);
        assert!((acc.transferred_fraction() - 0.36).abs() < 1e-9);
        assert!((acc.cop() - 0.5).abs() < 1e-9);
        assert!((acc.reuse_fraction() - 0.18).abs() < 1e-9);
        assert!((acc.reuse_potential() - 0.24).abs() < 1e-9);
        assert_eq!(acc.ticks, 2);
    }

    #[test]
    fn empty_account_safe() {
        let acc = EnergyAccount::new();
        assert_eq!(acc.cop(), 0.0);
        assert_eq!(acc.reuse_fraction(), 0.0);
    }

    #[test]
    fn paper_headline_band() {
        // With the paper's target values the reuse potential is ~25 %.
        let mut acc = EnergyAccount::new();
        acc.push(&scalars(51_000.0, 24_000.0, 18_500.0, 9_100.0), 5.0);
        let p = acc.reuse_potential();
        assert!((0.18..0.30).contains(&p), "potential {p}");
    }
}
