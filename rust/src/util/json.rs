//! Minimal recursive-descent JSON parser.
//!
//! The build environment vendors only the `xla` crate closure (no serde),
//! so artifact metadata (`manifest.json`, `lottery_n*.json`, `params.json`)
//! is parsed with this self-contained implementation. It supports the full
//! JSON grammar we emit from `python/compile/aot.py`: objects, arrays,
//! strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Flatten a numeric array (1-D) into f64s.
    pub fn as_vec_f64(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    /// Flatten a numeric array-of-arrays (2-D, row-major) into f64s.
    pub fn as_mat_f64(&self) -> Option<(Vec<f64>, usize, usize)> {
        let rows = self.as_arr()?;
        let ncols = rows.first()?.as_arr()?.len();
        let mut out = Vec::with_capacity(rows.len() * ncols);
        for r in rows {
            let r = r.as_arr()?;
            if r.len() != ncols {
                return None;
            }
            for v in r {
                out.push(v.as_f64()?);
            }
        }
        Some((out, rows.len(), ncols))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => write!(f, "{x}"),
            Json::Str(s) => write!(f, "{s:?}"),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{k:?}:{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Consuming builder for `Json::Obj` values. Keys land in a `BTreeMap`,
/// so the serialized key order is alphabetical regardless of insertion
/// order — every byte of emitted output is stable across runs and
/// platforms (the property the bench reports, the fleet `--json`
/// document and the serve-layer cache all rely on).
#[derive(Debug, Default)]
pub struct JsonBuilder {
    m: BTreeMap<String, Json>,
}

impl JsonBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(mut self, k: &str, v: Json) -> Self {
        self.m.insert(k.to_string(), v);
        self
    }

    pub fn num(self, k: &str, v: f64) -> Self {
        self.set(k, Json::Num(v))
    }

    pub fn str(self, k: &str, v: &str) -> Self {
        self.set(k, Json::Str(v.to_string()))
    }

    pub fn bool(self, k: &str, v: bool) -> Self {
        self.set(k, Json::Bool(v))
    }

    /// u64 as a `0x`-prefixed hex string — JSON numbers are f64 and
    /// cannot round-trip 64-bit ids (same convention as the
    /// `bench/record.rs` fingerprints).
    pub fn hex(self, k: &str, v: u64) -> Self {
        self.set(k, Json::Str(format!("{v:#018x}")))
    }

    pub fn arr(self, k: &str, items: Vec<Json>) -> Self {
        self.set(k, Json::Arr(items))
    }

    pub fn build(self) -> Json {
        Json::Obj(self.m)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\n"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\n"));
    }

    #[test]
    fn parses_matrix() {
        let v = Json::parse("[[1,2,3],[4,5,6]]").unwrap();
        let (flat, r, c) = v.as_mat_f64().unwrap();
        assert_eq!((r, c), (2, 3));
        assert_eq!(flat, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn ragged_matrix_rejected() {
        let v = Json::parse("[[1,2],[3]]").unwrap();
        assert!(v.as_mat_f64().is_none());
    }

    #[test]
    fn builder_emits_stable_alphabetical_order() {
        let j = JsonBuilder::new()
            .num("zeta", 1.0)
            .str("alpha", "x")
            .bool("mid", true)
            .hex("seed", 0xBEEF)
            .arr("list", vec![Json::Num(1.0), Json::Num(2.0)])
            .build();
        assert_eq!(
            j.to_string(),
            "{\"alpha\":\"x\",\"list\":[1,2],\"mid\":true,\
             \"seed\":\"0x000000000000beef\",\"zeta\":1}"
        );
        // and the emitted text re-parses to the same value
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
