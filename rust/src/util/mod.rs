//! Small self-contained utilities (the vendored crate set has no serde,
//! clap, or rand — these modules fill the gaps as first-class substrates).

pub mod bench;
pub mod cli;
pub mod http;
pub mod json;
pub mod lru;
pub mod shard;

/// Clamp helper for f32 (stable API, avoids float NaN surprises: NaN -> lo).
#[inline]
pub fn clampf(x: f32, lo: f32, hi: f32) -> f32 {
    if x >= hi {
        hi
    } else if x >= lo {
        x
    } else {
        lo
    }
}

/// Linear interpolation.
#[inline]
pub fn lerp(a: f32, b: f32, t: f32) -> f32 {
    a + (b - a) * t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamp_works() {
        assert_eq!(clampf(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clampf(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clampf(0.5, 0.0, 1.0), 0.5);
        assert_eq!(clampf(f32::NAN, 0.0, 1.0), 0.0);
    }

    #[test]
    fn lerp_works() {
        assert_eq!(lerp(0.0, 10.0, 0.25), 2.5);
    }
}
