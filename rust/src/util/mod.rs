//! Small self-contained utilities (the vendored crate set has no serde,
//! clap, or rand — these modules fill the gaps as first-class substrates).

pub mod bench;
pub mod cli;
pub mod http;
pub mod json;
pub mod lru;
pub mod shard;

/// Clamp helper for f32 with pinned NaN behavior: **NaN → `lo`**.
///
/// This is the repo's documented NaN convention at control boundaries
/// (DESIGN.md §8): a NaN reaching a clamp is mapped to the inert end of
/// the range (valve closed, fan at minimum, zero power) rather than
/// propagating — unlike `f32::clamp`, which panics debug-only on a NaN
/// *bound* and returns NaN for a NaN *input*. Detection (as opposed to
/// containment) is the job of the `is_finite` sentinels in the SoA
/// epilogues, which quarantine the offending plant.
#[inline]
pub fn clampf(x: f32, lo: f32, hi: f32) -> f32 {
    // Ordered comparisons are false for NaN, so a NaN `x` falls through
    // both arms to `lo`. Do not "simplify" to `x.max(lo).min(hi)`:
    // `f32::max` ignores a NaN argument and would return NaN for NaN x.
    if x >= hi {
        hi
    } else if x >= lo {
        x
    } else {
        lo
    }
}

/// Linear interpolation.
#[inline]
pub fn lerp(a: f32, b: f32, t: f32) -> f32 {
    a + (b - a) * t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamp_works() {
        assert_eq!(clampf(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clampf(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clampf(0.5, 0.0, 1.0), 0.5);
    }

    /// Regression for the documented NaN → `lo` convention: every NaN
    /// input lands on the inert end of the range, for any range, and
    /// infinities clamp like ordinary out-of-range values.
    #[test]
    fn clamp_nan_maps_to_lo() {
        assert_eq!(clampf(f32::NAN, 0.0, 1.0), 0.0);
        assert_eq!(clampf(-f32::NAN, 0.0, 1.0), 0.0);
        assert_eq!(clampf(f32::NAN, -3.0, -1.0), -3.0);
        assert_eq!(clampf(f32::INFINITY, 0.0, 1.0), 1.0);
        assert_eq!(clampf(f32::NEG_INFINITY, 0.0, 1.0), 0.0);
    }

    #[test]
    fn lerp_works() {
        assert_eq!(lerp(0.0, 10.0, 0.25), 2.5);
    }
}
