//! Least-recently-used cache (std-only; the vendored crate set has no
//! `lru` crate).
//!
//! Recency is tracked with a monotonic stamp per entry instead of a
//! linked list: `get` and `insert` bump the stamp, eviction scans for the
//! minimum. The scan makes `insert` O(len) at capacity, which is the
//! right trade for the serve-layer response cache (a few hundred entries,
//! values are `Arc`-shared response bodies) and keeps the structure
//! trivially correct — no unsafe, no index juggling.

use std::collections::HashMap;
use std::hash::Hash;

#[derive(Debug, Clone)]
struct Entry<V> {
    value: V,
    stamp: u64,
}

/// A bounded map that evicts the least-recently-used entry on overflow.
#[derive(Debug, Clone)]
pub struct Lru<K: Eq + Hash + Clone, V> {
    cap: usize,
    tick: u64,
    map: HashMap<K, Entry<V>>,
}

impl<K: Eq + Hash + Clone, V> Lru<K, V> {
    /// Create a cache holding at most `cap` entries.
    ///
    /// Panics when `cap == 0` (a zero-capacity LRU would evict every
    /// insert; callers that want caching off should branch, not
    /// construct a degenerate cache).
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "Lru capacity must be at least 1");
        Lru { cap, tick: 0, map: HashMap::with_capacity(cap.min(1024)) }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, k: &K) -> bool {
        self.map.contains_key(k)
    }

    /// Look up `k` and mark it most recently used.
    pub fn get(&mut self, k: &K) -> Option<&V> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(k) {
            Some(e) => {
                e.stamp = tick;
                Some(&e.value)
            }
            None => None,
        }
    }

    /// Look up `k` without touching its recency.
    pub fn peek(&self, k: &K) -> Option<&V> {
        self.map.get(k).map(|e| &e.value)
    }

    /// Insert (or replace) `k`, evicting the least-recently-used entry
    /// when at capacity. Returns the evicted key, if any. The freshly
    /// inserted key always carries the newest stamp, so it can never be
    /// the victim of its own insert.
    pub fn insert(&mut self, k: K, v: V) -> Option<K> {
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.map.get_mut(&k) {
            e.value = v;
            e.stamp = tick;
            return None;
        }
        let mut evicted = None;
        if self.map.len() >= self.cap {
            // Stamps are unique (every op bumps the counter), so the
            // minimum — and therefore the victim — is deterministic.
            if let Some(old) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&old);
                evicted = Some(old);
            }
        }
        self.map.insert(k, Entry { value: v, stamp: tick });
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_get() {
        let mut c: Lru<u32, &str> = Lru::new(2);
        assert!(c.is_empty());
        assert_eq!(c.insert(1, "a"), None);
        assert_eq!(c.insert(2, "b"), None);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&1), Some(&"a"));
        assert_eq!(c.get(&3), None);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c: Lru<u32, u32> = Lru::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        // Touch 1 so 2 becomes the LRU entry.
        assert_eq!(c.get(&1), Some(&10));
        assert_eq!(c.insert(3, 30), Some(2));
        assert!(c.contains(&1) && c.contains(&3) && !c.contains(&2));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn eviction_order_without_touches_is_insertion_order() {
        let mut c: Lru<u32, u32> = Lru::new(3);
        for k in 1..=3 {
            c.insert(k, k);
        }
        assert_eq!(c.insert(4, 4), Some(1));
        assert_eq!(c.insert(5, 5), Some(2));
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn replace_updates_value_and_recency() {
        let mut c: Lru<u32, u32> = Lru::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        // Re-inserting 1 refreshes it; 2 is now the victim.
        assert_eq!(c.insert(1, 11), None);
        assert_eq!(c.insert(3, 30), Some(2));
        assert_eq!(c.peek(&1), Some(&11));
    }

    #[test]
    fn peek_does_not_touch() {
        let mut c: Lru<u32, u32> = Lru::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        // Peeking 1 must not save it: it stays the LRU entry.
        assert_eq!(c.peek(&1), Some(&10));
        assert_eq!(c.insert(3, 30), Some(1));
    }

    #[test]
    fn capacity_one_always_keeps_latest() {
        let mut c: Lru<u32, u32> = Lru::new(1);
        assert_eq!(c.insert(1, 10), None);
        assert_eq!(c.insert(2, 20), Some(1));
        assert_eq!(c.insert(3, 30), Some(2));
        assert_eq!(c.len(), 1);
        assert_eq!(c.peek(&3), Some(&30));
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        let _ = Lru::<u32, u32>::new(0);
    }
}
