//! Least-recently-used cache (std-only; the vendored crate set has no
//! `lru` crate).
//!
//! Recency is tracked with a monotonic stamp per entry instead of a
//! linked list: `get` and `insert` bump the stamp, eviction scans for the
//! minimum. The scan makes `insert` O(len) at capacity, which is the
//! right trade for the serve-layer response cache (a few hundred entries,
//! values are `Arc`-shared response bodies) and keeps the structure
//! trivially correct — no unsafe, no index juggling.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Mutex;

#[derive(Debug, Clone)]
struct Entry<V> {
    value: V,
    stamp: u64,
}

/// A bounded map that evicts the least-recently-used entry on overflow.
#[derive(Debug, Clone)]
pub struct Lru<K: Eq + Hash + Clone, V> {
    cap: usize,
    tick: u64,
    map: HashMap<K, Entry<V>>,
}

impl<K: Eq + Hash + Clone, V> Lru<K, V> {
    /// Create a cache holding at most `cap` entries.
    ///
    /// Panics when `cap == 0` (a zero-capacity LRU would evict every
    /// insert; callers that want caching off should branch, not
    /// construct a degenerate cache).
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "Lru capacity must be at least 1");
        Lru { cap, tick: 0, map: HashMap::with_capacity(cap.min(1024)) }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, k: &K) -> bool {
        self.map.contains_key(k)
    }

    /// Look up `k` and mark it most recently used.
    pub fn get(&mut self, k: &K) -> Option<&V> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(k) {
            Some(e) => {
                e.stamp = tick;
                Some(&e.value)
            }
            None => None,
        }
    }

    /// Look up `k` without touching its recency.
    pub fn peek(&self, k: &K) -> Option<&V> {
        self.map.get(k).map(|e| &e.value)
    }

    /// Insert (or replace) `k`, evicting the least-recently-used entry
    /// when at capacity. Returns the evicted key, if any. The freshly
    /// inserted key always carries the newest stamp, so it can never be
    /// the victim of its own insert.
    pub fn insert(&mut self, k: K, v: V) -> Option<K> {
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.map.get_mut(&k) {
            e.value = v;
            e.stamp = tick;
            return None;
        }
        let mut evicted = None;
        if self.map.len() >= self.cap {
            // Stamps are unique (every op bumps the counter), so the
            // minimum — and therefore the victim — is deterministic.
            if let Some(old) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&old);
                evicted = Some(old);
            }
        }
        self.map.insert(k, Entry { value: v, stamp: tick });
        evicted
    }
}

/// A sharded LRU over `u64` keys: N independent `Mutex<Lru>` shards
/// selected by `key % N`, so concurrent lookups on different shards
/// never serialize on one lock. Keys are already-mixed fingerprints
/// (FNV output), so the low bits are uniform enough for modulo
/// selection.
///
/// The total capacity is distributed across shards (first `cap % N`
/// shards get one extra slot) so `cap()` still reports exactly the
/// configured bound. Because eviction is per-shard, a pathological key
/// distribution can evict earlier than a single LRU would — acceptable
/// for a response cache, where eviction only costs a recompute.
#[derive(Debug)]
pub struct ShardedLru<V> {
    shards: Vec<Mutex<Lru<u64, V>>>,
}

impl<V: Clone> ShardedLru<V> {
    /// Create a cache of total capacity `cap` split over `shards`
    /// locks. `shards` is clamped to `[1, cap]` so every shard holds
    /// at least one entry. Panics when `cap == 0`, like `Lru::new`.
    pub fn new(cap: usize, shards: usize) -> Self {
        assert!(cap > 0, "ShardedLru capacity must be at least 1");
        let n = shards.clamp(1, cap);
        let (base, extra) = (cap / n, cap % n);
        let shards = (0..n)
            .map(|i| Mutex::new(Lru::new(base + usize::from(i < extra))))
            .collect();
        ShardedLru { shards }
    }

    fn shard(&self, k: u64) -> &Mutex<Lru<u64, V>> {
        &self.shards[(k % self.shards.len() as u64) as usize]
    }

    /// Look up `k` (cloning the value out) and mark it most recently
    /// used within its shard.
    pub fn get(&self, k: u64) -> Option<V> {
        self.shard(k).lock().unwrap().get(&k).cloned()
    }

    /// Insert (or replace) `k`; at shard capacity the shard's
    /// least-recently-used entry is evicted. Returns the evicted key,
    /// if any, so callers can count evictions.
    pub fn insert(&self, k: u64, v: V) -> Option<u64> {
        self.shard(k).lock().unwrap().insert(k, v)
    }

    pub fn contains(&self, k: u64) -> bool {
        self.shard(k).lock().unwrap().contains(&k)
    }

    /// Total live entries across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total configured capacity (sum of per-shard capacities — exactly
    /// the `cap` passed to `new`).
    pub fn cap(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().cap()).sum()
    }

    /// Number of lock shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_get() {
        let mut c: Lru<u32, &str> = Lru::new(2);
        assert!(c.is_empty());
        assert_eq!(c.insert(1, "a"), None);
        assert_eq!(c.insert(2, "b"), None);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&1), Some(&"a"));
        assert_eq!(c.get(&3), None);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c: Lru<u32, u32> = Lru::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        // Touch 1 so 2 becomes the LRU entry.
        assert_eq!(c.get(&1), Some(&10));
        assert_eq!(c.insert(3, 30), Some(2));
        assert!(c.contains(&1) && c.contains(&3) && !c.contains(&2));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn eviction_order_without_touches_is_insertion_order() {
        let mut c: Lru<u32, u32> = Lru::new(3);
        for k in 1..=3 {
            c.insert(k, k);
        }
        assert_eq!(c.insert(4, 4), Some(1));
        assert_eq!(c.insert(5, 5), Some(2));
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn replace_updates_value_and_recency() {
        let mut c: Lru<u32, u32> = Lru::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        // Re-inserting 1 refreshes it; 2 is now the victim.
        assert_eq!(c.insert(1, 11), None);
        assert_eq!(c.insert(3, 30), Some(2));
        assert_eq!(c.peek(&1), Some(&11));
    }

    #[test]
    fn peek_does_not_touch() {
        let mut c: Lru<u32, u32> = Lru::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        // Peeking 1 must not save it: it stays the LRU entry.
        assert_eq!(c.peek(&1), Some(&10));
        assert_eq!(c.insert(3, 30), Some(1));
    }

    #[test]
    fn capacity_one_always_keeps_latest() {
        let mut c: Lru<u32, u32> = Lru::new(1);
        assert_eq!(c.insert(1, 10), None);
        assert_eq!(c.insert(2, 20), Some(1));
        assert_eq!(c.insert(3, 30), Some(2));
        assert_eq!(c.len(), 1);
        assert_eq!(c.peek(&3), Some(&30));
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        let _ = Lru::<u32, u32>::new(0);
    }

    #[test]
    fn sharded_capacity_distributes_exactly() {
        // 10 slots over 4 shards: 3+3+2+2, cap() reports 10.
        let c: ShardedLru<u32> = ShardedLru::new(10, 4);
        assert_eq!(c.cap(), 10);
        assert_eq!(c.n_shards(), 4);
        // Shard count is clamped to the capacity.
        let c: ShardedLru<u32> = ShardedLru::new(3, 8);
        assert_eq!(c.n_shards(), 3);
        assert_eq!(c.cap(), 3);
        let c: ShardedLru<u32> = ShardedLru::new(5, 0);
        assert_eq!(c.n_shards(), 1);
        assert_eq!(c.cap(), 5);
    }

    #[test]
    fn sharded_roundtrip_and_replace() {
        let c: ShardedLru<u32> = ShardedLru::new(8, 4);
        for k in 0..8u64 {
            c.insert(k, k as u32 * 10);
        }
        for k in 0..8u64 {
            assert_eq!(c.get(k), Some(k as u32 * 10));
        }
        c.insert(3, 99);
        assert_eq!(c.get(3), Some(99));
        assert_eq!(c.get(1000), None);
    }

    #[test]
    fn sharded_len_never_exceeds_cap() {
        let c: ShardedLru<u32> = ShardedLru::new(6, 3);
        for k in 0..100u64 {
            c.insert(k, k as u32);
            assert!(c.len() <= c.cap());
        }
        // Each shard is full (keys were uniform mod 3), so the cache
        // sits exactly at capacity.
        assert_eq!(c.len(), 6);
    }

    #[test]
    fn sharded_eviction_is_per_shard_lru() {
        // 2 shards x 2 slots; keys 0,2,4 hit shard 0, keys 1,3 shard 1.
        let c: ShardedLru<u32> = ShardedLru::new(4, 2);
        c.insert(0, 0);
        c.insert(2, 2);
        c.insert(1, 1);
        assert_eq!(c.get(0), Some(0)); // 2 is now shard 0's LRU entry
        c.insert(4, 4);
        assert!(c.contains(0) && c.contains(4) && !c.contains(2));
        assert!(c.contains(1)); // the other shard is untouched
    }

    #[test]
    #[should_panic]
    fn sharded_zero_capacity_rejected() {
        let _ = ShardedLru::<u32>::new(0, 4);
    }
}
