//! Round-robin shard assignment shared by the fleet engine and the
//! parallel setpoint sweep.
//!
//! Work item `i` lands in bucket `i % shards`. Assignment depends only on
//! the item order and the shard count — never on thread timing — which is
//! half of the determinism contract (the other half: reduce results in
//! item order, not completion order).

/// Distribute `items` over `shards` buckets round-robin (shards is
/// clamped to at least 1; trailing buckets may be empty when there are
/// fewer items than shards).
pub fn round_robin<T>(items: Vec<T>, shards: usize) -> Vec<Vec<T>> {
    let shards = shards.max(1);
    let mut buckets: Vec<Vec<T>> = (0..shards).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        buckets[i % shards].push(item);
    }
    buckets
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_is_by_index() {
        let buckets = round_robin((0..7).collect(), 3);
        assert_eq!(buckets, vec![vec![0, 3, 6], vec![1, 4], vec![2, 5]]);
    }

    #[test]
    fn zero_shards_clamped_to_one() {
        let buckets = round_robin(vec!["a", "b"], 0);
        assert_eq!(buckets, vec![vec!["a", "b"]]);
    }

    #[test]
    fn more_shards_than_items_leaves_empties() {
        let buckets = round_robin(vec![1], 3);
        assert_eq!(buckets.len(), 3);
        assert_eq!(buckets[0], vec![1]);
        assert!(buckets[1].is_empty() && buckets[2].is_empty());
    }
}
