//! Shard assignment shared by the fleet engine and the parallel
//! setpoint sweep.
//!
//! Assignment depends only on the item order and the shard count —
//! never on thread timing — which is half of the determinism contract
//! (the other half: reduce results in item order, not completion
//! order). Because reductions run in item order, the choice of
//! assignment is **order-independent for results**: any function of
//! (items, shards) produces bitwise-identical output, so it can be
//! picked purely for load balance.
//!
//! Contiguous blocks replaced the earlier round-robin assignment
//! (`i % shards`) in PR 5: both keep bucket sizes within one item of
//! each other, but round-robin correlates with the index-modulo
//! patterns workloads are built from — the fleet's `mixed` scenario
//! cycles stress/production/idle by `index % 3`, so a 3-shard
//! round-robin run put *every* expensive stress plant on shard 0 while
//! shard 2 idled. Contiguous blocks interleave such patterns across
//! shards instead, and keep in-shard order equal to fleet order (which
//! the megabatch arena also relies on for its plant ranges).

/// Distribute `items` over `shards` contiguous blocks in order; sizes
/// differ by at most one (earlier buckets take the remainder). Shards
/// is clamped to at least 1; trailing buckets may be empty when there
/// are fewer items than shards.
pub fn blocks<T>(items: Vec<T>, shards: usize) -> Vec<Vec<T>> {
    let shards = shards.max(1);
    let n = items.len();
    let (q, r) = (n / shards, n % shards);
    let mut it = items.into_iter();
    (0..shards)
        .map(|b| {
            let take = q + usize::from(b < r);
            it.by_ref().take(take).collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_shards_clamped_to_one() {
        let buckets = blocks(vec!["a", "b"], 0);
        assert_eq!(buckets, vec![vec!["a", "b"]]);
    }

    #[test]
    fn more_shards_than_items_leaves_empties() {
        let buckets = blocks(vec![1], 3);
        assert_eq!(buckets.len(), 3);
        assert_eq!(buckets[0], vec![1]);
        assert!(buckets[1].is_empty() && buckets[2].is_empty());
    }

    #[test]
    fn blocks_are_contiguous_and_balanced() {
        let buckets = blocks((0..7).collect(), 3);
        assert_eq!(buckets, vec![vec![0, 1, 2], vec![3, 4], vec![5, 6]]);
        // every n % shards: sizes within one of each other, order kept
        for n in 0..20usize {
            for k in 1..6usize {
                let buckets = blocks((0..n).collect(), k);
                assert_eq!(buckets.len(), k);
                let flat: Vec<usize> =
                    buckets.iter().flatten().copied().collect();
                assert_eq!(flat, (0..n).collect::<Vec<_>>(), "n={n} k={k}");
                let sizes: Vec<usize> =
                    buckets.iter().map(Vec::len).collect();
                let (lo, hi) = (sizes.iter().min().unwrap(),
                                sizes.iter().max().unwrap());
                assert!(hi - lo <= 1, "imbalance at n={n} k={k}: {sizes:?}");
            }
        }
    }

    #[test]
    fn blocks_decorrelate_index_modulo_patterns() {
        // The motivating fix: items expensive at index % 3 == 0 (the
        // mixed scenario's stress plants) all landed in round-robin
        // bucket 0 (i % shards puts indices 0, 3, 6 on shard 0), but
        // spread one-per-bucket across contiguous blocks.
        let bl = blocks((0..9).collect::<Vec<usize>>(), 3);
        for bucket in &bl {
            let heavy = bucket.iter().filter(|i| *i % 3 == 0).count();
            assert_eq!(heavy, 1, "each block gets exactly one heavy item");
        }
    }
}
