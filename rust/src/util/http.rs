//! Minimal HTTP/1.1 wire helpers (std::net only — the vendored crate set
//! has no hyper): request parsing with hard size limits, response
//! writing, and a tiny loopback client shared by the integration tests,
//! the `serve` bench suite and local smoke checks.
//!
//! Scope is deliberately narrow: `Content-Length` framing only (chunked
//! transfer is answered with 501), responses carry an explicit
//! `connection: close` or `connection: keep-alive` (the server reuses
//! connections; one-shot tools close), header keys are lowercased on
//! parse, and query strings split on `&`/`=` without percent-decoding
//! (the only query the server understands is `stream=1`).
//!
//! Every error body in the crate is the `idatacool-error/1` envelope
//! built by [`error_envelope`] — `{"schema": "idatacool-error/1",
//! "error": {"code", "message", "field?"}}` — so clients can branch on
//! a stable machine-readable `code` instead of scraping prose.

use std::collections::BTreeMap;
use std::fmt;
use std::io::{BufRead, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::util::json::Json;

/// Hard cap on the request line plus all headers.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Hard cap on a request body.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// A wire-level failure paired with the HTTP status it should be
/// answered with (400 malformed, 413 oversized body, ...).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub msg: String,
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "http {}: {}", self.status, self.msg)
    }
}

impl std::error::Error for HttpError {}

fn herr(status: u16, msg: impl Into<String>) -> HttpError {
    HttpError { status, msg: msg.into() }
}

/// A parsed request: method, path, split query, lowercased headers, body.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: BTreeMap<String, String>,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    /// Read one request from a buffered stream. `Ok(None)` means the
    /// peer closed the connection before sending anything (a clean EOF,
    /// e.g. the shutdown self-ping or a health prober dropping early).
    pub fn read_from<R: BufRead>(r: &mut R) -> Result<Option<Request>, HttpError> {
        let reqline = match read_line_limited(r, MAX_HEAD_BYTES)? {
            None => return Ok(None),
            Some(l) => l,
        };
        let mut head_bytes = reqline.len();
        let reqline = reqline.trim_end();
        let mut parts = reqline.split_whitespace();
        let method = parts
            .next()
            .ok_or_else(|| herr(400, "empty request line"))?
            .to_string();
        let target = parts
            .next()
            .ok_or_else(|| herr(400, "request line missing target"))?;
        let version = parts
            .next()
            .ok_or_else(|| herr(400, "request line missing version"))?;
        if !version.starts_with("HTTP/1.") {
            return Err(herr(505, format!("unsupported version '{version}'")));
        }
        let (path, query) = split_target(target);

        let mut headers = BTreeMap::new();
        loop {
            let line = read_line_limited(r, MAX_HEAD_BYTES)?
                .ok_or_else(|| herr(400, "unexpected eof in headers"))?;
            head_bytes += line.len();
            if head_bytes > MAX_HEAD_BYTES {
                return Err(herr(431, "headers too large"));
            }
            let h = line.trim_end();
            if h.is_empty() {
                break;
            }
            let (k, v) = h
                .split_once(':')
                .ok_or_else(|| herr(400, format!("malformed header '{h}'")))?;
            headers.insert(
                k.trim().to_ascii_lowercase(),
                v.trim().to_string(),
            );
        }

        if headers.contains_key("transfer-encoding") {
            return Err(herr(501, "chunked requests not supported"));
        }
        let body = match headers.get("content-length") {
            None => Vec::new(),
            Some(cl) => {
                let len: usize = cl.trim().parse().map_err(|_| {
                    herr(400, format!("bad content-length '{cl}'"))
                })?;
                if len > MAX_BODY_BYTES {
                    return Err(herr(413, format!(
                        "body of {len} bytes exceeds the {MAX_BODY_BYTES}-byte limit"
                    )));
                }
                let mut body = vec![0u8; len];
                r.read_exact(&mut body)
                    .map_err(|e| herr(400, format!("read body: {e}")))?;
                body
            }
        };

        Ok(Some(Request { method, path, query, headers, body }))
    }

    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(name).map(|s| s.as_str())
    }

    /// The body as UTF-8, or a 400-grade error.
    pub fn body_str(&self) -> Result<&str, HttpError> {
        std::str::from_utf8(&self.body)
            .map_err(|_| herr(400, "body is not valid utf-8"))
    }
}

/// Read one LF-terminated line (CR kept for the caller's `trim_end`),
/// enforcing `max` *as bytes are consumed* — unlike `read_line`, a peer
/// that streams forever without a newline is cut off at the cap (431)
/// instead of growing the buffer without bound. `Ok(None)` is a clean
/// EOF before any byte; EOF mid-line returns the partial line (the
/// caller's grammar then rejects it).
fn read_line_limited<R: BufRead>(r: &mut R, max: usize)
                                 -> Result<Option<String>, HttpError> {
    let mut bytes: Vec<u8> = Vec::new();
    loop {
        let (found, used) = {
            let buf = r
                .fill_buf()
                .map_err(|e| herr(400, format!("read request head: {e}")))?;
            if buf.is_empty() {
                if bytes.is_empty() {
                    return Ok(None);
                }
                break;
            }
            match buf.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    bytes.extend_from_slice(&buf[..=i]);
                    (true, i + 1)
                }
                None => {
                    bytes.extend_from_slice(buf);
                    (false, buf.len())
                }
            }
        };
        r.consume(used);
        if bytes.len() > max {
            return Err(herr(431, "request head line too long"));
        }
        if found {
            break;
        }
    }
    String::from_utf8(bytes)
        .map(Some)
        .map_err(|_| herr(400, "request head is not valid utf-8"))
}

/// Split a request target into path and query map (no percent-decoding).
fn split_target(target: &str) -> (String, BTreeMap<String, String>) {
    match target.split_once('?') {
        None => (target.to_string(), BTreeMap::new()),
        Some((p, q)) => {
            let mut map = BTreeMap::new();
            for pair in q.split('&') {
                if pair.is_empty() {
                    continue;
                }
                match pair.split_once('=') {
                    Some((k, v)) => map.insert(k.to_string(), v.to_string()),
                    None => map.insert(pair.to_string(), String::new()),
                };
            }
            (p.to_string(), map)
        }
    }
}

/// Stable machine-readable error code for a status (the `error.code`
/// field of the `idatacool-error/1` envelope).
pub fn error_code(status: u16) -> &'static str {
    match status {
        400 => "bad_request",
        404 => "not_found",
        405 => "method_not_allowed",
        413 => "payload_too_large",
        429 => "rate_limited",
        431 => "headers_too_large",
        500 => "internal_error",
        501 => "not_implemented",
        503 => "overloaded",
        504 => "deadline_exceeded",
        505 => "http_version_unsupported",
        _ => "error",
    }
}

/// Build the `idatacool-error/1` envelope document — the single source
/// of every error body the crate emits (`Response::error`, the server's
/// cached error path). `field` names the offending request field when
/// the caller knows it (e.g. a bad query parameter).
pub fn error_envelope(status: u16, msg: &str, field: Option<&str>) -> Json {
    let mut e = std::collections::BTreeMap::new();
    e.insert("code".to_string(), Json::Str(error_code(status).to_string()));
    e.insert("message".to_string(), Json::Str(msg.to_string()));
    if let Some(f) = field.or_else(|| infer_field(msg)) {
        e.insert("field".to_string(), Json::Str(f.to_string()));
    }
    let mut m = std::collections::BTreeMap::new();
    m.insert("schema".to_string(),
             Json::Str("idatacool-error/1".to_string()));
    m.insert("error".to_string(), Json::Obj(e));
    Json::Obj(m)
}

/// Pull the offending field name out of the crate's own strict-parse
/// messages ("unknown field 'durationn'", "field 'plants' must be
/// ..."), so the envelope's `field` is populated for the common 400s
/// without threading a side-channel through every `anyhow` error.
fn infer_field(msg: &str) -> Option<&str> {
    let at = msg.find("field '")?;
    let rest = &msg[at + "field '".len()..];
    let end = rest.find('\'')?;
    (end > 0).then_some(&rest[..end])
}

/// An outgoing response. `write_to` adds the `content-length` framing
/// header plus `connection: close` or `connection: keep-alive`
/// according to the `close` flag (constructors default to close; the
/// server flips it for reusable connections).
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    pub close: bool,
}

impl Response {
    pub fn new(status: u16, content_type: &str, body: Vec<u8>) -> Response {
        Response {
            status,
            headers: vec![("content-type".into(), content_type.into())],
            body,
            close: true,
        }
    }

    pub fn json(status: u16, j: &Json) -> Response {
        Response::new(status, "application/json", j.to_string().into_bytes())
    }

    pub fn ndjson(body: Vec<u8>) -> Response {
        Response::new(200, "application/x-ndjson", body)
    }

    /// An `idatacool-error/1` JSON envelope response.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::error_in(status, msg, None)
    }

    /// Like `error`, naming the offending request field.
    pub fn error_in(status: u16, msg: &str, field: Option<&str>)
                    -> Response {
        Response::json(status, &error_envelope(status, msg, field))
    }

    pub fn with_header(mut self, k: &str, v: &str) -> Response {
        self.headers.push((k.into(), v.into()));
        self
    }

    /// Mark the connection reusable: `write_to` emits
    /// `connection: keep-alive` instead of `close`.
    pub fn keep_alive(mut self) -> Response {
        self.close = false;
        self
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        write!(w, "HTTP/1.1 {} {}\r\n", self.status, reason(self.status))?;
        for (k, v) in &self.headers {
            write!(w, "{k}: {v}\r\n")?;
        }
        write!(w, "content-length: {}\r\n", self.body.len())?;
        let conn = if self.close { "close" } else { "keep-alive" };
        write!(w, "connection: {conn}\r\n\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Reason phrase for the status codes the server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// A client-side view of one exchange.
#[derive(Debug, Clone)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(name).map(|s| s.as_str())
    }

    pub fn body_str(&self) -> anyhow::Result<&str> {
        Ok(std::str::from_utf8(&self.body)?)
    }
}

/// One blocking request/response exchange against `addr` (e.g.
/// `127.0.0.1:8080`). Connection-close framing: the server ends the body
/// by closing, so the client simply reads to EOF.
pub fn http_roundtrip(
    addr: &str,
    method: &str,
    target: &str,
    body: Option<&[u8]>,
) -> anyhow::Result<ClientResponse> {
    let mut s = TcpStream::connect(addr)?;
    s.set_nodelay(true)?;
    // Generous: a full (non-quick) sweep request simulates for minutes.
    s.set_read_timeout(Some(Duration::from_secs(600)))?;
    s.set_write_timeout(Some(Duration::from_secs(60)))?;
    let b = body.unwrap_or(&[]);
    write!(
        s,
        "{method} {target} HTTP/1.1\r\nhost: {addr}\r\n\
         content-length: {}\r\nconnection: close\r\n\r\n",
        b.len()
    )?;
    s.write_all(b)?;
    s.flush()?;
    let mut raw = Vec::new();
    s.read_to_end(&mut raw)?;
    parse_client_response(&raw)
}

/// Parse a full raw response (head + body) read to EOF.
pub fn parse_client_response(raw: &[u8]) -> anyhow::Result<ClientResponse> {
    let split = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("response has no header terminator"))?;
    let head = std::str::from_utf8(&raw[..split])?;
    let body = raw[split + 4..].to_vec();
    let mut lines = head.split("\r\n");
    let status_line = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty response"))?;
    let mut parts = status_line.split_whitespace();
    let version = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty status line"))?;
    anyhow::ensure!(
        version.starts_with("HTTP/1."),
        "unexpected response version '{version}'"
    );
    let status: u16 = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("status line missing code"))?
        .parse()?;
    let mut headers = BTreeMap::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    // Sanity: with content-length present the body must not be shorter
    // (connection-close reads can't truncate silently).
    if let Some(cl) = headers.get("content-length") {
        let want: usize = cl.parse()?;
        anyhow::ensure!(
            body.len() == want,
            "body length {} != content-length {want}",
            body.len()
        );
    }
    Ok(ClientResponse { status, headers, body })
}

/// Read one response from a buffered stream, framed by
/// `content-length` (the keep-alive counterpart of
/// `parse_client_response`, which frames by EOF). `Ok(None)` means the
/// server closed before a status line.
pub fn read_client_response<R: BufRead>(r: &mut R)
                                        -> anyhow::Result<Option<ClientResponse>> {
    let mut head = Vec::new();
    // Accumulate lines until the blank separator; server responses are
    // trusted, so a simple unbounded read_until is fine here.
    loop {
        let start = head.len();
        let n = r.read_until(b'\n', &mut head)?;
        if n == 0 {
            if head.is_empty() {
                return Ok(None);
            }
            anyhow::bail!("eof inside response head");
        }
        if head[start..] == *b"\r\n" || head[start..] == *b"\n" {
            break;
        }
    }
    let head = std::str::from_utf8(&head)?;
    let mut lines = head.lines();
    let status_line =
        lines.next().ok_or_else(|| anyhow::anyhow!("empty response"))?;
    let mut parts = status_line.split_whitespace();
    let version =
        parts.next().ok_or_else(|| anyhow::anyhow!("empty status line"))?;
    anyhow::ensure!(
        version.starts_with("HTTP/1."),
        "unexpected response version '{version}'"
    );
    let status: u16 = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("status line missing code"))?
        .parse()?;
    let mut headers = BTreeMap::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = headers
        .get("content-length")
        .ok_or_else(|| anyhow::anyhow!("response has no content-length"))?
        .parse()?;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Some(ClientResponse { status, headers, body }))
}

/// Fire every request down ONE connection back-to-back (HTTP/1.1
/// pipelining over keep-alive), then read the responses in order,
/// framed by `content-length`. Each request is
/// `(method, target, body)`; the last one asks the server to close.
pub fn http_pipeline(
    addr: &str,
    reqs: &[(&str, &str, Option<&[u8]>)],
) -> anyhow::Result<Vec<ClientResponse>> {
    let mut s = TcpStream::connect(addr)?;
    s.set_nodelay(true)?;
    s.set_read_timeout(Some(Duration::from_secs(600)))?;
    s.set_write_timeout(Some(Duration::from_secs(60)))?;
    for (i, (method, target, body)) in reqs.iter().enumerate() {
        let b = body.unwrap_or(&[]);
        let conn =
            if i + 1 == reqs.len() { "close" } else { "keep-alive" };
        write!(
            s,
            "{method} {target} HTTP/1.1\r\nhost: {addr}\r\n\
             content-length: {}\r\nconnection: {conn}\r\n\r\n",
            b.len()
        )?;
        s.write_all(b)?;
    }
    s.flush()?;
    let mut r = std::io::BufReader::new(s);
    let mut out = Vec::with_capacity(reqs.len());
    for i in 0..reqs.len() {
        let resp = read_client_response(&mut r)?.ok_or_else(|| {
            anyhow::anyhow!("connection closed after {i} of {} responses",
                            reqs.len())
        })?;
        out.push(resp);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &str) -> Result<Option<Request>, HttpError> {
        Request::read_from(&mut BufReader::new(raw.as_bytes()))
    }

    #[test]
    fn parses_get_with_query() {
        let r = parse("GET /simulate?stream=1&x=y HTTP/1.1\r\nHost: h\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/simulate");
        assert_eq!(r.query.get("stream").map(String::as_str), Some("1"));
        assert_eq!(r.query.get("x").map(String::as_str), Some("y"));
        assert_eq!(r.header("host"), Some("h"));
        assert!(r.body.is_empty());
    }

    #[test]
    fn newline_free_flood_is_cut_off_at_the_cap() {
        // A peer streaming bytes with no '\n' must be rejected once the
        // head cap is consumed — not buffered until OOM.
        let raw = vec![b'A'; MAX_HEAD_BYTES + 64];
        let err =
            Request::read_from(&mut BufReader::new(raw.as_slice())).unwrap_err();
        assert_eq!(err.status, 431);
        // Same cap inside the header block.
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        raw.extend(vec![b'B'; MAX_HEAD_BYTES + 64]);
        let err =
            Request::read_from(&mut BufReader::new(raw.as_slice())).unwrap_err();
        assert_eq!(err.status, 431);
    }

    #[test]
    fn parses_post_with_body() {
        let r = parse(
            "POST /fleet HTTP/1.1\r\nContent-Length: 7\r\n\r\n{\"a\":1}",
        )
        .unwrap()
        .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.body_str().unwrap(), "{\"a\":1}");
    }

    #[test]
    fn clean_eof_is_none() {
        assert!(parse("").unwrap().is_none());
    }

    #[test]
    fn malformed_requests_are_400s() {
        assert_eq!(parse("GET\r\n\r\n").unwrap_err().status, 400);
        assert_eq!(
            parse("GET / HTTP/1.1\r\nbadheader\r\n\r\n").unwrap_err().status,
            400
        );
        assert_eq!(
            parse("GET / HTTP/1.1\r\ncontent-length: x\r\n\r\n")
                .unwrap_err()
                .status,
            400
        );
        // body shorter than content-length
        assert_eq!(
            parse("POST / HTTP/1.1\r\ncontent-length: 10\r\n\r\nab")
                .unwrap_err()
                .status,
            400
        );
    }

    #[test]
    fn oversize_body_is_413() {
        let req = format!(
            "POST / HTTP/1.1\r\ncontent-length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        assert_eq!(parse(&req).unwrap_err().status, 413);
    }

    #[test]
    fn chunked_is_501_and_http2_is_505() {
        assert_eq!(
            parse("POST / HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n")
                .unwrap_err()
                .status,
            501
        );
        assert_eq!(parse("GET / HTTP/2\r\n\r\n").unwrap_err().status, 505);
    }

    #[test]
    fn response_round_trips_through_client_parser() {
        let resp = Response::json(
            200,
            &Json::parse("{\"ok\":true}").unwrap(),
        )
        .with_header("x-cache", "hit");
        let mut wire = Vec::new();
        resp.write_to(&mut wire).unwrap();
        let back = parse_client_response(&wire).unwrap();
        assert_eq!(back.status, 200);
        assert_eq!(back.header("x-cache"), Some("hit"));
        assert_eq!(back.header("connection"), Some("close"));
        assert_eq!(back.body_str().unwrap(), "{\"ok\":true}");
    }

    #[test]
    fn error_envelope_is_structured() {
        let resp = Response::error(404, "no route for /nope");
        let mut wire = Vec::new();
        resp.write_to(&mut wire).unwrap();
        let back = parse_client_response(&wire).unwrap();
        assert_eq!(back.status, 404);
        let j = Json::parse(back.body_str().unwrap()).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(),
                   Some("idatacool-error/1"));
        let e = j.get("error").unwrap();
        assert_eq!(e.get("code").unwrap().as_str(), Some("not_found"));
        assert_eq!(e.get("message").unwrap().as_str(),
                   Some("no route for /nope"));
        assert!(e.get("field").is_none());
    }

    #[test]
    fn envelope_field_explicit_and_inferred() {
        // Explicit field name wins.
        let j = error_envelope(400, "expects 0|1", Some("stream"));
        assert_eq!(j.get("error").unwrap().get("field").unwrap().as_str(),
                   Some("stream"));
        // The strict-parser message convention is recognized...
        let j = error_envelope(400, "unknown field 'durationn'", None);
        let e = j.get("error").unwrap();
        assert_eq!(e.get("code").unwrap().as_str(), Some("bad_request"));
        assert_eq!(e.get("field").unwrap().as_str(), Some("durationn"));
        // ...and prose without the marker yields no field at all.
        let j = error_envelope(500, "worker panicked", None);
        assert!(j.get("error").unwrap().get("field").is_none());
    }

    #[test]
    fn keep_alive_flag_switches_the_connection_header() {
        let resp = Response::json(200, &Json::parse("{}").unwrap());
        let mut wire = Vec::new();
        resp.clone().keep_alive().write_to(&mut wire).unwrap();
        let back = parse_client_response(&wire).unwrap();
        assert_eq!(back.header("connection"), Some("keep-alive"));
        wire.clear();
        resp.write_to(&mut wire).unwrap();
        let back = parse_client_response(&wire).unwrap();
        assert_eq!(back.header("connection"), Some("close"));
    }

    #[test]
    fn client_reader_frames_by_content_length() {
        // Two responses on one "connection": the reader must split them
        // on content-length, not EOF.
        let mut wire = Vec::new();
        Response::json(200, &Json::parse("{\"n\":1}").unwrap())
            .keep_alive()
            .write_to(&mut wire)
            .unwrap();
        Response::json(200, &Json::parse("{\"n\":22}").unwrap())
            .write_to(&mut wire)
            .unwrap();
        let mut r = BufReader::new(wire.as_slice());
        let a = read_client_response(&mut r).unwrap().unwrap();
        let b = read_client_response(&mut r).unwrap().unwrap();
        assert_eq!(a.body_str().unwrap(), "{\"n\":1}");
        assert_eq!(b.body_str().unwrap(), "{\"n\":22}");
        assert!(read_client_response(&mut r).unwrap().is_none());
    }

    #[test]
    fn truncated_client_body_detected() {
        let raw = b"HTTP/1.1 200 OK\r\ncontent-length: 10\r\n\r\nabc";
        assert!(parse_client_response(raw).is_err());
    }
}
