//! Minimal CLI argument parser (no clap in the vendored crate set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments; collects unknown flags for error reporting.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .is_some_and(|n| !n.starts_with("--"))
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.bools.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key) || self.flags.contains_key(key)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Like `usize_or`, but a present-yet-unparseable value is an error
    /// instead of a silent fall-back to the default (user-facing flags
    /// like `--plants`/`--shards` must not misbehave quietly).
    pub fn usize_strict(&self, key: &str, default: usize)
                        -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse::<usize>().map_err(|_| {
                anyhow::anyhow!(
                    "--{key} expects a non-negative integer, got '{s}'"
                )
            }),
        }
    }

    /// Strict boolean flag: absent uses the default, a bare `--key`
    /// means true, and `--key <0|1|true|false>` parses strictly — any
    /// other value is an error, never a silent fall-back.
    pub fn bool_strict(&self, key: &str, default: bool)
                       -> anyhow::Result<bool> {
        match self.get(key) {
            None => {
                // `has` also sees bare boolean flags (`--megabatch`).
                Ok(if self.has(key) { true } else { default })
            }
            Some(v) => parse_bool(v).ok_or_else(|| {
                anyhow::anyhow!("--{key} expects 0|1|true|false, got '{v}'")
            }),
        }
    }
}

/// The shared strict-bool vocabulary of CLI flags and env knobs.
fn parse_bool(v: &str) -> Option<bool> {
    match v {
        "1" | "true" => Some(true),
        "0" | "false" => Some(false),
        _ => None,
    }
}

/// Strict env-var counterpart of `Args::usize_strict`: an unset (or
/// blank) variable is `None`, a present-yet-unparseable value is an
/// error — env knobs like `IDATACOOL_SWEEP_SHARDS` and
/// `IDATACOOL_SERVE_WORKERS` must not misbehave any more quietly than
/// their CLI-flag twins.
pub fn env_usize_strict(name: &str) -> anyhow::Result<Option<usize>> {
    match std::env::var_os(name) {
        None => Ok(None),
        Some(os) => {
            let v = os.to_str().ok_or_else(|| {
                anyhow::anyhow!("{name} is not valid unicode")
            })?;
            parse_usize_env(name, v)
        }
    }
}

/// The parse half of `env_usize_strict`, split out so it is testable
/// without mutating process-global environment state.
pub fn parse_usize_env(name: &str, value: &str)
                       -> anyhow::Result<Option<usize>> {
    let t = value.trim();
    if t.is_empty() {
        return Ok(None);
    }
    t.parse::<usize>().map(Some).map_err(|_| {
        anyhow::anyhow!(
            "{name} expects a non-negative integer, got '{value}'"
        )
    })
}

/// Strict boolean env knob (`IDATACOOL_FLEET_MEGABATCH=0|1|true|false`):
/// unset or blank is `None`, anything else must parse — garbage is an
/// error, matching `env_usize_strict`.
pub fn env_bool_strict(name: &str) -> anyhow::Result<Option<bool>> {
    match std::env::var_os(name) {
        None => Ok(None),
        Some(os) => {
            let v = os.to_str().ok_or_else(|| {
                anyhow::anyhow!("{name} is not valid unicode")
            })?;
            parse_bool_env(name, v)
        }
    }
}

/// The parse half of `env_bool_strict`, split out so it is testable
/// without mutating process-global environment state.
pub fn parse_bool_env(name: &str, value: &str)
                      -> anyhow::Result<Option<bool>> {
    let t = value.trim();
    if t.is_empty() {
        return Ok(None);
    }
    parse_bool(t).map(Some).ok_or_else(|| {
        anyhow::anyhow!("{name} expects 0|1|true|false, got '{value}'")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse("figures --fig 4a --quick --out=results run");
        assert_eq!(a.positional, vec!["figures", "run"]);
        assert_eq!(a.get("fig"), Some("4a"));
        assert_eq!(a.get("out"), Some("results"));
        assert!(a.has("quick"));
        assert!(!a.has("nope"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse("--nodes 13 --setpoint 67.5");
        assert_eq!(a.usize_or("nodes", 0), 13);
        assert_eq!(a.f64_or("setpoint", 0.0), 67.5);
        assert_eq!(a.f64_or("missing", 1.5), 1.5);
    }

    #[test]
    fn strict_accessor_rejects_garbage() {
        let a = parse("--plants 4 --shards nope");
        assert_eq!(a.usize_strict("plants", 1).unwrap(), 4);
        assert_eq!(a.usize_strict("missing", 7).unwrap(), 7);
        let err = a.usize_strict("shards", 1).unwrap_err().to_string();
        assert!(err.contains("--shards") && err.contains("nope"), "{err}");
        // negative and fractional values are rejected, not truncated
        let a = parse("--plants -2");
        assert!(a.usize_strict("plants", 1).is_err());
        let a = parse("--plants 2.5");
        assert!(a.usize_strict("plants", 1).is_err());
    }

    #[test]
    fn boolean_before_flag() {
        let a = parse("--quick --fig 4a");
        assert!(a.has("quick"));
        assert_eq!(a.get("fig"), Some("4a"));
    }

    #[test]
    fn bool_flag_is_strict() {
        let a = parse("--megabatch 0 --other");
        assert!(!a.bool_strict("megabatch", true).unwrap());
        assert!(a.bool_strict("missing", true).unwrap());
        assert!(!a.bool_strict("missing", false).unwrap());
        // bare boolean flag means true
        let a = parse("--megabatch");
        assert!(a.bool_strict("megabatch", false).unwrap());
        for (v, want) in [("1", true), ("true", true), ("0", false),
                          ("false", false)] {
            let a = parse(&format!("--megabatch {v}"));
            assert_eq!(a.bool_strict("megabatch", !want).unwrap(), want);
        }
        let a = parse("--megabatch yes");
        let err = a.bool_strict("megabatch", true).unwrap_err().to_string();
        assert!(err.contains("--megabatch") && err.contains("yes"), "{err}");
    }

    #[test]
    fn env_bool_parse_is_strict() {
        assert_eq!(parse_bool_env("X", "1").unwrap(), Some(true));
        assert_eq!(parse_bool_env("X", "true").unwrap(), Some(true));
        assert_eq!(parse_bool_env("X", " 0 ").unwrap(), Some(false));
        assert_eq!(parse_bool_env("X", "false").unwrap(), Some(false));
        assert_eq!(parse_bool_env("X", "").unwrap(), None);
        assert_eq!(parse_bool_env("X", "  ").unwrap(), None);
        let err = parse_bool_env("X", "on").unwrap_err().to_string();
        assert!(err.contains('X') && err.contains("on"), "{err}");
    }

    #[test]
    fn env_parse_is_strict() {
        assert_eq!(parse_usize_env("X", "4").unwrap(), Some(4));
        assert_eq!(parse_usize_env("X", " 8 ").unwrap(), Some(8));
        assert_eq!(parse_usize_env("X", "").unwrap(), None);
        assert_eq!(parse_usize_env("X", "  ").unwrap(), None);
        let err = parse_usize_env("X", "nope").unwrap_err().to_string();
        assert!(err.contains('X') && err.contains("nope"), "{err}");
        assert!(parse_usize_env("X", "-1").is_err());
        assert!(parse_usize_env("X", "2.5").is_err());
    }
}
