//! Back-compat shim: the micro-benchmark harness was promoted to the
//! first-class `crate::bench` subsystem (runner + JSON records + baseline
//! comparator + suite registry). Existing call sites
//! (`rust/benches/*.rs`, `examples/perf_scan.rs`) keep working through
//! these re-exports; new code should use `crate::bench` directly.

pub use crate::bench::{fast_mode, fmt_s, Bench, BenchResult};
