//! iDataCool digital twin: HPC hot-water cooling and energy reuse.
//!
//! Reproduction of *iDataCool: HPC with Hot-Water Cooling and Energy
//! Reuse* (Meyer, Ries, Solbrig, Wettig — ISC 2013) as a three-layer
//! Rust + JAX + Pallas co-simulation framework:
//!
//!  * **L1** (`python/compile/kernels/`): Pallas kernel for the batched
//!    node RC thermal update (the compute hot-spot).
//!  * **L2** (`python/compile/model.py`): whole-plant JAX model, AOT-
//!    lowered once to HLO text.
//!  * **L3** (this crate): the data-center control plane — scheduler,
//!    PID/valve control, chiller supervision, failover, telemetry,
//!    energy accounting — executing the plant via PJRT on every tick.
//!  * **Fleet** (`fleet`): N plants sharded across OS threads against one
//!    shared facility loop (pooled heat recovery + aggregate adsorption
//!    chiller), with a declarative scenario catalog.
//!  * **Serve** (`server`): the twin as a resident service — a std-only
//!    HTTP/1.1 server (versioned `/v1` API, keep-alive) with a worker
//!    pool, in-flight request coalescing, continuous request batching
//!    into shared lane arenas, and a sharded fingerprint-keyed LRU
//!    response cache (`idatacool serve`).
//!  * **Obs** (`obs`): the flight recorder — crate-wide tracing spans
//!    flushed to Chrome `trace_event` JSON, plus a Prometheus-ready
//!    metrics registry; zero-cost when disabled (the default).
//!  * **Resilience** (`resilience`): per-plant fault quarantine,
//!    seeded deterministic chaos injection, and crash-consistent
//!    `idatacool-ckpt/1` checkpoint/resume.
//!  * **Optimize** (`optimize`): closed-loop operating-point search —
//!    typed parameter space, weighted PUE/ERE/throttle/cost objective,
//!    deterministic drivers (grid / coordinate descent / cross-entropy)
//!    over cached megabatch fleet evaluations; recovers the paper's
//!    ~60–70 degC setpoint band as an output (`idatacool optimize`).
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-figure reproductions.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod economics;
pub mod figures;
pub mod fleet;
pub mod obs;
pub mod optimize;
pub mod plant;
pub mod report;
pub mod resilience;
pub mod runtime;
pub mod server;
pub mod stats;
pub mod util;
pub mod variability;
pub mod workload;
