//! The temperature-setpoint sweep shared by Figs. 4a/5a/5b/6a/6b/7a/7b.
//!
//! For each rack-outlet setpoint: warm-start the plant near the operating
//! point, let the PID settle, then measure over a fixed window, collecting
//! the statistics the paper reports (time+node averages with standard
//! deviations for the 13 selected nodes, plant-level energy fractions,
//! and per-node (T_core, P_node) pairs for the Fig. 5b interpolation).
//!
//! Setpoints are independent simulations (each builds its own driver from
//! the same config), so the sweep parallelizes with the fleet engine's
//! sharding pattern: setpoints are split into contiguous index blocks
//! (`util::shard::blocks`, one block per OS thread — assignment is
//! order-independent for results, see the module docs there), and the
//! reduction walks results in setpoint order — a K-shard sweep is
//! bitwise identical to the serial one (`tests/sweep_parallel.rs` is
//! the gate).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{SimConfig, WorkloadKind};
use crate::coordinator::energy::EnergyAccount;
use crate::coordinator::SimulationDriver;
use crate::plant::layout::*;
use crate::plant::TickOutput;
use crate::stats::Running;
use crate::util::shard::blocks;

/// Sweep timing knobs (short values for tests, long for real runs).
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Settling time after warm start [simulated s].
    pub settle_s: f64,
    /// Measurement window [simulated s].
    pub measure_s: f64,
    /// Additional settle ticks until |T_out - setpoint| < tol.
    pub settle_tol: f64,
    pub max_extra_settle_s: f64,
    /// Samples of the core-temperature population for Fig. 4b.
    pub histogram_samples: usize,
    /// Duration of the Sect.-3 cold-start equilibrium run [s].
    pub equilibrium_s: f64,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            settle_s: 1800.0,
            measure_s: 1200.0,
            settle_tol: 0.6,
            max_extra_settle_s: 3600.0,
            histogram_samples: 30,
            equilibrium_s: 16_000.0,
        }
    }
}

impl SweepOptions {
    /// Fast variant for unit/integration tests.
    pub fn quick() -> Self {
        SweepOptions {
            settle_s: 300.0,
            measure_s: 240.0,
            settle_tol: 1.5,
            max_extra_settle_s: 600.0,
            histogram_samples: 4,
            equilibrium_s: 4000.0,
        }
    }
}

/// Steady-state measurement at one setpoint.
pub struct SweepPoint {
    pub setpoint: f64,
    /// Rack outlet temperature over the window (mean = x value, std = the
    /// paper's horizontal error bars).
    pub t_out: Running,
    pub t_tank: Running,
    /// Mean core temperature over the 13 selected nodes (time+node agg).
    pub sel_core: Running,
    /// Node DC power over the 13 selected nodes.
    pub sel_power: Running,
    /// Plant-level fractions from the energy account.
    pub hiw: f64,
    pub hiw_err: f64,
    pub pd_frac: f64,
    pub cop: f64,
    pub reuse: f64,
    pub valve_mean: f64,
    pub p_ac: f64,
}

/// Full sweep result.
pub struct SweepData {
    pub points: Vec<SweepPoint>,
    /// Per six-core node: (core_mean, node_power) at each setpoint —
    /// the raw material of Fig. 5b's interpolation to 80 degC.
    pub node_series: BTreeMap<usize, Vec<(f64, f64)>>,
    pub selected: Vec<usize>,
}

impl SweepData {
    /// Machine-readable view (`util::json`, BTreeMap-stable key order)
    /// — the `data` block of the server's `POST /sweep` response. Pure
    /// measurement outputs, no wall-clock fields.
    pub fn to_json_value(&self) -> crate::util::json::Json {
        use crate::util::json::{Json, JsonBuilder};
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                JsonBuilder::new()
                    .num("setpoint", p.setpoint)
                    .num("t_out_mean", p.t_out.mean())
                    .num("t_out_std", p.t_out.std())
                    .num("t_tank_mean", p.t_tank.mean())
                    .num("sel_core_mean", p.sel_core.mean())
                    .num("sel_core_std", p.sel_core.std())
                    .num("sel_power_mean", p.sel_power.mean())
                    .num("sel_power_std", p.sel_power.std())
                    .num("hiw", p.hiw)
                    .num("hiw_err", p.hiw_err)
                    .num("pd_frac", p.pd_frac)
                    .num("cop", p.cop)
                    .num("reuse", p.reuse)
                    .num("valve_mean", p.valve_mean)
                    .num("p_ac_w", p.p_ac)
                    .build()
            })
            .collect();
        // node_series as an array of {node, points: [[t, p], ...]} —
        // arrays preserve numeric node order (object keys would sort
        // lexicographically).
        let nodes: Vec<Json> = self
            .node_series
            .iter()
            .map(|(&n, tps)| {
                JsonBuilder::new()
                    .num("node", n as f64)
                    .arr(
                        "points",
                        tps.iter()
                            .map(|&(t, p)| {
                                Json::Arr(vec![Json::Num(t), Json::Num(p)])
                            })
                            .collect(),
                    )
                    .build()
            })
            .collect();
        JsonBuilder::new()
            .arr("points", points)
            .arr("node_series", nodes)
            .arr(
                "selected",
                self.selected.iter().map(|&n| Json::Num(n as f64)).collect(),
            )
            .build()
    }
}

/// One setpoint's finished measurement — the unit of parallel work
/// behind both the figure sweeps and the optimizer's best-point detail
/// (`optimize::run_optimize` re-measures the winning candidate through
/// [`evaluate_point`], so sweep figures and optimizer reports can never
/// disagree about what one operating point looks like).
pub struct SetpointRun {
    pub point: SweepPoint,
    /// (six-core node index, (core_mean, node_power)) in node order.
    pub node_tp: Vec<(usize, (f64, f64))>,
    pub selected: Vec<usize>,
}

/// Shard count for a sweep: every available core (capped at the setpoint
/// count), overridable via `IDATACOOL_SWEEP_SHARDS`. The override gets
/// the same strict treatment as the `--shards` CLI flag
/// (`util::cli::env_usize_strict`): an unparseable value is an error —
/// not a silent fall-back — zero is an error, and a value beyond the
/// setpoint count clamps with a warning.
pub fn default_sweep_shards(n_setpoints: usize) -> Result<usize> {
    let cap = n_setpoints.max(1);
    match crate::util::cli::env_usize_strict("IDATACOOL_SWEEP_SHARDS")? {
        Some(0) => anyhow::bail!(
            "IDATACOOL_SWEEP_SHARDS must be at least 1 \
             (use 1 for a serial sweep)"
        ),
        Some(k) if k > cap => {
            eprintln!(
                "warning: IDATACOOL_SWEEP_SHARDS={k} exceeds the \
                 {n_setpoints} setpoints; clamping to {cap} \
                 (one shard per setpoint)"
            );
            Ok(cap)
        }
        Some(k) => Ok(k),
        None => Ok(available_cores().clamp(1, cap)),
    }
}

fn available_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run the stress sweep over the given setpoints, sharded across all
/// configured threads. Bitwise identical to `run_sweep_serial`.
pub fn run_sweep(cfg: &SimConfig, setpoints: &[f64], opts: &SweepOptions)
                 -> Result<SweepData> {
    run_sweep_sharded(cfg, setpoints, opts,
                      default_sweep_shards(setpoints.len())?)
}

/// The single-threaded reference path.
pub fn run_sweep_serial(cfg: &SimConfig, setpoints: &[f64],
                        opts: &SweepOptions) -> Result<SweepData> {
    run_sweep_sharded(cfg, setpoints, opts, 1)
}

/// Run the sweep over an explicit shard (OS thread) count.
pub fn run_sweep_sharded(cfg: &SimConfig, setpoints: &[f64],
                         opts: &SweepOptions, shards: usize)
                         -> Result<SweepData> {
    let n = setpoints.len();
    let shards = shards.clamp(1, n.max(1));
    let mut slots: Vec<Option<SetpointRun>> = (0..n).map(|_| None).collect();

    if shards <= 1 {
        for (i, &sp) in setpoints.iter().enumerate() {
            slots[i] = Some(evaluate_point(cfg, sp, opts)?);
        }
    } else {
        let indexed: Vec<(usize, f64)> =
            setpoints.iter().copied().enumerate().collect();
        let buckets = blocks(indexed, shards);
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(buckets.len());
            for bucket in buckets {
                handles.push(scope.spawn(
                    move || -> Result<Vec<(usize, SetpointRun)>> {
                        let mut runs = Vec::with_capacity(bucket.len());
                        for (i, sp) in bucket {
                            runs.push((i, evaluate_point(cfg, sp, opts)?));
                        }
                        Ok(runs)
                    },
                ));
            }
            for h in handles {
                let shard_runs = h
                    .join()
                    .map_err(|_| anyhow::anyhow!("sweep shard panicked"))??;
                for (i, run) in shard_runs {
                    slots[i] = Some(run);
                }
            }
            Ok(())
        })?;
    }

    // Reduce in setpoint order — identical for every shard count.
    let mut points = Vec::with_capacity(n);
    let mut node_series: BTreeMap<usize, Vec<(f64, f64)>> = BTreeMap::new();
    let mut selected = Vec::new();
    for (i, slot) in slots.into_iter().enumerate() {
        let run = slot.ok_or_else(|| {
            anyhow::anyhow!("setpoint {i} produced no measurement")
        })?;
        if selected.is_empty() {
            selected = run.selected;
        }
        for (node, tp) in run.node_tp {
            node_series.entry(node).or_default().push(tp);
        }
        points.push(run.point);
    }
    Ok(SweepData { points, node_series, selected })
}

/// Warm-start, settle and measure one setpoint. Self-contained: builds
/// its own driver from `cfg`, so concurrent setpoints share nothing —
/// the unit of work behind the figure sweeps, (via `run_sweep_sharded`)
/// the server's `POST /sweep` endpoint, and the optimizer's best-point
/// detail (`optimize`). The existing setpoint sweep is exactly this
/// function mapped over a 1-D setpoint grid — which is why the
/// optimizer's degenerate 1-D grid case reproduces it.
pub fn evaluate_point(cfg: &SimConfig, sp: f64, opts: &SweepOptions)
                      -> Result<SetpointRun> {
    let mut c = cfg.clone();
    c.workload = WorkloadKind::Stress;
    c.stress_background = 1.0; // full background so high T_out is reachable
    c.t_out_setpoint = sp;
    c.t_water_init = (sp - 3.0).max(20.0); // warm start
    let mut driver = SimulationDriver::new(c)?;
    let tick_s = driver.backend.tick_seconds(&driver.cfg.pp);

    // --- settle -----------------------------------------------------------
    driver.run_ticks((opts.settle_s / tick_s).ceil() as u64, 0)?;
    let mut extra = 0.0;
    loop {
        let t_out = driver.backend.circuit_state()[C_T_RACK_OUT] as f64;
        if (t_out - sp).abs() < opts.settle_tol
            || extra >= opts.max_extra_settle_s
        {
            break;
        }
        driver.run_ticks((60.0 / tick_s).ceil() as u64, 0)?;
        extra += 60.0;
    }

    // --- measure ----------------------------------------------------------
    let sel = parse_selected(&driver.workload.stats(), &driver);
    let mut t_out = Running::new();
    let mut t_tank = Running::new();
    let mut sel_core = Running::new();
    let mut sel_power = Running::new();
    let mut valve = Running::new();
    let mut energy = EnergyAccount::new();
    // per-node accumulators over the window (six-core only)
    let six = driver.lottery.six_core_nodes().to_vec();
    let mut node_t: BTreeMap<usize, Running> = BTreeMap::new();
    let mut node_p: BTreeMap<usize, Running> = BTreeMap::new();

    // Hot loop: one TickOutput + one observation buffer reused across the
    // whole window (no per-tick allocation).
    let mut out = TickOutput::new(driver.backend.n_padded());
    let mut obs: Vec<[f64; OBS_N]> =
        Vec::with_capacity(driver.backend.n_nodes());
    let ticks = (opts.measure_s / tick_s).ceil() as u64;
    for _ in 0..ticks {
        let sample = driver.tick_into(&mut out)?;
        energy.push(&out.scalars, tick_s);
        t_out.push(sample.t_rack_out);
        t_tank.push(sample.t_tank);
        valve.push(sample.valve);
        driver.node_observations_into(&out, &mut obs);
        for &n in &sel {
            sel_core.push(obs[n][O_CORE_MEAN]);
            sel_power.push(obs[n][O_NODE_POWER]);
        }
        for &n in &six {
            node_t.entry(n).or_default().push(obs[n][O_CORE_MEAN]);
            node_p.entry(n).or_default().push(obs[n][O_NODE_POWER]);
        }
    }

    let node_tp = six
        .iter()
        .map(|&n| (n, (node_t[&n].mean(), node_p[&n].mean())))
        .collect();

    // Fig. 7a error bars: temporal fluctuations of in/out temps + flow
    let hiw = energy.heat_in_water_fraction();
    let hiw_err = hiw
        * ((t_out.std() / (t_out.mean() - 20.0).max(1.0)).powi(2)
            + 0.005f64.powi(2))
        .sqrt()
        + 0.01;
    Ok(SetpointRun {
        point: SweepPoint {
            setpoint: sp,
            t_out,
            t_tank,
            sel_core,
            sel_power,
            hiw,
            hiw_err,
            pd_frac: energy.transferred_fraction(),
            cop: energy.cop(),
            reuse: energy.reuse_fraction(),
            valve_mean: valve.mean(),
            p_ac: energy.mean_p_ac(),
        },
        node_tp,
        selected: sel,
    })
}

/// The driver owns the workload behind a trait object; recover the
/// selected stress nodes from the lottery + seed (deterministic).
fn parse_selected(_stats: &str, driver: &SimulationDriver) -> Vec<usize> {
    use crate::workload::stress::StressWorkload;
    StressWorkload::new(
        &driver.lottery,
        driver.cfg.stress_nodes,
        driver.cfg.seed,
    )
    .selected
}
