//! Figure-reproduction harness: regenerates every figure of the paper's
//! evaluation (Sect. 4) plus the Sect. 3 equilibrium narrative and the
//! redundancy/fault experiment. See DESIGN.md §4 for the experiment index.
//!
//! Protocols follow the paper:
//!  * Figs. 4a/5a/6a: 13 randomly selected six-core nodes under `stress`,
//!    swept over rack-outlet setpoints (the rest of the cluster carries a
//!    full background load so high outlet temperatures are reachable).
//!  * Figs. 4b/5b: population histograms + Gaussian fits.
//!  * Figs. 6b/7a/7b: plant-level fractions vs temperature with the
//!    paper's 10 % flow-meter error bars.

pub mod sweep;

use anyhow::Result;

use crate::config::{SimConfig, WorkloadKind};
use crate::coordinator::supervisor::Fault;
use crate::coordinator::SimulationDriver;
use crate::plant::hydraulics::{Manifold, ManifoldKind};
use crate::plant::layout::O_CORE_MAX;
use crate::report::Series;
use crate::stats::gauss;
use crate::stats::histogram::Histogram;
use crate::stats::interp;
use sweep::{SweepData, SweepOptions};

/// The paper's sweep band: Fig. 4a spans ~49..70 degC outlet.
pub const SETPOINTS: &[f64] = &[49.0, 52.5, 56.0, 59.5, 63.0, 66.5, 70.0];

/// All figure ids the harness can regenerate.
pub const ALL_FIGURES: &[&str] =
    &["4a", "4b", "5a", "5b", "6a", "6b", "7a", "7b", "r1", "s3", "r2",
      "manifold", "binning", "econ"];

/// Run one figure (or "all"); returns the resulting series.
pub fn run_figure(id: &str, cfg: &SimConfig, opts: &SweepOptions)
                  -> Result<Vec<Series>> {
    match id {
        "4a" | "5a" | "6a" | "6b" | "7a" | "7b" => {
            let data = sweep::run_sweep(cfg, SETPOINTS, opts)?;
            Ok(vec![match id {
                "4a" => fig4a(&data),
                "5a" => fig5a(&data),
                "6a" => fig6a(&data),
                "6b" => fig6b(&data),
                "7a" => fig7a(&data),
                _ => fig7b(&data),
            }])
        }
        "sweep" => {
            let data = sweep::run_sweep(cfg, SETPOINTS, opts)?;
            Ok(all_sweep_figures(&data))
        }
        "4b" => Ok(vec![fig4b(cfg, opts)?]),
        "5b" => {
            let data = sweep::run_sweep(cfg, SETPOINTS, opts)?;
            Ok(vec![fig5b(&data)])
        }
        "r1" => {
            let data = sweep::run_sweep(cfg, SETPOINTS, opts)?;
            Ok(vec![reuse_table(&data, cfg, opts)?])
        }
        "s3" => Ok(vec![equilibrium(cfg, opts)?]),
        "r2" => Ok(vec![fault_injection(cfg, opts)?]),
        "manifold" => Ok(vec![manifold_ablation(cfg)]),
        "binning" => Ok(vec![binning(cfg, opts)?]),
        "econ" => Ok(vec![economics(cfg, opts)?]),
        _ => anyhow::bail!("unknown figure '{id}' (have {ALL_FIGURES:?})"),
    }
}

/// All figures that share the stress sweep (4a, 5a, 5b, 6a, 6b, 7a, 7b).
pub fn all_sweep_figures(data: &SweepData) -> Vec<Series> {
    vec![fig4a(data), fig5a(data), fig5b(data), fig6a(data), fig6b(data),
         fig7a(data), fig7b(data)]
}

/// Fig. 4(a): average core temperature of the 13 stressed nodes vs T_out.
pub fn fig4a(data: &SweepData) -> Series {
    let mut s = Series::new(
        "fig4a",
        "Core temperature vs outlet temperature (13 nodes under stress)",
        &["t_out", "t_out_err", "core_mean", "core_std", "dt_core_out"],
    );
    s.note("paper: DT(core-out) grows ~15 -> 17.5 degC over the band");
    for p in &data.points {
        s.push(vec![
            p.t_out.mean(),
            p.t_out.std(),
            p.sel_core.mean(),
            p.sel_core.std(),
            p.sel_core.mean() - p.t_out.mean(),
        ]);
    }
    s
}

/// Fig. 4(b): core-temperature histogram of the whole cluster in
/// production mode at T_out ~ 67 degC, with Gaussian fit.
pub fn fig4b(cfg: &SimConfig, opts: &SweepOptions) -> Result<Series> {
    let mut c = cfg.clone();
    c.workload = WorkloadKind::Production;
    c.t_out_setpoint = 67.0;
    // Warm start close to the operating point: the 800 l tank heats at
    // only ~1 K/h from the production-load surplus, so a cold-ish start
    // would bias the sampled population low.
    c.t_water_init = 66.5;
    let mut driver = SimulationDriver::new(c)?;
    let tick_s = driver.backend.tick_seconds(&driver.cfg.pp);
    // settle, then sample the core-temperature population periodically
    let settle = (opts.settle_s / tick_s) as u64;
    driver.run_ticks(settle, 0)?;
    let mut temps = Vec::new();
    for _ in 0..opts.histogram_samples {
        driver.run_ticks((120.0 / tick_s) as u64, 0)?;
        temps.extend(driver.core_temperatures());
    }
    let mut h = Histogram::new(40.0, 105.0, 65);
    h.push_all(temps.iter().copied());
    let fit = gauss::fit_sigma_clipped(&temps_above(&temps, 65.0), 2.5, 8);
    let mut s = Series::new(
        "fig4b",
        "Core temperature distribution, production mode @ T_out=67",
        &["t_core", "density"],
    );
    for (x, d) in h.centers().into_iter().zip(h.densities()) {
        s.push(vec![x, d]);
    }
    s.note(format!(
        "gaussian fit: mu={:.1} degC sigma={:.2} degC (paper: 84 / 2.8); \
         idle bump below 65 degC excluded from fit",
        fit.mu, fit.sigma
    ));
    s.note(format!("samples: {} core readings", temps.len()));
    Ok(s)
}

fn temps_above(temps: &[f64], lo: f64) -> Vec<f64> {
    let hot: Vec<f64> = temps.iter().copied().filter(|&t| t > lo).collect();
    if hot.len() > 10 {
        hot
    } else {
        temps.to_vec()
    }
}

/// Fig. 5(a): node DC power vs average core temperature (13 nodes).
pub fn fig5a(data: &SweepData) -> Series {
    let mut s = Series::new(
        "fig5a",
        "Node power vs core temperature (13 nodes under stress)",
        &["core_mean", "core_std", "p_node", "p_node_std"],
    );
    s.note("paper: rising with temperature (leakage), large node spread");
    for p in &data.points {
        s.push(vec![
            p.sel_core.mean(),
            p.sel_core.std(),
            p.sel_power.mean(),
            p.sel_power.std(),
        ]);
    }
    s
}

/// Fig. 5(b): histogram of node power interpolated to core T = 80 degC.
pub fn fig5b(data: &SweepData) -> Series {
    // per-node (core_temp, power) across setpoints -> interpolate to 80
    let mut interpolated = Vec::new();
    for series in data.node_series.values() {
        if series.len() < 2 {
            continue;
        }
        let xs: Vec<f64> = series.iter().map(|&(t, _)| t).collect();
        let ys: Vec<f64> = series.iter().map(|&(_, p)| p).collect();
        if let Some(line) = interp::fit_line(&xs, &ys) {
            interpolated.push(line.at(80.0));
        }
    }
    let fit = gauss::fit_sigma_clipped(&interpolated, 3.0, 6);
    let mut h = Histogram::new(170.0, 250.0, 40);
    h.push_all(interpolated.iter().copied());
    let mut s = Series::new(
        "fig5b",
        "Node power interpolated to T_core=80 degC (six-core nodes)",
        &["p_node", "density"],
    );
    for (x, d) in h.centers().into_iter().zip(h.densities()) {
        s.push(vec![x, d]);
    }
    s.note(format!(
        "gaussian fit: mu={:.1} W sigma={:.2} W (paper: 206 / 5.4) over {} nodes",
        fit.mu, fit.sigma, interpolated.len()
    ));
    s
}

/// Fig. 6(a): relative node-power increase vs T_out (normalized to the
/// lowest setpoint, 49 degC).
pub fn fig6a(data: &SweepData) -> Series {
    let mut s = Series::new(
        "fig6a",
        "Relative node power increase vs outlet temperature",
        &["t_out", "rel_power", "rel_power_err"],
    );
    s.note("paper: ~ +7 % from 49 to 70 degC");
    let base = data.points.first().map(|p| p.sel_power.mean()).unwrap_or(1.0);
    for p in &data.points {
        let rel = p.sel_power.mean() / base;
        let err = p.sel_power.std() / base / (13f64).sqrt();
        s.push(vec![p.t_out.mean(), rel, err]);
    }
    s
}

/// Fig. 6(b): chiller COP vs driving temperature, 10 % flow-meter bars.
pub fn fig6b(data: &SweepData) -> Series {
    let mut s = Series::new(
        "fig6b",
        "Adsorption chiller COP vs temperature",
        &["t", "cop", "cop_err", "t_tank"],
    );
    s.note("paper: standby below ~57, +90 % from 57 to 70 degC");
    s.note("x-axis: rack outlet temperature (footnote 2: 'the driving \
            temperature T equals the outlet temperature of the rack')");
    for p in &data.points {
        if p.cop > 0.01 {
            // 10 % flow meters on both P_c and P_d: ~14 % combined (2 sigma/2)
            s.push(vec![p.t_out.mean(), p.cop, p.cop * 0.071,
                        p.t_tank.mean()]);
        }
    }
    s
}

/// Fig. 7(a): heat-in-water fraction vs T_out.
pub fn fig7a(data: &SweepData) -> Series {
    let mut s = Series::new(
        "fig7a",
        "Heat-in-water fraction vs outlet temperature",
        &["t_out", "heat_in_water", "err"],
    );
    s.note("paper: drastically decreasing with temperature (insulation)");
    for p in &data.points {
        s.push(vec![p.t_out.mean(), p.hiw, p.hiw_err]);
    }
    s
}

/// Fig. 7(b): P_d / P_electric vs temperature.
pub fn fig7b(data: &SweepData) -> Series {
    let mut s = Series::new(
        "fig7b",
        "Power transferred to the driving circuit / electric power",
        &["t_out", "transferred_frac", "err"],
    );
    s.note("paper: increasing with temperature; well below Fig. 7a");
    // Below the chiller's standby band the tank saturates and the
    // transferred power is losses only; the paper's plot starts at ~57.
    for p in &data.points {
        if p.cop > 0.01 {
            s.push(vec![p.t_out.mean(), p.pd_frac, p.pd_frac * 0.05]);
        }
    }
    s
}

/// Headline table: energy-reuse fraction (Fig. 6b x Fig. 7a) ~ 25 % at
/// 60..70 degC, nearly doubling with ideal insulation (Sect. 5).
pub fn reuse_table(data: &SweepData, cfg: &SimConfig, opts: &SweepOptions)
                   -> Result<Series> {
    let mut s = Series::new(
        "r1",
        "Energy-reuse fraction (COP x heat-in-water)",
        &["t_out", "reuse_potential", "reuse_actual", "reuse_paper_method"],
    );
    s.note("paper: 'on the order of 25 % for T = 60...70 degC'");
    s.note("reuse_paper_method multiplies the chiller COP *curve* at the \
            outlet temperature (footnote 2) by Fig. 7a, as the paper does");
    for p in &data.points {
        let cop_curve = cfg.pp.cop(p.t_out.mean());
        s.push(vec![p.t_out.mean(), p.cop * p.hiw, p.reuse,
                    cop_curve * p.hiw]);
    }
    // Ideal-insulation ablation (native backend: params differ from the
    // AOT artifacts, which bake the production constants).
    let mut c = cfg.clone();
    c.pp = c.pp.with_ideal_insulation();
    c.backend = "native".into();
    let ideal = sweep::run_sweep(&c, &[70.0], opts)?;
    if let Some(p) = ideal.points.first() {
        s.note(format!(
            "ideal insulation @70: heat-in-water {:.2} (vs {:.2}), reuse \
             potential {:.1}% (paper: 'almost a factor of two' / 'almost 50%')",
            p.hiw,
            data.points.last().map(|q| q.hiw).unwrap_or(0.0),
            100.0 * p.cop * p.hiw
        ));
    }
    Ok(s)
}

/// Sect. 3 equilibrium: cold start, valve shut, full stress. The system
/// must heat through the standby band, wake the chiller at 55 degC and
/// settle where P_d^max(T) + losses meet the input power (60..70 band).
pub fn equilibrium(cfg: &SimConfig, opts: &SweepOptions) -> Result<Series> {
    let mut c = cfg.clone();
    c.workload = WorkloadKind::Stress;
    c.stress_nodes = c.n_nodes; // maximum load
    c.stress_background = 0.0;
    c.regulate = false;
    c.valve_fixed = 0.0;
    c.t_water_init = 20.0;
    c.duration_s = opts.equilibrium_s;
    let mut driver = SimulationDriver::new(c)?;
    let res = driver.run(6)?;
    let mut s = Series::new(
        "s3",
        "Cold-start equilibrium (valve shut, max load)",
        &["t_s", "t_out", "t_tank", "p_d_kw", "p_c_kw", "chiller_on"],
    );
    for t in &res.trace {
        s.push(vec![
            t.t_s,
            t.t_rack_out,
            t.t_tank,
            t.p_d / 1e3,
            t.p_c / 1e3,
            if t.chiller_on { 1.0 } else { 0.0 },
        ]);
    }
    let t_final = res.trace.last().map(|t| t.t_rack_out).unwrap_or(0.0);
    let pp = &driver.cfg.pp;
    s.note(format!(
        "settles at T_out ~ {:.1} degC (paper: equilibrium in the 60-70 \
         band); P_d^max(70) = {:.1} kW vs rack transfer at max load",
        t_final,
        pp.pd_max(70.0) / 1e3
    ));
    let wake = res
        .trace
        .iter()
        .find(|t| t.chiller_on)
        .map(|t| t.t_tank)
        .unwrap_or(0.0);
    s.note(format!("chiller left standby at T_tank = {wake:.1} degC \
                    (threshold {:.0})", pp.chiller_t_on));
    Ok(s)
}

/// Redundancy experiment (Sect. 3): chiller failure mid-run; the primary
/// + central circuits must keep the rack regulated.
pub fn fault_injection(cfg: &SimConfig, opts: &SweepOptions) -> Result<Series> {
    let mut c = cfg.clone();
    c.workload = WorkloadKind::Production;
    c.t_water_init = 64.0;
    let fail_start = opts.settle_s;
    let fail_end = fail_start + 3600.0;
    c.duration_s = fail_end + 3600.0;
    let mut driver = SimulationDriver::with_faults(
        c,
        vec![Fault::ChillerFailure { start_s: fail_start, end_s: fail_end }],
    )?;
    let res = driver.run(6)?;
    let mut s = Series::new(
        "r2",
        "Chiller-failure failover (valve -> primary -> central)",
        &["t_s", "t_out", "valve", "p_central_kw", "chiller_on"],
    );
    let mut max_during = 0.0f64;
    for t in &res.trace {
        if t.t_s >= fail_start && t.t_s <= fail_end {
            max_during = max_during.max(t.t_rack_out);
        }
        s.push(vec![
            t.t_s,
            t.t_rack_out,
            t.valve,
            0.0, // p_central is in events/energy; keep the column for shape
            if t.chiller_on { 1.0 } else { 0.0 },
        ]);
    }
    s.note(format!(
        "max T_out during chiller failure: {max_during:.1} degC \
         (failover keeps the rack below the 71.5 limit)"
    ));
    s.note(format!("supervisor events: {}", res.events.len()));
    Ok(s)
}

/// Manifold ablation (Sect. 2's Tichelmann claim).
pub fn manifold_ablation(cfg: &SimConfig) -> Series {
    let pp = &cfg.pp;
    let mut s = Series::new(
        "manifold",
        "Tichelmann vs direct-return manifold (flow self-balancing)",
        &["flow_lpm", "imb_tichelmann", "imb_direct", "dt_spread_tich",
          "dt_spread_direct"],
    );
    s.note("paper: 'the water flow rates balance themselves automatically'");
    let tich = Manifold::from_params(pp, 72, ManifoldKind::Tichelmann);
    let dirr = Manifold::from_params(pp, 72, ManifoldKind::DirectReturn);
    for scale in [0.5, 0.75, 1.0, 1.25] {
        let q = 72.0 * pp.node_flow_lpm * scale;
        s.push(vec![
            q,
            tich.imbalance(q),
            dirr.imbalance(q),
            tich.outlet_temp_spread(q, 180.0, pp),
            dirr.outlet_temp_spread(q, 180.0, pp),
        ]);
    }
    s
}

/// Chip-binning experiment (Sect. 4): "If we desired higher temperatures
/// we could sort out the 'bad' chips and run them at lower temperature in
/// a separate system. The high end of the histogram ... indicates that we
/// could perhaps gain another 5 degC in this way."
///
/// Runs the cluster at full stress, measures each node's hottest-core
/// margin to the throttle limit, and reports the achievable outlet
/// temperature with 0/5/10/20 % of the worst nodes binned out.
pub fn binning(cfg: &SimConfig, opts: &SweepOptions) -> Result<Series> {
    let mut c = cfg.clone();
    c.workload = WorkloadKind::Stress;
    c.stress_nodes = c.n_nodes;
    c.stress_background = 0.0;
    c.t_out_setpoint = 67.0;
    c.t_water_init = 64.0;
    c.sensor_noise = false;
    let mut driver = SimulationDriver::new(c)?;
    let tick_s = driver.backend.tick_seconds(&driver.cfg.pp);
    driver.run_ticks((opts.settle_s / tick_s).ceil() as u64, 0)?;
    let (out, sample) = driver.tick_once()?;
    let n = driver.backend.n_nodes();
    // per-node excess = hottest core above the rack outlet
    let mut excess: Vec<f64> = (0..n)
        .map(|i| out.node(i)[O_CORE_MAX] as f64 - sample.t_rack_out)
        .collect();
    excess.sort_by(|a, b| b.total_cmp(a)); // worst first
    let t_throttle = driver.cfg.pp.t_throttle;
    let margin = 1.0; // stay a degree under the throttle point
    let mut s = Series::new(
        "binning",
        "Outlet-temperature headroom from binning out hot chips (Sect. 4)",
        &["binned_frac", "binned_nodes", "worst_excess", "t_out_max",
          "gain_vs_unbinned"],
    );
    s.note("paper: 'we could perhaps gain another 5 degC in this way'");
    let base_tout = t_throttle - margin - excess[0];
    for frac in [0.0, 0.05, 0.10, 0.20] {
        let k = ((n as f64 * frac) as usize).min(n - 1);
        let worst = excess[k];
        let t_out_max = t_throttle - margin - worst;
        s.push(vec![frac, k as f64, worst, t_out_max,
                    t_out_max - base_tout]);
    }
    Ok(s)
}

/// Economics experiment (Sect. 2): retrofit cost vs free-cooling +
/// energy-reuse savings at the measured operating point.
pub fn economics(cfg: &SimConfig, opts: &SweepOptions) -> Result<Series> {
    let data = sweep::run_sweep(cfg, &[66.5], opts)?;
    let p = data
        .points
        .first()
        .ok_or_else(|| anyhow::anyhow!("sweep produced no points"))?;
    let model = crate::economics::CostModel::default();
    let p_chilled = p.cop * p.pd_frac * p.p_ac;
    let a = model.analyze(cfg.n_nodes, p.p_ac, p.hiw, p_chilled);
    let mut s = Series::new(
        "econ",
        "Cooling-retrofit amortization (Sect. 2: ~120 EUR/node)",
        &["capex_eur", "savings_eur_y", "payback_years",
          "free_cooling_eur_y", "reuse_credit_eur_y", "overhead_eur_y"],
    );
    s.note("paper: 'a small fraction of the overall cost and can be \
            amortized quickly by the savings from free cooling and energy \
            reuse'");
    s.note(format!(
        "operating point: P_AC={:.1} kW, heat-in-water={:.2}, \
         P_chilled={:.1} kW @ T_out={:.1}",
        p.p_ac / 1e3, p.hiw, p_chilled / 1e3, p.t_out.mean()));
    s.push(vec![a.capex_eur, a.savings_eur_per_year, a.payback_years,
                a.free_cooling_eur_per_year, a.reuse_credit_eur_per_year,
                a.loop_overhead_eur_per_year]);
    Ok(s)
}
