//! Reporting: CSV series, ASCII plots and formatted tables for the
//! figure-reproduction harness (EXPERIMENTS.md is generated from these).

use std::fmt::Write as _;
use std::path::Path;

/// A reproduced figure/table: named columns and numeric rows.
#[derive(Debug, Clone)]
pub struct Series {
    pub id: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
    pub notes: Vec<String>,
}

impl Series {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Self {
        Series {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        debug_assert_eq!(row.len(), self.columns.len());
        self.rows.push(row);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn col(&self, name: &str) -> Option<Vec<f64>> {
        let i = self.columns.iter().position(|c| c == name)?;
        Some(self.rows.iter().map(|r| r[i]).collect())
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "# {} — {}", self.id, self.title);
        for n in &self.notes {
            let _ = writeln!(s, "# {n}");
        }
        let _ = writeln!(s, "{}", self.columns.join(","));
        for row in &self.rows {
            let cells: Vec<String> =
                row.iter().map(|v| format!("{v:.6}")).collect();
            let _ = writeln!(s, "{}", cells.join(","));
        }
        s
    }

    pub fn save_csv(&self, dir: &Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.id));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }

    /// Pretty table for the terminal.
    pub fn to_table(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "== {} — {}", self.id, self.title);
        for n in &self.notes {
            let _ = writeln!(s, "   {n}");
        }
        let widths: Vec<usize> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| {
                self.rows
                    .iter()
                    .map(|r| format!("{:.3}", r[i]).len())
                    .chain(std::iter::once(c.len()))
                    .max()
                    .unwrap_or(8)
            })
            .collect();
        let header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect();
        let _ = writeln!(s, "   {}", header.join("  "));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(v, w)| format!("{:>w$}", format!("{v:.3}")))
                .collect();
            let _ = writeln!(s, "   {}", cells.join("  "));
        }
        s
    }

    /// ASCII scatter of column y vs column x (terminal "figure").
    pub fn ascii_plot(&self, xcol: &str, ycol: &str, width: usize,
                      height: usize) -> String {
        let (xs, ys) = match (self.col(xcol), self.col(ycol)) {
            (Some(a), Some(b)) => (a, b),
            _ => return String::from("(missing columns)\n"),
        };
        ascii_scatter(&xs, &ys, xcol, ycol, width, height)
    }
}

/// Standalone ASCII scatter plot.
pub fn ascii_scatter(xs: &[f64], ys: &[f64], xlabel: &str, ylabel: &str,
                     width: usize, height: usize) -> String {
    if xs.is_empty() || xs.len() != ys.len() {
        return String::from("(no data)\n");
    }
    let (xmin, xmax) = bounds(xs);
    let (ymin, ymax) = bounds(ys);
    let mut grid = vec![vec![b' '; width]; height];
    for (&x, &y) in xs.iter().zip(ys) {
        let xi = scale(x, xmin, xmax, width);
        let yi = scale(y, ymin, ymax, height);
        grid[height - 1 - yi][xi] = b'*';
    }
    let mut s = String::new();
    let _ = writeln!(s, "  {ylabel} [{ymin:.2} .. {ymax:.2}]");
    for row in grid {
        let _ = writeln!(s, "  |{}", String::from_utf8_lossy(&row));
    }
    let _ = writeln!(s, "  +{}", "-".repeat(width));
    let _ = writeln!(s, "   {xlabel} [{xmin:.2} .. {xmax:.2}]");
    s
}

fn bounds(v: &[f64]) -> (f64, f64) {
    let mut lo = f64::MAX;
    let mut hi = f64::MIN;
    for &x in v {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if (hi - lo).abs() < 1e-12 {
        (lo - 1.0, hi + 1.0)
    } else {
        (lo, hi)
    }
}

fn scale(x: f64, lo: f64, hi: f64, n: usize) -> usize {
    let t = ((x - lo) / (hi - lo)).clamp(0.0, 1.0);
    ((t * (n - 1) as f64).round() as usize).min(n - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Series {
        let mut s = Series::new("fig_x", "test", &["t", "v"]);
        s.push(vec![1.0, 10.0]);
        s.push(vec![2.0, 20.0]);
        s.note("a note");
        s
    }

    #[test]
    fn csv_roundtrip_shape() {
        let csv = sample().to_csv();
        assert!(csv.contains("t,v"));
        assert!(csv.contains("1.000000,10.000000"));
        assert!(csv.contains("# a note"));
    }

    #[test]
    fn col_access() {
        let s = sample();
        assert_eq!(s.col("v").unwrap(), vec![10.0, 20.0]);
        assert!(s.col("nope").is_none());
    }

    #[test]
    fn ascii_plot_renders() {
        let s = sample();
        let p = s.ascii_plot("t", "v", 20, 5);
        assert!(p.contains('*'));
        assert!(p.lines().count() >= 7);
    }

    #[test]
    fn table_renders() {
        let t = sample().to_table();
        assert!(t.contains("fig_x"));
        assert!(t.contains("10.000"));
    }
}
