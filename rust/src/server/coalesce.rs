//! In-flight request coalescing ("single-flight").
//!
//! When several concurrent requests hash to the same cache key, exactly
//! one (the *leader*) runs the simulation; the rest (*followers*) block
//! on the leader's slot and receive a clone of its response. Combined
//! with the LRU response cache this gives three request outcomes,
//! surfaced to clients as the `x-cache` header: `hit` (served from the
//! cache), `coalesced` (waited on an identical in-flight run), `miss`
//! (computed here).
//!
//! The leader *must* call `complete` exactly once — including on the
//! error path — or followers would wait forever; the server wraps the
//! compute in `catch_unwind` and completes the slot with a 500 response
//! when the simulation panics.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// One in-flight computation; followers park here.
pub struct Slot<V> {
    result: Mutex<Option<V>>,
    ready: Condvar,
}

impl<V: Clone> Slot<V> {
    /// Crate-visible so the batch scheduler (`server/batch.rs`) can use
    /// the same park/publish primitive for per-job round slots.
    pub(crate) fn new() -> Self {
        Slot { result: Mutex::new(None), ready: Condvar::new() }
    }

    /// Block until the leader publishes, then return a clone.
    pub fn wait(&self) -> V {
        let mut g = self.result.lock().unwrap();
        while g.is_none() {
            g = self.ready.wait(g).unwrap();
        }
        g.as_ref().cloned().unwrap()
    }

    /// `wait` with a bound: `None` when the leader has not published
    /// within `budget` (the follower's share of a request deadline).
    /// The slot itself is unaffected — the leader still publishes, and
    /// other followers still receive the value.
    pub fn wait_timeout(&self, budget: std::time::Duration) -> Option<V> {
        let deadline = std::time::Instant::now() + budget;
        let mut g = self.result.lock().unwrap();
        while g.is_none() {
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, timeout) =
                self.ready.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if timeout.timed_out() && g.is_none() {
                return None;
            }
        }
        g.as_ref().cloned()
    }

    pub(crate) fn publish(&self, v: V) {
        *self.result.lock().unwrap() = Some(v);
        self.ready.notify_all();
    }
}

/// The outcome of claiming a key.
pub enum Claim<V> {
    /// First arrival: compute, then `Coalescer::complete`.
    Leader(Arc<Slot<V>>),
    /// An identical request is already running: `Slot::wait` on it.
    Follower(Arc<Slot<V>>),
}

/// Key -> in-flight slot registry.
pub struct Coalescer<V> {
    slots: Mutex<HashMap<u64, Arc<Slot<V>>>>,
}

impl<V: Clone> Default for Coalescer<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V: Clone> Coalescer<V> {
    pub fn new() -> Self {
        Coalescer { slots: Mutex::new(HashMap::new()) }
    }

    /// Atomically become the leader for `key`, or a follower when a
    /// leader is already in flight.
    pub fn claim(&self, key: u64) -> Claim<V> {
        let mut m = self.slots.lock().unwrap();
        match m.get(&key) {
            Some(slot) => Claim::Follower(slot.clone()),
            None => {
                let slot = Arc::new(Slot::new());
                m.insert(key, slot.clone());
                Claim::Leader(slot)
            }
        }
    }

    /// Publish the leader's result: wake every follower and retire the
    /// key so the next identical request consults the cache afresh.
    pub fn complete(&self, key: u64, slot: &Arc<Slot<V>>, v: V) {
        // Remove the registry entry *before* waking followers: a new
        // request arriving now becomes a fresh leader (or a cache hit)
        // instead of following a finished slot.
        self.slots.lock().unwrap().remove(&key);
        slot.publish(v);
    }

    /// Number of distinct keys currently in flight.
    pub fn in_flight(&self) -> usize {
        self.slots.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leader_then_follower_then_retired() {
        let c: Coalescer<String> = Coalescer::new();
        let leader = match c.claim(7) {
            Claim::Leader(s) => s,
            Claim::Follower(_) => panic!("first claim must lead"),
        };
        assert!(matches!(c.claim(7), Claim::Follower(_)));
        assert_eq!(c.in_flight(), 1);
        c.complete(7, &leader, "done".into());
        assert_eq!(c.in_flight(), 0);
        // retired key: next claim leads again
        assert!(matches!(c.claim(7), Claim::Leader(_)));
    }

    #[test]
    fn followers_receive_the_leader_result() {
        let c: Arc<Coalescer<u64>> = Arc::new(Coalescer::new());
        let leader = match c.claim(1) {
            Claim::Leader(s) => s,
            _ => unreachable!(),
        };
        let mut joins = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            joins.push(std::thread::spawn(move || match c.claim(1) {
                Claim::Follower(s) => s.wait(),
                // A thread scheduled after `complete` would lead; give
                // it the same answer so the assert below stays simple.
                Claim::Leader(s) => {
                    c.complete(1, &s, 42);
                    s.wait()
                }
            }));
        }
        // Let followers park, then publish.
        std::thread::sleep(std::time::Duration::from_millis(20));
        c.complete(1, &leader, 42);
        for j in joins {
            assert_eq!(j.join().unwrap(), 42);
        }
    }

    #[test]
    fn wait_timeout_bounds_the_follower() {
        let c: Arc<Coalescer<u8>> = Arc::new(Coalescer::new());
        let leader = match c.claim(9) {
            Claim::Leader(s) => s,
            _ => unreachable!(),
        };
        let follower = match c.claim(9) {
            Claim::Follower(s) => s,
            _ => unreachable!(),
        };
        // Leader never publishes within the budget: follower times out.
        assert_eq!(
            follower.wait_timeout(std::time::Duration::from_millis(10)),
            None
        );
        // Late publish still lands for patient waiters.
        c.complete(9, &leader, 5);
        assert_eq!(
            follower.wait_timeout(std::time::Duration::from_millis(10)),
            Some(5)
        );
    }

    #[test]
    fn distinct_keys_do_not_coalesce() {
        let c: Coalescer<u8> = Coalescer::new();
        assert!(matches!(c.claim(1), Claim::Leader(_)));
        assert!(matches!(c.claim(2), Claim::Leader(_)));
        assert_eq!(c.in_flight(), 2);
    }
}
