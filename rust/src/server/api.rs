//! Request/response schemas of the sim-as-a-service endpoints.
//!
//! Requests are flat JSON objects of overrides applied on top of the
//! server's base `SimConfig`. Parsing is *strict*: an unknown field is a
//! 400, so a typo can never silently fall back to a default (and then be
//! answered from the cache as if it had been honored).
//!
//! Cache keys: every parsed request is re-serialized into a canonical
//! BTreeMap-ordered JSON document listing *every* knob that affects the
//! run (env-resolved kernel included). The key is the bench subsystem's
//! `config_fingerprint` (bench/record.rs) extended by the same FNV mix
//! over the endpoint name and the canonical bytes — identical requests
//! map to one key, any semantic difference changes it, and two textually
//! different bodies meaning the same run (field order, whitespace,
//! explicit defaults) share one cache entry.
//!
//! Response documents deliberately contain **no wall-clock fields**: a
//! response is a pure function of the request, so a cache hit is
//! byte-identical to recomputation and the `/fleet` body equals the
//! `idatacool fleet --json` file for the same configuration.

use anyhow::{Context, Result};

use crate::config::{OptimizeSettings, SimConfig};
use crate::coordinator::energy::EnergyAccount;
use crate::coordinator::{RunResult, TraceSample};
use crate::figures::sweep::SweepOptions;
use crate::fleet::scenario::Scenario;
use crate::fleet::FleetConfig;
use crate::optimize::OptimizeConfig;
use crate::plant::PlantKernel;
use crate::runtime::BackendKind;
use crate::util::json::{Json, JsonBuilder};

use std::collections::BTreeMap;

/// Parsed `POST /simulate` body.
pub struct SimRequest {
    pub cfg: SimConfig,
    /// Trace sampling stride (1 = every tick), as in
    /// `SimulationDriver::run`.
    pub sample_every: usize,
}

/// Parsed `POST /sweep` body.
pub struct SweepRequest {
    pub cfg: SimConfig,
    pub setpoints: Vec<f64>,
    pub quick: bool,
    pub shards: usize,
}

/// Which typed parser a registry row selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EndpointKind {
    Simulate,
    Fleet,
    Sweep,
    Optimize,
}

impl EndpointKind {
    /// The endpoint's fingerprint/metrics name.
    pub fn name(self) -> &'static str {
        match self {
            EndpointKind::Simulate => "simulate",
            EndpointKind::Fleet => "fleet",
            EndpointKind::Sweep => "sweep",
            EndpointKind::Optimize => "optimize",
        }
    }
}

/// The typed form of any simulation request — what the server's
/// `Endpoint` registry parses bodies into. Consolidating the three
/// endpoint parsers behind one enum keeps unknown-field strictness and
/// fingerprint canonicalization on a single code path instead of three
/// copies.
pub enum ApiRequest {
    Simulate { sim: SimRequest, stream: bool },
    Fleet(FleetConfig),
    Sweep(SweepRequest),
    Optimize(OptimizeConfig),
}

impl ApiRequest {
    /// Parse a request body for `kind` (strict; unknown fields are
    /// errors, surfaced to clients as a 400 envelope).
    pub fn parse(kind: EndpointKind, body: &str, stream: bool,
                 base: &SimConfig) -> Result<ApiRequest> {
        Ok(match kind {
            EndpointKind::Simulate => ApiRequest::Simulate {
                sim: parse_sim_request(body, base)?,
                stream,
            },
            EndpointKind::Fleet => {
                ApiRequest::Fleet(parse_fleet_request(body, base)?)
            }
            EndpointKind::Sweep => {
                ApiRequest::Sweep(parse_sweep_request(body, base)?)
            }
            EndpointKind::Optimize => {
                ApiRequest::Optimize(parse_optimize_request(body, base)?)
            }
        })
    }

    pub fn kind(&self) -> EndpointKind {
        match self {
            ApiRequest::Simulate { .. } => EndpointKind::Simulate,
            ApiRequest::Fleet(_) => EndpointKind::Fleet,
            ApiRequest::Sweep(_) => EndpointKind::Sweep,
            ApiRequest::Optimize(_) => EndpointKind::Optimize,
        }
    }

    /// The canonical request document (cache-key input; see module doc).
    pub fn canonical(&self) -> Json {
        match self {
            ApiRequest::Simulate { sim, stream } => {
                canonical_sim_json(&sim.cfg, sim.sample_every, *stream)
            }
            ApiRequest::Fleet(fc) => canonical_fleet_json(fc),
            ApiRequest::Sweep(sr) => canonical_sweep_json(sr),
            ApiRequest::Optimize(oc) => canonical_optimize_json(oc),
        }
    }

    /// The shared cache/coalesce key: one fingerprint rule for every
    /// endpoint.
    pub fn fingerprint(&self) -> u64 {
        let cfg = match self {
            ApiRequest::Simulate { sim, .. } => &sim.cfg,
            ApiRequest::Fleet(fc) => &fc.base,
            ApiRequest::Sweep(sr) => &sr.cfg,
            ApiRequest::Optimize(oc) => &oc.base,
        };
        request_fingerprint(self.kind().name(), &self.canonical(), cfg)
    }

    /// Admission-control cost estimate in **nominal tick × plant**
    /// units (`server::admit`). The true tick count depends on the
    /// resolved backend's substep split, which would require building
    /// a driver just to price the request — admission only needs a
    /// consistent relative scale, so this prices every request at the
    /// paper's 5 s control tick: `ceil(duration / 5 s) × plants`
    /// (× setpoints for sweeps, × budget evaluations for optimize).
    pub fn cost_estimate(&self) -> f64 {
        const NOMINAL_TICK_S: f64 = 5.0;
        let ticks =
            |dur_s: f64| (dur_s / NOMINAL_TICK_S).ceil().max(1.0);
        match self {
            ApiRequest::Simulate { sim, .. } => ticks(sim.cfg.duration_s),
            ApiRequest::Fleet(fc) => {
                ticks(fc.base.duration_s) * fc.n_plants as f64
            }
            ApiRequest::Sweep(sr) => {
                ticks(sr.cfg.duration_s) * sr.setpoints.len().max(1) as f64
            }
            ApiRequest::Optimize(oc) => {
                ticks(oc.eval_duration_s)
                    * (oc.budget * oc.n_plants).max(1) as f64
            }
        }
    }
}

/// SimConfig fields a request may override.
const SIM_KEYS: &[&str] = &[
    "preset",
    "name",
    "nodes",
    "backend",
    "kernel",
    "seed",
    "duration_s",
    "setpoint",
    "workload",
    "stress_nodes",
    "stress_background",
    "production_load",
    "pump_speed",
    "t_ambient",
    "t_central",
    "gpu_load",
    "t_water_init",
    "sensor_noise",
    "regulate",
    "valve_fixed",
];

fn obj_of(body: &str) -> Result<BTreeMap<String, Json>> {
    let t = body.trim();
    if t.is_empty() {
        return Ok(BTreeMap::new());
    }
    match Json::parse(t)? {
        Json::Obj(m) => Ok(m),
        _ => anyhow::bail!("request body must be a JSON object"),
    }
}

fn take_f64(m: &BTreeMap<String, Json>, k: &str) -> Result<Option<f64>> {
    match m.get(k) {
        None => Ok(None),
        Some(j) => j
            .as_f64()
            .map(Some)
            .with_context(|| format!("field '{k}' must be a number")),
    }
}

fn take_usize(m: &BTreeMap<String, Json>, k: &str) -> Result<Option<usize>> {
    match take_f64(m, k)? {
        None => Ok(None),
        Some(x) => {
            anyhow::ensure!(
                x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64,
                "field '{k}' must be a non-negative integer, got {x}"
            );
            Ok(Some(x as usize))
        }
    }
}

fn take_bool(m: &BTreeMap<String, Json>, k: &str) -> Result<Option<bool>> {
    match m.get(k) {
        None => Ok(None),
        Some(j) => j
            .as_bool()
            .map(Some)
            .with_context(|| format!("field '{k}' must be a boolean")),
    }
}

fn take_str<'a>(m: &'a BTreeMap<String, Json>, k: &str)
                -> Result<Option<&'a str>> {
    match m.get(k) {
        None => Ok(None),
        Some(j) => j
            .as_str()
            .map(Some)
            .with_context(|| format!("field '{k}' must be a string")),
    }
}

/// Seeds: a JSON number (exact below 2^53) or a string — decimal or
/// `0x`-prefixed hex — for full 64-bit ids.
fn take_seed(m: &BTreeMap<String, Json>, k: &str) -> Result<Option<u64>> {
    match m.get(k) {
        None => Ok(None),
        Some(Json::Num(x)) => {
            anyhow::ensure!(
                *x >= 0.0 && x.fract() == 0.0 && *x <= 9.007_199_254_740_992e15,
                "field '{k}': numeric seeds must be integers below 2^53 \
                 (use a hex string for larger ids)"
            );
            Ok(Some(*x as u64))
        }
        Some(Json::Str(s)) => {
            let v = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
                Some(h) => u64::from_str_radix(h, 16),
                None => s.parse::<u64>(),
            };
            Ok(Some(v.map_err(|_| {
                anyhow::anyhow!("field '{k}': bad seed string '{s}'")
            })?))
        }
        Some(_) => anyhow::bail!("field '{k}' must be a number or string"),
    }
}

/// Apply the shared SimConfig override fields from `m` onto `cfg`.
/// Fields listed in `extra` belong to the caller (endpoint-specific) and
/// are skipped here; anything else outside `SIM_KEYS` is an error.
fn apply_sim_overrides(
    m: &BTreeMap<String, Json>,
    cfg: &mut SimConfig,
    extra: &[&str],
) -> Result<()> {
    for k in m.keys() {
        if !SIM_KEYS.contains(&k.as_str()) && !extra.contains(&k.as_str()) {
            anyhow::bail!(
                "unknown field '{k}' (sim fields: {SIM_KEYS:?}; \
                 endpoint fields: {extra:?})"
            );
        }
    }
    // `preset` first: it replaces the whole config, keeping only the
    // server-side plant constants and artifacts location.
    if let Some(p) = take_str(m, "preset")? {
        let mut fresh = match p {
            "full" => SimConfig::idatacool_full(),
            "subset13" => SimConfig::subset13(),
            "test_small" => SimConfig::test_small(),
            other => anyhow::bail!("unknown preset '{other}'"),
        };
        fresh.artifacts_dir = cfg.artifacts_dir.clone();
        fresh.pp = cfg.pp.clone();
        *cfg = fresh;
    }
    if let Some(v) = take_str(m, "name")? {
        cfg.name = v.to_string();
    }
    if let Some(v) = take_usize(m, "nodes")? {
        cfg.n_nodes = v;
    }
    if let Some(v) = take_str(m, "backend")? {
        cfg.backend = v.to_string();
    }
    if let Some(v) = take_str(m, "kernel")? {
        cfg.kernel = v.to_string();
    }
    if let Some(v) = take_seed(m, "seed")? {
        cfg.seed = v;
    }
    if let Some(v) = take_f64(m, "duration_s")? {
        cfg.duration_s = v;
    }
    if let Some(v) = take_f64(m, "setpoint")? {
        cfg.t_out_setpoint = v;
    }
    if let Some(v) = take_str(m, "workload")? {
        cfg.workload = v.parse()?;
    }
    if let Some(v) = take_usize(m, "stress_nodes")? {
        cfg.stress_nodes = v;
    }
    if let Some(v) = take_f64(m, "stress_background")? {
        cfg.stress_background = v;
    }
    if let Some(v) = take_f64(m, "production_load")? {
        cfg.production_load = v;
    }
    if let Some(v) = take_f64(m, "pump_speed")? {
        cfg.pump_speed = v;
    }
    if let Some(v) = take_f64(m, "t_ambient")? {
        cfg.t_ambient = v;
    }
    if let Some(v) = take_f64(m, "t_central")? {
        cfg.t_central = v;
    }
    if let Some(v) = take_f64(m, "gpu_load")? {
        cfg.gpu_load = v;
    }
    if let Some(v) = take_f64(m, "t_water_init")? {
        cfg.t_water_init = v;
    }
    if let Some(v) = take_bool(m, "sensor_noise")? {
        cfg.sensor_noise = v;
    }
    if let Some(v) = take_bool(m, "regulate")? {
        cfg.regulate = v;
    }
    if let Some(v) = take_f64(m, "valve_fixed")? {
        cfg.valve_fixed = v;
    }
    // "auto" resolves to the artifact-independent native backend, like
    // fleet runs; an explicitly requested "hlo" stays hlo.
    if cfg.backend == "auto" {
        cfg.backend = "native".into();
    }
    let _: BackendKind = cfg.backend.parse()?;
    // Canonicalize the kernel now (env-resolved): the cache key must
    // name the kernel that actually runs, not "auto".
    cfg.kernel = PlantKernel::resolve(&cfg.kernel)?.name().to_string();
    cfg.validate()?;
    Ok(())
}

/// Parse a `POST /simulate` body against the server's base config.
pub fn parse_sim_request(body: &str, base: &SimConfig) -> Result<SimRequest> {
    let m = obj_of(body)?;
    let mut cfg = base.clone();
    apply_sim_overrides(&m, &mut cfg, &["sample_every"])?;
    let sample_every = take_usize(&m, "sample_every")?.unwrap_or(1);
    anyhow::ensure!(sample_every >= 1, "sample_every must be at least 1");
    Ok(SimRequest { cfg, sample_every })
}

/// Server-side sanity cap on `POST /fleet` fleet size. Fleet memory is
/// O(n_plants) per request — every plant's trace is held for the
/// facility pass, and the default megabatch path additionally keeps all
/// drivers plus the lane arena resident — so an unbounded request could
/// OOM the serve process. The CLI stays uncapped (the operator owns
/// that machine); mirrors the `resolve_workers` clamp discipline.
pub const MAX_REQUEST_PLANTS: usize = 1024;

/// Parse a `POST /fleet` body. `shards` defaults to 1 — the server
/// already parallelizes across requests, and a fixed default keeps the
/// per-request compute footprint host-independent. Shard count and
/// `megabatch` (default: the server's env-resolved
/// `fleet::default_megabatch`) never change results — both are
/// execution shape under the fleet determinism contract, and neither
/// appears in the response document (see `FleetRun::to_json_value`).
pub fn parse_fleet_request(body: &str, base: &SimConfig)
                           -> Result<FleetConfig> {
    let m = obj_of(body)?;
    let mut cfg = base.clone();
    apply_sim_overrides(&m, &mut cfg,
                        &["plants", "shards", "scenario", "megabatch"])?;
    let n_plants = take_usize(&m, "plants")?.unwrap_or(4);
    anyhow::ensure!(n_plants >= 1, "plants must be at least 1");
    anyhow::ensure!(
        n_plants <= MAX_REQUEST_PLANTS,
        "plants must be at most {MAX_REQUEST_PLANTS} per request"
    );
    let shards = take_usize(&m, "shards")?.unwrap_or(1);
    anyhow::ensure!(shards >= 1, "shards must be at least 1");
    // Clamp here (as FleetDriver::run would) so over-asked shard counts
    // canonicalize onto the same cache key.
    let shards = shards.min(n_plants);
    let scenario =
        Scenario::by_name(take_str(&m, "scenario")?.unwrap_or("baseline"))?;
    let megabatch = match take_bool(&m, "megabatch")? {
        Some(b) => b,
        None => crate::fleet::default_megabatch()?,
    };
    let fleet_seed = cfg.seed;
    Ok(FleetConfig {
        n_plants,
        shards,
        base: cfg,
        fleet_seed,
        scenario,
        megabatch,
    })
}

/// Parse a `POST /sweep` body. `quick` defaults to true (full sweeps
/// settle for 30+ simulated minutes per setpoint).
pub fn parse_sweep_request(body: &str, base: &SimConfig)
                           -> Result<SweepRequest> {
    let m = obj_of(body)?;
    let mut cfg = base.clone();
    apply_sim_overrides(&m, &mut cfg, &["setpoints", "quick", "shards"])?;
    let setpoints = match m.get("setpoints") {
        None => vec![45.0, 55.0, 65.0],
        Some(j) => j
            .as_vec_f64()
            .context("field 'setpoints' must be an array of numbers")?,
    };
    anyhow::ensure!(!setpoints.is_empty(), "setpoints must not be empty");
    // Each setpoint becomes t_out_setpoint of its own run; reject values
    // the config layer would reject, with the same message.
    for sp in &setpoints {
        let mut c = cfg.clone();
        c.t_out_setpoint = *sp;
        c.validate().with_context(|| format!("setpoint {sp}"))?;
    }
    let quick = take_bool(&m, "quick")?.unwrap_or(true);
    let shards = take_usize(&m, "shards")?.unwrap_or(1);
    anyhow::ensure!(shards >= 1, "shards must be at least 1");
    let shards = shards.min(setpoints.len());
    Ok(SweepRequest { cfg, setpoints, quick, shards })
}

impl SweepRequest {
    pub fn options(&self) -> SweepOptions {
        if self.quick {
            SweepOptions::quick()
        } else {
            SweepOptions::default()
        }
    }
}

/// Server-side cap on `POST /optimize` physical-evaluation budgets. One
/// evaluation is a full (small) fleet run, so a request's compute is
/// O(budget x plants x eval_duration); the cap keeps a single request
/// from monopolizing the worker pool the way `MAX_REQUEST_PLANTS` keeps
/// `/fleet` from OOMing it. The CLI stays uncapped.
pub const MAX_REQUEST_BUDGET: usize = 64;

/// Parse a `POST /optimize` body: the shared SimConfig overrides
/// configure the candidate base plant, and the endpoint fields mirror
/// the `[optimize]` TOML section one for one. Defaults (ere objective,
/// grid driver, budget 24, 2 plants, mixed scenario, setpoint axis)
/// resolve through the same `OptimizeConfig::from_settings` the CLI
/// uses, so a body and a flag set meaning the same search produce the
/// same resolved config — and the same response bytes.
pub fn parse_optimize_request(body: &str, base: &SimConfig)
                              -> Result<OptimizeConfig> {
    let m = obj_of(body)?;
    let mut cfg = base.clone();
    apply_sim_overrides(
        &m,
        &mut cfg,
        &[
            "objective", "driver", "budget", "plants", "scenario", "axes",
            "gen_size", "eval_duration_s", "detail", "w_pue", "w_ere",
            "w_throttle", "w_cost",
        ],
    )?;
    // Like fleet runs, candidate evaluation always uses the native
    // backend path unless the request pinned one.
    let s = OptimizeSettings {
        objective: take_str(&m, "objective")?.map(str::to_string),
        driver: take_str(&m, "driver")?.map(str::to_string),
        budget: take_usize(&m, "budget")?,
        plants: take_usize(&m, "plants")?,
        scenario: take_str(&m, "scenario")?.map(str::to_string),
        axes: take_str(&m, "axes")?.map(str::to_string),
        gen_size: take_usize(&m, "gen_size")?,
        eval_duration_s: take_f64(&m, "eval_duration_s")?,
        detail: take_bool(&m, "detail")?,
        w_pue: take_f64(&m, "w_pue")?,
        w_ere: take_f64(&m, "w_ere")?,
        w_throttle: take_f64(&m, "w_throttle")?,
        w_cost: take_f64(&m, "w_cost")?,
    };
    let oc = OptimizeConfig::from_settings(cfg, &s)?;
    anyhow::ensure!(oc.budget >= 1, "budget must be at least 1");
    anyhow::ensure!(
        oc.budget <= MAX_REQUEST_BUDGET,
        "budget must be at most {MAX_REQUEST_BUDGET} per request"
    );
    anyhow::ensure!(oc.gen_size >= 1, "gen_size must be at least 1");
    anyhow::ensure!(oc.n_plants >= 1, "plants must be at least 1");
    anyhow::ensure!(
        oc.n_plants <= MAX_REQUEST_PLANTS,
        "plants must be at most {MAX_REQUEST_PLANTS} per request"
    );
    Ok(oc)
}

/// Every SimConfig knob that affects a run, as a canonical builder the
/// per-endpoint canonical documents extend.
fn sim_config_builder(cfg: &SimConfig) -> JsonBuilder {
    JsonBuilder::new()
        .str("backend", &cfg.backend)
        .num("duration_s", cfg.duration_s)
        .num("gpu_load", cfg.gpu_load)
        .str("kernel", &cfg.kernel)
        .str("name", &cfg.name)
        .num("n_nodes", cfg.n_nodes as f64)
        .num("production_load", cfg.production_load)
        .num("pump_speed", cfg.pump_speed)
        .bool("regulate", cfg.regulate)
        .hex("seed", cfg.seed)
        .bool("sensor_noise", cfg.sensor_noise)
        .num("stress_background", cfg.stress_background)
        .num("stress_nodes", cfg.stress_nodes as f64)
        .num("t_ambient", cfg.t_ambient)
        .num("t_central", cfg.t_central)
        .num("t_out_setpoint", cfg.t_out_setpoint)
        .num("t_water_init", cfg.t_water_init)
        .num("valve_fixed", cfg.valve_fixed)
        .str("workload", cfg.workload.name())
}

/// Canonical `/simulate` request document (the cache-key input).
pub fn canonical_sim_json(cfg: &SimConfig, sample_every: usize,
                          stream: bool) -> Json {
    sim_config_builder(cfg)
        .num("sample_every", sample_every as f64)
        .bool("stream", stream)
        .build()
}

/// Canonical `/fleet` request document. `shards` and `megabatch` are
/// deliberately absent: the fleet determinism contract makes responses
/// bitwise identical across shard counts and across the
/// megabatch/per-plant execution paths (`tests/fleet_integration.rs`),
/// so requests differing only in execution shape must share one cache
/// entry.
pub fn canonical_fleet_json(fc: &FleetConfig) -> Json {
    sim_config_builder(&fc.base)
        .hex("fleet_seed", fc.fleet_seed)
        .num("plants", fc.n_plants as f64)
        .str("scenario", fc.scenario.name())
        .build()
}

/// Canonical `/sweep` request document. Like `/fleet`, `shards` is
/// execution shape — a K-shard sweep is bitwise identical to serial
/// (tests/sweep_parallel.rs) — so it stays out of the cache key.
pub fn canonical_sweep_json(req: &SweepRequest) -> Json {
    sim_config_builder(&req.cfg)
        .bool("quick", req.quick)
        .arr(
            "setpoints",
            req.setpoints.iter().map(|&s| Json::Num(s)).collect(),
        )
        .build()
}

/// Canonical `/optimize` request document: the *resolved* search — the
/// full space (bounds, steps, frozen axes), effective weights, driver,
/// budget and scenario — not the raw body, so a body naming the
/// defaults explicitly shares a cache entry with the empty body.
/// `shards` and megabatch stay out: candidates evaluate on the fleet
/// determinism contract, so the trajectory (and the response bytes) are
/// identical across execution shapes.
pub fn canonical_optimize_json(c: &OptimizeConfig) -> Json {
    let axes: Vec<Json> = c
        .space
        .axes()
        .iter()
        .map(|a| {
            JsonBuilder::new()
                .num("fixed", a.fixed)
                .bool("frozen", a.frozen)
                .num("hi", a.hi)
                .num("lo", a.lo)
                .str("name", a.name)
                .num("step", a.step)
                .build()
        })
        .collect();
    sim_config_builder(&c.base)
        .num("budget", c.budget as f64)
        .bool("detail", c.detail)
        .str("driver", c.kind.name())
        .num("eval_duration_s", c.eval_duration_s)
        .num("gen_size", c.gen_size as f64)
        .str("objective", &c.objective_name)
        .num("plants", c.n_plants as f64)
        .str("scenario", c.scenario.name())
        .arr("space", axes)
        .num("w_cost", c.weights.cost)
        .num("w_ere", c.weights.ere)
        .num("w_pue", c.weights.pue)
        .num("w_throttle", c.weights.throttle)
        .build()
}

/// The cache key: the bench subsystem's config fingerprint
/// (bench/record.rs — the knobs CI already keys perf reports on),
/// extended with the same FNV mix over the endpoint name and the
/// canonical request bytes so *every* remaining knob contributes.
pub fn request_fingerprint(endpoint: &str, canonical: &Json,
                           cfg: &SimConfig) -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
    }
    let mut h = crate::bench::record::config_fingerprint(cfg);
    for b in endpoint.bytes() {
        h = mix(h, b as u64);
    }
    for b in canonical.to_string().bytes() {
        h = mix(h, b as u64);
    }
    h
}

/// One trace sample as a JSON object (an NDJSON line of `?stream=1`).
pub fn trace_sample_json(s: &TraceSample) -> Json {
    JsonBuilder::new()
        .num("t_s", s.t_s)
        .num("t_rack_in", s.t_rack_in)
        .num("t_rack_out", s.t_rack_out)
        .num("t_tank", s.t_tank)
        .num("t_primary", s.t_primary)
        .num("p_ac", s.p_ac)
        .num("p_dc", s.p_dc)
        .num("p_r", s.p_r)
        .num("p_d", s.p_d)
        .num("p_c", s.p_c)
        .num("p_add", s.p_add)
        .num("valve", s.valve)
        .bool("chiller_on", s.chiller_on)
        .num("core_max", s.core_max)
        .num("throttling", s.throttling as f64)
        .num("utilization", s.utilization)
        .build()
}

fn energy_json(e: &EnergyAccount) -> Json {
    JsonBuilder::new()
        .num("e_ac_j", e.e_ac)
        .num("e_dc_j", e.e_dc)
        .num("e_water_j", e.e_water)
        .num("e_drive_j", e.e_drive)
        .num("e_chilled_j", e.e_chilled)
        .num("e_add_j", e.e_add)
        .num("e_loss_plumbing_j", e.e_loss_plumbing)
        .num("e_central_j", e.e_central)
        .num("seconds", e.seconds)
        .num("ticks", e.ticks as f64)
        .num("heat_in_water_fraction", e.heat_in_water_fraction())
        .num("transferred_fraction", e.transferred_fraction())
        .num("cop", e.cop())
        .num("reuse_fraction", e.reuse_fraction())
        .num("reuse_potential", e.reuse_potential())
        .num("mean_p_ac_w", e.mean_p_ac())
        .build()
}

/// The `/simulate` summary document. Wall-clock perf fields are
/// deliberately absent: the document is a pure function of the request.
pub fn simulate_summary_json(
    cfg: &SimConfig,
    kernel: &str,
    sample_every: usize,
    res: &RunResult,
) -> Json {
    let events: Vec<Json> = res
        .events
        .iter()
        .map(|e| {
            JsonBuilder::new().num("t_s", e.t_s).str("msg", &e.msg).build()
        })
        .collect();
    JsonBuilder::new()
        .str("schema", "idatacool-sim/1")
        .str("backend", res.backend)
        .str("kernel", kernel)
        .str("name", &cfg.name)
        .num("n_nodes", cfg.n_nodes as f64)
        .hex("seed", cfg.seed)
        .num("duration_s", cfg.duration_s)
        .num("ticks", res.ticks as f64)
        .num("sample_every", sample_every as f64)
        .num("trace_len", res.trace.len() as f64)
        .set("energy", energy_json(&res.energy))
        .set("events", Json::Arr(events))
        .str("workload_stats", &res.workload_stats)
        .set(
            "final",
            res.trace.last().map(trace_sample_json).unwrap_or(Json::Null),
        )
        .build()
}

/// The `?stream=1` body: one NDJSON line per trace sample, closed by the
/// summary document.
pub fn trace_ndjson(
    cfg: &SimConfig,
    kernel: &str,
    sample_every: usize,
    res: &RunResult,
) -> Vec<u8> {
    let mut out = Vec::new();
    for s in &res.trace {
        out.extend_from_slice(trace_sample_json(s).to_string().as_bytes());
        out.push(b'\n');
    }
    out.extend_from_slice(
        simulate_summary_json(cfg, kernel, sample_every, res)
            .to_string()
            .as_bytes(),
    );
    out.push(b'\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadKind;

    fn base() -> SimConfig {
        let mut c = SimConfig::test_small();
        c.duration_s = 60.0;
        c
    }

    #[test]
    fn cost_estimate_scales_with_ticks_and_plants() {
        let b = base(); // 60 s → 12 nominal ticks
        let sim = ApiRequest::parse(EndpointKind::Simulate, "", false, &b)
            .unwrap();
        assert_eq!(sim.cost_estimate(), 12.0);
        let fleet = ApiRequest::parse(
            EndpointKind::Fleet, r#"{"plants": 3}"#, false, &b)
            .unwrap();
        assert_eq!(fleet.cost_estimate(), 36.0);
        let sweep = ApiRequest::parse(
            EndpointKind::Sweep, r#"{"setpoints": [30, 45, 60, 70]}"#,
            false, &b)
            .unwrap();
        assert_eq!(sweep.cost_estimate(), 48.0);
        // Optimize prices the per-candidate window times the budget.
        let opt = ApiRequest::parse(
            EndpointKind::Optimize,
            r#"{"budget": 4, "eval_duration_s": 60}"#, false, &b)
            .unwrap();
        match &opt {
            ApiRequest::Optimize(oc) => assert!(oc.n_plants >= 1),
            _ => unreachable!(),
        }
        assert!(opt.cost_estimate() >= 48.0);
        // Degenerate durations still cost at least one tick.
        let mut tiny = b.clone();
        tiny.duration_s = 0.5;
        let r = parse_sim_request("", &tiny).unwrap();
        assert_eq!(
            ApiRequest::Simulate { sim: r, stream: false }.cost_estimate(),
            1.0
        );
    }

    #[test]
    fn empty_body_is_the_base_config() {
        let r = parse_sim_request("", &base()).unwrap();
        assert_eq!(r.cfg.n_nodes, 13);
        assert_eq!(r.sample_every, 1);
        // kernel canonicalized away from "auto"
        assert_ne!(r.cfg.kernel, "auto");
    }

    #[test]
    fn overrides_apply_and_validate() {
        let r = parse_sim_request(
            r#"{"duration_s": 120, "setpoint": 55, "seed": 9,
                "workload": "stress", "sample_every": 3}"#,
            &base(),
        )
        .unwrap();
        assert_eq!(r.cfg.duration_s, 120.0);
        assert_eq!(r.cfg.t_out_setpoint, 55.0);
        assert_eq!(r.cfg.seed, 9);
        assert_eq!(r.cfg.workload, WorkloadKind::Stress);
        assert_eq!(r.sample_every, 3);
    }

    #[test]
    fn unknown_field_is_rejected() {
        let err = parse_sim_request(r#"{"duration": 120}"#, &base())
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown field 'duration'"), "{err}");
    }

    #[test]
    fn invalid_values_are_rejected() {
        let b = base();
        assert!(parse_sim_request(r#"{"setpoint": 150}"#, &b).is_err());
        assert!(parse_sim_request(r#"{"workload": "bogus"}"#, &b).is_err());
        assert!(parse_sim_request(r#"{"backend": "bogus"}"#, &b).is_err());
        assert!(parse_sim_request(r#"{"kernel": "bogus"}"#, &b).is_err());
        assert!(parse_sim_request(r#"{"sample_every": 0}"#, &b).is_err());
        assert!(parse_sim_request(r#"{"nodes": 2.5}"#, &b).is_err());
        assert!(parse_sim_request("[1,2]", &b).is_err());
        assert!(parse_sim_request("{bad json", &b).is_err());
    }

    #[test]
    fn seeds_accept_numbers_and_hex_strings() {
        let b = base();
        let r = parse_sim_request(r#"{"seed": "0xDEADBEEF"}"#, &b).unwrap();
        assert_eq!(r.cfg.seed, 0xDEAD_BEEF);
        let r = parse_sim_request(r#"{"seed": "12345"}"#, &b).unwrap();
        assert_eq!(r.cfg.seed, 12345);
        assert!(parse_sim_request(r#"{"seed": -1}"#, &b).is_err());
        assert!(parse_sim_request(r#"{"seed": "xyz"}"#, &b).is_err());
    }

    #[test]
    fn fingerprint_canonicalizes_equivalent_bodies() {
        let b = base();
        // Different field order + whitespace, same meaning.
        let r1 = parse_sim_request(
            r#"{"seed": 5, "duration_s": 60}"#, &b).unwrap();
        let r2 = parse_sim_request(
            r#"{ "duration_s":60.0,"seed":5 }"#, &b).unwrap();
        let k1 = request_fingerprint(
            "simulate", &canonical_sim_json(&r1.cfg, 1, false), &r1.cfg);
        let k2 = request_fingerprint(
            "simulate", &canonical_sim_json(&r2.cfg, 1, false), &r2.cfg);
        assert_eq!(k1, k2);
        // Any semantic difference separates keys.
        let r3 = parse_sim_request(
            r#"{"seed": 6, "duration_s": 60}"#, &b).unwrap();
        let k3 = request_fingerprint(
            "simulate", &canonical_sim_json(&r3.cfg, 1, false), &r3.cfg);
        assert_ne!(k1, k3);
        // The stream flag and the endpoint separate keys too.
        let ks = request_fingerprint(
            "simulate", &canonical_sim_json(&r1.cfg, 1, true), &r1.cfg);
        assert_ne!(k1, ks);
        let kf = request_fingerprint(
            "fleet", &canonical_sim_json(&r1.cfg, 1, false), &r1.cfg);
        assert_ne!(k1, kf);
    }

    #[test]
    fn typed_requests_share_the_fingerprint_rule() {
        let b = base();
        // The registry path (ApiRequest) and the explicit per-endpoint
        // path must produce the same key for the same body.
        let body = r#"{"seed": 5, "duration_s": 60}"#;
        let typed = ApiRequest::parse(EndpointKind::Simulate, body, false, &b)
            .unwrap();
        let r = parse_sim_request(body, &b).unwrap();
        let explicit = request_fingerprint(
            "simulate", &canonical_sim_json(&r.cfg, 1, false), &r.cfg);
        assert_eq!(typed.fingerprint(), explicit);
        assert_eq!(typed.kind(), EndpointKind::Simulate);
        // Fleet, sweep and optimize parse through the same entry point.
        let fleet = ApiRequest::parse(EndpointKind::Fleet, "", false, &b)
            .unwrap();
        let sweep = ApiRequest::parse(EndpointKind::Sweep, "", false, &b)
            .unwrap();
        let opt = ApiRequest::parse(EndpointKind::Optimize, "", false, &b)
            .unwrap();
        assert_eq!(fleet.kind(), EndpointKind::Fleet);
        assert_eq!(sweep.kind(), EndpointKind::Sweep);
        assert_eq!(opt.kind(), EndpointKind::Optimize);
        assert_ne!(fleet.fingerprint(), sweep.fingerprint());
        assert_ne!(fleet.fingerprint(), opt.fingerprint());
        // Strictness is shared: the unknown-field error reaches every
        // kind through the one parser.
        for kind in [
            EndpointKind::Simulate,
            EndpointKind::Fleet,
            EndpointKind::Sweep,
            EndpointKind::Optimize,
        ] {
            let err = format!(
                "{:#}",
                ApiRequest::parse(kind, r#"{"bogus_field": 1}"#, false, &b)
                    .unwrap_err()
            );
            assert!(err.contains("unknown field 'bogus_field'"), "{err}");
        }
    }

    #[test]
    fn fleet_request_defaults_and_clamps() {
        let fc = parse_fleet_request("", &base()).unwrap();
        assert_eq!(fc.n_plants, 4);
        assert_eq!(fc.shards, 1);
        assert_eq!(fc.scenario.name(), "baseline");
        let fc = parse_fleet_request(
            r#"{"plants": 2, "shards": 16, "scenario": "heatwave"}"#,
            &base(),
        )
        .unwrap();
        assert_eq!(fc.shards, 2, "shards clamp to plants");
        assert_eq!(fc.scenario.name(), "heatwave");
        assert!(parse_fleet_request(r#"{"plants": 0}"#, &base()).is_err());
        // per-request fleet size is sanity-capped (fleet memory is
        // O(n_plants); an unbounded request could OOM the server)
        assert!(
            parse_fleet_request(r#"{"plants": 100000}"#, &base()).is_err()
        );
        assert!(
            parse_fleet_request(
                &format!("{{\"plants\": {MAX_REQUEST_PLANTS}}}"),
                &base()
            )
            .is_ok()
        );
        assert!(
            parse_fleet_request(r#"{"scenario": "nope"}"#, &base()).is_err()
        );
        // megabatch is a recognized (strict-boolean) execution knob
        let fc = parse_fleet_request(r#"{"megabatch": false}"#, &base())
            .unwrap();
        assert!(!fc.megabatch);
        let fc = parse_fleet_request(r#"{"megabatch": true}"#, &base())
            .unwrap();
        assert!(fc.megabatch);
        assert!(
            parse_fleet_request(r#"{"megabatch": 1}"#, &base()).is_err()
        );
    }

    #[test]
    fn shard_count_never_enters_the_cache_key() {
        // Responses are bitwise identical across shard counts, so
        // requests differing only in shards share one fingerprint.
        let a = parse_fleet_request(r#"{"plants": 4}"#, &base()).unwrap();
        let b = parse_fleet_request(
            r#"{"plants": 4, "shards": 4}"#, &base()).unwrap();
        let ka = request_fingerprint(
            "fleet", &canonical_fleet_json(&a), &a.base);
        let kb = request_fingerprint(
            "fleet", &canonical_fleet_json(&b), &b.base);
        assert_eq!(ka, kb);
        let s1 = parse_sweep_request(
            r#"{"setpoints": [50, 60]}"#, &base()).unwrap();
        let s2 = parse_sweep_request(
            r#"{"setpoints": [50, 60], "shards": 2}"#, &base()).unwrap();
        let k1 = request_fingerprint(
            "sweep", &canonical_sweep_json(&s1), &s1.cfg);
        let k2 = request_fingerprint(
            "sweep", &canonical_sweep_json(&s2), &s2.cfg);
        assert_eq!(k1, k2);
        // megabatch is execution shape too: same cache key either way
        let m = parse_fleet_request(
            r#"{"plants": 4, "megabatch": false}"#, &base()).unwrap();
        let km = request_fingerprint(
            "fleet", &canonical_fleet_json(&m), &m.base);
        assert_eq!(ka, km);
        // ...but real knobs still separate keys.
        let c = parse_fleet_request(r#"{"plants": 5}"#, &base()).unwrap();
        let kc = request_fingerprint(
            "fleet", &canonical_fleet_json(&c), &c.base);
        assert_ne!(ka, kc);
    }

    #[test]
    fn optimize_request_defaults_resolve_like_the_cli() {
        let oc = parse_optimize_request("", &base()).unwrap();
        assert_eq!(oc.objective_name, "ere");
        assert_eq!(oc.kind.name(), "grid");
        assert_eq!(oc.budget, 24);
        assert_eq!(oc.n_plants, 2);
        assert_eq!(oc.scenario.name(), "mixed");
        assert_eq!(oc.seed, base().seed, "search seed is the base seed");
        // only the setpoint axis is free by default
        assert!(!oc.space.setpoint.frozen);
        assert!(oc.space.pump.frozen);
        let oc = parse_optimize_request(
            r#"{"objective": "cost", "driver": "cem", "budget": 10,
                "axes": "setpoint,pump", "w_throttle": 2.0,
                "eval_duration_s": 300, "detail": false, "seed": 7}"#,
            &base(),
        )
        .unwrap();
        assert_eq!(oc.kind.name(), "cem");
        assert_eq!(oc.weights.cost, 1.0);
        assert_eq!(oc.weights.throttle, 2.0);
        assert!(!oc.space.pump.frozen);
        assert!(!oc.detail);
        assert_eq!(oc.seed, 7);
    }

    #[test]
    fn optimize_request_caps_and_rejects() {
        let b = base();
        assert!(parse_optimize_request(r#"{"budget": 0}"#, &b).is_err());
        assert!(parse_optimize_request(
            &format!("{{\"budget\": {}}}", MAX_REQUEST_BUDGET + 1),
            &b
        )
        .is_err());
        assert!(parse_optimize_request(
            &format!("{{\"budget\": {MAX_REQUEST_BUDGET}}}"),
            &b
        )
        .is_ok());
        assert!(parse_optimize_request(r#"{"plants": 0}"#, &b).is_err());
        assert!(parse_optimize_request(r#"{"gen_size": 0}"#, &b).is_err());
        assert!(
            parse_optimize_request(r#"{"objective": "speed"}"#, &b).is_err()
        );
        assert!(
            parse_optimize_request(r#"{"driver": "anneal"}"#, &b).is_err()
        );
        assert!(parse_optimize_request(r#"{"axes": "turbo"}"#, &b).is_err());
        assert!(parse_optimize_request(
            r#"{"eval_duration_s": 0}"#, &b).is_err());
        let err = parse_optimize_request(r#"{"budgett": 5}"#, &b)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown field 'budgett'"), "{err}");
    }

    #[test]
    fn optimize_fingerprint_is_resolution_canonical() {
        let b = base();
        // A body naming the defaults explicitly shares the empty body's
        // cache entry: the canonical document is the *resolved* search.
        let empty = parse_optimize_request("", &b).unwrap();
        let explicit = parse_optimize_request(
            r#"{"objective": "ere", "driver": "grid", "budget": 24,
                "plants": 2, "scenario": "mixed"}"#,
            &b,
        )
        .unwrap();
        let ke = request_fingerprint(
            "optimize", &canonical_optimize_json(&empty), &empty.base);
        let kx = request_fingerprint(
            "optimize", &canonical_optimize_json(&explicit), &explicit.base);
        assert_eq!(ke, kx);
        // Real knobs separate keys: budget, weights, axes.
        for body in [
            r#"{"budget": 12}"#,
            r#"{"w_throttle": 9.0}"#,
            r#"{"axes": "setpoint,pump"}"#,
        ] {
            let other = parse_optimize_request(body, &b).unwrap();
            let ko = request_fingerprint(
                "optimize", &canonical_optimize_json(&other), &other.base);
            assert_ne!(ke, ko, "{body} must change the cache key");
        }
    }

    #[test]
    fn sweep_request_defaults_and_validation() {
        let r = parse_sweep_request("", &base()).unwrap();
        assert_eq!(r.setpoints, vec![45.0, 55.0, 65.0]);
        assert!(r.quick);
        assert_eq!(r.shards, 1);
        let r = parse_sweep_request(
            r#"{"setpoints": [50, 60], "shards": 8, "quick": true}"#,
            &base(),
        )
        .unwrap();
        assert_eq!(r.shards, 2, "shards clamp to setpoint count");
        assert!(
            parse_sweep_request(r#"{"setpoints": []}"#, &base()).is_err()
        );
        assert!(
            parse_sweep_request(r#"{"setpoints": [150]}"#, &base()).is_err()
        );
    }

    #[test]
    fn summary_json_has_no_wall_clock_fields() {
        let cfg = base();
        let res = RunResult {
            trace: vec![TraceSample { t_s: 5.0, ..Default::default() }],
            energy: EnergyAccount::new(),
            events: Vec::new(),
            workload_stats: "idle".into(),
            backend: "native",
            plant_wall_s: 1.25,
            total_wall_s: 2.5,
            ticks: 1,
        };
        let j = simulate_summary_json(&cfg, "soa", 1, &res);
        let text = j.to_string();
        assert!(!text.contains("wall"), "{text}");
        assert_eq!(j.get("ticks").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("kernel").unwrap().as_str(), Some("soa"));
        assert!(j.get("final").unwrap().get("t_s").is_some());
        // NDJSON: one line per sample + the summary line.
        let nd = trace_ndjson(&cfg, "soa", 1, &res);
        let lines: Vec<&str> =
            std::str::from_utf8(&nd).unwrap().trim_end().lines().collect();
        assert_eq!(lines.len(), 2);
        for l in &lines {
            Json::parse(l).unwrap();
        }
    }
}
