//! Supervised worker pool for the serve path (DESIGN.md §10).
//!
//! `pool::WorkerPool` runs the handler bare: a panic kills the thread
//! and silently shrinks the pool forever, and a stalled compute holds
//! its victim's connection open until the 30 s socket timeout. This
//! module wraps the same queue-draining loop in a supervision
//! contract:
//!
//!  * every job runs under `catch_unwind`; a panic answers the victim
//!    500 on a dup'd write half, then the thread dies *visibly* — a
//!    monitor thread respawns the slot (bounded by `[serve]
//!    restart_budget`, counted in `idatacool_worker_restarts_total`);
//!  * each worker stamps a relaxed `AtomicU64` heartbeat per job; the
//!    monitor condemns a busy worker whose heartbeat age exceeds the
//!    stall threshold (4 × the request deadline), answers the victim
//!    504 with a computed `Retry-After`, and hands the slot to a fresh
//!    thread — the stuck one discovers its stale generation on wake
//!    and exits without touching the slot;
//!  * the chaos site `worker_tick` fires once per popped job (the
//!    `plant` selector addresses the worker slot), so tests drive both
//!    paths deterministically: `kind=panic` exercises die-and-respawn,
//!    `kind=stall_ms` exercises the watchdog.
//!
//! Supervision is pure execution shape: it decides *which thread*
//! answers and *when to give up*, never *what bytes* an admitted
//! request gets — response bodies stay bitwise identical to solo CLI
//! runs.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::resilience::inject::{self, Site};
use crate::util::http::Response;

use super::admit;
use super::pool::JobQueue;
use super::{Conn, ServeScratch};

/// Monitor cadence: how often heartbeats and liveness are checked.
/// Small enough that a watchdog 504 lands promptly; large enough to
/// stay invisible in profiles.
const MONITOR_POLL: Duration = Duration::from_millis(20);

type Handler = Arc<dyn Fn(Conn, &mut ServeScratch) + Send + Sync>;

/// One worker slot's supervision state. The thread occupying a slot
/// changes over time; the `generation` counter says which thread owns
/// it — a condemned or replaced thread sees a newer generation and
/// must not touch the slot again.
struct Slot {
    /// Last heartbeat, in ms since pool construction (relaxed stamp).
    heartbeat_ms: AtomicU64,
    /// A job is being served (stamped with the heartbeat at pop).
    busy: AtomicBool,
    /// The slot has a thread draining the queue.
    live: AtomicBool,
    /// Which spawn owns the slot; bumped on condemn and respawn.
    generation: AtomicU64,
    /// Dup'd write half of the connection being served, so the monitor
    /// (stall) or the unwinding worker (panic) can answer the victim.
    victim: Mutex<Option<(u64, TcpStream)>>,
}

/// Shared supervision state: slots plus the restart budget. Created by
/// `Server::bind` (the health endpoint reads it) and driven by
/// [`spawn`].
pub struct PoolState {
    slots: Vec<Slot>,
    started: Instant,
    /// Remaining respawns — the fuse against a crash loop.
    budget: AtomicU64,
    restarts: AtomicU64,
    stalls: AtomicU64,
    /// Heartbeat age past which a busy worker is condemned; `None`
    /// disables the watchdog (no deadline configured).
    stall: Option<Duration>,
    shutdown: AtomicBool,
}

impl PoolState {
    pub fn new(workers: usize, restart_budget: u64,
               stall: Option<Duration>) -> Arc<PoolState> {
        let slots = (0..workers)
            .map(|_| Slot {
                heartbeat_ms: AtomicU64::new(0),
                busy: AtomicBool::new(false),
                live: AtomicBool::new(false),
                generation: AtomicU64::new(0),
                victim: Mutex::new(None),
            })
            .collect();
        Arc::new(PoolState {
            slots,
            started: Instant::now(),
            budget: AtomicU64::new(restart_budget),
            restarts: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
            stall,
            shutdown: AtomicBool::new(false),
        })
    }

    fn now_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// Atomically take one respawn from the budget; `false` = spent.
    fn take_budget(&self) -> bool {
        self.budget
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
                b.checked_sub(1)
            })
            .is_ok()
    }

    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// Slots currently occupied by a draining thread.
    pub fn live_workers(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.live.load(Ordering::Relaxed))
            .count()
    }

    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    pub fn stalls(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }

    pub fn budget_left(&self) -> u64 {
        self.budget.load(Ordering::Relaxed)
    }
}

/// The running pool: worker threads, their monitor, and the state they
/// share with the server.
pub struct SupervisedPool {
    state: Arc<PoolState>,
    handles: Arc<Mutex<Vec<(usize, u64, JoinHandle<()>)>>>,
    monitor: JoinHandle<()>,
}

/// Spawn the configured worker count plus the monitor thread. The
/// handler serves one popped connection (it is `handle_connection` in
/// production).
pub fn spawn<F>(state: Arc<PoolState>, queue: Arc<JobQueue<Conn>>,
                handler: F) -> SupervisedPool
where
    F: Fn(Conn, &mut ServeScratch) + Send + Sync + 'static,
{
    let handler: Handler = Arc::new(handler);
    let handles = Arc::new(Mutex::new(Vec::new()));
    {
        let mut hs = handles.lock().unwrap();
        for w in 0..state.workers() {
            let gen = state.slots[w].generation.load(Ordering::Relaxed);
            state.slots[w].live.store(true, Ordering::Relaxed);
            state.slots[w].heartbeat_ms.store(state.now_ms(),
                                              Ordering::Relaxed);
            hs.push((w, gen,
                     spawn_worker(state.clone(), queue.clone(),
                                  handler.clone(), w, gen)));
        }
    }
    let monitor = {
        let state = state.clone();
        let handles = handles.clone();
        std::thread::Builder::new()
            .name("serve-monitor".into())
            .spawn(move || monitor_loop(&state, &queue, &handler, &handles))
            .expect("spawn serve monitor")
    };
    SupervisedPool { state, handles, monitor }
}

impl SupervisedPool {
    /// Drain shutdown: close the queue first, then call this. Joins
    /// the monitor and every current-generation worker; condemned
    /// stale threads are left to finish detached (joining a thread
    /// that is still stuck in the stalled compute would block
    /// shutdown — process exit reaps it).
    pub fn join(self) {
        self.state.shutdown.store(true, Ordering::Relaxed);
        let _ = self.monitor.join();
        let handles = std::mem::take(&mut *self.handles.lock().unwrap());
        for (w, gen, h) in handles {
            if self.state.slots[w].generation.load(Ordering::Relaxed) == gen {
                let _ = h.join();
            }
        }
    }
}

fn spawn_worker(state: Arc<PoolState>, queue: Arc<JobQueue<Conn>>,
                handler: Handler, w: usize, gen: u64) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("serve-worker-{w}.{gen}"))
        .spawn(move || worker_loop(&state, &queue, &handler, w, gen))
        .expect("spawn serve worker")
}

/// Clears `live` when the thread exits for any reason — unless a newer
/// generation already owns the slot (then its liveness is not ours to
/// report).
struct LiveGuard<'a> {
    slot: &'a Slot,
    gen: u64,
}

impl Drop for LiveGuard<'_> {
    fn drop(&mut self) {
        if self.slot.generation.load(Ordering::Relaxed) == self.gen {
            self.slot.live.store(false, Ordering::Relaxed);
        }
    }
}

/// Take the victim connection out of the slot if it still belongs to
/// `gen`; anything newer is left for its owner.
fn take_victim(slot: &Slot, gen: u64) -> Option<TcpStream> {
    let mut v = slot.victim.lock().unwrap();
    match v.take() {
        Some((g, s)) if g == gen => Some(s),
        other => {
            *v = other;
            None
        }
    }
}

fn worker_loop(state: &PoolState, queue: &JobQueue<Conn>, handler: &Handler,
               w: usize, gen: u64) {
    let slot = &state.slots[w];
    let _live = LiveGuard { slot, gen };
    let mut scratch = ServeScratch::new(w);
    loop {
        if state.shutdown.load(Ordering::Relaxed)
            || slot.generation.load(Ordering::Relaxed) != gen
        {
            return;
        }
        let Some(conn) = queue.pop() else { return };
        slot.heartbeat_ms.store(state.now_ms(), Ordering::Relaxed);
        slot.busy.store(true, Ordering::Relaxed);
        if let Ok(dup) = conn.stream.try_clone() {
            *slot.victim.lock().unwrap() = Some((gen, dup));
        }
        let panicked = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                // Chaos site: fires once per popped job, before the
                // handler, addressed by worker slot.
                if inject::armed() {
                    let _ = inject::fire(Site::WorkerTick, Some(w));
                }
                // An injected stall long enough for the watchdog to
                // condemn this generation means the victim was already
                // answered 504 — don't compute for a client that is
                // gone.
                if slot.generation.load(Ordering::Relaxed) != gen {
                    return;
                }
                handler(conn, &mut scratch);
            }),
        )
        .is_err();
        let victim = take_victim(slot, gen);
        if slot.generation.load(Ordering::Relaxed) == gen {
            slot.busy.store(false, Ordering::Relaxed);
            slot.heartbeat_ms.store(state.now_ms(), Ordering::Relaxed);
        }
        if panicked {
            // An unwind that reaches here escaped the handler's own
            // catch (e.g. the chaos site above), so no response was
            // written yet: answer the victim on the dup'd write half,
            // then die — the monitor respawns the slot.
            if let Some(mut s) = victim {
                let _ = Response::error(
                    500,
                    "worker panicked before answering; worker is being \
                     replaced",
                )
                .write_to(&mut s);
            }
            return;
        }
    }
}

fn monitor_loop(state: &Arc<PoolState>, queue: &Arc<JobQueue<Conn>>,
                handler: &Handler,
                handles: &Arc<Mutex<Vec<(usize, u64, JoinHandle<()>)>>>) {
    while !state.shutdown.load(Ordering::Relaxed) {
        std::thread::sleep(MONITOR_POLL);
        if state.shutdown.load(Ordering::Relaxed) || queue.is_closed() {
            return;
        }
        let now = state.now_ms();
        for w in 0..state.slots.len() {
            let slot = &state.slots[w];
            let gen = slot.generation.load(Ordering::Relaxed);
            // Stall watchdog: a busy worker whose heartbeat age passed
            // the threshold is condemned — its victim gets the 504 now
            // instead of at stall end, and the slot gets a fresh
            // thread. The stuck thread exits on wake (stale
            // generation); if its compute does finish, the result is
            // still cached and published before it notices.
            if let Some(stall) = state.stall {
                if slot.live.load(Ordering::Relaxed)
                    && slot.busy.load(Ordering::Relaxed)
                {
                    let hb = slot.heartbeat_ms.load(Ordering::Relaxed);
                    if now.saturating_sub(hb) > stall.as_millis() as u64 {
                        condemn(state, queue, w, gen);
                        respawn(state, queue, handler, handles, w);
                        continue;
                    }
                }
            }
            // Panic exit: the LiveGuard cleared `live` under a current
            // generation — a death, not a replacement in progress.
            if !slot.live.load(Ordering::Relaxed) {
                respawn(state, queue, handler, handles, w);
            }
        }
    }
}

/// Answer the condemned worker's victim 504 and take the slot away
/// from the stuck thread by bumping its generation.
fn condemn(state: &PoolState, queue: &JobQueue<Conn>, w: usize, gen: u64) {
    let slot = &state.slots[w];
    if let Some(mut s) = take_victim(slot, gen) {
        let retry =
            admit::retry_after_secs(queue.len(), state.workers(), 0.0);
        let _ = Response::error(
            504,
            "deadline exceeded: compute stalled; worker is being \
             replaced (result may be cached)",
        )
        .with_header("retry-after", &retry.to_string())
        .write_to(&mut s);
    }
    state.stalls.fetch_add(1, Ordering::Relaxed);
    slot.generation.fetch_add(1, Ordering::Relaxed);
    slot.live.store(false, Ordering::Relaxed);
    slot.busy.store(false, Ordering::Relaxed);
    slot.heartbeat_ms.store(state.now_ms(), Ordering::Relaxed);
}

/// Give a dark slot a fresh thread, budget permitting. A spent budget
/// leaves the slot dark — the degradation ladder reports the shrunken
/// pool instead of masking a crash loop.
fn respawn(state: &Arc<PoolState>, queue: &Arc<JobQueue<Conn>>,
           handler: &Handler,
           handles: &Arc<Mutex<Vec<(usize, u64, JoinHandle<()>)>>>,
           w: usize) {
    if state.shutdown.load(Ordering::Relaxed) || queue.is_closed() {
        return;
    }
    if !state.take_budget() {
        return;
    }
    let slot = &state.slots[w];
    let gen = slot.generation.fetch_add(1, Ordering::Relaxed) + 1;
    slot.live.store(true, Ordering::Relaxed);
    slot.busy.store(false, Ordering::Relaxed);
    slot.heartbeat_ms.store(state.now_ms(), Ordering::Relaxed);
    state.restarts.fetch_add(1, Ordering::Relaxed);
    crate::obs::metrics::worker_restarts().inc();
    let h = spawn_worker(state.clone(), queue.clone(), handler.clone(),
                         w, gen);
    handles.lock().unwrap().push((w, gen, h));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::net::TcpListener;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn restart_budget_is_a_fuse() {
        let state = PoolState::new(2, 3, None);
        assert_eq!(state.budget_left(), 3);
        assert!(state.take_budget());
        assert!(state.take_budget());
        assert!(state.take_budget());
        assert!(!state.take_budget(), "budget must not underflow");
        assert_eq!(state.budget_left(), 0);
    }

    #[test]
    fn live_accounting_counts_occupied_slots() {
        let state = PoolState::new(3, 0, None);
        assert_eq!(state.workers(), 3);
        assert_eq!(state.live_workers(), 0);
        state.slots[0].live.store(true, Ordering::Relaxed);
        state.slots[2].live.store(true, Ordering::Relaxed);
        assert_eq!(state.live_workers(), 2);
    }

    /// A connected (client, server-side Conn) pair on loopback.
    fn conn_pair(listener: &TcpListener) -> (TcpStream, Conn) {
        let client =
            TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (s, _) = listener.accept().unwrap();
        (client, Conn { stream: s, leftover: Vec::new(),
                        enqueued: Instant::now() })
    }

    fn wait_until(what: &str, mut done: impl FnMut() -> bool) {
        let t0 = Instant::now();
        while !done() {
            assert!(t0.elapsed() < Duration::from_secs(10),
                    "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn panic_kills_worker_and_monitor_respawns_within_budget() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let state = PoolState::new(1, 4, None);
        let queue = Arc::new(JobQueue::new(8));
        let served = Arc::new(AtomicUsize::new(0));
        let pool = spawn(state.clone(), queue.clone(), {
            let served = served.clone();
            move |_conn, _scratch| {
                if served.fetch_add(1, Ordering::SeqCst) == 0 {
                    panic!("first job dies");
                }
            }
        });
        assert_eq!(state.live_workers(), 1);

        let (mut client, conn) = conn_pair(&listener);
        assert!(queue.push(conn).is_ok());
        wait_until("respawn", || state.restarts() >= 1);
        // The panicking worker answered its victim before dying.
        let mut buf = String::new();
        client
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        client.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 500"), "{buf}");

        // The replacement drains the queue again.
        let (_client2, conn2) = conn_pair(&listener);
        assert!(queue.push(conn2).is_ok());
        wait_until("second job", || served.load(Ordering::SeqCst) >= 2);
        assert_eq!(state.live_workers(), 1);
        assert_eq!(state.restarts(), 1);

        queue.close();
        pool.join();
    }

    #[test]
    fn stalled_worker_is_condemned_and_victim_answered_504() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let state =
            PoolState::new(1, 4, Some(Duration::from_millis(50)));
        let queue = Arc::new(JobQueue::new(8));
        let pool = spawn(state.clone(), queue.clone(), |_conn, _scratch| {
            std::thread::sleep(Duration::from_millis(400));
        });

        let (mut client, conn) = conn_pair(&listener);
        assert!(queue.push(conn).is_ok());
        let mut buf = String::new();
        client
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        client.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 504"), "{buf}");
        assert!(buf.contains("retry-after:"), "computed hint: {buf}");
        assert!(buf.contains("\"idatacool-error/1\""), "{buf}");
        assert!(state.stalls() >= 1);
        wait_until("replacement live", || state.live_workers() == 1
            && state.restarts() >= 1);

        queue.close();
        pool.join();
    }
}
