//! Continuous request batching: concurrent `/v1/simulate` and
//! `/v1/fleet` requests admitted into one shared SoA lane arena.
//!
//! PR 4's coalescer only merges *identical* requests and PR 5's
//! megabatch only batches plants inside one fleet run; heterogeneous
//! concurrent traffic still paid one full kernel sweep per request.
//! This scheduler closes that gap with the classic continuous-batching
//! shape: an admission window collects in-flight jobs, groups them by
//! compatible tick grid, packs every plant into one `LockstepFleet`
//! arena (`fleet/megabatch.rs`), advances the whole batch in tick
//! lockstep — one `soa_substep_ranges` sweep per substep for all
//! plants of all requests — and demultiplexes per-request responses.
//!
//! # Round protocol (leader-based, no dedicated thread)
//!
//! The first worker to submit while no round is collecting becomes the
//! round *leader*: it enqueues its job, sleeps `batch_window_ms`, then
//! swaps out everything that accumulated and runs the round. The
//! collecting flag is cleared at swap time, so while one round
//! computes, the next is already admitting — worker parallelism across
//! rounds is preserved. Followers just park on their job's slot (the
//! same condvar primitive the coalescer uses). With `batch_window_ms =
//! 0` the server never constructs a `Batcher` and every request runs
//! solo, exactly as before this scheduler existed.
//!
//! # Determinism
//!
//! Batched responses are bitwise identical to solo runs, and the mix
//! of concurrently admitted requests can never leak into a response:
//!
//! * `tests/fleet_integration.rs` pins lockstep-vs-sequential bitwise
//!   parity per plant; the arena adds plants side by side in
//!   independent SoA lanes, never across lanes.
//! * Jobs are grouped by tick count and only lockstep when
//!   `LockstepFleet::new` accepts the bucket (uniform plant constants /
//!   substeps / tick grid); any refused bucket is handed back and run
//!   per plant — the bitwise-identical fallback.
//! * `/simulate` with `sample_every = k` is admitted by recording every
//!   tick in the arena and keeping indices `i % k == 0` afterwards —
//!   the exact set of ticks `run_ticks_into` pushes when sampling
//!   solo, carrying bitwise-identical samples.
//! * Response documents contain no wall-clock fields (`server/api.rs`
//!   keeps them out deliberately), so serialization is a pure function
//!   of the per-plant results.
//!
//! Gated end to end by the parity tests in
//! `tests/serve_integration.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::SimConfig;
use crate::coordinator::SimulationDriver;
use crate::fleet::aggregate::FleetAggregate;
use crate::fleet::facility::FacilityParams;
use crate::fleet::megabatch::{self, LockstepFleet, PlantCtx};
use crate::fleet::{run_facility, FleetConfig, FleetDriver, FleetRun, PlantRun};
use crate::obs::metrics::{batch_occupancy, batch_window_wait_ms, BATCH_SHARDS};

use super::api::{self, SimRequest};
use super::coalesce::Slot;
use super::CachedResponse;

/// How a batched job's response is serialized after the shared sweep.
pub enum JobKind {
    Sim {
        /// The driver's post-construction config (what solo
        /// serialization uses too).
        cfg: SimConfig,
        kernel: &'static str,
        sample_every: usize,
        stream: bool,
    },
    Fleet { fc: FleetConfig },
}

/// One admitted request: its ready-to-run plant contexts (1 for
/// `/simulate`, `n_plants` for `/fleet`) plus serialization intent.
pub struct BatchJob {
    /// Tick-grid group key: jobs lockstep only with equal tick counts.
    ticks: u64,
    ctxs: Vec<PlantCtx>,
    kind: JobKind,
}

impl BatchJob {
    /// A `/simulate` job: one plant, driver built exactly as the solo
    /// path builds it. Callers must have passed `megabatch::precheck`.
    pub fn sim(sim: SimRequest, stream: bool) -> Result<BatchJob> {
        let sample_every = sim.sample_every;
        let driver = SimulationDriver::new(sim.cfg)?;
        let cfg = driver.cfg.clone();
        let kernel = driver.backend.kernel_name();
        let tick_s = driver.backend.tick_seconds(&cfg.pp);
        let ticks = (cfg.duration_s / tick_s).ceil() as u64;
        let ctx = PlantCtx {
            index: 0,
            label: cfg.name.clone(),
            seed: cfg.seed,
            tick_s,
            driver,
        };
        Ok(BatchJob {
            ticks,
            ctxs: vec![ctx],
            kind: JobKind::Sim { cfg, kernel, sample_every, stream },
        })
    }

    /// A `/fleet` job: every plant of the fleet, in plant-index order
    /// (indices are fleet-local, which is what the facility replay and
    /// the aggregate expect).
    pub fn fleet(fc: FleetConfig) -> Result<BatchJob> {
        let driver = FleetDriver::new(fc)?;
        let ctxs = megabatch::build_ctxs(driver.specs())?;
        let fc = driver.cfg;
        let first = ctxs.first().expect("FleetDriver guarantees n_plants > 0");
        let ticks =
            (first.driver.cfg.duration_s / first.tick_s).ceil() as u64;
        Ok(BatchJob { ticks, ctxs, kind: JobKind::Fleet { fc } })
    }

    /// Number of SoA lanes this job occupies in an arena.
    pub fn plants(&self) -> usize {
        self.ctxs.len()
    }
}

/// `(response-or-error, batch occupancy)` published to each job's slot.
/// The error side is a `String` so the payload stays `Clone`; `submit`
/// rehydrates it into `anyhow::Error` for the caller.
type Verdict = (std::result::Result<CachedResponse, String>, usize);

struct Pending {
    job: BatchJob,
    slot: Arc<Slot<Verdict>>,
    enqueued: Instant,
}

#[derive(Default)]
struct RoundState {
    jobs: Vec<Pending>,
    /// A leader is currently inside its admission window.
    collecting: bool,
}

/// The admission-window scheduler. One per server, behind
/// `[serve] batch_window_ms > 0`.
pub struct Batcher {
    window: Duration,
    max_plants: usize,
    round: Mutex<RoundState>,
    /// Rotates metric pushes across histogram shards; rounds run on
    /// whichever worker led them, so there is no stable worker index.
    shard: AtomicUsize,
}

impl Batcher {
    pub fn new(window: Duration, max_plants: usize) -> Self {
        assert!(max_plants >= 1, "batch_max_plants must be at least 1");
        Batcher {
            window,
            max_plants,
            round: Mutex::new(RoundState::default()),
            shard: AtomicUsize::new(0),
        }
    }

    /// Admit `job` and block until its round has run — at most
    /// `deadline`, when the server has one. Returns the response plus
    /// the occupancy (total plants) of the arena chunk that carried it
    /// — surfaced to clients as the `x-batch` header.
    pub fn submit(&self, job: BatchJob, deadline: Option<Duration>)
                  -> Result<(CachedResponse, usize)> {
        let admit_span = crate::obs::span("batch_admit");
        let slot = Arc::new(Slot::new());
        let lead = {
            let mut g = self.round.lock().unwrap();
            let lead = !g.collecting;
            if lead {
                g.collecting = true;
            }
            g.jobs.push(Pending {
                job,
                slot: slot.clone(),
                enqueued: Instant::now(),
            });
            lead
        };
        if lead {
            std::thread::sleep(self.window);
            let jobs = {
                let mut g = self.round.lock().unwrap();
                // Clear before computing so the next arrival starts a
                // new round while this one sweeps.
                g.collecting = false;
                std::mem::take(&mut g.jobs)
            };
            self.run_round(jobs);
        }
        drop(admit_span);
        // A leader's slot is already published by its own `run_round`;
        // only a follower's wait can hit the bound. The round still
        // publishes the real verdict to the slot — this caller just
        // stops waiting for it.
        let verdict = match deadline {
            Some(d) => slot.wait_timeout(d),
            None => Some(slot.wait()),
        };
        let Some((result, occupancy)) = verdict else {
            return Ok((
                super::error_cached(
                    504,
                    "deadline exceeded waiting for the batch round; retry",
                ),
                0,
            ));
        };
        match result {
            Ok(resp) => Ok((resp, occupancy)),
            Err(msg) => Err(anyhow::anyhow!(msg)),
        }
    }

    /// Group a round's jobs by tick grid, chunk each group by the
    /// plant budget, and run every chunk. Publishes every slot exactly
    /// once — the leader's own slot included.
    fn run_round(&self, jobs: Vec<Pending>) {
        let mut groups: std::collections::BTreeMap<u64, Vec<Pending>> =
            std::collections::BTreeMap::new();
        for p in jobs {
            groups.entry(p.job.ticks).or_default().push(p);
        }
        for (_, group) in groups {
            // Greedy packing; a job's plants never split across chunks,
            // so an oversized fleet simply forms its own chunk.
            let mut chunk: Vec<Pending> = Vec::new();
            let mut plants = 0usize;
            for p in group {
                let n = p.job.plants();
                if !chunk.is_empty() && plants + n > self.max_plants {
                    self.run_chunk(std::mem::take(&mut chunk));
                    plants = 0;
                }
                plants += n;
                chunk.push(p);
            }
            if !chunk.is_empty() {
                self.run_chunk(chunk);
            }
        }
    }

    /// Sweep one chunk and publish a verdict to every job's slot. A
    /// panic inside the sweep publishes an error to all of them, so
    /// followers can never hang (mirror of the coalescer's
    /// complete-exactly-once contract).
    fn run_chunk(&self, chunk: Vec<Pending>) {
        let occupancy: usize = chunk.iter().map(|p| p.job.plants()).sum();
        let shard =
            self.shard.fetch_add(1, Ordering::Relaxed) % BATCH_SHARDS;
        batch_occupancy().push(shard, occupancy as f64);
        for p in &chunk {
            let ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
            batch_window_wait_ms().push(shard, ms.max(1e-9).log10());
        }

        let n = chunk.len();
        let (slots, jobs): (Vec<_>, Vec<_>) =
            chunk.into_iter().map(|p| (p.slot, p.job)).unzip();
        let results = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| sweep(jobs)),
        )
        .unwrap_or_else(|_| {
            vec![Err("batched sweep panicked".to_string()); n]
        });
        debug_assert_eq!(results.len(), n);
        for (slot, result) in slots.into_iter().zip(results) {
            slot.publish((result, occupancy));
        }
    }
}

/// Run one chunk's plants through a shared arena (or the per-plant
/// fallback when the bucket refuses lockstep) and serialize one
/// response per job.
fn sweep(
    jobs: Vec<BatchJob>,
) -> Vec<std::result::Result<CachedResponse, String>> {
    let mut counts = Vec::with_capacity(jobs.len());
    let mut kinds = Vec::with_capacity(jobs.len());
    let mut all: Vec<PlantCtx> = Vec::new();
    for job in jobs {
        counts.push(job.ctxs.len());
        kinds.push(job.kind);
        all.extend(job.ctxs);
    }

    let runs = {
        let _span = crate::obs::span("batch_sweep");
        match LockstepFleet::new(all) {
            Ok(arena) => arena.run(None).map(|(plants, _, q)| (plants, q)),
            // Mixed tick lengths / plant constants across requests:
            // hand the drivers back and run them one by one — bitwise
            // identical, just without the shared sweep.
            Err(ctxs) => megabatch::run_ctxs_sequential(ctxs),
        }
    };
    let (runs, quarantined) = match runs {
        Ok(pair) => pair,
        Err(e) => {
            let msg = format!("{e:#}");
            return kinds.iter().map(|_| Err(msg.clone())).collect();
        }
    };
    // A quarantine inside a *batched* sweep cannot be attributed to one
    // job: plant indices are job-local (every `/simulate` lane is index
    // 0), so the lane→job demux below relies on every admitted plant
    // surviving. Containment here is the error envelope — each request
    // in the chunk gets a retriable failure instead of a silently
    // truncated document. (Solo and CLI fleet paths degrade per plant;
    // see `fleet::run_resilient`.)
    if !quarantined.is_empty() {
        let msg = format!(
            "{} plant(s) quarantined during batched sweep ({}); retry solo",
            quarantined.len(),
            quarantined[0].reason,
        );
        return kinds.iter().map(|_| Err(msg.clone())).collect();
    }

    // Demux: lanes were packed in job order, so split by plant counts.
    debug_assert_eq!(runs.len(), counts.iter().sum::<usize>());
    let mut runs = runs.into_iter();
    kinds
        .into_iter()
        .zip(counts)
        .map(|(kind, n)| {
            let slice: Vec<PlantRun> = runs.by_ref().take(n).collect();
            respond(kind, slice).map_err(|e| format!("{e:#}"))
        })
        .collect()
}

/// Serialize one job's response from its demuxed plant runs — byte
/// identical to what the solo compute path produces.
fn respond(kind: JobKind, mut runs: Vec<PlantRun>) -> Result<CachedResponse> {
    let _span = crate::obs::span("serialize");
    match kind {
        JobKind::Sim { cfg, kernel, sample_every, stream } => {
            anyhow::ensure!(runs.len() == 1, "sim job demuxed {} plants",
                            runs.len());
            let mut res = runs.pop().expect("checked").result;
            if sample_every > 1 {
                // The arena recorded every tick; keep the ticks the
                // solo sampler would have kept (`i % sample_every == 0`
                // in `run_ticks_into`).
                let mut i = 0usize;
                res.trace.retain(|_| {
                    let keep = i % sample_every == 0;
                    i += 1;
                    keep
                });
            }
            let (content_type, body) = if stream {
                ("application/x-ndjson",
                 api::trace_ndjson(&cfg, kernel, sample_every, &res))
            } else {
                ("application/json",
                 api::simulate_summary_json(&cfg, kernel, sample_every, &res)
                     .to_string()
                     .into_bytes())
            };
            Ok(CachedResponse {
                status: 200,
                content_type: content_type.to_string(),
                body: Arc::new(body),
            })
        }
        JobKind::Fleet { fc } => {
            // Same post-hoc facility replay + aggregation the sharded
            // CLI path performs; the document carries no shard or wall
            // fields, so the assembled run serializes byte-equal to
            // `idatacool fleet --json`.
            let facility = run_facility(
                &runs,
                FacilityParams::from_plant(&fc.base.pp, fc.n_plants),
            );
            // The sweep guarantees a quarantine-free chunk (see above),
            // so the aggregate's quarantined section is always empty on
            // this path — batched bodies stay byte-equal to solo ones.
            let aggregate = FleetAggregate::build(&runs, &facility,
                                                  Vec::new());
            let run = FleetRun {
                plants: runs,
                facility,
                aggregate,
                shards: fc.shards,
                wall_s: 0.0,
            };
            Ok(CachedResponse {
                status: 200,
                content_type: "application/json".to_string(),
                body: Arc::new(run.to_json(&fc).into_bytes()),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn base() -> SimConfig {
        let mut cfg = SimConfig::test_small();
        cfg.duration_s = 60.0;
        cfg.backend = "native".into();
        cfg
    }

    fn sim_job(seed: u64) -> BatchJob {
        let mut cfg = base();
        cfg.seed = seed;
        BatchJob::sim(SimRequest { cfg, sample_every: 1 }, false).unwrap()
    }

    #[test]
    fn jobs_group_by_tick_grid_and_chunk_by_plant_budget() {
        // Rounds sweep real fleets; keep chaos plans armed by other
        // tests in this binary from firing mid-round.
        let _guard = crate::resilience::inject::test_lock();
        let b = Batcher::new(Duration::from_millis(0), 2);
        // 3 one-plant jobs with a budget of 2: the round must answer
        // all of them, as one chunk of 2 and one of 1.
        let pending: Vec<Pending> = (1..=3u64)
            .map(|seed| Pending {
                job: sim_job(seed),
                slot: Arc::new(Slot::new()),
                enqueued: Instant::now(),
            })
            .collect();
        let slots: Vec<_> = pending.iter().map(|p| p.slot.clone()).collect();
        b.run_round(pending);
        let mut occupancies: Vec<usize> =
            slots.iter().map(|s| s.wait().1).collect();
        occupancies.sort_unstable();
        assert_eq!(occupancies, vec![1, 2, 2]);
        for slot in &slots {
            assert_eq!(slot.wait().0.unwrap().status, 200);
        }
    }

    #[test]
    fn oversized_job_forms_its_own_chunk() {
        let _guard = crate::resilience::inject::test_lock();
        let b = Batcher::new(Duration::from_millis(0), 1);
        let fc = FleetConfig {
            n_plants: 3,
            shards: 1,
            base: base(),
            fleet_seed: 7,
            scenario: crate::fleet::scenario::Scenario::by_name("baseline")
                .unwrap(),
            megabatch: false,
        };
        let job = BatchJob::fleet(fc).unwrap();
        assert_eq!(job.plants(), 3);
        let slot = Arc::new(Slot::new());
        b.run_round(vec![Pending {
            job,
            slot: slot.clone(),
            enqueued: Instant::now(),
        }]);
        let (result, occupancy) = slot.wait();
        assert_eq!(occupancy, 3);
        assert_eq!(result.unwrap().status, 200);
    }

    #[test]
    fn submit_window_collects_concurrent_jobs() {
        let _guard = crate::resilience::inject::test_lock();
        let b = Arc::new(Batcher::new(Duration::from_millis(150), 16));
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for seed in 1..=3u64 {
                let b = b.clone();
                joins.push(s.spawn(move || {
                    b.submit(sim_job(seed), None).unwrap()
                }));
            }
            let results: Vec<(CachedResponse, usize)> =
                joins.into_iter().map(|j| j.join().unwrap()).collect();
            // All three landed inside one 150 ms window on one arena.
            for (resp, occupancy) in &results {
                assert_eq!(resp.status, 200);
                assert_eq!(*occupancy, 3);
            }
            // Distinct seeds ⇒ distinct bodies.
            assert_ne!(results[0].0.body, results[1].0.body);
        });
    }

    #[test]
    fn follower_deadline_answers_504() {
        let b = Batcher::new(Duration::from_millis(5), 16);
        // Pose as a stuck round leader so the submit below follows —
        // and nobody ever publishes its slot within the budget.
        b.round.lock().unwrap().collecting = true;
        let (resp, n) =
            b.submit(sim_job(1), Some(Duration::from_millis(30))).unwrap();
        assert_eq!(resp.status, 504);
        assert_eq!(n, 0);
    }

    #[test]
    fn mixed_tick_grids_fall_back_per_group() {
        // 60 s and 120 s jobs must not lockstep together; both still
        // answer correctly via separate groups.
        let _guard = crate::resilience::inject::test_lock();
        let b = Batcher::new(Duration::from_millis(0), 16);
        let mut long = base();
        long.duration_s = 120.0;
        long.seed = 9;
        let jobs = vec![
            sim_job(1),
            BatchJob::sim(SimRequest { cfg: long, sample_every: 1 }, false)
                .unwrap(),
        ];
        let ticks: Vec<u64> = jobs.iter().map(|j| j.ticks).collect();
        assert_ne!(ticks[0], ticks[1]);
        let pending: Vec<Pending> = jobs
            .into_iter()
            .map(|job| Pending {
                job,
                slot: Arc::new(Slot::new()),
                enqueued: Instant::now(),
            })
            .collect();
        let slots: Vec<_> = pending.iter().map(|p| p.slot.clone()).collect();
        b.run_round(pending);
        for slot in &slots {
            let (result, occupancy) = slot.wait();
            assert_eq!(occupancy, 1);
            assert_eq!(result.unwrap().status, 200);
        }
    }
}
