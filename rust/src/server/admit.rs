//! Cost-aware admission control for the serve path (DESIGN.md §10).
//!
//! Three cooperating mechanisms, all execution-shape only — none of
//! them ever reaches a response body or a cache key:
//!
//! 1. A **token bucket** rate limiter denominated in the same cost
//!    units as [`super::api::ApiRequest::cost_estimate`] (nominal
//!    ticks × plants). `[serve] rate_limit` sets the refill rate in
//!    cost units per second; the burst capacity is four seconds of
//!    refill. `0` (the default) disables the bucket entirely.
//!
//! 2. A **degradation ladder** — healthy → degraded → saturated —
//!    derived from live signals (queue depth, live worker count,
//!    breaker state). Saturated sheds everything with 503; degraded
//!    sheds expensive requests with 429 so cheap traffic keeps
//!    flowing. "Cheapest-first" means the refusal itself is cheap:
//!    the 429/503 verdict is computed from the already-parsed request
//!    before any simulation work starts.
//!
//! 3. A per-endpoint-class **circuit breaker** (rolling outcome
//!    window, open → half-open probe → close) so a poisoned endpoint
//!    fails fast instead of burning workers.
//!
//! Every refusal carries the standard `idatacool-error/1` envelope and
//! a *computed* `Retry-After` (see [`retry_after_secs`]).

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::api::EndpointKind;

/// Requests costlier than this (in nominal tick × plant units) are
/// shed with 429 while the ladder reports `Degraded`. At the 5 s
/// nominal tick this admits e.g. a 4-plant fleet over ~21 minutes but
/// refuses wide sweeps until the server recovers.
pub const DEGRADED_COST_CAP: f64 = 1024.0;

/// Token-bucket burst capacity, in seconds of refill.
pub const BUCKET_BURST_S: f64 = 4.0;

/// Rolling outcome window per breaker class.
pub const BREAKER_WINDOW: usize = 16;

/// Failures inside the window that trip the breaker open.
pub const BREAKER_OPEN_FAILS: usize = 5;

/// How long an open breaker fails fast before allowing one probe.
pub const BREAKER_OPEN_FOR: Duration = Duration::from_secs(1);

/// Upper clamp for computed `Retry-After` values, seconds.
pub const RETRY_AFTER_MAX_S: u64 = 30;

/// Pure refill/consume model of the token bucket. Kept free of clocks
/// and locks so the property test in `tests/proptests.rs` can drive it
/// through arbitrary advance/consume interleavings.
#[derive(Clone, Debug)]
pub struct Bucket {
    cap: f64,
    rate: f64,
    tokens: f64,
}

impl Bucket {
    /// A full bucket holding `cap` tokens, refilling at `rate` per
    /// second. Both must be positive and finite.
    pub fn new(cap: f64, rate: f64) -> Bucket {
        assert!(cap > 0.0 && cap.is_finite(), "bucket cap must be positive");
        assert!(rate > 0.0 && rate.is_finite(), "bucket rate must be positive");
        Bucket { cap, rate, tokens: cap }
    }

    /// Advance time by `dt_s` seconds, refilling up to the cap.
    pub fn advance(&mut self, dt_s: f64) {
        let dt = dt_s.max(0.0);
        self.tokens = (self.tokens + dt * self.rate).min(self.cap);
    }

    /// Take `cost` tokens if available; `false` leaves the bucket
    /// untouched.
    pub fn try_consume(&mut self, cost: f64) -> bool {
        let cost = cost.max(0.0);
        if cost <= self.tokens {
            self.tokens -= cost;
            true
        } else {
            false
        }
    }

    /// Seconds until `cost` tokens will be available at the current
    /// refill rate (0 when available now). Costs above the burst cap
    /// are clamped to the cap: the caller gets the soonest time the
    /// bucket could possibly grant, not infinity.
    pub fn eta_s(&self, cost: f64) -> f64 {
        let need = cost.clamp(0.0, self.cap) - self.tokens;
        (need / self.rate).max(0.0)
    }

    pub fn tokens(&self) -> f64 {
        self.tokens
    }

    pub fn cap(&self) -> f64 {
        self.cap
    }
}

/// Clock-coupled wrapper: one mutex holds the model plus the instant
/// it was last advanced, so concurrent workers see a consistent
/// refill.
pub struct TokenBucket {
    inner: Mutex<(Bucket, Instant)>,
}

impl TokenBucket {
    /// `rate` cost units per second, burst of [`BUCKET_BURST_S`]
    /// seconds.
    pub fn new(rate: f64) -> TokenBucket {
        TokenBucket {
            inner: Mutex::new((Bucket::new(rate * BUCKET_BURST_S, rate), Instant::now())),
        }
    }

    /// Try to admit a request of `cost`; `Err` carries the seconds
    /// until the bucket could grant it.
    pub fn try_take(&self, cost: f64) -> Result<(), f64> {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        let dt = now.duration_since(g.1).as_secs_f64();
        g.0.advance(dt);
        g.1 = now;
        if g.0.try_consume(cost) {
            Ok(())
        } else {
            Err(g.0.eta_s(cost))
        }
    }
}

/// Circuit-breaker state, surfaced verbatim in the health document.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half_open",
        }
    }
}

struct BreakerInner {
    /// Rolling outcome window, `true` = failure (5xx, incl. 504).
    window: VecDeque<bool>,
    state: BreakerState,
    opened_at: Option<Instant>,
    /// A half-open probe is in flight; further admits fail fast until
    /// its outcome is recorded.
    probing: bool,
}

/// One breaker per endpoint class. `admit` gates entry, `record`
/// feeds the rolling window with the request's outcome.
pub struct Breaker {
    inner: Mutex<BreakerInner>,
    open_for: Duration,
}

impl Breaker {
    pub fn new(open_for: Duration) -> Breaker {
        Breaker {
            inner: Mutex::new(BreakerInner {
                window: VecDeque::with_capacity(BREAKER_WINDOW),
                state: BreakerState::Closed,
                opened_at: None,
                probing: false,
            }),
            open_for,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.inner.lock().unwrap().state
    }

    /// Gate a request. `Err(secs)` means fail fast, with the seconds
    /// until the next half-open probe slot. An `Ok` while half-open
    /// marks this caller as the probe.
    pub fn admit(&self) -> Result<(), f64> {
        let mut g = self.inner.lock().unwrap();
        match g.state {
            BreakerState::Closed => Ok(()),
            BreakerState::Open => {
                let elapsed = g.opened_at.map(|t| t.elapsed()).unwrap_or(self.open_for);
                if elapsed >= self.open_for {
                    g.state = BreakerState::HalfOpen;
                    g.probing = true;
                    Ok(())
                } else {
                    Err((self.open_for - elapsed).as_secs_f64())
                }
            }
            BreakerState::HalfOpen => {
                if g.probing {
                    Err(self.open_for.as_secs_f64())
                } else {
                    g.probing = true;
                    Ok(())
                }
            }
        }
    }

    /// Record an admitted request's outcome (`failure` = status ≥ 500).
    pub fn record(&self, failure: bool) {
        let mut g = self.inner.lock().unwrap();
        match g.state {
            BreakerState::HalfOpen => {
                g.probing = false;
                g.window.clear();
                if failure {
                    g.state = BreakerState::Open;
                    g.opened_at = Some(Instant::now());
                } else {
                    g.state = BreakerState::Closed;
                    g.opened_at = None;
                }
            }
            BreakerState::Closed => {
                if g.window.len() == BREAKER_WINDOW {
                    g.window.pop_front();
                }
                g.window.push_back(failure);
                let fails = g.window.iter().filter(|&&f| f).count();
                if fails >= BREAKER_OPEN_FAILS {
                    g.state = BreakerState::Open;
                    g.opened_at = Some(Instant::now());
                    g.window.clear();
                }
            }
            // Stragglers admitted before the trip: their outcome is
            // stale, the open timer already owns the decision.
            BreakerState::Open => {}
        }
    }

    /// Seconds left before an open breaker allows a probe (0 when not
    /// open).
    pub fn open_remaining_s(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        match (g.state, g.opened_at) {
            (BreakerState::Open, Some(t)) => {
                (self.open_for.as_secs_f64() - t.elapsed().as_secs_f64()).max(0.0)
            }
            _ => 0.0,
        }
    }
}

/// The degradation ladder. Ordering matters: `Saturated` wins over
/// `Degraded` wins over `Healthy`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    Healthy,
    Degraded,
    Saturated,
}

impl Health {
    pub fn name(self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Degraded => "degraded",
            Health::Saturated => "saturated",
        }
    }
}

/// Derive the ladder state from live signals: a full queue or a dead
/// pool is saturated; a half-full queue, a shrunken pool, or breaker
/// trouble (any class open or half-open) is degraded.
pub fn ladder(queue_len: usize, queue_cap: usize, live_workers: usize,
              configured_workers: usize, breaker_trouble: bool) -> Health {
    if live_workers == 0 || queue_len >= queue_cap {
        Health::Saturated
    } else if breaker_trouble
        || live_workers < configured_workers
        || queue_len * 2 >= queue_cap
    {
        Health::Degraded
    } else {
        Health::Healthy
    }
}

/// Compute `Retry-After` from what the server actually knows: the
/// queue backlog per live worker plus any breaker open-time, clamped
/// to `[1, RETRY_AFTER_MAX_S]` seconds. Headers only — never bodies.
pub fn retry_after_secs(queue_len: usize, workers: usize,
                        breaker_remaining_s: f64) -> u64 {
    let backlog = 1 + (queue_len / workers.max(1)) as u64;
    backlog
        .max(breaker_remaining_s.ceil() as u64)
        .clamp(1, RETRY_AFTER_MAX_S)
}

/// An admission verdict: either proceed to compute, or shed now with
/// this status / message / retry hint.
pub enum Verdict {
    Admit,
    Shed {
        status: u16,
        retry_after_s: u64,
        msg: String,
    },
}

/// The server's admission state: one optional token bucket plus one
/// breaker per compute endpoint class.
pub struct Admission {
    bucket: Option<TokenBucket>,
    breakers: [Breaker; 4],
}

fn class_index(kind: EndpointKind) -> usize {
    match kind {
        EndpointKind::Simulate => 0,
        EndpointKind::Fleet => 1,
        EndpointKind::Sweep => 2,
        EndpointKind::Optimize => 3,
    }
}

pub const CLASS_NAMES: [&str; 4] = ["simulate", "fleet", "sweep", "optimize"];

impl Admission {
    /// `rate_limit` in cost units per second; 0 disables the bucket.
    pub fn new(rate_limit: usize) -> Admission {
        Admission {
            bucket: (rate_limit > 0).then(|| TokenBucket::new(rate_limit as f64)),
            breakers: std::array::from_fn(|_| Breaker::new(BREAKER_OPEN_FOR)),
        }
    }

    pub fn breaker(&self, kind: EndpointKind) -> &Breaker {
        &self.breakers[class_index(kind)]
    }

    /// Breaker states by class, for the health document.
    pub fn breaker_states(&self) -> [(&'static str, BreakerState); 4] {
        std::array::from_fn(|i| (CLASS_NAMES[i], self.breakers[i].state()))
    }

    /// Any class open or half-open — feeds the ladder.
    pub fn breaker_trouble(&self) -> bool {
        self.breakers.iter().any(|b| b.state() != BreakerState::Closed)
    }

    /// Largest remaining open-time across classes — feeds Retry-After.
    pub fn max_open_remaining_s(&self) -> f64 {
        self.breakers
            .iter()
            .map(|b| b.open_remaining_s())
            .fold(0.0, f64::max)
    }

    /// The ladder + bucket decision for one parsed request of `cost`.
    /// The breaker gate is separate (`breaker(kind).admit()`) because
    /// its outcome must be recorded per class after compute.
    pub fn check(&self, health: Health, cost: f64, queue_len: usize,
                 workers: usize) -> Verdict {
        match health {
            Health::Saturated => Verdict::Shed {
                status: 503,
                retry_after_s: retry_after_secs(queue_len, workers,
                                                self.max_open_remaining_s()),
                msg: "server saturated (queue full or no live workers)"
                    .to_string(),
            },
            Health::Degraded if cost > DEGRADED_COST_CAP => Verdict::Shed {
                status: 429,
                retry_after_s: retry_after_secs(queue_len, workers,
                                                self.max_open_remaining_s()),
                msg: format!(
                    "server degraded; request cost {cost:.0} exceeds the \
                     degraded admission cap {DEGRADED_COST_CAP:.0}"
                ),
            },
            _ => match &self.bucket {
                Some(b) => match b.try_take(cost) {
                    Ok(()) => Verdict::Admit,
                    Err(eta_s) => Verdict::Shed {
                        status: 429,
                        retry_after_s: (eta_s.ceil() as u64)
                            .clamp(1, RETRY_AFTER_MAX_S),
                        msg: format!(
                            "rate limit exceeded for request cost {cost:.0}"
                        ),
                    },
                },
                None => Verdict::Admit,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_refills_to_cap_and_consumes_exactly() {
        let mut b = Bucket::new(100.0, 10.0);
        assert!(b.try_consume(100.0));
        assert!(!b.try_consume(0.5));
        b.advance(5.0);
        assert!((b.tokens() - 50.0).abs() < 1e-9);
        b.advance(100.0);
        assert!((b.tokens() - 100.0).abs() < 1e-9, "refill clamps at cap");
        // eta: need 30 more than the 100 available → 0; drain first.
        assert!(b.try_consume(70.0));
        assert!((b.eta_s(50.0) - 2.0).abs() < 1e-9);
        assert_eq!(b.eta_s(10.0), 0.0);
    }

    #[test]
    fn bucket_eta_clamps_oversized_costs_to_the_cap() {
        let mut b = Bucket::new(40.0, 10.0);
        assert!(b.try_consume(40.0));
        // A cost above the cap can never be granted outright; the eta
        // answers "when is the bucket as full as it can get".
        assert!((b.eta_s(1e9) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn breaker_opens_after_window_failures_then_probe_closes() {
        let b = Breaker::new(Duration::from_millis(10));
        assert_eq!(b.state(), BreakerState::Closed);
        for _ in 0..BREAKER_OPEN_FAILS {
            assert!(b.admit().is_ok());
            b.record(true);
        }
        assert_eq!(b.state(), BreakerState::Open);
        let err = b.admit().unwrap_err();
        assert!(err > 0.0 && err <= 0.010 + 1e-3, "remaining {err}");

        std::thread::sleep(Duration::from_millis(20));
        // First caller after the open window becomes the probe…
        assert!(b.admit().is_ok());
        assert_eq!(b.state(), BreakerState::HalfOpen);
        // …and everyone else still fails fast until it reports.
        assert!(b.admit().is_err());
        b.record(false);
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.admit().is_ok());
    }

    #[test]
    fn breaker_failed_probe_reopens() {
        let b = Breaker::new(Duration::from_millis(5));
        for _ in 0..BREAKER_OPEN_FAILS {
            b.admit().unwrap();
            b.record(true);
        }
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(10));
        assert!(b.admit().is_ok(), "probe slot");
        b.record(true);
        assert_eq!(b.state(), BreakerState::Open, "failed probe re-opens");
        assert!(b.admit().is_err(), "open again fails fast");
        // A fresh open window + successful probe recovers fully.
        std::thread::sleep(Duration::from_millis(10));
        assert!(b.admit().is_ok());
        b.record(false);
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn breaker_mixed_outcomes_below_threshold_stay_closed() {
        let b = Breaker::new(Duration::from_millis(5));
        for i in 0..3 * BREAKER_WINDOW {
            b.admit().unwrap();
            // 1 failure per 4 outcomes: never ≥ BREAKER_OPEN_FAILS in
            // any 16-outcome window.
            b.record(i % 4 == 0);
        }
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn ladder_orders_saturated_over_degraded_over_healthy() {
        use Health::*;
        assert_eq!(ladder(0, 8, 4, 4, false), Healthy);
        assert_eq!(ladder(4, 8, 4, 4, false), Degraded, "half-full queue");
        assert_eq!(ladder(0, 8, 3, 4, false), Degraded, "shrunken pool");
        assert_eq!(ladder(0, 8, 4, 4, true), Degraded, "breaker trouble");
        assert_eq!(ladder(8, 8, 4, 4, false), Saturated, "full queue");
        assert_eq!(ladder(0, 8, 0, 4, false), Saturated, "dead pool");
    }

    #[test]
    fn retry_after_scales_with_backlog_and_clamps() {
        assert_eq!(retry_after_secs(0, 4, 0.0), 1);
        assert_eq!(retry_after_secs(8, 4, 0.0), 3);
        assert_eq!(retry_after_secs(8, 0, 0.0), 9, "worker floor of 1");
        assert_eq!(retry_after_secs(0, 4, 2.3), 3, "breaker remaining wins");
        assert_eq!(retry_after_secs(10_000, 1, 0.0), RETRY_AFTER_MAX_S);
    }

    #[test]
    fn admission_sheds_by_ladder_state() {
        let a = Admission::new(0);
        match a.check(Health::Saturated, 1.0, 8, 2) {
            Verdict::Shed { status, retry_after_s, .. } => {
                assert_eq!(status, 503);
                assert!(retry_after_s >= 1);
            }
            Verdict::Admit => panic!("saturated must shed"),
        }
        match a.check(Health::Degraded, DEGRADED_COST_CAP + 1.0, 0, 2) {
            Verdict::Shed { status, .. } => assert_eq!(status, 429),
            Verdict::Admit => panic!("expensive request must shed degraded"),
        }
        assert!(matches!(a.check(Health::Degraded, 10.0, 0, 2),
                         Verdict::Admit),
                "cheap request flows while degraded");
        assert!(matches!(a.check(Health::Healthy, 1e9, 0, 2),
                         Verdict::Admit),
                "no bucket → no rate shed");
    }

    #[test]
    fn admission_bucket_rejects_with_computed_eta() {
        let a = Admission::new(10); // cap 40, refill 10/s
        assert!(matches!(a.check(Health::Healthy, 40.0, 0, 2),
                         Verdict::Admit));
        match a.check(Health::Healthy, 40.0, 0, 2) {
            Verdict::Shed { status, retry_after_s, .. } => {
                assert_eq!(status, 429);
                assert!((1..=4).contains(&retry_after_s),
                        "eta ≈ 4 s, got {retry_after_s}");
            }
            Verdict::Admit => panic!("drained bucket must 429"),
        }
    }
}
