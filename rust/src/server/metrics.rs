//! Serving metrics: request/status/cache counters plus a latency
//! histogram, rendered as the `GET /metrics` JSON document and as
//! Prometheus text exposition (`GET /metrics?format=prometheus`).
//!
//! Built on the `obs::metrics` registry: every counter is a lock-free
//! atomic, and latency is recorded into **per-worker** histogram shards
//! (`ShardedHistogram`) merged only at scrape time — the request hot
//! path never takes a lock. Latency is stored as log10(milliseconds)
//! over 1 us .. 100 s: uniform bins in log space resolve both a 40 us
//! cache hit and a 4 s fleet run; the p50/p99 the endpoint reports come
//! from `Histogram::quantile`, mapped back to milliseconds.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::obs::metrics::{Counter, CounterVec, Gauge, Registry, ShardedHistogram};
use crate::stats::histogram::Histogram;
use crate::util::json::{Json, JsonBuilder};

/// Endpoint labels, in the order the counters are kept.
pub const ENDPOINTS: &[&str] = &[
    "simulate", "fleet", "sweep", "optimize", "healthz", "metrics",
    "shutdown", "other",
];

/// Map a request path to its counter index (`other` catches the rest).
/// The match returns the index directly — no catalog scan per request.
/// `/v1/...` and the deprecated unprefixed aliases count into the same
/// bucket: the version prefix is routing surface, not traffic shape.
pub fn endpoint_index(path: &str) -> usize {
    let path = match path.strip_prefix("/v1") {
        Some(rest) if rest.starts_with('/') => rest,
        _ => path,
    };
    match path {
        "/simulate" => 0,
        "/fleet" => 1,
        "/sweep" => 2,
        "/optimize" => 3,
        "/healthz" => 4,
        "/metrics" => 5,
        "/shutdown" => 6,
        _ => 7,
    }
}

pub struct Metrics {
    registry: Registry,
    requests: Arc<Counter>,
    by_endpoint: Arc<CounterVec>,
    status_2xx: Arc<Counter>,
    status_4xx: Arc<Counter>,
    status_5xx: Arc<Counter>,
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    coalesced: Arc<Counter>,
    cache_evictions: Arc<Counter>,
    shed: Arc<Counter>,
    rate_limited: Arc<Counter>,
    queue_high_water: Arc<Gauge>,
    /// log10(latency [ms]) over [-3, 5): 1 us .. 100 s, 160 bins,
    /// one shard per worker.
    latency_log_ms: Arc<ShardedHistogram>,
}

impl Metrics {
    /// `workers` sizes the latency histogram's shard set (one lock-free
    /// shard per worker thread).
    pub fn new(workers: usize) -> Self {
        let r = Registry::new();
        let requests =
            r.counter("idatacool_requests_total", "Requests handled");
        let by_endpoint = r.counter_vec(
            "idatacool_requests_by_endpoint_total",
            "Requests handled, by endpoint",
            "endpoint",
            ENDPOINTS,
        );
        let status_2xx =
            r.counter("idatacool_status_2xx_total", "2xx responses");
        let status_4xx =
            r.counter("idatacool_status_4xx_total", "4xx responses");
        let status_5xx =
            r.counter("idatacool_status_5xx_total", "5xx responses");
        let cache_hits =
            r.counter("idatacool_cache_hits_total", "Response cache hits");
        let cache_misses =
            r.counter("idatacool_cache_misses_total", "Response cache misses");
        let coalesced = r.counter(
            "idatacool_coalesced_total",
            "Requests served by waiting on an identical in-flight compute",
        );
        let cache_evictions = r.counter(
            "idatacool_cache_evictions_total",
            "LRU response-cache evictions",
        );
        let shed = r.counter(
            "idatacool_shed_total",
            "Requests shed with 503 (queue full, saturated, or breaker \
             open)",
        );
        let rate_limited = r.counter(
            "idatacool_rate_limited_total",
            "Requests shed with 429 by cost-aware admission control",
        );
        let queue_high_water = r.gauge(
            "idatacool_queue_depth_high_water",
            "Deepest the job queue has ever been",
        );
        let latency_log_ms = r.histogram(
            "idatacool_request_latency_ms",
            "Request latency [ms] (log10-binned, per-worker shards)",
            -3.0,
            5.0,
            160,
            workers.max(1),
            true,
        );
        // Touch the process-global sim-domain counters and the batching
        // histograms so a scrape renders them (at zero) even before any
        // traced run or batched sweep.
        let _ = crate::obs::metrics::throttle_events();
        let _ = crate::obs::metrics::lane_sync_transitions();
        let _ = crate::obs::metrics::batch_occupancy();
        let _ = crate::obs::metrics::batch_window_wait_ms();
        let _ = crate::obs::metrics::worker_restarts();
        let _ = crate::obs::metrics::deadline_drops();
        Metrics {
            registry: r,
            requests,
            by_endpoint,
            status_2xx,
            status_4xx,
            status_5xx,
            cache_hits,
            cache_misses,
            coalesced,
            cache_evictions,
            shed,
            rate_limited,
            queue_high_water,
            latency_log_ms,
        }
    }

    /// Record one finished request on `worker`'s histogram shard.
    pub fn record(&self, endpoint: usize, status: u16, latency_s: f64,
                  worker: usize) {
        self.requests.inc();
        self.by_endpoint.inc(endpoint);
        match status {
            200..=299 => self.status_2xx.inc(),
            400..=499 => self.status_4xx.inc(),
            _ => self.status_5xx.inc(),
        };
        let ms = (latency_s * 1e3).max(1e-9);
        self.latency_log_ms.push(worker, ms.log10());
    }

    pub fn cache_hit(&self) {
        self.cache_hits.inc();
    }

    pub fn cache_miss(&self) {
        self.cache_misses.inc();
    }

    pub fn coalesce(&self) {
        self.coalesced.inc();
    }

    pub fn cache_evicted(&self) {
        self.cache_evictions.inc();
    }

    pub fn shed(&self) {
        self.shed.inc();
    }

    pub fn rate_limited(&self) {
        self.rate_limited.inc();
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.get()
    }

    pub fn rate_limited_count(&self) -> u64 {
        self.rate_limited.get()
    }

    /// Refresh the queue-depth high-water gauge (called at scrape).
    pub fn set_queue_high_water(&self, v: u64) {
        self.queue_high_water.record_max(v);
    }

    pub fn cache_hit_count(&self) -> u64 {
        self.cache_hits.get()
    }

    pub fn cache_miss_count(&self) -> u64 {
        self.cache_misses.get()
    }

    /// The `GET /metrics` JSON document.
    pub fn to_json_value(
        &self,
        cache_entries: usize,
        cache_cap: usize,
        workers: usize,
        uptime_s: f64,
    ) -> Json {
        let h = self.latency_log_ms.merged();
        let by: BTreeMap<String, Json> = ENDPOINTS
            .iter()
            .enumerate()
            .map(|(i, n)| {
                (n.to_string(), Json::Num(self.by_endpoint.get(i) as f64))
            })
            .collect();
        JsonBuilder::new()
            .str("schema", "idatacool-serve/1")
            .num("requests_total", self.requests.get() as f64)
            .set("by_endpoint", Json::Obj(by))
            .set(
                "status",
                JsonBuilder::new()
                    .num("s2xx", self.status_2xx.get() as f64)
                    .num("s4xx", self.status_4xx.get() as f64)
                    .num("s5xx", self.status_5xx.get() as f64)
                    .build(),
            )
            .set(
                "cache",
                JsonBuilder::new()
                    .num("hits", self.cache_hits.get() as f64)
                    .num("misses", self.cache_misses.get() as f64)
                    .num("coalesced", self.coalesced.get() as f64)
                    .num("evictions", self.cache_evictions.get() as f64)
                    .num("entries", cache_entries as f64)
                    .num("capacity", cache_cap as f64)
                    .build(),
            )
            .set(
                "queue",
                JsonBuilder::new()
                    .num("shed", self.shed.get() as f64)
                    .num("rate_limited", self.rate_limited.get() as f64)
                    .num(
                        "deadline_drops",
                        crate::obs::metrics::deadline_drops().get() as f64,
                    )
                    .num(
                        "worker_restarts",
                        crate::obs::metrics::worker_restarts().get() as f64,
                    )
                    .num(
                        "depth_high_water",
                        self.queue_high_water.get() as f64,
                    )
                    .build(),
            )
            .set(
                "latency_ms",
                JsonBuilder::new()
                    .num("count", h.total as f64)
                    .num("p50", quantile_ms(&h, 0.50))
                    .num("p99", quantile_ms(&h, 0.99))
                    .build(),
            )
            .set("batch", batch_json())
            .num("workers", workers as f64)
            .num("uptime_s", uptime_s)
            .build()
    }

    /// Prometheus text exposition: every registered serving metric,
    /// scrape-time gauges (cache occupancy, workers, uptime), and the
    /// process-global sim-domain counters.
    pub fn to_prometheus(
        &self,
        cache_entries: usize,
        cache_cap: usize,
        workers: usize,
        uptime_s: f64,
    ) -> String {
        let mut out = self.registry.to_prometheus();
        let gauge = |out: &mut String, name: &str, help: &str, v: f64| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"
            ));
        };
        gauge(&mut out, "idatacool_cache_entries",
              "Response cache occupancy", cache_entries as f64);
        gauge(&mut out, "idatacool_cache_capacity",
              "Response cache capacity", cache_cap as f64);
        gauge(&mut out, "idatacool_workers", "Worker threads",
              workers as f64);
        gauge(&mut out, "idatacool_uptime_seconds",
              "Seconds since the server started", uptime_s);
        out.push_str(&crate::obs::metrics::global().to_prometheus());
        out
    }
}

/// A latency quantile back in milliseconds (0 when nothing recorded).
///
/// NaN convention (DESIGN.md §8): `Histogram::quantile` signals "no
/// samples" with NaN; serialization boundaries map it to the inert
/// in-range value (0 here) so NaN never reaches a JSON document.
fn quantile_ms(h: &Histogram, q: f64) -> f64 {
    let lg = h.quantile(q);
    if lg.is_nan() {
        0.0
    } else {
        10f64.powf(lg)
    }
}

/// A quantile of a linear histogram (0 when nothing recorded). Same
/// NaN-at-the-boundary convention as [`quantile_ms`].
fn quantile_or_zero(h: &Histogram, q: f64) -> f64 {
    let v = h.quantile(q);
    if v.is_nan() {
        0.0
    } else {
        v
    }
}

/// The `batch` section of the JSON document — continuous-batching
/// occupancy and admission-window wait, read from the process-global
/// histograms the `Batcher` pushes into (`obs::metrics`). They also
/// reach the Prometheus exposition via the global-registry append in
/// `to_prometheus`.
fn batch_json() -> Json {
    let occ = crate::obs::metrics::batch_occupancy().merged();
    let wait = crate::obs::metrics::batch_window_wait_ms().merged();
    JsonBuilder::new()
        .num("sweeps", occ.total as f64)
        .num("occupancy_p50", quantile_or_zero(&occ, 0.50))
        .num("occupancy_p99", quantile_or_zero(&occ, 0.99))
        .num("window_wait_ms_p50", quantile_ms(&wait, 0.50))
        .num("window_wait_ms_p99", quantile_ms(&wait, 0.99))
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_indices_cover_catalog() {
        assert_eq!(ENDPOINTS[endpoint_index("/simulate")], "simulate");
        assert_eq!(ENDPOINTS[endpoint_index("/fleet")], "fleet");
        assert_eq!(ENDPOINTS[endpoint_index("/sweep")], "sweep");
        assert_eq!(ENDPOINTS[endpoint_index("/optimize")], "optimize");
        assert_eq!(ENDPOINTS[endpoint_index("/v1/optimize")], "optimize");
        assert_eq!(ENDPOINTS[endpoint_index("/healthz")], "healthz");
        assert_eq!(ENDPOINTS[endpoint_index("/metrics")], "metrics");
        assert_eq!(ENDPOINTS[endpoint_index("/shutdown")], "shutdown");
        assert_eq!(ENDPOINTS[endpoint_index("/nope")], "other");
        // The v1 prefix maps to the same buckets as the legacy alias.
        assert_eq!(ENDPOINTS[endpoint_index("/v1/simulate")], "simulate");
        assert_eq!(ENDPOINTS[endpoint_index("/v1/metrics")], "metrics");
        assert_eq!(ENDPOINTS[endpoint_index("/v1/nope")], "other");
        // "/v12" is not a version prefix.
        assert_eq!(ENDPOINTS[endpoint_index("/v12/simulate")], "other");
    }

    #[test]
    fn counters_render() {
        let m = Metrics::new(4);
        m.record(endpoint_index("/simulate"), 200, 0.010, 0);
        m.record(endpoint_index("/simulate"), 200, 0.012, 1);
        m.record(endpoint_index("/fleet"), 400, 0.001, 2);
        m.cache_hit();
        m.cache_miss();
        m.coalesce();
        m.cache_evicted();
        m.shed();
        m.rate_limited();
        m.set_queue_high_water(5);
        let j = m.to_json_value(3, 64, 4, 1.5);
        assert_eq!(j.get("requests_total").unwrap().as_f64(), Some(3.0));
        let by = j.get("by_endpoint").unwrap();
        assert_eq!(by.get("simulate").unwrap().as_f64(), Some(2.0));
        assert_eq!(by.get("fleet").unwrap().as_f64(), Some(1.0));
        let st = j.get("status").unwrap();
        assert_eq!(st.get("s2xx").unwrap().as_f64(), Some(2.0));
        assert_eq!(st.get("s4xx").unwrap().as_f64(), Some(1.0));
        let c = j.get("cache").unwrap();
        assert_eq!(c.get("hits").unwrap().as_f64(), Some(1.0));
        assert_eq!(c.get("evictions").unwrap().as_f64(), Some(1.0));
        assert_eq!(c.get("capacity").unwrap().as_f64(), Some(64.0));
        let q = j.get("queue").unwrap();
        assert_eq!(q.get("shed").unwrap().as_f64(), Some(1.0));
        assert_eq!(q.get("rate_limited").unwrap().as_f64(), Some(1.0));
        // Deadline drops and worker restarts are process-global (other
        // tests may have bumped them) — only presence is asserted.
        assert!(q.get("deadline_drops").unwrap().as_f64().unwrap() >= 0.0);
        assert!(q.get("worker_restarts").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(q.get("depth_high_water").unwrap().as_f64(), Some(5.0));
        let lat = j.get("latency_ms").unwrap();
        assert_eq!(lat.get("count").unwrap().as_f64(), Some(3.0));
        // ~10 ms requests dominate: p50 lands near 10 ms in log space.
        let p50 = lat.get("p50").unwrap().as_f64().unwrap();
        assert!(p50 > 5.0 && p50 < 20.0, "p50 {p50}");
        // The batch section renders (values come from the process-global
        // histograms, so only shape is asserted here).
        let b = j.get("batch").unwrap();
        for field in [
            "sweeps",
            "occupancy_p50",
            "occupancy_p99",
            "window_wait_ms_p50",
            "window_wait_ms_p99",
        ] {
            assert!(b.get(field).unwrap().as_f64().unwrap() >= 0.0);
        }
    }

    #[test]
    fn empty_latency_is_zero_not_nan() {
        let m = Metrics::new(1);
        let j = m.to_json_value(0, 1, 1, 0.0);
        let lat = j.get("latency_ms").unwrap();
        assert_eq!(lat.get("p50").unwrap().as_f64(), Some(0.0));
        assert_eq!(lat.get("p99").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn prometheus_covers_every_json_counter() {
        let m = Metrics::new(2);
        m.record(endpoint_index("/simulate"), 200, 0.010, 0);
        m.cache_hit();
        let text = m.to_prometheus(1, 64, 2, 3.0);
        for name in [
            "idatacool_requests_total",
            "idatacool_requests_by_endpoint_total",
            "idatacool_status_2xx_total",
            "idatacool_status_4xx_total",
            "idatacool_status_5xx_total",
            "idatacool_cache_hits_total",
            "idatacool_cache_misses_total",
            "idatacool_coalesced_total",
            "idatacool_cache_evictions_total",
            "idatacool_shed_total",
            "idatacool_rate_limited_total",
            "idatacool_worker_restarts_total",
            "idatacool_deadline_drops_total",
            "idatacool_queue_depth_high_water",
            "idatacool_request_latency_ms",
            "idatacool_cache_entries",
            "idatacool_cache_capacity",
            "idatacool_workers",
            "idatacool_uptime_seconds",
            "idatacool_throttle_events_total",
            "idatacool_lane_sync_transitions_total",
            "idatacool_batch_occupancy",
            "idatacool_batch_window_wait_ms",
        ] {
            assert!(text.contains(&format!("# TYPE {name} ")),
                    "missing TYPE line for {name}:\n{text}");
        }
        assert!(text.contains("idatacool_requests_total 1\n"));
        assert!(text.contains(
            "idatacool_requests_by_endpoint_total{endpoint=\"simulate\"} 1\n"
        ));
    }
}
