//! Serving metrics: request/status/cache counters plus a latency
//! histogram, rendered as the `GET /metrics` JSON document.
//!
//! Latency is recorded as log10(milliseconds) into a fixed-bin
//! `stats::histogram::Histogram` spanning 1 us .. 100 s — uniform bins
//! in log space resolve both a 40 us cache hit and a 4 s fleet run; the
//! p50/p99 the endpoint reports come from `Histogram::quantile`, mapped
//! back to milliseconds.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::stats::histogram::Histogram;
use crate::util::json::{Json, JsonBuilder};

/// Endpoint labels, in the order the counters are kept.
pub const ENDPOINTS: &[&str] =
    &["simulate", "fleet", "sweep", "healthz", "metrics", "shutdown", "other"];

/// Map a request path to its counter index (`other` catches the rest).
pub fn endpoint_index(path: &str) -> usize {
    let name = match path {
        "/simulate" => "simulate",
        "/fleet" => "fleet",
        "/sweep" => "sweep",
        "/healthz" => "healthz",
        "/metrics" => "metrics",
        "/shutdown" => "shutdown",
        _ => "other",
    };
    ENDPOINTS.iter().position(|e| *e == name).unwrap()
}

pub struct Metrics {
    requests: AtomicU64,
    by_endpoint: Vec<AtomicU64>,
    status_2xx: AtomicU64,
    status_4xx: AtomicU64,
    status_5xx: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    coalesced: AtomicU64,
    /// log10(latency [ms]) over [-3, 5): 1 us .. 100 s, 160 bins.
    latency_log_ms: Mutex<Histogram>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            by_endpoint: (0..ENDPOINTS.len()).map(|_| AtomicU64::new(0)).collect(),
            status_2xx: AtomicU64::new(0),
            status_4xx: AtomicU64::new(0),
            status_5xx: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            latency_log_ms: Mutex::new(Histogram::new(-3.0, 5.0, 160)),
        }
    }

    /// Record one finished request.
    pub fn record(&self, endpoint: usize, status: u16, latency_s: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.by_endpoint[endpoint].fetch_add(1, Ordering::Relaxed);
        match status {
            200..=299 => self.status_2xx.fetch_add(1, Ordering::Relaxed),
            400..=499 => self.status_4xx.fetch_add(1, Ordering::Relaxed),
            _ => self.status_5xx.fetch_add(1, Ordering::Relaxed),
        };
        let ms = (latency_s * 1e3).max(1e-9);
        self.latency_log_ms.lock().unwrap().push(ms.log10());
    }

    pub fn cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn coalesce(&self) {
        self.coalesced.fetch_add(1, Ordering::Relaxed);
    }

    pub fn cache_hit_count(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    pub fn cache_miss_count(&self) -> u64 {
        self.cache_misses.load(Ordering::Relaxed)
    }

    /// The `GET /metrics` document.
    pub fn to_json_value(
        &self,
        cache_entries: usize,
        cache_cap: usize,
        workers: usize,
        uptime_s: f64,
    ) -> Json {
        let h = self.latency_log_ms.lock().unwrap();
        let by: BTreeMap<String, Json> = ENDPOINTS
            .iter()
            .enumerate()
            .map(|(i, n)| {
                (
                    n.to_string(),
                    Json::Num(self.by_endpoint[i].load(Ordering::Relaxed) as f64),
                )
            })
            .collect();
        JsonBuilder::new()
            .str("schema", "idatacool-serve/1")
            .num("requests_total", self.requests.load(Ordering::Relaxed) as f64)
            .set("by_endpoint", Json::Obj(by))
            .set(
                "status",
                JsonBuilder::new()
                    .num("s2xx", self.status_2xx.load(Ordering::Relaxed) as f64)
                    .num("s4xx", self.status_4xx.load(Ordering::Relaxed) as f64)
                    .num("s5xx", self.status_5xx.load(Ordering::Relaxed) as f64)
                    .build(),
            )
            .set(
                "cache",
                JsonBuilder::new()
                    .num("hits", self.cache_hits.load(Ordering::Relaxed) as f64)
                    .num("misses", self.cache_misses.load(Ordering::Relaxed) as f64)
                    .num("coalesced", self.coalesced.load(Ordering::Relaxed) as f64)
                    .num("entries", cache_entries as f64)
                    .num("capacity", cache_cap as f64)
                    .build(),
            )
            .set(
                "latency_ms",
                JsonBuilder::new()
                    .num("count", h.total as f64)
                    .num("p50", quantile_ms(&h, 0.50))
                    .num("p99", quantile_ms(&h, 0.99))
                    .build(),
            )
            .num("workers", workers as f64)
            .num("uptime_s", uptime_s)
            .build()
    }
}

/// A latency quantile back in milliseconds (0 when nothing recorded).
fn quantile_ms(h: &Histogram, q: f64) -> f64 {
    let lg = h.quantile(q);
    if lg.is_nan() {
        0.0
    } else {
        10f64.powf(lg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_indices_cover_catalog() {
        assert_eq!(ENDPOINTS[endpoint_index("/simulate")], "simulate");
        assert_eq!(ENDPOINTS[endpoint_index("/fleet")], "fleet");
        assert_eq!(ENDPOINTS[endpoint_index("/healthz")], "healthz");
        assert_eq!(ENDPOINTS[endpoint_index("/nope")], "other");
    }

    #[test]
    fn counters_render() {
        let m = Metrics::new();
        m.record(endpoint_index("/simulate"), 200, 0.010);
        m.record(endpoint_index("/simulate"), 200, 0.012);
        m.record(endpoint_index("/fleet"), 400, 0.001);
        m.cache_hit();
        m.cache_miss();
        m.coalesce();
        let j = m.to_json_value(3, 64, 4, 1.5);
        assert_eq!(j.get("requests_total").unwrap().as_f64(), Some(3.0));
        let by = j.get("by_endpoint").unwrap();
        assert_eq!(by.get("simulate").unwrap().as_f64(), Some(2.0));
        assert_eq!(by.get("fleet").unwrap().as_f64(), Some(1.0));
        let st = j.get("status").unwrap();
        assert_eq!(st.get("s2xx").unwrap().as_f64(), Some(2.0));
        assert_eq!(st.get("s4xx").unwrap().as_f64(), Some(1.0));
        let c = j.get("cache").unwrap();
        assert_eq!(c.get("hits").unwrap().as_f64(), Some(1.0));
        assert_eq!(c.get("capacity").unwrap().as_f64(), Some(64.0));
        let lat = j.get("latency_ms").unwrap();
        assert_eq!(lat.get("count").unwrap().as_f64(), Some(3.0));
        // ~10 ms requests dominate: p50 lands near 10 ms in log space.
        let p50 = lat.get("p50").unwrap().as_f64().unwrap();
        assert!(p50 > 5.0 && p50 < 20.0, "p50 {p50}");
    }

    #[test]
    fn empty_latency_is_zero_not_nan() {
        let m = Metrics::new();
        let j = m.to_json_value(0, 1, 1, 0.0);
        let lat = j.get("latency_ms").unwrap();
        assert_eq!(lat.get("p50").unwrap().as_f64(), Some(0.0));
        assert_eq!(lat.get("p99").unwrap().as_f64(), Some(0.0));
    }
}
