//! Bounded job queue + `std::thread` worker pool.
//!
//! The accept loop pushes accepted connections; `push` is non-blocking
//! and hands the job back when the queue is full, so the caller can shed
//! load (503) instead of queueing unboundedly. Workers block in `pop`
//! until a job arrives or the queue is closed *and* drained — closing is
//! how the server performs a graceful shutdown: everything already
//! accepted still gets an answer.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

struct State<T> {
    q: VecDeque<T>,
    closed: bool,
    /// Deepest the queue has ever been (tracked under the existing
    /// lock, so the high-water mark costs no extra synchronization).
    high_water: usize,
}

/// A multi-producer multi-consumer FIFO with a hard capacity.
pub struct JobQueue<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    cap: usize,
}

impl<T> JobQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "JobQueue capacity must be at least 1");
        JobQueue {
            state: Mutex::new(State {
                q: VecDeque::with_capacity(cap),
                closed: false,
                high_water: 0,
            }),
            not_empty: Condvar::new(),
            cap,
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue without blocking. `Err(job)` hands the job back when the
    /// queue is full or already closed.
    pub fn push(&self, job: T) -> Result<(), T> {
        let mut s = self.state.lock().unwrap();
        if s.closed || s.q.len() >= self.cap {
            return Err(job);
        }
        s.q.push_back(job);
        s.high_water = s.high_water.max(s.q.len());
        drop(s);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Deepest the queue has ever been (a scrape-time gauge).
    pub fn high_water(&self) -> usize {
        self.state.lock().unwrap().high_water
    }

    /// Dequeue, blocking until a job is available. `None` means the
    /// queue is closed and fully drained — the worker should exit.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(job) = s.q.pop_front() {
                return Some(job);
            }
            if s.closed {
                return None;
            }
            s = self.not_empty.wait(s).unwrap();
        }
    }

    /// Whether `close` has been called. The supervisor's monitor checks
    /// this so a worker that exits during drain is not "dead" — it is
    /// done — and must not be respawned against a closing queue.
    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Close the queue: no further pushes succeed; poppers drain what is
    /// left, then observe `None`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }
}

/// A fixed-size pool of worker threads draining one shared `JobQueue`.
pub struct WorkerPool {
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `n` workers, each running `handler` on every popped job
    /// until the queue closes.
    pub fn spawn<T, F>(n: usize, queue: Arc<JobQueue<T>>, handler: F) -> WorkerPool
    where
        T: Send + 'static,
        F: Fn(T) + Send + Sync + 'static,
    {
        Self::spawn_with(n, queue, |_| (), move |job, _state| handler(job))
    }

    /// `spawn` with per-worker state: `init` runs once on each worker
    /// thread (so the state type need not be `Send`), receives the
    /// worker's index `0..n` (the serve path uses it to address a
    /// per-worker histogram shard), and the resulting value is handed
    /// mutably to every job that worker processes. This is how the
    /// serve path keeps one reusable simulation scratch buffer per
    /// worker instead of allocating per request.
    pub fn spawn_with<T, S, I, F>(n: usize, queue: Arc<JobQueue<T>>,
                                  init: I, handler: F) -> WorkerPool
    where
        T: Send + 'static,
        S: 'static,
        I: Fn(usize) -> S + Send + Sync + 'static,
        F: Fn(T, &mut S) + Send + Sync + 'static,
    {
        let init = Arc::new(init);
        let handler = Arc::new(handler);
        let handles = (0..n)
            .map(|i| {
                let queue = queue.clone();
                let init = init.clone();
                let handler = handler.clone();
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || {
                        let mut state = init(i);
                        while let Some(job) = queue.pop() {
                            handler(job, &mut state);
                        }
                    })
                    .expect("spawn worker thread")
            })
            .collect();
        WorkerPool { handles }
    }

    /// Wait for every worker to exit (close the queue first).
    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn fifo_order_and_drain() {
        let q: JobQueue<u32> = JobQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn full_queue_hands_job_back() {
        let q: JobQueue<u32> = JobQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(3), Err(3));
        assert_eq!(q.pop(), Some(1));
        q.push(3).unwrap();
    }

    #[test]
    fn high_water_tracks_deepest_fill() {
        let q: JobQueue<u32> = JobQueue::new(8);
        assert_eq!(q.high_water(), 0);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.push(3).unwrap();
        assert_eq!(q.high_water(), 3);
        // draining does not lower the mark
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.high_water(), 3);
        q.push(4).unwrap();
        assert_eq!(q.high_water(), 3);
    }

    #[test]
    fn closed_queue_rejects_pushes_but_drains() {
        let q: JobQueue<u32> = JobQueue::new(4);
        q.push(7).unwrap();
        assert!(!q.is_closed());
        q.close();
        assert!(q.is_closed());
        assert_eq!(q.push(8), Err(8));
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn per_worker_state_persists_across_jobs() {
        // Each worker's state is created once and mutated by every job
        // it handles: the per-job counters must sum to the job count.
        let q = Arc::new(JobQueue::<usize>::new(64));
        let handled = Arc::new(AtomicUsize::new(0));
        let pool = {
            let handled = handled.clone();
            WorkerPool::spawn_with(
                3,
                q.clone(),
                |_worker| 0usize, // per-worker scratch (not Send-required)
                move |_j, seen| {
                    *seen += 1;
                    handled.fetch_add(1, Ordering::SeqCst);
                },
            )
        };
        for j in 0..30 {
            let mut job = j;
            while let Err(back) = q.push(job) {
                job = back;
                std::thread::yield_now();
            }
        }
        q.close();
        pool.join();
        assert_eq!(handled.load(Ordering::SeqCst), 30);
    }

    #[test]
    fn workers_process_every_job() {
        let q = Arc::new(JobQueue::<usize>::new(64));
        let sum = Arc::new(AtomicUsize::new(0));
        let pool = {
            let sum = sum.clone();
            WorkerPool::spawn(4, q.clone(), move |j| {
                sum.fetch_add(j, Ordering::SeqCst);
            })
        };
        let mut expect = 0usize;
        for j in 1..=50 {
            expect += j;
            // Retry on transient fullness: workers are draining.
            let mut job = j;
            loop {
                match q.push(job) {
                    Ok(()) => break,
                    Err(back) => {
                        job = back;
                        std::thread::yield_now();
                    }
                }
            }
        }
        q.close();
        pool.join();
        assert_eq!(sum.load(Ordering::SeqCst), expect);
    }
}
