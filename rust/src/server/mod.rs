//! Sim-as-a-service: a dependency-free (std::net, hand-rolled HTTP/1.1)
//! simulation server — `idatacool serve`.
//!
//! Architecture: a single accept loop feeds accepted connections into a
//! bounded `pool::JobQueue` drained by a `std::thread` worker pool. Each
//! worker parses one request (`util::http`), routes it, and answers with
//! `connection: close`. The three simulation endpoints share one serving
//! discipline (`serve_cached`):
//!
//!  1. **LRU response cache** (`util::lru`), keyed by the request
//!     fingerprint (`api::request_fingerprint` — the bench subsystem's
//!     config fingerprint extended over the canonical request document).
//!     A repeat of an identical request is answered with the *stored
//!     bytes* — `x-cache: hit`, body bitwise identical to the first
//!     answer.
//!  2. **In-flight coalescing** (`coalesce`): concurrent identical
//!     requests share one simulation; followers get `x-cache:
//!     coalesced`.
//!  3. Otherwise the worker computes (`x-cache: miss`), caches, and
//!     publishes to followers. Error responses are published but never
//!     cached.
//!
//! Determinism: a response body is a pure function of the request (no
//! wall-clock fields — see `api`), simulations are seeded, and the
//! `/fleet` body reuses the exact `idatacool fleet --json` serializer —
//! so a K-worker server answers bitwise identically to a one-shot CLI
//! run, and cache hits are indistinguishable from recomputation.
//!
//! Endpoints: `POST /simulate` (`?stream=1` for per-tick NDJSON),
//! `POST /fleet`, `POST /sweep`, `GET /healthz`, `GET /metrics`,
//! `POST /shutdown`.

pub mod api;
pub mod coalesce;
pub mod metrics;
pub mod pool;

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{ServeConfig, SimConfig};
use crate::coordinator::SimulationDriver;
use crate::figures::sweep;
use crate::fleet::FleetDriver;
use crate::plant::TickOutput;
use crate::util::http::{Request, Response};
use crate::util::json::JsonBuilder;
use crate::util::lru::Lru;

use coalesce::{Claim, Coalescer};
use metrics::Metrics;
use pool::{JobQueue, WorkerPool};

/// Upper clamp on the worker-thread count.
pub const MAX_WORKERS: usize = 256;

/// Validate a requested worker count the way the fleet CLI validates
/// `--shards`: zero is an error, an excessive value clamps with a
/// warning instead of failing or silently obeying.
pub fn resolve_workers(requested: usize) -> Result<usize> {
    anyhow::ensure!(
        requested >= 1,
        "workers must be at least 1 (use 1 for a serial server)"
    );
    if requested > MAX_WORKERS {
        eprintln!(
            "warning: {requested} workers exceeds the supported maximum; \
             clamping to {MAX_WORKERS}"
        );
        return Ok(MAX_WORKERS);
    }
    Ok(requested)
}

/// Server construction inputs: the launcher knobs (one
/// `config::ServeConfig`, however it was assembled from defaults, the
/// `[serve]` TOML section, env and CLI flags) plus the base simulation
/// configuration requests override (it carries the artifacts dir and
/// plant constants loaded at startup).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub cfg: ServeConfig,
    pub base: SimConfig,
}

impl ServeOptions {
    pub fn new(base: SimConfig) -> Self {
        ServeOptions { cfg: ServeConfig::default(), base }
    }
}

/// A cacheable response body (status + content type + shared bytes).
#[derive(Clone)]
pub struct CachedResponse {
    pub status: u16,
    pub content_type: String,
    pub body: Arc<Vec<u8>>,
}

impl CachedResponse {
    fn to_response(&self, cache_status: &str) -> Response {
        Response::new(self.status, &self.content_type, (*self.body).clone())
            .with_header("x-cache", cache_status)
    }
}

fn error_cached(status: u16, msg: &str) -> CachedResponse {
    let body = JsonBuilder::new().str("error", msg).build().to_string();
    CachedResponse {
        status,
        content_type: "application/json".into(),
        body: Arc::new(body.into_bytes()),
    }
}

/// Per-worker reusable simulation buffers: each worker thread owns one
/// and hands it down to the compute path, so a `/simulate` request
/// reuses the previous request's tick/observation buffer
/// (`SimulationDriver::run_into` resets it) instead of allocating a
/// fresh `TickOutput` per request.
pub struct ServeScratch {
    out: TickOutput,
    /// This worker's index — addresses its latency-histogram shard.
    worker: usize,
}

impl ServeScratch {
    pub fn new(worker: usize) -> Self {
        ServeScratch { out: TickOutput::new(0), worker }
    }
}

impl Default for ServeScratch {
    fn default() -> Self {
        Self::new(0)
    }
}

/// State shared between the accept loop and every worker.
struct Shared {
    base: SimConfig,
    cache: Mutex<Lru<u64, CachedResponse>>,
    inflight: Coalescer<CachedResponse>,
    metrics: Metrics,
    shutdown: AtomicBool,
    local_addr: SocketAddr,
    workers: usize,
    cache_cap: usize,
    started: Instant,
    /// The accept-loop job queue — held here so a metrics scrape can
    /// read its depth high-water mark.
    queue: Arc<JobQueue<TcpStream>>,
}

/// The bound-but-not-yet-running server.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl Server {
    pub fn bind(opts: ServeOptions) -> Result<Server> {
        let sc = opts.cfg;
        let workers = resolve_workers(sc.workers)?;
        anyhow::ensure!(sc.cache_cap >= 1, "cache-cap must be at least 1");
        anyhow::ensure!(sc.queue_cap >= 1, "queue-cap must be at least 1");
        let mut base = opts.base;
        // "auto" resolves to the artifact-independent native backend
        // (mirrors fleet runs); requests may still pin "hlo".
        if base.backend == "auto" {
            base.backend = "native".into();
        }
        base.validate()?;
        let listener = TcpListener::bind(&sc.addr)
            .with_context(|| format!("bind {}", sc.addr))?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            base,
            cache: Mutex::new(Lru::new(sc.cache_cap)),
            inflight: Coalescer::new(),
            metrics: Metrics::new(workers),
            shutdown: AtomicBool::new(false),
            local_addr,
            workers,
            cache_cap: sc.cache_cap,
            started: Instant::now(),
            queue: Arc::new(JobQueue::new(sc.queue_cap)),
        });
        Ok(Server { listener, shared })
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.local_addr
    }

    /// Blocking accept loop; returns after `POST /shutdown` (every
    /// already-accepted connection still gets an answer).
    pub fn run(self) -> Result<()> {
        let queue = self.shared.queue.clone();
        let pool = {
            let shared = self.shared.clone();
            WorkerPool::spawn_with(
                self.shared.workers,
                queue.clone(),
                ServeScratch::new,
                move |s, scratch| handle_connection(s, &shared, scratch),
            )
        };
        for stream in self.listener.incoming() {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(s) => {
                    if let Err(s) = queue.push(s) {
                        self.shared.metrics.shed();
                        shed(s);
                    }
                }
                Err(e) => eprintln!("accept error: {e}"),
            }
        }
        queue.close();
        pool.join();
        Ok(())
    }

    /// Run on a background thread (tests, benches). Stop with
    /// `ServerHandle::stop`.
    pub fn spawn(self) -> ServerHandle {
        let addr = self.local_addr();
        let shared = self.shared.clone();
        let join = std::thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || self.run())
            .expect("spawn server thread");
        ServerHandle { addr, shared, join }
    }
}

/// Handle to a background server.
pub struct ServerHandle {
    pub addr: SocketAddr,
    shared: Arc<Shared>,
    join: std::thread::JoinHandle<Result<()>>,
}

impl ServerHandle {
    /// Shut the server down and join the accept loop. The flag is set
    /// directly (not via `POST /shutdown`), so stopping cannot be
    /// defeated by a full job queue shedding the wire request; the
    /// connect ping only wakes the blocked accept call.
    pub fn stop(self) -> Result<()> {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for _ in 0..50 {
            if self.join.is_finished()
                || TcpStream::connect(self.addr).is_ok()
            {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        match self.join.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("server thread panicked"),
        }
    }
}

/// Reject an accepted connection when the job queue is full.
fn shed(mut s: TcpStream) {
    let _ = Response::error(503, "job queue full; retry later")
        .write_to(&mut s);
}

fn handle_connection(stream: TcpStream, shared: &Arc<Shared>,
                     scratch: &mut ServeScratch) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_nodelay(true);
    let _req_span = crate::obs::span("request");
    let mut reader = BufReader::new(&stream);
    let req = {
        let _parse_span = crate::obs::span("parse");
        match Request::read_from(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => return, // clean EOF (health probe, shutdown ping)
            Err(e) => {
                let _ =
                    Response::error(e.status, &e.msg).write_to(&mut &stream);
                return;
            }
        }
    };
    let t0 = Instant::now();
    // Belt and suspenders: `serve_cached` already isolates simulation
    // panics (they must complete the coalescing slot); this outer catch
    // keeps a routing bug from killing the worker thread. The scratch
    // is safe to reuse after an unwind: every run resets it first.
    let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        route(&req, shared, scratch)
    }))
    .unwrap_or_else(|_| Response::error(500, "internal panic in handler"));
    let elapsed_s = t0.elapsed().as_secs_f64();
    shared.metrics.record(
        metrics::endpoint_index(&req.path),
        resp.status,
        elapsed_s,
        scratch.worker,
    );
    // Wall-clock lives in headers only — response *bodies* stay a pure
    // function of the request (cache hits are compared bitwise on body).
    let resp = resp
        .with_header("x-timing", &format!("total={:.3}ms", elapsed_s * 1e3));
    let _ = resp.write_to(&mut &stream);
    if req.method == "POST" && req.path == "/shutdown" {
        // Wake the accept loop (it is blocked in accept) so it observes
        // the shutdown flag set by `route`.
        let _ = TcpStream::connect(shared.local_addr);
    }
}

fn route(req: &Request, shared: &Arc<Shared>, scratch: &mut ServeScratch)
         -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => healthz(shared),
        ("GET", "/metrics") => metrics_response(req, shared),
        ("POST", "/shutdown") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            Response::json(
                200,
                &JsonBuilder::new().str("status", "shutting-down").build(),
            )
        }
        ("POST", "/simulate") => handle_simulate(req, shared, scratch),
        ("POST", "/fleet") => handle_fleet(req, shared),
        ("POST", "/sweep") => handle_sweep(req, shared),
        (
            _,
            "/healthz" | "/metrics" | "/shutdown" | "/simulate" | "/fleet"
            | "/sweep",
        ) => Response::error(
            405,
            &format!("method {} not allowed for {}", req.method, req.path),
        ),
        _ => Response::error(404, &format!("no route for {}", req.path)),
    }
}

fn healthz(shared: &Arc<Shared>) -> Response {
    Response::json(
        200,
        &JsonBuilder::new()
            .str("status", "ok")
            .num("in_flight", shared.inflight.in_flight() as f64)
            .num("uptime_s", shared.started.elapsed().as_secs_f64())
            .num("workers", shared.workers as f64)
            .build(),
    )
}

/// `GET /metrics[?format=json|prometheus]`. Strict query contract like
/// every other endpoint: an unknown parameter or format value is a 400,
/// never a silently ignored default.
fn metrics_response(req: &Request, shared: &Arc<Shared>) -> Response {
    let mut prometheus = false;
    for (k, v) in &req.query {
        if k == "format" {
            match v.as_str() {
                "json" => prometheus = false,
                "prometheus" => prometheus = true,
                other => {
                    return Response::error(
                        400,
                        &format!(
                            "query parameter 'format' must be \
                             json|prometheus, got '{other}'"
                        ),
                    )
                }
            }
        } else {
            return Response::error(
                400,
                &format!("unknown query parameter '{k}'"),
            );
        }
    }
    let entries = shared.cache.lock().unwrap().len();
    shared
        .metrics
        .set_queue_high_water(shared.queue.high_water() as u64);
    let uptime_s = shared.started.elapsed().as_secs_f64();
    if prometheus {
        let body = shared.metrics.to_prometheus(
            entries,
            shared.cache_cap,
            shared.workers,
            uptime_s,
        );
        return Response::new(
            200,
            "text/plain; version=0.0.4",
            body.into_bytes(),
        );
    }
    Response::json(
        200,
        &shared.metrics.to_json_value(
            entries,
            shared.cache_cap,
            shared.workers,
            uptime_s,
        ),
    )
}

/// The shared serving discipline: cache, coalesce, or compute.
fn serve_cached<F>(shared: &Arc<Shared>, key: u64, compute: F) -> Response
where
    F: FnOnce() -> Result<CachedResponse>,
{
    let lookup_span = crate::obs::span("cache_lookup");
    let hit = shared.cache.lock().unwrap().get(&key).cloned();
    drop(lookup_span);
    if let Some(c) = hit {
        shared.metrics.cache_hit();
        return c.to_response("hit");
    }
    match shared.inflight.claim(key) {
        Claim::Follower(slot) => {
            shared.metrics.coalesce();
            let _wait_span = crate::obs::span("coalesce_wait");
            slot.wait().to_response("coalesced")
        }
        Claim::Leader(slot) => {
            // Double-check the cache now that we hold leadership: a
            // previous leader for this key may have completed between
            // our fast-path cache check and the claim. Without this a
            // successfully cached request could be recomputed; with it,
            // a successful simulation runs exactly once per key
            // (errors are not cached, so those may legitimately rerun).
            let raced = shared.cache.lock().unwrap().get(&key).cloned();
            if let Some(c) = raced {
                shared.metrics.cache_hit();
                shared.inflight.complete(key, &slot, c.clone());
                return c.to_response("hit");
            }
            shared.metrics.cache_miss();
            let compute_span = crate::obs::span("compute");
            let outcome = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(compute),
            );
            drop(compute_span);
            let (resp, cacheable) = match outcome {
                Ok(Ok(c)) => (c, true),
                Ok(Err(e)) => (error_cached(500, &format!("{e:#}")), false),
                Err(_) => (error_cached(500, "simulation panicked"), false),
            };
            if cacheable {
                let evicted =
                    shared.cache.lock().unwrap().insert(key, resp.clone());
                if evicted.is_some() {
                    shared.metrics.cache_evicted();
                }
            }
            // Must always run, or followers would wait forever.
            shared.inflight.complete(key, &slot, resp.clone());
            resp.to_response("miss")
        }
    }
}

/// Strict query parsing, mirroring the strict body contract: the only
/// recognized parameter is `stream` (and only where `allow_stream`),
/// with an explicit boolean value — a typo like `steam=1` or
/// `stream=yes` is a 400, never a silently ignored default.
fn parse_query(req: &Request, allow_stream: bool) -> Result<bool, Response> {
    let mut stream = false;
    for (k, v) in &req.query {
        if k == "stream" && allow_stream {
            match v.as_str() {
                "1" | "true" => stream = true,
                "0" | "false" => stream = false,
                other => {
                    return Err(Response::error(
                        400,
                        &format!(
                            "query parameter 'stream' must be \
                             0|1|true|false, got '{other}'"
                        ),
                    ))
                }
            }
        } else {
            return Err(Response::error(
                400,
                &format!("unknown query parameter '{k}'"),
            ));
        }
    }
    Ok(stream)
}

fn handle_simulate(req: &Request, shared: &Arc<Shared>,
                   scratch: &mut ServeScratch) -> Response {
    let stream = match parse_query(req, true) {
        Ok(s) => s,
        Err(resp) => return resp,
    };
    let body = match req.body_str() {
        Ok(b) => b,
        Err(e) => return Response::error(e.status, &e.msg),
    };
    let sim = match api::parse_sim_request(body, &shared.base) {
        Ok(s) => s,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let canon = api::canonical_sim_json(&sim.cfg, sim.sample_every, stream);
    let key = api::request_fingerprint("simulate", &canon, &sim.cfg);
    serve_cached(shared, key, move || compute_simulate(sim, stream, scratch))
}

fn compute_simulate(sim: api::SimRequest, stream: bool,
                    scratch: &mut ServeScratch) -> Result<CachedResponse> {
    let sample_every = sim.sample_every;
    let mut driver = SimulationDriver::new(sim.cfg)?;
    let kernel = driver.backend.kernel_name();
    // The worker's reusable tick/observation buffer: `run_into` resets
    // it (size + zero) so a reused buffer behaves exactly like a fresh
    // allocation — responses stay bitwise identical across workers.
    let res = driver.run_into(sample_every, &mut scratch.out)?;
    let cfg = &driver.cfg;
    let _ser_span = crate::obs::span("serialize");
    if stream {
        Ok(CachedResponse {
            status: 200,
            content_type: "application/x-ndjson".into(),
            body: Arc::new(api::trace_ndjson(cfg, kernel, sample_every, &res)),
        })
    } else {
        Ok(CachedResponse {
            status: 200,
            content_type: "application/json".into(),
            body: Arc::new(
                api::simulate_summary_json(cfg, kernel, sample_every, &res)
                    .to_string()
                    .into_bytes(),
            ),
        })
    }
}

fn handle_fleet(req: &Request, shared: &Arc<Shared>) -> Response {
    if let Err(resp) = parse_query(req, false) {
        return resp;
    }
    let body = match req.body_str() {
        Ok(b) => b,
        Err(e) => return Response::error(e.status, &e.msg),
    };
    let fc = match api::parse_fleet_request(body, &shared.base) {
        Ok(c) => c,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let canon = api::canonical_fleet_json(&fc);
    let key = api::request_fingerprint("fleet", &canon, &fc.base);
    serve_cached(shared, key, move || compute_fleet(fc))
}

fn compute_fleet(fc: crate::fleet::FleetConfig) -> Result<CachedResponse> {
    let driver = FleetDriver::new(fc)?;
    let run = driver.run()?;
    let _ser_span = crate::obs::span("serialize");
    Ok(CachedResponse {
        status: 200,
        content_type: "application/json".into(),
        // Exactly the `idatacool fleet --json` document.
        body: Arc::new(run.to_json(&driver.cfg).into_bytes()),
    })
}

fn handle_sweep(req: &Request, shared: &Arc<Shared>) -> Response {
    if let Err(resp) = parse_query(req, false) {
        return resp;
    }
    let body = match req.body_str() {
        Ok(b) => b,
        Err(e) => return Response::error(e.status, &e.msg),
    };
    let sr = match api::parse_sweep_request(body, &shared.base) {
        Ok(r) => r,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let canon = api::canonical_sweep_json(&sr);
    let key = api::request_fingerprint("sweep", &canon, &sr.cfg);
    serve_cached(shared, key, move || compute_sweep(sr))
}

fn compute_sweep(sr: api::SweepRequest) -> Result<CachedResponse> {
    let opts = sr.options();
    let data =
        sweep::run_sweep_sharded(&sr.cfg, &sr.setpoints, &opts, sr.shards)?;
    let _ser_span = crate::obs::span("serialize");
    let body = JsonBuilder::new()
        .str("schema", "idatacool-sweep/1")
        .bool("quick", sr.quick)
        .arr(
            "setpoints",
            sr.setpoints.iter().map(|&s| crate::util::json::Json::Num(s)).collect(),
        )
        .set("data", data.to_json_value())
        .build()
        .to_string();
    Ok(CachedResponse {
        status: 200,
        content_type: "application/json".into(),
        body: Arc::new(body.into_bytes()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_resolution_matches_cli_discipline() {
        assert!(resolve_workers(0).is_err());
        assert_eq!(resolve_workers(1).unwrap(), 1);
        assert_eq!(resolve_workers(MAX_WORKERS).unwrap(), MAX_WORKERS);
        assert_eq!(resolve_workers(MAX_WORKERS + 100).unwrap(), MAX_WORKERS);
    }

    #[test]
    fn bind_rejects_degenerate_options() {
        let base = SimConfig::test_small();
        let mut o = ServeOptions::new(base.clone());
        o.cfg.addr = "127.0.0.1:0".into();
        o.cfg.cache_cap = 0;
        assert!(Server::bind(o).is_err());
        let mut o = ServeOptions::new(base.clone());
        o.cfg.addr = "127.0.0.1:0".into();
        o.cfg.workers = 0;
        assert!(Server::bind(o).is_err());
        let mut o = ServeOptions::new(base);
        o.cfg.addr = "127.0.0.1:0".into();
        o.cfg.queue_cap = 0;
        assert!(Server::bind(o).is_err());
    }

    #[test]
    fn ephemeral_bind_resolves_port() {
        let mut o = ServeOptions::new(SimConfig::test_small());
        o.cfg.addr = "127.0.0.1:0".into();
        o.cfg.workers = 1;
        let s = Server::bind(o).unwrap();
        assert_ne!(s.local_addr().port(), 0);
    }

    #[test]
    fn error_responses_carry_the_cache_header() {
        let c = error_cached(500, "boom");
        let r = c.to_response("miss");
        assert_eq!(r.status, 500);
        assert!(r
            .headers
            .iter()
            .any(|(k, v)| k == "x-cache" && v == "miss"));
    }
}
