//! Sim-as-a-service: a dependency-free (std::net, hand-rolled HTTP/1.1)
//! simulation server — `idatacool serve`.
//!
//! Architecture: a single **nonblocking readiness loop** accepts
//! connections and polls them (plus keep-alive connections handed back
//! by workers) for readable bytes; ready connections are dispatched
//! through a bounded `pool::JobQueue` to a `std::thread` worker pool.
//! Each worker parses one request (`util::http`), routes it through the
//! `ENDPOINTS` registry, answers, and — under HTTP/1.1 keep-alive —
//! parks the connection back with the loop, carrying any pipelined
//! bytes it over-read.
//!
//! Routing is **versioned**: every endpoint lives under `/v1/...`;
//! the legacy unprefixed paths remain as aliases for one release and
//! answer with a `Deprecation: true` header. Every error body is the
//! single `idatacool-error/1` JSON envelope (`util::http::error_envelope`).
//!
//! The three simulation endpoints share one serving discipline
//! (`serve_cached`):
//!
//!  1. **Sharded LRU response cache** (`util::lru::ShardedLru`), keyed
//!     by the request fingerprint (`api::request_fingerprint`). A
//!     repeat of an identical request is answered with the *stored
//!     bytes* — `x-cache: hit`, body bitwise identical to the first
//!     answer — and lookups on different shards never serialize.
//!  2. **In-flight coalescing** (`coalesce`): concurrent identical
//!     requests share one simulation; followers get `x-cache:
//!     coalesced`.
//!  3. Otherwise the worker computes (`x-cache: miss`), caches, and
//!     publishes to followers. Error responses are published but never
//!     cached.
//!
//! Computes for *heterogeneous* concurrent `/simulate` and `/fleet`
//! requests additionally pass through the continuous-batching
//! scheduler (`batch`, gated by `[serve] batch_window_ms`): an
//! admission window packs all pending jobs' plants into one shared SoA
//! lane arena and advances them in tick lockstep — one kernel sweep
//! per substep for the whole batch. Batched responses carry an
//! `x-batch: <occupancy>` header and are bitwise identical to solo
//! runs (see `batch` for the determinism argument).
//!
//! Determinism: a response body is a pure function of the request (no
//! wall-clock fields — see `api`), simulations are seeded, and the
//! `/fleet` body reuses the exact `idatacool fleet --json` serializer —
//! so a K-worker server answers bitwise identically to a one-shot CLI
//! run, and cache hits are indistinguishable from recomputation.
//!
//! **Deadlines** (`[serve] deadline_ms`, 0 = unbounded): every cached
//! compute carries a wall-clock budget. Followers bound their wait on
//! the leader (`Slot::wait_timeout`), batched jobs bound their wait on
//! the round leader, and an over-budget answer is replaced by a 504
//! `idatacool-error/1` envelope carrying `Retry-After` — the computed
//! result is still cached and published, so an immediate retry is a
//! cache hit. 503 (shed) and 504 responses always carry `Retry-After`.
//!
//! **Self-healing** (DESIGN.md §10): workers run under a supervised
//! pool (`supervise`) — panics answer the victim and respawn the slot
//! within `[serve] restart_budget`, a stall watchdog condemns workers
//! stuck past 4 × the deadline — and every typed request passes
//! cost-aware admission control (`admit`): a token bucket (`[serve]
//! rate_limit`), a healthy → degraded → saturated ladder, and a
//! per-endpoint-class circuit breaker. Sheds answer 429/503
//! `idatacool-error/1` envelopes with a *computed* `Retry-After`;
//! `GET /v1/healthz` reports the whole picture as an
//! `idatacool-health/1` document. None of it touches response bodies
//! or cache keys — supervision is execution shape.
//!
//! **Shutdown**: `POST /v1/shutdown`, `ServerHandle::stop`, SIGTERM and
//! SIGINT all converge on the same drain path — stop accepting, close
//! the job queue, join the worker pool (every already-dispatched
//! connection still gets an answer).
//!
//! Endpoints: `POST /v1/simulate` (`?stream=1` for per-tick NDJSON),
//! `POST /v1/fleet`, `POST /v1/sweep`, `POST /v1/optimize` (the
//! closed-loop search; body mirrors the `[optimize]` TOML section,
//! response is the exact `idatacool optimize --json` document),
//! `GET /v1/healthz`, `GET /v1/metrics`, `POST /v1/shutdown` (all also
//! reachable unprefixed, deprecated).

pub mod admit;
pub mod api;
pub mod batch;
pub mod coalesce;
pub mod metrics;
pub mod pool;
pub mod supervise;

use std::cell::Cell;
use std::io::{BufReader, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{ServeConfig, SimConfig};
use crate::coordinator::SimulationDriver;
use crate::figures::sweep;
use crate::fleet::{megabatch, FleetDriver};
use crate::plant::TickOutput;
use crate::resilience::inject::{self, Site};
use crate::util::http::{error_envelope, Request, Response};
use crate::util::json::JsonBuilder;
use crate::util::lru::ShardedLru;

use admit::{Admission, Health, Verdict};
use api::{ApiRequest, EndpointKind};
use batch::{BatchJob, Batcher};
use coalesce::{Claim, Coalescer};
use metrics::Metrics;
use pool::JobQueue;
use supervise::PoolState;

/// Upper clamp on the worker-thread count.
pub const MAX_WORKERS: usize = 256;

/// Lock shards for the response cache.
const CACHE_SHARDS: usize = 8;

/// A worker busy past this many request deadlines is condemned as
/// stalled: long enough that the in-band 504 paths (follower timeout,
/// leader post-hoc check) have all had their chance, short enough that
/// a wedged compute cannot hold a slot hostage.
const STALL_DEADLINES: u32 = 4;

/// An idle (no bytes readable) connection is dropped after this long.
/// Clients mid-request get the worker-side 30 s read timeout instead —
/// a connection only counts as idle *between* requests.
const IDLE_TIMEOUT: Duration = Duration::from_secs(5);

/// Readiness-loop sleep when nothing was accepted, ready, or closed.
const POLL_SLEEP: Duration = Duration::from_millis(1);

/// Validate a requested worker count the way the fleet CLI validates
/// `--shards`: zero is an error, an excessive value clamps with a
/// warning instead of failing or silently obeying.
pub fn resolve_workers(requested: usize) -> Result<usize> {
    anyhow::ensure!(
        requested >= 1,
        "workers must be at least 1 (use 1 for a serial server)"
    );
    if requested > MAX_WORKERS {
        eprintln!(
            "warning: {requested} workers exceeds the supported maximum; \
             clamping to {MAX_WORKERS}"
        );
        return Ok(MAX_WORKERS);
    }
    Ok(requested)
}

/// Server construction inputs: the launcher knobs (one
/// `config::ServeConfig`, however it was assembled from defaults, the
/// `[serve]` TOML section, env and CLI flags) plus the base simulation
/// configuration requests override (it carries the artifacts dir and
/// plant constants loaded at startup).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub cfg: ServeConfig,
    pub base: SimConfig,
}

impl ServeOptions {
    pub fn new(base: SimConfig) -> Self {
        ServeOptions { cfg: ServeConfig::default(), base }
    }
}

/// A cacheable response body (status + content type + shared bytes).
#[derive(Clone)]
pub struct CachedResponse {
    pub status: u16,
    pub content_type: String,
    pub body: Arc<Vec<u8>>,
}

impl CachedResponse {
    fn to_response(&self, cache_status: &str) -> Response {
        Response::new(self.status, &self.content_type, (*self.body).clone())
            .with_header("x-cache", cache_status)
    }
}

/// An error in `CachedResponse` form — same `idatacool-error/1`
/// envelope every other error path emits. Crate-visible so the batch
/// scheduler can answer a deadline overrun with the same envelope.
pub(crate) fn error_cached(status: u16, msg: &str) -> CachedResponse {
    let body = error_envelope(status, msg, None).to_string();
    CachedResponse {
        status,
        content_type: "application/json".into(),
        body: Arc::new(body.into_bytes()),
    }
}

/// Finish a `serve_cached` outcome on the wire: attach the `x-cache`
/// header and, for back-pressure statuses (429/503/504), tell the
/// client when to come back — computed from the live queue backlog and
/// breaker open-time, not a constant. A 504 retry is typically a cache
/// hit — the leader's result is cached even when this client's budget
/// ran out.
fn answer(c: CachedResponse, cache_status: &str, shared: &Shared)
          -> Response {
    let status = c.status;
    let resp = c.to_response(cache_status);
    if status == 429 || status == 503 || status == 504 {
        resp.with_header("retry-after",
                         &shared.retry_after_secs().to_string())
    } else {
        resp
    }
}

/// The 504 every deadline overrun answers with.
fn deadline_response(cache_status: &str, shared: &Shared) -> Response {
    answer(
        error_cached(504, "deadline exceeded; retry (result may be cached)"),
        cache_status,
        shared,
    )
}

/// Per-worker reusable simulation buffers: each worker thread owns one
/// and hands it down to the compute path, so a `/simulate` request
/// reuses the previous request's tick/observation buffer
/// (`SimulationDriver::run_into` resets it) instead of allocating a
/// fresh `TickOutput` per request.
pub struct ServeScratch {
    out: TickOutput,
    /// This worker's index — addresses its latency-histogram shard.
    worker: usize,
}

impl ServeScratch {
    pub fn new(worker: usize) -> Self {
        ServeScratch { out: TickOutput::new(0), worker }
    }
}

impl Default for ServeScratch {
    fn default() -> Self {
        Self::new(0)
    }
}

/// One client connection plus any pipelined bytes a worker already
/// read past the previous request (HTTP/1.1 keep-alive carry).
pub struct Conn {
    stream: TcpStream,
    leftover: Vec<u8>,
    /// When the readiness loop pushed this connection into the job
    /// queue — the deadline-aware drop compares it at pop, so a
    /// request that waited out its whole budget in the queue is
    /// answered 504 without entering compute.
    enqueued: Instant,
}

/// State shared between the readiness loop and every worker.
struct Shared {
    base: SimConfig,
    cache: ShardedLru<CachedResponse>,
    inflight: Coalescer<CachedResponse>,
    /// The continuous-batching scheduler; `None` when
    /// `batch_window_ms = 0` (every request computes solo).
    batch: Option<Batcher>,
    /// Per-request wall-clock budget; `None` when `deadline_ms = 0`.
    /// Overruns answer 504 — see the module docs.
    deadline: Option<Duration>,
    metrics: Metrics,
    shutdown: AtomicBool,
    local_addr: SocketAddr,
    workers: usize,
    cache_cap: usize,
    started: Instant,
    /// The readiness-loop job queue — held here so a metrics scrape can
    /// read its depth high-water mark.
    queue: Arc<JobQueue<Conn>>,
    /// Keep-alive connections workers hand back for further polling.
    parked: Mutex<Vec<Conn>>,
    /// Most connections the readiness loop holds open at once
    /// (`[serve] max_parked`); beyond this, arrivals are shed 503.
    max_parked: usize,
    /// Supervision state (live workers, restarts, stalls) — created at
    /// bind so the health endpoint can read it, driven by `run`.
    pool: Arc<PoolState>,
    /// Admission control: token bucket, degradation ladder, breakers.
    admission: Admission,
}

impl Shared {
    /// The degradation ladder, derived from live signals on every
    /// admission decision and health scrape.
    fn health(&self) -> Health {
        admit::ladder(
            self.queue.len(),
            self.queue.cap(),
            self.pool.live_workers(),
            self.workers,
            self.admission.breaker_trouble(),
        )
    }

    /// The computed `Retry-After` every back-pressure response carries.
    fn retry_after_secs(&self) -> u64 {
        admit::retry_after_secs(
            self.queue.len(),
            self.workers,
            self.admission.max_open_remaining_s(),
        )
    }
}

/// The bound-but-not-yet-running server.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl Server {
    pub fn bind(opts: ServeOptions) -> Result<Server> {
        let sc = opts.cfg;
        let workers = resolve_workers(sc.workers)?;
        anyhow::ensure!(sc.cache_cap >= 1, "cache-cap must be at least 1");
        anyhow::ensure!(sc.queue_cap >= 1, "queue-cap must be at least 1");
        anyhow::ensure!(
            sc.batch_max_plants >= 1,
            "batch-max-plants must be at least 1"
        );
        anyhow::ensure!(sc.max_parked >= 1, "max-parked must be at least 1");
        let mut base = opts.base;
        // "auto" resolves to the artifact-independent native backend
        // (mirrors fleet runs); requests may still pin "hlo".
        if base.backend == "auto" {
            base.backend = "native".into();
        }
        base.validate()?;
        let listener = TcpListener::bind(&sc.addr)
            .with_context(|| format!("bind {}", sc.addr))?;
        let local_addr = listener.local_addr()?;
        let batch = (sc.batch_window_ms > 0).then(|| {
            Batcher::new(
                Duration::from_millis(sc.batch_window_ms as u64),
                sc.batch_max_plants,
            )
        });
        let deadline = (sc.deadline_ms > 0)
            .then(|| Duration::from_millis(sc.deadline_ms as u64));
        // The stall watchdog only makes sense relative to a request
        // budget: no deadline, no watchdog.
        let stall = deadline.map(|d| d * STALL_DEADLINES);
        let pool = PoolState::new(workers, sc.restart_budget as u64, stall);
        let shared = Arc::new(Shared {
            base,
            cache: ShardedLru::new(sc.cache_cap, CACHE_SHARDS),
            inflight: Coalescer::new(),
            batch,
            deadline,
            metrics: Metrics::new(workers),
            shutdown: AtomicBool::new(false),
            local_addr,
            workers,
            cache_cap: sc.cache_cap,
            started: Instant::now(),
            queue: Arc::new(JobQueue::new(sc.queue_cap)),
            parked: Mutex::new(Vec::new()),
            max_parked: sc.max_parked,
            pool,
            admission: Admission::new(sc.rate_limit),
        });
        Ok(Server { listener, shared })
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.local_addr
    }

    /// The readiness loop; returns after `POST /shutdown` (every
    /// already-dispatched connection still gets an answer).
    ///
    /// Everything here is std-only: the listener and parked sockets run
    /// nonblocking, readiness is a 1-byte `peek`, and the loop sleeps
    /// `POLL_SLEEP` only when a pass found no work. A connection with a
    /// non-empty keep-alive carry is ready by definition — its next
    /// request (or part of it) is already in user space, where `peek`
    /// cannot see it.
    pub fn run(self) -> Result<()> {
        let queue = self.shared.queue.clone();
        let pool = {
            let shared = self.shared.clone();
            supervise::spawn(
                self.shared.pool.clone(),
                queue.clone(),
                move |conn, scratch| handle_connection(conn, &shared, scratch),
            )
        };
        self.listener
            .set_nonblocking(true)
            .context("set listener nonblocking")?;
        signal::install();
        let mut parked: Vec<(Conn, Instant)> = Vec::new();
        while !self.shared.shutdown.load(Ordering::SeqCst)
            && !signal::pending()
        {
            let mut active = false;
            // 1. Drain the accept backlog.
            loop {
                match self.listener.accept() {
                    Ok((s, _)) => {
                        active = true;
                        if parked.len() >= self.shared.max_parked {
                            self.shared.metrics.shed();
                            shed(s, &self.shared,
                                 "connection limit (max_parked) reached; \
                                  retry later");
                            continue;
                        }
                        let _ = s.set_nonblocking(true);
                        let conn = Conn {
                            stream: s,
                            leftover: Vec::new(),
                            enqueued: Instant::now(),
                        };
                        parked.push((conn, Instant::now()));
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        break
                    }
                    Err(e) => {
                        eprintln!("accept error: {e}");
                        break;
                    }
                }
            }
            // 2. Reclaim keep-alive connections handed back by workers.
            for conn in self.shared.parked.lock().unwrap().drain(..) {
                let _ = conn.stream.set_nonblocking(true);
                parked.push((conn, Instant::now()));
            }
            // 3. Poll for readable connections and dispatch them.
            let mut i = 0;
            while i < parked.len() {
                let state = if parked[i].0.leftover.is_empty() {
                    probe(&parked[i].0.stream)
                } else {
                    ConnState::Ready
                };
                match state {
                    ConnState::Ready => {
                        active = true;
                        let (mut conn, _) = parked.swap_remove(i);
                        // Workers read/write blocking (with timeouts).
                        let _ = conn.stream.set_nonblocking(false);
                        conn.enqueued = Instant::now();
                        if let Err(conn) = queue.push(conn) {
                            self.shared.metrics.shed();
                            shed(conn.stream, &self.shared,
                                 "job queue full; retry later");
                        }
                    }
                    ConnState::Closed => {
                        active = true;
                        parked.swap_remove(i);
                    }
                    ConnState::Idle => {
                        if parked[i].1.elapsed() > IDLE_TIMEOUT {
                            parked.swap_remove(i);
                        } else {
                            i += 1;
                        }
                    }
                }
            }
            if !active {
                std::thread::sleep(POLL_SLEEP);
            }
        }
        queue.close();
        pool.join();
        Ok(())
    }

    /// Run on a background thread (tests, benches). Stop with
    /// `ServerHandle::stop`.
    pub fn spawn(self) -> ServerHandle {
        let addr = self.local_addr();
        let shared = self.shared.clone();
        let join = std::thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || self.run())
            .expect("spawn server thread");
        ServerHandle { addr, shared, join }
    }
}

/// What a 1-byte `peek` says about a parked connection.
enum ConnState {
    Ready,
    Idle,
    Closed,
}

fn probe(s: &TcpStream) -> ConnState {
    let mut b = [0u8; 1];
    match s.peek(&mut b) {
        Ok(0) => ConnState::Closed, // orderly EOF
        Ok(_) => ConnState::Ready,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
            ConnState::Idle
        }
        Err(_) => ConnState::Closed,
    }
}

/// Handle to a background server.
pub struct ServerHandle {
    pub addr: SocketAddr,
    shared: Arc<Shared>,
    join: std::thread::JoinHandle<Result<()>>,
}

impl ServerHandle {
    /// Shut the server down and join the readiness loop. The flag is
    /// set directly (not via `POST /shutdown`), so stopping cannot be
    /// defeated by a full job queue shedding the wire request; the loop
    /// observes the flag on its next pass (≤ `POLL_SLEEP`).
    pub fn stop(self) -> Result<()> {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for _ in 0..50 {
            if self.join.is_finished() {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        match self.join.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("server thread panicked"),
        }
    }
}

/// Reject a connection when the job queue or the parked set is full —
/// the standard envelope plus the same computed `Retry-After` every
/// other back-pressure path derives.
fn shed(mut s: TcpStream, shared: &Shared, msg: &str) {
    let _ = s.set_nonblocking(false);
    let _ = Response::error(503, msg)
        .with_header("retry-after", &shared.retry_after_secs().to_string())
        .write_to(&mut s);
}

/// SIGTERM/SIGINT → the same graceful drain as `POST /v1/shutdown`:
/// the readiness loop observes the flag on its next pass, stops
/// accepting, closes the job queue, and joins the worker pool.
#[cfg(unix)]
mod signal {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);

    // Async-signal-safe by construction: one atomic store, no
    // allocation, no locks.
    extern "C" fn on_term(_sig: i32) {
        TERM.store(true, Ordering::SeqCst);
    }

    /// Install the handlers through the raw `signal(2)` symbol std
    /// already links on unix — no new dependency. Idempotent.
    pub(super) fn install() {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGINT, on_term as usize);
            signal(SIGTERM, on_term as usize);
        }
    }

    pub(super) fn pending() -> bool {
        TERM.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod signal {
    pub(super) fn install() {}

    pub(super) fn pending() -> bool {
        false
    }
}

/// Serve **one** request from `conn`, then either drop it or park it
/// back with the readiness loop (HTTP/1.1 keep-alive). Any bytes read
/// past the request's end — pipelined follow-ups — ride along in
/// `Conn::leftover` and are replayed ahead of the socket next time.
fn handle_connection(mut conn: Conn, shared: &Arc<Shared>,
                     scratch: &mut ServeScratch) {
    // Deadline-aware queue drop: a request that already waited out its
    // whole budget parked in the job queue is answered 504 right here
    // — before parsing, before compute — so a saturated server spends
    // worker time on requests that can still make their deadline.
    if let Some(d) = shared.deadline {
        if conn.enqueued.elapsed() > d {
            crate::obs::metrics::deadline_drops().inc();
            let _ = Response::error(
                504,
                "deadline expired while queued; retry later",
            )
            .with_header("retry-after",
                         &shared.retry_after_secs().to_string())
            .write_to(&mut &conn.stream);
            return;
        }
    }
    let _ = conn.stream.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = conn.stream.set_nodelay(true);
    let _req_span = crate::obs::span("request");
    let carry = std::mem::take(&mut conn.leftover);
    let mut reader =
        BufReader::new(std::io::Cursor::new(carry).chain(&conn.stream));
    let req = {
        let _parse_span = crate::obs::span("parse");
        match Request::read_from(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => return, // clean EOF (probe or keep-alive close)
            Err(e) => {
                // Wire-level error: answer and close — framing is no
                // longer trustworthy, so never keep the connection.
                let _ = Response::error(e.status, &e.msg)
                    .write_to(&mut &conn.stream);
                return;
            }
        }
    };
    let t0 = Instant::now();
    // Belt and suspenders: `serve_cached` already isolates simulation
    // panics (they must complete the coalescing slot); this outer catch
    // keeps a routing bug from killing the worker thread. The scratch
    // is safe to reuse after an unwind: every run resets it first.
    let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        route(&req, shared, scratch)
    }))
    .unwrap_or_else(|_| Response::error(500, "internal panic in handler"));
    let elapsed_s = t0.elapsed().as_secs_f64();
    shared.metrics.record(
        metrics::endpoint_index(&req.path),
        resp.status,
        elapsed_s,
        scratch.worker,
    );
    let keep = !shared.shutdown.load(Ordering::SeqCst)
        && !req
            .header("connection")
            .is_some_and(|v| v.eq_ignore_ascii_case("close"));
    // Wall-clock lives in headers only — response *bodies* stay a pure
    // function of the request (cache hits are compared bitwise on body).
    let mut resp = resp
        .with_header("x-timing", &format!("total={:.3}ms", elapsed_s * 1e3));
    if keep {
        resp = resp.keep_alive();
    }
    let wrote = resp.write_to(&mut &conn.stream).is_ok();
    if !(keep && wrote) {
        return;
    }
    // Reassemble the unconsumed tail in stream order: the BufReader's
    // buffer holds the earliest over-read bytes, then whatever is left
    // of the previous carry.
    let mut leftover = reader.buffer().to_vec();
    let (cursor, _stream) = reader.into_inner().into_inner();
    let pos = (cursor.position() as usize).min(cursor.get_ref().len());
    leftover.extend_from_slice(&cursor.get_ref()[pos..]);
    conn.leftover = leftover;
    let mut parked = shared.parked.lock().unwrap();
    if parked.len() < shared.max_parked {
        parked.push(conn);
    }
}

/// One routable endpoint. The table is the routing authority — method,
/// path, parser (`api`), query contract, and cache policy all live
/// here; there is no hand-rolled per-path dispatch.
struct Endpoint {
    method: &'static str,
    path: &'static str,
    /// `Some(kind)`: a simulation endpoint parsed into a typed
    /// [`ApiRequest`]. `None`: infrastructure (no body parsing).
    api: Option<EndpointKind>,
    /// Whether `?stream=` is a recognized query parameter.
    allow_stream: bool,
    /// Whether responses enter the LRU + coalescer (`serve_cached`).
    cached: bool,
    handler: fn(&Endpoint, &Request, &Arc<Shared>, &mut ServeScratch)
        -> Response,
}

/// The registry. Paths are version-stripped (`/v1/simulate` and the
/// deprecated `/simulate` both match the `/simulate` row).
const ENDPOINTS: &[Endpoint] = &[
    Endpoint {
        method: "GET",
        path: "/healthz",
        api: None,
        allow_stream: false,
        cached: false,
        handler: ep_healthz,
    },
    Endpoint {
        method: "GET",
        path: "/metrics",
        api: None,
        allow_stream: false,
        cached: false,
        handler: ep_metrics,
    },
    Endpoint {
        method: "POST",
        path: "/shutdown",
        api: None,
        allow_stream: false,
        cached: false,
        handler: ep_shutdown,
    },
    Endpoint {
        method: "POST",
        path: "/simulate",
        api: Some(EndpointKind::Simulate),
        allow_stream: true,
        cached: true,
        handler: ep_api,
    },
    Endpoint {
        method: "POST",
        path: "/fleet",
        api: Some(EndpointKind::Fleet),
        allow_stream: false,
        cached: true,
        handler: ep_api,
    },
    Endpoint {
        method: "POST",
        path: "/sweep",
        api: Some(EndpointKind::Sweep),
        allow_stream: false,
        cached: true,
        handler: ep_api,
    },
    Endpoint {
        method: "POST",
        path: "/optimize",
        api: Some(EndpointKind::Optimize),
        allow_stream: false,
        cached: true,
        handler: ep_api,
    },
];

/// Split the API version off a request path. Unprefixed paths still
/// resolve (legacy aliases) but are flagged so the response can carry
/// the `Deprecation` header.
fn split_version(path: &str) -> (&str, bool) {
    match path.strip_prefix("/v1") {
        Some(rest) if rest.starts_with('/') => (rest, true),
        _ => (path, false),
    }
}

fn route(req: &Request, shared: &Arc<Shared>, scratch: &mut ServeScratch)
         -> Response {
    let (path, versioned) = split_version(&req.path);
    let Some(ep) = ENDPOINTS.iter().find(|e| e.path == path) else {
        return Response::error(404, &format!("no route for {}", req.path));
    };
    let resp = if ep.method == req.method {
        (ep.handler)(ep, req, shared, scratch)
    } else {
        Response::error(
            405,
            &format!("method {} not allowed for {}", req.method, req.path),
        )
    };
    if versioned {
        resp
    } else {
        resp.with_header("deprecation", "true")
    }
}

/// `GET /v1/healthz`: the `idatacool-health/1` document — ladder
/// state, live worker count, breaker states, shed counts. Always HTTP
/// 200 (a probe can reach a saturated server; the *state* field is the
/// gate), and never cached — this is the one endpoint whose body is
/// live operational state, not a pure function of the request.
fn ep_healthz(_: &Endpoint, _: &Request, shared: &Arc<Shared>,
              _: &mut ServeScratch) -> Response {
    let mut breakers = JsonBuilder::new();
    for (name, state) in shared.admission.breaker_states() {
        breakers = breakers.str(name, state.name());
    }
    Response::json(
        200,
        &JsonBuilder::new()
            .str("schema", "idatacool-health/1")
            .str("state", shared.health().name())
            .set(
                "workers",
                JsonBuilder::new()
                    .num("configured", shared.workers as f64)
                    .num("live", shared.pool.live_workers() as f64)
                    .num("restarts", shared.pool.restarts() as f64)
                    .num("restart_budget_left",
                         shared.pool.budget_left() as f64)
                    .build(),
            )
            .set("breakers", breakers.build())
            .set(
                "queue",
                JsonBuilder::new()
                    .num("depth", shared.queue.len() as f64)
                    .num("capacity", shared.queue.cap() as f64)
                    .build(),
            )
            .set(
                "shed",
                JsonBuilder::new()
                    .num("overload", shared.metrics.shed_count() as f64)
                    .num("rate_limited",
                         shared.metrics.rate_limited_count() as f64)
                    .num("deadline_drops",
                         crate::obs::metrics::deadline_drops().get() as f64)
                    .num("stalls", shared.pool.stalls() as f64)
                    .build(),
            )
            .num("in_flight", shared.inflight.in_flight() as f64)
            .num("uptime_s", shared.started.elapsed().as_secs_f64())
            .build(),
    )
}

/// `GET /v1/metrics[?format=json|prometheus]`. Strict query contract
/// like every other endpoint: an unknown parameter or format value is a
/// 400, never a silently ignored default.
fn ep_metrics(_: &Endpoint, req: &Request, shared: &Arc<Shared>,
              _: &mut ServeScratch) -> Response {
    let mut prometheus = false;
    for (k, v) in &req.query {
        if k == "format" {
            match v.as_str() {
                "json" => prometheus = false,
                "prometheus" => prometheus = true,
                other => {
                    return Response::error(
                        400,
                        &format!(
                            "query parameter 'format' must be \
                             json|prometheus, got '{other}'"
                        ),
                    )
                }
            }
        } else {
            return Response::error(
                400,
                &format!("unknown query parameter '{k}'"),
            );
        }
    }
    let entries = shared.cache.len();
    shared
        .metrics
        .set_queue_high_water(shared.queue.high_water() as u64);
    let uptime_s = shared.started.elapsed().as_secs_f64();
    if prometheus {
        let body = shared.metrics.to_prometheus(
            entries,
            shared.cache_cap,
            shared.workers,
            uptime_s,
        );
        return Response::new(
            200,
            "text/plain; version=0.0.4",
            body.into_bytes(),
        );
    }
    Response::json(
        200,
        &shared.metrics.to_json_value(
            entries,
            shared.cache_cap,
            shared.workers,
            uptime_s,
        ),
    )
}

fn ep_shutdown(_: &Endpoint, _: &Request, shared: &Arc<Shared>,
               _: &mut ServeScratch) -> Response {
    shared.shutdown.store(true, Ordering::SeqCst);
    Response::json(
        200,
        &JsonBuilder::new().str("status", "shutting-down").build(),
    )
}

/// The one handler behind every simulation endpoint: strict query
/// parse, typed body parse ([`ApiRequest::parse`]), shared fingerprint,
/// then the registry's cache policy. Batched computes surface their
/// arena occupancy as `x-batch` (cache hits and coalesced followers
/// never carry it — they did not sweep).
fn ep_api(ep: &Endpoint, req: &Request, shared: &Arc<Shared>,
          scratch: &mut ServeScratch) -> Response {
    let kind = ep.api.expect("registry row is a typed api endpoint");
    let stream = match parse_query(req, ep.allow_stream) {
        Ok(s) => s,
        Err(resp) => return resp,
    };
    let body = match req.body_str() {
        Ok(b) => b,
        Err(e) => return Response::error(e.status, &e.msg),
    };
    let areq = match ApiRequest::parse(kind, body, stream, &shared.base) {
        Ok(a) => a,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    // Cost-aware admission: the ladder + token bucket price the parsed
    // request before any compute. Shedding here is the cheapest
    // possible refusal — envelope out, worker freed.
    let cost = areq.cost_estimate();
    match shared.admission.check(shared.health(), cost,
                                 shared.queue.len(), shared.workers) {
        Verdict::Admit => {}
        Verdict::Shed { status, retry_after_s, msg } => {
            if status == 429 {
                shared.metrics.rate_limited();
            } else {
                shared.metrics.shed();
            }
            return Response::error(status, &msg)
                .with_header("retry-after", &retry_after_s.to_string());
        }
    }
    // Per-endpoint-class circuit breaker: a class that keeps failing
    // fails fast until its half-open probe proves recovery.
    if let Err(remaining_s) = shared.admission.breaker(kind).admit() {
        shared.metrics.shed();
        let retry = (remaining_s.ceil() as u64)
            .clamp(1, admit::RETRY_AFTER_MAX_S);
        return Response::error(
            503,
            &format!("circuit open for {}; failing fast", req.path),
        )
        .with_header("retry-after", &retry.to_string());
    }
    let key = areq.fingerprint();
    let occupancy: Cell<Option<usize>> = Cell::new(None);
    let resp = if ep.cached {
        serve_cached(shared, key, || {
            compute_api(areq, shared, scratch, &occupancy)
        })
    } else {
        match compute_api(areq, shared, scratch, &occupancy) {
            Ok(c) => c.to_response("bypass"),
            Err(e) => Response::error(500, &format!("{e:#}")),
        }
    };
    // Feed the breaker the admitted request's outcome (5xx = failure;
    // a 504 is a timeout in breaker terms).
    shared.admission.breaker(kind).record(resp.status >= 500);
    match occupancy.get() {
        Some(n) => resp.with_header("x-batch", &n.to_string()),
        None => resp,
    }
}

/// The shared serving discipline: cache, coalesce, or compute — all
/// under the configured deadline, when there is one.
fn serve_cached<F>(shared: &Arc<Shared>, key: u64, compute: F) -> Response
where
    F: FnOnce() -> Result<CachedResponse>,
{
    let t0 = Instant::now();
    let lookup_span = crate::obs::span("cache_lookup");
    let hit = shared.cache.get(key);
    drop(lookup_span);
    if let Some(c) = hit {
        shared.metrics.cache_hit();
        return c.to_response("hit");
    }
    match shared.inflight.claim(key) {
        Claim::Follower(slot) => {
            shared.metrics.coalesce();
            let _wait_span = crate::obs::span("coalesce_wait");
            match shared.deadline {
                // Bounded wait: give up on the leader at the deadline.
                // The slot is untouched — the leader still publishes
                // and caches, so this client's retry hits the cache.
                Some(d) => match slot.wait_timeout(d) {
                    Some(c) => answer(c, "coalesced", shared),
                    None => deadline_response("coalesced", shared),
                },
                None => answer(slot.wait(), "coalesced", shared),
            }
        }
        Claim::Leader(slot) => {
            // Double-check the cache now that we hold leadership: a
            // previous leader for this key may have completed between
            // our fast-path cache check and the claim. Without this a
            // successfully cached request could be recomputed; with it,
            // a successful simulation runs exactly once per key
            // (errors are not cached, so those may legitimately rerun).
            if let Some(c) = shared.cache.get(key) {
                shared.metrics.cache_hit();
                shared.inflight.complete(key, &slot, c.clone());
                return c.to_response("hit");
            }
            shared.metrics.cache_miss();
            let compute_span = crate::obs::span("compute");
            let outcome = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(compute),
            );
            drop(compute_span);
            let (resp, cacheable) = match outcome {
                // Only *successful* bodies enter the cache: an Ok carry
                // can be a deadline 504 minted on the batch path, and
                // error envelopes must never be replayed as hits.
                Ok(Ok(c)) => {
                    let ok = c.status < 400;
                    (c, ok)
                }
                Ok(Err(e)) => (error_cached(500, &format!("{e:#}")), false),
                Err(_) => (error_cached(500, "simulation panicked"), false),
            };
            if cacheable
                && shared.cache.insert(key, resp.clone()).is_some()
            {
                shared.metrics.cache_evicted();
            }
            // Must always run, or followers would wait forever. The
            // real result is published even when the leader itself is
            // over budget — followers with time left still get it.
            shared.inflight.complete(key, &slot, resp.clone());
            if let Some(d) = shared.deadline {
                if resp.status < 400 && t0.elapsed() > d {
                    // Computed, cached, published — but this client's
                    // budget is spent; answer what the deadline
                    // contract promises.
                    return deadline_response("miss", shared);
                }
            }
            answer(resp, "miss", shared)
        }
    }
}

/// Strict query parsing, mirroring the strict body contract: the only
/// recognized parameter is `stream` (and only where `allow_stream`),
/// with an explicit boolean value — a typo like `steam=1` or
/// `stream=yes` is a 400, never a silently ignored default.
fn parse_query(req: &Request, allow_stream: bool) -> Result<bool, Response> {
    let mut stream = false;
    for (k, v) in &req.query {
        if k == "stream" && allow_stream {
            match v.as_str() {
                "1" | "true" => stream = true,
                "0" | "false" => stream = false,
                other => {
                    return Err(Response::error(
                        400,
                        &format!(
                            "query parameter 'stream' must be \
                             0|1|true|false, got '{other}'"
                        ),
                    ))
                }
            }
        } else {
            return Err(Response::error(
                400,
                &format!("unknown query parameter '{k}'"),
            ));
        }
    }
    Ok(stream)
}

/// Compute one typed request. SoA-native `/simulate` and `/fleet` jobs
/// go through the continuous-batching admission window when the server
/// has one; everything else (sweeps, pinned backends/kernels, fleet
/// requests with `megabatch: false`) computes solo exactly as before.
/// Either way the response bytes are identical — batching is an
/// execution shape, not a result shape.
fn compute_api(areq: ApiRequest, shared: &Arc<Shared>,
               scratch: &mut ServeScratch,
               occupancy: &Cell<Option<usize>>) -> Result<CachedResponse> {
    // Chaos site `server_compute`: an injected panic unwinds into
    // `serve_cached`'s catch, which publishes a 500 envelope to every
    // follower and leaves the cache untouched — the containment path a
    // real simulation panic would take. (Only the panic kind is
    // meaningful here; a poison-NaN plan is a no-op at this site.)
    if inject::armed() {
        let _ = inject::fire(Site::ServerCompute, None);
    }
    match areq {
        ApiRequest::Simulate { sim, stream } => {
            if let Some(b) = &shared.batch {
                if megabatch::precheck(&sim.cfg) {
                    let (resp, n) = b
                        .submit(BatchJob::sim(sim, stream)?, shared.deadline)?;
                    if resp.status < 400 {
                        occupancy.set(Some(n));
                    }
                    return Ok(resp);
                }
            }
            compute_simulate(sim, stream, scratch)
        }
        ApiRequest::Fleet(fc) => {
            if let Some(b) = &shared.batch {
                if fc.megabatch && megabatch::precheck(&fc.base) {
                    let (resp, n) =
                        b.submit(BatchJob::fleet(fc)?, shared.deadline)?;
                    if resp.status < 400 {
                        occupancy.set(Some(n));
                    }
                    return Ok(resp);
                }
            }
            compute_fleet(fc)
        }
        ApiRequest::Sweep(sr) => compute_sweep(sr),
        ApiRequest::Optimize(oc) => compute_optimize(oc),
    }
}

fn compute_optimize(oc: crate::optimize::OptimizeConfig)
                    -> Result<CachedResponse> {
    let run = crate::optimize::run_optimize(&oc)?;
    let _ser_span = crate::obs::span("serialize");
    Ok(CachedResponse {
        status: 200,
        content_type: "application/json".into(),
        // Exactly the `idatacool optimize --json` document.
        body: Arc::new(run.to_json(&oc).into_bytes()),
    })
}

fn compute_simulate(sim: api::SimRequest, stream: bool,
                    scratch: &mut ServeScratch) -> Result<CachedResponse> {
    let sample_every = sim.sample_every;
    let mut driver = SimulationDriver::new(sim.cfg)?;
    let kernel = driver.backend.kernel_name();
    // The worker's reusable tick/observation buffer: `run_into` resets
    // it (size + zero) so a reused buffer behaves exactly like a fresh
    // allocation — responses stay bitwise identical across workers.
    let res = driver.run_into(sample_every, &mut scratch.out)?;
    let cfg = &driver.cfg;
    let _ser_span = crate::obs::span("serialize");
    if stream {
        Ok(CachedResponse {
            status: 200,
            content_type: "application/x-ndjson".into(),
            body: Arc::new(api::trace_ndjson(cfg, kernel, sample_every, &res)),
        })
    } else {
        Ok(CachedResponse {
            status: 200,
            content_type: "application/json".into(),
            body: Arc::new(
                api::simulate_summary_json(cfg, kernel, sample_every, &res)
                    .to_string()
                    .into_bytes(),
            ),
        })
    }
}

fn compute_fleet(fc: crate::fleet::FleetConfig) -> Result<CachedResponse> {
    let driver = FleetDriver::new(fc)?;
    let run = driver.run()?;
    let _ser_span = crate::obs::span("serialize");
    Ok(CachedResponse {
        status: 200,
        content_type: "application/json".into(),
        // Exactly the `idatacool fleet --json` document.
        body: Arc::new(run.to_json(&driver.cfg).into_bytes()),
    })
}

fn compute_sweep(sr: api::SweepRequest) -> Result<CachedResponse> {
    let opts = sr.options();
    let data =
        sweep::run_sweep_sharded(&sr.cfg, &sr.setpoints, &opts, sr.shards)?;
    let _ser_span = crate::obs::span("serialize");
    let body = JsonBuilder::new()
        .str("schema", "idatacool-sweep/1")
        .bool("quick", sr.quick)
        .arr(
            "setpoints",
            sr.setpoints.iter().map(|&s| crate::util::json::Json::Num(s)).collect(),
        )
        .set("data", data.to_json_value())
        .build()
        .to_string();
    Ok(CachedResponse {
        status: 200,
        content_type: "application/json".into(),
        body: Arc::new(body.into_bytes()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_resolution_matches_cli_discipline() {
        assert!(resolve_workers(0).is_err());
        assert_eq!(resolve_workers(1).unwrap(), 1);
        assert_eq!(resolve_workers(MAX_WORKERS).unwrap(), MAX_WORKERS);
        assert_eq!(resolve_workers(MAX_WORKERS + 100).unwrap(), MAX_WORKERS);
    }

    #[test]
    fn bind_rejects_degenerate_options() {
        let base = SimConfig::test_small();
        let mut o = ServeOptions::new(base.clone());
        o.cfg.addr = "127.0.0.1:0".into();
        o.cfg.cache_cap = 0;
        assert!(Server::bind(o).is_err());
        let mut o = ServeOptions::new(base.clone());
        o.cfg.addr = "127.0.0.1:0".into();
        o.cfg.workers = 0;
        assert!(Server::bind(o).is_err());
        let mut o = ServeOptions::new(base.clone());
        o.cfg.addr = "127.0.0.1:0".into();
        o.cfg.queue_cap = 0;
        assert!(Server::bind(o).is_err());
        let mut o = ServeOptions::new(base.clone());
        o.cfg.addr = "127.0.0.1:0".into();
        o.cfg.batch_max_plants = 0;
        assert!(Server::bind(o).is_err());
        let mut o = ServeOptions::new(base);
        o.cfg.addr = "127.0.0.1:0".into();
        o.cfg.max_parked = 0;
        assert!(Server::bind(o).is_err());
    }

    #[test]
    fn ephemeral_bind_resolves_port() {
        let mut o = ServeOptions::new(SimConfig::test_small());
        o.cfg.addr = "127.0.0.1:0".into();
        o.cfg.workers = 1;
        let s = Server::bind(o).unwrap();
        assert_ne!(s.local_addr().port(), 0);
    }

    #[test]
    fn error_responses_carry_the_cache_header() {
        let c = error_cached(500, "boom");
        let r = c.to_response("miss");
        assert_eq!(r.status, 500);
        assert!(r
            .headers
            .iter()
            .any(|(k, v)| k == "x-cache" && v == "miss"));
        // And the body is the structured envelope, like every other
        // error path.
        let s = String::from_utf8((*c.body).clone()).unwrap();
        assert!(s.contains("\"idatacool-error/1\""));
        assert!(s.contains("\"internal_error\""));
    }

    #[test]
    fn version_prefix_splits_and_legacy_paths_resolve() {
        assert_eq!(split_version("/v1/simulate"), ("/simulate", true));
        assert_eq!(split_version("/simulate"), ("/simulate", false));
        assert_eq!(split_version("/v1/"), ("/", true));
        // Not a version segment: "/v12" must not strip.
        assert_eq!(split_version("/v12/simulate"), ("/v12/simulate", false));
        assert_eq!(split_version("/v1"), ("/v1", false));
        // Every registry path resolves both ways to the same row.
        for ep in ENDPOINTS {
            let v1 = format!("/v1{}", ep.path);
            assert_eq!(split_version(&v1), (ep.path, true));
        }
    }

    #[test]
    fn registry_rows_are_unique_and_typed_rows_are_cached() {
        for (i, a) in ENDPOINTS.iter().enumerate() {
            for b in &ENDPOINTS[i + 1..] {
                assert_ne!(a.path, b.path, "duplicate registry path");
            }
            // Cache policy: exactly the typed endpoints are cached.
            assert_eq!(a.api.is_some(), a.cached);
            // `?stream=` only where the endpoint supports NDJSON.
            if a.allow_stream {
                assert_eq!(a.path, "/simulate");
            }
        }
    }
}
