//! Cost & amortization model (Sect. 2 of the paper).
//!
//! "For us the total cost of the liquid-cooling solution was about 120
//! Euro per node (excluding external infrastructure). While this is more
//! expensive than an air-cooled solution, it is a small fraction of the
//! overall cost and can be amortized quickly by the savings from free
//! cooling and energy reuse."
//!
//! This module quantifies that claim: the retrofit cost against (a) the
//! chiller electricity a conventional air-cooled machine room would have
//! spent on the same heat, (b) the chilled-water credit from the
//! adsorption chiller (the energy-reuse path), and (c) the pump/recooler
//! overhead the liquid loop adds.

/// Economic parameters (2012-ish German industrial prices).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Retrofit cost per node [EUR] (paper: ~120).
    pub cooling_cost_per_node_eur: f64,
    /// Electricity price [EUR/kWh].
    pub eur_per_kwh: f64,
    /// COP of the conventional compression chiller an air-cooled room
    /// would use (electric kW per kW of heat removed = 1/COP).
    pub conventional_chiller_cop: f64,
    /// Electric overhead of the liquid loop: pumps + dry-recooler fans,
    /// as a fraction of the heat transported.
    pub loop_overhead_frac: f64,
    /// Chilled water displaced by the adsorption chiller is valued at the
    /// conventional chiller's electric cost of producing it.
    pub value_chilled_water: bool,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            cooling_cost_per_node_eur: 120.0,
            eur_per_kwh: 0.12,
            conventional_chiller_cop: 3.5,
            loop_overhead_frac: 0.03,
            value_chilled_water: true,
        }
    }
}

/// Outcome of the amortization analysis.
#[derive(Debug, Clone)]
pub struct Amortization {
    pub capex_eur: f64,
    /// Savings rate [EUR/year].
    pub savings_eur_per_year: f64,
    pub payback_years: f64,
    /// Breakdown [EUR/year].
    pub free_cooling_eur_per_year: f64,
    pub reuse_credit_eur_per_year: f64,
    pub loop_overhead_eur_per_year: f64,
}

impl CostModel {
    /// Analyze a steady operating point.
    ///
    /// * `n_nodes` — cluster size;
    /// * `p_ac_w` — cluster electrical power;
    /// * `heat_in_water` — Fig. 7a fraction at the operating temperature;
    /// * `p_chilled_w` — chilled-water power delivered by the adsorption
    ///   chiller (Fig. 6b x transferred power).
    pub fn analyze(&self, n_nodes: usize, p_ac_w: f64, heat_in_water: f64,
                   p_chilled_w: f64) -> Amortization {
        let hours = 24.0 * 365.0;
        let kwh = |w: f64| w / 1000.0 * hours;

        // (a) Free cooling: the heat now carried by water at 65-70 degC
        // needs no compression chiller (dry recooler suffices year-round);
        // an air-cooled room would have spent P_heat / COP_conv electric.
        let p_heat_watercooled = p_ac_w * heat_in_water;
        let free_cooling =
            kwh(p_heat_watercooled / self.conventional_chiller_cop)
                * self.eur_per_kwh;

        // (b) Energy reuse: chilled water produced thermally displaces
        // the same amount produced electrically elsewhere.
        let reuse_credit = if self.value_chilled_water {
            kwh(p_chilled_w / self.conventional_chiller_cop)
                * self.eur_per_kwh
        } else {
            0.0
        };

        // (c) The loop's own pumps and fans.
        let overhead = kwh(p_heat_watercooled * self.loop_overhead_frac)
            * self.eur_per_kwh;

        let savings = free_cooling + reuse_credit - overhead;
        let capex = self.cooling_cost_per_node_eur * n_nodes as f64;
        Amortization {
            capex_eur: capex,
            savings_eur_per_year: savings,
            payback_years: if savings > 0.0 { capex / savings } else { f64::INFINITY },
            free_cooling_eur_per_year: free_cooling,
            reuse_credit_eur_per_year: reuse_credit,
            loop_overhead_eur_per_year: overhead,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's operating point: 216 nodes, ~50 kW AC, heat-in-water
    /// ~0.45 at 70 degC, ~9 kW chilled water.
    fn paper_point() -> Amortization {
        CostModel::default().analyze(216, 50_000.0, 0.45, 6_500.0)
    }

    #[test]
    fn amortizes_quickly() {
        let a = paper_point();
        assert!((20_000.0..30_000.0).contains(&a.capex_eur));
        // "can be amortized quickly": payback well under 5 years
        assert!(a.payback_years < 5.0, "payback {:.1} y", a.payback_years);
        assert!(a.payback_years > 0.5, "implausibly fast {:.1} y",
                a.payback_years);
    }

    #[test]
    fn free_cooling_dominates() {
        let a = paper_point();
        assert!(a.free_cooling_eur_per_year > a.reuse_credit_eur_per_year);
        assert!(a.loop_overhead_eur_per_year
                < 0.2 * a.free_cooling_eur_per_year);
    }

    #[test]
    fn no_reuse_credit_variant() {
        let m = CostModel { value_chilled_water: false, ..Default::default() };
        let a = m.analyze(216, 50_000.0, 0.45, 6_500.0);
        assert_eq!(a.reuse_credit_eur_per_year, 0.0);
        assert!(a.payback_years > paper_point().payback_years);
    }

    #[test]
    fn amortization_math_is_pinned() {
        // Pin each term of analyze() against the closed forms the
        // module docs promise, at an easy round-number operating point.
        let m = CostModel::default();
        let a = m.analyze(100, 10_000.0, 0.5, 1_000.0);
        let hours = 24.0 * 365.0;
        let kwh = |w: f64| w / 1000.0 * hours;
        let p_heat = 10_000.0 * 0.5;
        let free = kwh(p_heat / 3.5) * 0.12;
        let reuse = kwh(1_000.0 / 3.5) * 0.12;
        let overhead = kwh(p_heat * 0.03) * 0.12;
        assert_eq!(a.capex_eur, 120.0 * 100.0);
        assert!((a.free_cooling_eur_per_year - free).abs() < 1e-9);
        assert!((a.reuse_credit_eur_per_year - reuse).abs() < 1e-9);
        assert!((a.loop_overhead_eur_per_year - overhead).abs() < 1e-9);
        let savings = free + reuse - overhead;
        assert!((a.savings_eur_per_year - savings).abs() < 1e-9);
        assert!((a.payback_years - a.capex_eur / savings).abs() < 1e-9);
    }

    #[test]
    fn terms_scale_linearly() {
        let m = CostModel::default();
        let a = m.analyze(100, 10_000.0, 0.5, 1_000.0);
        // capex linear in node count, payback with it (same savings)
        let b = m.analyze(200, 10_000.0, 0.5, 1_000.0);
        assert!((b.capex_eur - 2.0 * a.capex_eur).abs() < 1e-9);
        assert!((b.payback_years - 2.0 * a.payback_years).abs() < 1e-9);
        // free cooling and overhead linear in the cluster power
        let c = m.analyze(100, 20_000.0, 0.5, 1_000.0);
        assert!((c.free_cooling_eur_per_year
                 - 2.0 * a.free_cooling_eur_per_year)
            .abs() < 1e-9);
        assert!((c.loop_overhead_eur_per_year
                 - 2.0 * a.loop_overhead_eur_per_year)
            .abs() < 1e-9);
        // reuse credit linear in the chilled-water power
        let d = m.analyze(100, 10_000.0, 0.5, 2_000.0);
        assert!((d.reuse_credit_eur_per_year
                 - 2.0 * a.reuse_credit_eur_per_year)
            .abs() < 1e-9);
    }

    #[test]
    fn zero_savings_is_infinite_payback() {
        let m = CostModel {
            conventional_chiller_cop: 1e12,
            value_chilled_water: false,
            ..Default::default()
        };
        let a = m.analyze(216, 50_000.0, 0.45, 0.0);
        assert!(a.payback_years.is_infinite());
    }
}
