//! `idatacool` — launcher for the iDataCool digital twin.
//!
//! Subcommands:
//!   run         simulate a configuration and print the run report
//!   fleet       sharded multi-plant fleet + shared facility loop
//!   optimize    closed-loop operating-point search over the fleet path
//!   serve       sim-as-a-service HTTP server (v1 API, request batching)
//!   figures     regenerate the paper's figures (CSV + ASCII)
//!   equilibrium the Sect.-3 cold-start narrative (alias: figures --fig s3)
//!   bench       registered benchmark suites + perf-regression gate
//!   validate    cross-backend validation + fault-injection checks
//!   info        artifact / manifest / platform info
//!
//! Examples:
//!   idatacool run --preset full --duration 3600 --setpoint 67
//!   idatacool fleet --plants 8 --scenario heatwave --shards 4
//!   idatacool fleet --plants 8 --scenario heatwave --json fleet.json
//!   idatacool fleet --plants 8 --megabatch 0   # per-plant reference path
//!   idatacool optimize --objective ere --budget 20 --seed 7 --json opt.json
//!   idatacool optimize --driver cem --axes setpoint,pump --budget 40
//!   idatacool serve --addr 127.0.0.1:8080 --workers 4 --batch-window-ms 2
//!   idatacool figures --fig all --quick --out results
//!   idatacool bench --suite hotpath --json BENCH_hotpath.json
//!   idatacool bench --suite all --json . --compare bench/baseline.json
//!   idatacool validate --faults

use std::path::PathBuf;

use anyhow::Result;

use idatacool::config::SimConfig;
use idatacool::coordinator::SimulationDriver;
use idatacool::figures::{self, sweep::SweepOptions};
use idatacool::fleet::scenario::Scenario;
use idatacool::fleet::{FleetConfig, FleetDriver};
use idatacool::runtime::manifest::Manifest;
use idatacool::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("serve") => cmd_serve(&args),
        Some("figures") => cmd_figures(&args),
        Some("equilibrium") => cmd_figures_with(&args, "s3"),
        Some("bench") => cmd_bench(&args),
        Some("validate") => cmd_validate(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
idatacool — digital twin of the iDataCool hot-water-cooled HPC system

USAGE: idatacool <run|fleet|optimize|serve|figures|equilibrium|bench|validate|info> [flags]

common flags:
  --config <file.toml>   load a TOML config (presets: full|subset13|test_small)
  --preset <name>        start from a preset instead of the default
  --nodes <n>            cluster size (artifact must exist for hlo backend)
  --backend <hlo|native|auto>
  --kernel <soa|reference|auto>
                         native substep kernel (auto: IDATACOOL_KERNEL
                         env override, then the lane-major SoA default;
                         \"reference\" is the node-major oracle)
  --artifacts <dir>      artifacts directory (default: artifacts)
  --duration <s>         simulated duration
  --setpoint <degC>      rack-outlet setpoint
  --workload <stress|production|idle>
  --seed <n>
  --trace-out <path>     (run|fleet|bench) record tick/request phase spans
                         and write a Chrome trace_event JSON (load in
                         Perfetto / chrome://tracing); tracing never
                         changes simulation results
  --chaos <spec>         (run|fleet|serve) arm deterministic fault
                         injection: \"[seed=N;]site=...,kind=...[,plant=P]
                         [,tick=T];...\" with sites plant_tick|
                         megabatch_sweep|facility_step|server_compute|
                         optimize_eval|worker_tick and
                         kinds panic|stall_ms|poison_nan; fired rules are
                         reported after the run (env IDATACOOL_CHAOS and a
                         --config [chaos] section arm the same injector;
                         flags win over env, env wins over TOML)
fleet flags:
  --plants <n>           number of plants in the fleet (default 4)
  --shards <k>           OS threads to shard plants over (default: cores;
                         plants split into contiguous index blocks)
  --scenario <name>      baseline|heatwave|chiller-outage|pump-degradation|
                         load-surge|mixed (default baseline)
  --megabatch <0|1>      tick-lockstep each shard's plants over one shared
                         SoA lane arena (default on; env override
                         IDATACOOL_FLEET_MEGABATCH, strict-parsed; bitwise
                         identical to the per-plant path either way)
  --json <path>          also write the machine-readable fleet summary
                         (idatacool-fleet/1: PUE/ERE aggregates, per-plant
                         credits, quarantine report, determinism
                         fingerprint — the same document POST /fleet
                         serves)
  --checkpoint <path>    write a crash-consistent idatacool-ckpt/1
                         snapshot (atomic tmp+rename) every
                         --checkpoint-every ticks; forces the 1-shard
                         lockstep path
  --checkpoint-every <n> snapshot cadence in ticks (requires --checkpoint)
  --resume <path>        restart from a snapshot; the resumed run
                         reproduces the uninterrupted fingerprint and
                         --json bytes exactly
  (common flags above configure the per-plant base; a --config file's
   [fleet] section sets plants/shards/megabatch, flags win over env, env
   wins over TOML; every scenario except baseline sets the workload
   itself, and backend \"auto\" resolves to native for fleet runs)
optimize flags:
  --objective <name>     ere|pue|cost weight preset (default ere; lower
                         score is better)
  --driver <name>        grid|coordinate|cem (default grid: exhaustive
                         lattice + random restarts; coordinate: descent
                         with restarts; cem: cross-entropy refits)
  --budget <n>           physical-evaluation budget (default 24; cache
                         hits are free; env IDATACOOL_OPT_BUDGET)
  --plants <n>           plants per candidate fleet (default 2)
  --scenario <name>      candidate-fleet scenario (default mixed — its
                         stress plant is the throttle signal)
  --axes <csv>           free axes: setpoint|pump|chiller|share
                         (default setpoint only — the paper's 1-D sweep
                         as a degenerate grid search)
  --gen-size <n>         candidates per generation (default 8)
  --eval-duration <s>    simulated seconds per candidate (default 900)
  --detail <0|1>         re-measure the winner with the sweep instrument
                         and attach it as best_detail (default 1)
  --w-pue|--w-ere|--w-throttle|--w-cost <x>
                         override individual objective weights after the
                         preset is applied
  --json <path>          write the idatacool-optimize/1 report (the same
                         bytes POST /v1/optimize serves); a fixed --seed
                         reproduces the whole trajectory bitwise
  (a --config file's [optimize] section sets the same knobs; flags win
   over env IDATACOOL_OPT_OBJECTIVE/IDATACOOL_OPT_DRIVER/
   IDATACOOL_OPT_BUDGET, env wins over TOML; common flags configure the
   candidate base plant, and backend \"auto\" resolves to native)
serve flags:
  --addr <host:port>     bind address (default 127.0.0.1:8080; :0 picks an
                         ephemeral port)
  --workers <k>          worker threads (default: cores; env override
                         IDATACOOL_SERVE_WORKERS, strict-parsed)
  --cache-cap <n>        LRU response-cache entries (default 64)
  --queue-cap <n>        bounded job queue; overflow answers 503
  --batch-window-ms <ms> continuous-batching admission window (default 2;
                         0 disables batching; env override
                         IDATACOOL_SERVE_BATCH_WINDOW_MS)
  --batch-max-plants <n> most plants per batched arena sweep (default 16)
  --deadline-ms <ms>     per-request wall-clock budget; overruns answer a
                         504 idatacool-error/1 envelope with Retry-After
                         (0 = unbounded, the default; the result is still
                         cached, so an immediate retry is a hit)
  --max-parked <n>       most keep-alive connections parked between
                         requests (default 1024, must be >= 1; overflow
                         answers 503; env IDATACOOL_SERVE_MAX_PARKED)
  --rate-limit <n>       cost-aware admission budget in cost units/s
                         (cost ~ simulated ticks x plants; burst = 4s of
                         refill; 0 = unlimited, the default; over-budget
                         requests answer 429 with a computed Retry-After;
                         env IDATACOOL_SERVE_RATE_LIMIT)
  --restart-budget <n>   supervised-worker respawns before the pool stops
                         healing (default 16; 0 disables respawning; env
                         IDATACOOL_SERVE_RESTART_BUDGET)
  (a --config file's [serve] section sets the same knobs; flags win over
   env, env wins over TOML. Endpoints under /v1 — POST /v1/simulate
   [?stream=1], POST /v1/fleet, POST /v1/sweep, POST /v1/optimize,
   GET /v1/healthz, GET /v1/metrics, POST /v1/shutdown; unprefixed paths
   still answer but
   carry a Deprecation header. SIGTERM/SIGINT drain gracefully, same as
   POST /v1/shutdown)
figures flags:
  --fig <id|all|sweep>   4a 4b 5a 5b 6a 6b 7a 7b r1 s3 r2 manifold binning econ
  --out <dir>            write CSVs here (default: results)
  --quick                short settle/measure windows (CI-sized)
bench flags:
  --suite <name|all>     registered suite (hotpath|fleet; default all)
  --filter <substring>   run only benches whose id contains <substring>
                         (suite setup still runs; skipped benches are
                         absent from the report — missing-vs-baseline
                         is a warning, never a gate failure)
  --json <path>          write BENCH_<suite>.json (file for one suite,
                         directory for several); BENCH_FAST=1 shrinks runs
  --compare <baseline>   gate against bench/baseline.json-style file
  --max-regress <pct>    regression threshold for --compare (default 25)
  --baseline-out <path>  write all suite reports as a new baseline file
                         (refuses --filter: partial baselines un-gate)
  --list                 list registered suites
validate flags:
  --faults               include fault-injection scenarios
  --ticks <n>            trajectory length for backend comparison
";

/// Arm the flight recorder when `--trace-out` is present: enable span
/// recording and clear any prior rings. Returns the output path so the
/// caller can flush once the work completes.
fn trace_out_arm(args: &Args) -> Option<PathBuf> {
    let path = args.get("trace-out").map(PathBuf::from)?;
    idatacool::obs::trace::reset();
    idatacool::obs::enable();
    Some(path)
}

/// Flush the recorder's rings to `path` as Chrome `trace_event` JSON and
/// disarm it.
fn trace_out_flush(path: &std::path::Path) -> Result<()> {
    idatacool::obs::disable();
    idatacool::obs::trace::write_chrome_trace(path)?;
    println!("wrote trace {}", path.display());
    Ok(())
}

/// Arm the chaos injector from (rising precedence) the config file's
/// `[chaos]` section, the `IDATACOOL_CHAOS` env var, and the `--chaos`
/// flag — the same TOML < env < flag ladder every other knob uses. The
/// env/flag spec may carry its own seed (`seed=N;plan`); the TOML
/// section keeps seed and plan separate. Returns whether a plan was
/// armed, so the caller knows to print the injected-event log.
fn chaos_arm(
    args: &Args,
    doc: Option<&idatacool::config::toml::TomlDoc>,
) -> Result<bool> {
    use idatacool::resilience::inject;
    let spec = args.get("chaos").map(str::to_string).or_else(|| {
        std::env::var("IDATACOOL_CHAOS")
            .ok()
            .filter(|s| !s.trim().is_empty())
    });
    if let Some(spec) = spec {
        inject::arm_spec(&spec)?;
        return Ok(true);
    }
    if let Some(doc) = doc {
        let cs = idatacool::config::ChaosSettings::from_toml(doc)?;
        if let Some(plan) = &cs.plan {
            inject::arm(plan, cs.seed.unwrap_or(0))?;
            return Ok(true);
        }
    }
    Ok(false)
}

/// Print and drain the injected-event log after a chaos-armed run.
fn chaos_report(armed: bool) {
    if !armed {
        return;
    }
    let events = idatacool::resilience::inject::take_log();
    if events.is_empty() {
        println!("chaos: plan armed, no rule fired");
    }
    for e in events {
        println!("chaos: fired {e}");
    }
}

/// Read and parse `--config` once; `None` when the flag is absent.
fn load_config_doc(args: &Args)
                   -> Result<Option<idatacool::config::toml::TomlDoc>> {
    match args.get("config") {
        None => Ok(None),
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("read {path}: {e}"))?;
            Ok(Some(idatacool::config::toml::TomlDoc::parse(&text)?))
        }
    }
}

fn build_config(args: &Args) -> Result<SimConfig> {
    build_config_with(args, load_config_doc(args)?.as_ref())
}

fn build_config_with(
    args: &Args,
    doc: Option<&idatacool::config::toml::TomlDoc>,
) -> Result<SimConfig> {
    let mut cfg = if let Some(doc) = doc {
        SimConfig::from_toml_doc(doc)?
    } else {
        match args.str_or("preset", "full") {
            "full" => SimConfig::idatacool_full(),
            "subset13" => SimConfig::subset13(),
            "test_small" => SimConfig::test_small(),
            other => anyhow::bail!("unknown preset '{other}'"),
        }
    };
    cfg.n_nodes = args.usize_or("nodes", cfg.n_nodes);
    cfg.backend = args.str_or("backend", &cfg.backend).to_string();
    cfg.kernel = args.str_or("kernel", &cfg.kernel).to_string();
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(d);
    }
    cfg.duration_s = args.f64_or("duration", cfg.duration_s);
    cfg.t_out_setpoint = args.f64_or("setpoint", cfg.t_out_setpoint);
    if let Some(w) = args.get("workload") {
        cfg.workload = w.parse()?;
    }
    cfg.seed = args.f64_or("seed", cfg.seed as f64) as u64;
    // Load plant constants from artifacts when available, so native ==
    // HLO numerics.
    cfg.pp = idatacool::config::constants::PlantParams::from_artifacts(
        &cfg.artifacts_dir,
    );
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let doc = load_config_doc(args)?;
    let cfg = build_config_with(args, doc.as_ref())?;
    println!(
        "run '{}': {} nodes, backend={}, workload={:?}, {}s sim",
        cfg.name, cfg.n_nodes, cfg.backend, cfg.workload, cfg.duration_s
    );
    let chaos = chaos_arm(args, doc.as_ref())?;
    let trace_out = trace_out_arm(args);
    let mut driver = SimulationDriver::new(cfg)?;
    let tick_s = driver.backend.tick_seconds(&driver.cfg.pp);
    let kernel = driver.backend.kernel_name();
    let res = driver.run(12)?;
    if let Some(path) = &trace_out {
        trace_out_flush(path)?;
    }
    chaos_report(chaos);
    println!("backend: {} (kernel: {})", res.backend, kernel);
    println!("{}", res.energy.summary());
    println!("workload: {}", res.workload_stats);
    println!(
        "perf: {} ticks in {:.2}s wall ({:.0}x realtime; plant {:.1}% of wall)",
        res.ticks,
        res.total_wall_s,
        res.speedup(tick_s),
        100.0 * res.plant_wall_s / res.total_wall_s.max(1e-9),
    );
    for e in res.events.iter().take(10) {
        println!("event @{:.0}s: {}", e.t_s, e.msg);
    }
    if let Some(last) = res.trace.last() {
        println!(
            "final: T_out={:.1} T_in={:.1} T_tank={:.1} P_ac={:.1}kW \
             COP_inst={:.2} valve={:.2} throttling={}",
            last.t_rack_out,
            last.t_rack_in,
            last.t_tank,
            last.p_ac / 1e3,
            if last.p_d > 1.0 { last.p_c / last.p_d } else { 0.0 },
            last.valve,
            last.throttling
        );
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<()> {
    use idatacool::config::FleetSettings;

    // One read+parse of --config serves both consumers: the SimConfig
    // base and the [fleet] section.
    let doc = load_config_doc(args)?;
    let mut base = build_config_with(args, doc.as_ref())?;
    // Fleet runs shard plant backends across threads; resolve the default
    // "auto" to the artifact-independent native backend, but respect a
    // backend pinned via --backend or a config file.
    if base.backend == "auto" {
        base.backend = "native".into();
    }
    let mut fs = FleetSettings::default();
    if let Some(doc) = &doc {
        fs = FleetSettings::from_toml(doc)?;
    }
    let n_plants = args.usize_strict("plants", fs.plants.unwrap_or(4))?;
    anyhow::ensure!(
        n_plants >= 1,
        "--plants must be at least 1 (a fleet needs at least one plant)"
    );
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let shards_req = args
        .usize_strict("shards", fs.shards.unwrap_or(cores.min(n_plants)))?;
    anyhow::ensure!(
        shards_req >= 1,
        "--shards must be at least 1 (use 1 for a serial run)"
    );
    // Clamp exactly as FleetDriver::run will, so the header matches what
    // actually runs — but tell the user instead of doing it silently.
    let shards = if shards_req > n_plants {
        eprintln!(
            "warning: --shards {shards_req} exceeds --plants {n_plants}; \
             clamping to {n_plants} (one shard per plant)"
        );
        n_plants
    } else {
        shards_req
    };
    // Precedence: TOML [fleet] < IDATACOOL_FLEET_MEGABATCH env < flag.
    // The unset-everything default lives in fleet::default_megabatch —
    // the single source the server and bench suites also resolve from.
    let mut megabatch = match idatacool::util::cli::env_bool_strict(
        "IDATACOOL_FLEET_MEGABATCH",
    )? {
        Some(b) => b,
        None => match fs.megabatch {
            Some(b) => b,
            None => idatacool::fleet::default_megabatch()?,
        },
    };
    megabatch = args.bool_strict("megabatch", megabatch)?;
    let scenario = Scenario::by_name(args.str_or("scenario", "baseline"))?;
    let kernel = idatacool::plant::PlantKernel::resolve(&base.kernel)?;

    println!(
        "fleet: {} plants x {} nodes ({} backend, {} kernel), \
         scenario '{}' ({}), {} shards, megabatch {}, {:.0}s sim, \
         fleet seed {:#x}",
        n_plants, base.n_nodes, base.backend, kernel.name(), scenario.name(),
        scenario.description(), shards,
        if megabatch { "on" } else { "off" }, base.duration_s, base.seed,
    );

    // Crash-consistent checkpointing: --checkpoint + --checkpoint-every
    // name the snapshot file and cadence; --resume restarts from one.
    // Both force the 1-shard lockstep path (fleet::run_resilient), and
    // a resumed run reproduces the uninterrupted fingerprint and --json
    // bytes exactly.
    let ckpt_every = args.usize_strict("checkpoint-every", 0)?;
    let ckpt = match (args.get("checkpoint"), ckpt_every) {
        (Some(path), every) if every >= 1 => {
            Some(idatacool::fleet::CheckpointSpec {
                path: PathBuf::from(path),
                every: every as u64,
            })
        }
        (Some(_), _) => anyhow::bail!(
            "--checkpoint needs --checkpoint-every <ticks> (>= 1)"
        ),
        (None, every) if every >= 1 => anyhow::bail!(
            "--checkpoint-every needs --checkpoint <path>"
        ),
        _ => None,
    };
    let resume = args.get("resume").map(PathBuf::from);

    let chaos = chaos_arm(args, doc.as_ref())?;
    let fleet_seed = base.seed;
    let trace_out = trace_out_arm(args);
    let driver = FleetDriver::new(FleetConfig {
        n_plants,
        shards,
        base,
        fleet_seed,
        scenario,
        megabatch,
    })?;
    let run = driver.run_resilient(ckpt.as_ref(), resume.as_deref())?;
    if let Some(path) = &trace_out {
        trace_out_flush(path)?;
    }
    chaos_report(chaos);
    for q in &run.aggregate.quarantined {
        println!("quarantined plant {}: {}", q.index, q.reason);
    }

    for s in run.aggregate.series() {
        println!("{}", s.to_table());
        if s.columns.len() >= 2 && s.rows.len() >= 3 {
            let (xc, yc) = (s.columns[0].clone(), s.columns[1].clone());
            println!("{}", s.ascii_plot(&xc, &yc, 64, 12));
        }
    }
    println!("{}", run.facility.summary());
    println!("{}", run.aggregate.summary());
    println!(
        "fleet perf: {} plants on {} shards in {:.2}s wall",
        run.plants.len(),
        run.shards,
        run.wall_s
    );
    println!(
        "aggregate fingerprint: {:#018x} (shard-count independent)",
        run.aggregate.fingerprint()
    );
    if let Some(path) = args.get("json") {
        let path = PathBuf::from(path);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        // The same serializer backs the server's POST /fleet response,
        // so this file is byte-identical to the served body.
        std::fs::write(&path, run.to_json(&driver.cfg))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<()> {
    use idatacool::config::OptimizeSettings;
    use idatacool::optimize::{run_optimize, OptimizeConfig};

    // One read+parse of --config serves both consumers: the SimConfig
    // base (the candidate plant) and the [optimize] section.
    let doc = load_config_doc(args)?;
    let mut base = build_config_with(args, doc.as_ref())?;
    // Candidate evaluations run on the fleet path, which shards plant
    // backends across threads; resolve "auto" the same way cmd_fleet
    // does, but respect a pinned backend.
    if base.backend == "auto" {
        base.backend = "native".into();
    }
    let mut os = OptimizeSettings::default();
    if let Some(doc) = &doc {
        os = OptimizeSettings::from_toml(doc)?;
    }
    // Precedence: TOML [optimize] < env < flag — the same ladder every
    // other subcommand uses. Env overrides are strict-parsed.
    if let Some(v) = std::env::var("IDATACOOL_OPT_OBJECTIVE")
        .ok()
        .filter(|s| !s.trim().is_empty())
    {
        os.objective = Some(v);
    }
    if let Some(v) = std::env::var("IDATACOOL_OPT_DRIVER")
        .ok()
        .filter(|s| !s.trim().is_empty())
    {
        os.driver = Some(v);
    }
    if let Some(b) =
        idatacool::util::cli::env_usize_strict("IDATACOOL_OPT_BUDGET")?
    {
        os.budget = Some(b);
    }
    if let Some(v) = args.get("objective") {
        os.objective = Some(v.to_string());
    }
    if let Some(v) = args.get("driver") {
        os.driver = Some(v.to_string());
    }
    if let Some(v) = args.get("scenario") {
        os.scenario = Some(v.to_string());
    }
    if let Some(v) = args.get("axes") {
        os.axes = Some(v.to_string());
    }
    os.budget = Some(args.usize_strict("budget", os.budget.unwrap_or(24))?);
    os.plants = Some(args.usize_strict("plants", os.plants.unwrap_or(2))?);
    os.gen_size =
        Some(args.usize_strict("gen-size", os.gen_size.unwrap_or(8))?);
    os.eval_duration_s = Some(args.f64_or(
        "eval-duration",
        os.eval_duration_s.unwrap_or(900.0),
    ));
    os.detail = Some(args.bool_strict("detail", os.detail.unwrap_or(true))?);
    let weight_flag = |name: &str, cur: Option<f64>| -> Result<Option<f64>> {
        match args.get(name) {
            None => Ok(cur),
            Some(s) => Ok(Some(s.parse::<f64>().map_err(|_| {
                anyhow::anyhow!("--{name} expects a number, got '{s}'")
            })?)),
        }
    };
    os.w_pue = weight_flag("w-pue", os.w_pue)?;
    os.w_ere = weight_flag("w-ere", os.w_ere)?;
    os.w_throttle = weight_flag("w-throttle", os.w_throttle)?;
    os.w_cost = weight_flag("w-cost", os.w_cost)?;

    let c = OptimizeConfig::from_settings(base, &os)?;
    let free: Vec<&str> = c
        .space
        .axes()
        .iter()
        .filter(|a| !a.frozen)
        .map(|a| a.name)
        .collect();
    println!(
        "optimize: objective '{}' ({} driver), axes [{}], budget {} \
         physical evals (gen size {}), {} plants x {} nodes per \
         candidate, scenario '{}', {:.0}s eval windows, seed {:#x}",
        c.objective_name,
        c.kind.name(),
        free.join(", "),
        c.budget,
        c.gen_size,
        c.n_plants,
        c.base.n_nodes,
        c.scenario.name(),
        c.eval_duration_s,
        c.seed,
    );

    let chaos = chaos_arm(args, doc.as_ref())?;
    let trace_out = trace_out_arm(args);
    let run = run_optimize(&c)?;
    if let Some(path) = &trace_out {
        trace_out_flush(path)?;
    }
    chaos_report(chaos);

    for g in &run.gens {
        println!(
            "gen {:>3}: {:>3} candidates ({:>3} physical)  \
             best {:>12.6}  mean {:>12.6}",
            g.index, g.submitted, g.physical, g.best, g.mean,
        );
    }
    let failed = run.records.iter().filter(|r| r.failed).count();
    if failed > 0 {
        println!("optimize: {failed} candidate evals failed and were \
                  scored worst-case");
    }
    println!("{}", run.summary(&c));
    if let Some(d) = &run.best_detail {
        let p = &d.point;
        println!(
            "best point re-measured: T_out {:.1} degC, heat-in-water \
             {:.2}, reuse {:.2}, COP {:.2}, P_ac {:.1} kW",
            p.t_out.mean(),
            p.hiw,
            p.reuse,
            p.cop,
            p.p_ac / 1e3,
        );
    }
    println!(
        "trajectory fingerprint: {:#018x} (seed-reproducible, \
         shard-count independent)",
        run.fingerprint()
    );
    if let Some(path) = args.get("json") {
        let path = PathBuf::from(path);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        // The same serializer backs the POST /v1/optimize response, so
        // this file is byte-identical to the served body.
        std::fs::write(&path, run.to_json(&c))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use idatacool::config::ServeConfig;
    use idatacool::server::{resolve_workers, ServeOptions, Server};

    // One read+parse of --config serves both consumers: the SimConfig
    // base and the [serve] section.
    let doc = load_config_doc(args)?;
    let base = build_config_with(args, doc.as_ref())?;
    let mut sc = ServeConfig::default();
    if let Some(doc) = &doc {
        sc = sc.apply_toml(doc)?;
    }
    // Precedence: TOML < env < CLI flag. The env override gets the same
    // strict parse + clamp-with-warning treatment as the flag.
    if let Some(k) =
        idatacool::util::cli::env_usize_strict("IDATACOOL_SERVE_WORKERS")?
    {
        sc.workers = k;
    }
    if let Some(ms) = idatacool::util::cli::env_usize_strict(
        "IDATACOOL_SERVE_BATCH_WINDOW_MS",
    )? {
        sc.batch_window_ms = ms;
    }
    if let Some(n) = idatacool::util::cli::env_usize_strict(
        "IDATACOOL_SERVE_MAX_PARKED",
    )? {
        sc.max_parked = n;
    }
    if let Some(n) = idatacool::util::cli::env_usize_strict(
        "IDATACOOL_SERVE_RATE_LIMIT",
    )? {
        sc.rate_limit = n;
    }
    if let Some(n) = idatacool::util::cli::env_usize_strict(
        "IDATACOOL_SERVE_RESTART_BUDGET",
    )? {
        sc.restart_budget = n;
    }
    sc.workers = resolve_workers(args.usize_strict("workers", sc.workers)?)?;
    sc.addr = args.str_or("addr", &sc.addr).to_string();
    sc.cache_cap = args.usize_strict("cache-cap", sc.cache_cap)?;
    sc.queue_cap = args.usize_strict("queue-cap", sc.queue_cap)?;
    sc.batch_window_ms =
        args.usize_strict("batch-window-ms", sc.batch_window_ms)?;
    sc.batch_max_plants =
        args.usize_strict("batch-max-plants", sc.batch_max_plants)?;
    sc.deadline_ms = args.usize_strict("deadline-ms", sc.deadline_ms)?;
    sc.max_parked = args.usize_strict("max-parked", sc.max_parked)?;
    sc.rate_limit = args.usize_strict("rate-limit", sc.rate_limit)?;
    sc.restart_budget =
        args.usize_strict("restart-budget", sc.restart_budget)?;

    let chaos = chaos_arm(args, doc.as_ref())?;
    let (workers, cache_cap, queue_cap) =
        (sc.workers, sc.cache_cap, sc.queue_cap);
    let batching = if sc.batch_window_ms > 0 {
        format!(
            "batching {}ms/{} plants",
            sc.batch_window_ms, sc.batch_max_plants
        )
    } else {
        "batching off".to_string()
    };
    let deadline = if sc.deadline_ms > 0 {
        format!("deadline {}ms", sc.deadline_ms)
    } else {
        "no deadline".to_string()
    };
    let server = Server::bind(ServeOptions { cfg: sc, base })?;
    println!(
        "serving http://{} — {} workers, cache {} entries, queue {}, {}, {} \
         (POST /v1/simulate | /v1/fleet | /v1/sweep | /v1/optimize, \
         GET /v1/healthz | /v1/metrics, POST /v1/shutdown or SIGTERM \
         to stop)",
        server.local_addr(),
        workers,
        cache_cap,
        queue_cap,
        batching,
        deadline,
    );
    let result = server.run();
    chaos_report(chaos);
    result
}

fn cmd_figures(args: &Args) -> Result<()> {
    let id = args.str_or("fig", "all").to_string();
    cmd_figures_with(args, &id)
}

fn cmd_figures_with(args: &Args, id: &str) -> Result<()> {
    let cfg = build_config(args)?;
    let opts = if args.has("quick") {
        SweepOptions::quick()
    } else {
        SweepOptions::default()
    };
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    let ids: Vec<&str> = if id == "all" {
        // one shared sweep + the standalone experiments
        vec!["sweep", "4b", "r1", "s3", "r2", "manifold", "binning", "econ"]
    } else {
        vec![id]
    };
    for id in ids {
        println!("--- figure {id} ---");
        let t0 = std::time::Instant::now();
        let series = figures::run_figure(id, &cfg, &opts)?;
        for s in &series {
            println!("{}", s.to_table());
            if s.columns.len() >= 2 && s.rows.len() >= 3 {
                let (xc, yc) = (s.columns[0].clone(), s.columns[1].clone());
                println!("{}", s.ascii_plot(&xc, &yc, 64, 14));
            }
            let path = s.save_csv(&out_dir)?;
            println!("saved {}", path.display());
        }
        println!("({:.1}s wall)", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    use idatacool::bench::compare::Comparison;
    use idatacool::bench::record::BaselineFile;
    use idatacool::bench::suites;

    if args.has("list") {
        for s in suites::SUITES {
            println!("{:<10} {}", s.name, s.description);
        }
        return Ok(());
    }

    let which = args.str_or("suite", "all");
    let names: Vec<&'static str> = if which == "all" {
        suites::SUITES.iter().map(|s| s.name).collect()
    } else {
        vec![suites::by_name(which)?.name]
    };
    let max_regress = args.f64_or("max-regress", 25.0);
    let filter = args.get("filter");
    // A filtered run produces a partial report; written as a baseline it
    // would silently drop every filtered-out bench from the regression
    // gate forever (missing baseline entries are never gated).
    anyhow::ensure!(
        !(filter.is_some() && args.has("baseline-out")),
        "--filter cannot be combined with --baseline-out: a partial \
         baseline would permanently un-gate the filtered-out benches"
    );
    let baseline = match args.get("compare") {
        Some(p) => Some(BaselineFile::load(std::path::Path::new(p))?),
        None => None,
    };

    // Armed before the suites run so every BenchResult (and therefore
    // every BENCH_*.json record) carries its per-phase breakdown.
    let trace_out = trace_out_arm(args);
    let mut reports = Vec::new();
    let mut failures: Vec<String> = Vec::new();
    for name in &names {
        let report = suites::run_suite_filtered(name, filter)?;
        if let Some(json) = args.get("json") {
            let path = bench_json_path(json, name, names.len() > 1);
            if let Some(dir) = path.parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            std::fs::write(&path, report.to_json())?;
            println!("wrote {}", path.display());
        }
        if let Some(base) = &baseline {
            match base.find(name) {
                Some(b) => {
                    let cmp = Comparison::build(b, &report, max_regress);
                    print!("{}", cmp.report());
                    for d in cmp.regressions() {
                        failures.push(format!(
                            "{}/{} +{:.1}% (gate {:.0}%)",
                            name, d.id, d.delta_pct, d.threshold_pct
                        ));
                    }
                }
                None => println!(
                    "baseline has no suite '{name}'; nothing gated"
                ),
            }
        }
        reports.push(report);
        println!();
    }

    if let Some(out) = args.get("baseline-out") {
        let path = std::path::Path::new(out);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, BaselineFile { reports }.to_json())?;
        println!("baseline written to {out}");
    }

    // Flush before the gate so a regression failure still leaves the
    // trace on disk for diagnosis.
    if let Some(path) = &trace_out {
        trace_out_flush(path)?;
    }

    anyhow::ensure!(
        failures.is_empty(),
        "perf regression gate failed: {}",
        failures.join("; ")
    );
    Ok(())
}

/// Resolve `--json` into a concrete file path: a directory (or a
/// multi-suite run) gets `BENCH_<suite>.json` inside it; a single suite
/// with a non-directory path writes exactly that file.
fn bench_json_path(arg: &str, suite: &str, multi: bool) -> PathBuf {
    let p = PathBuf::from(arg);
    if p.is_dir() || multi {
        p.join(format!("BENCH_{suite}.json"))
    } else {
        p
    }
}

fn cmd_validate(args: &Args) -> Result<()> {
    use idatacool::plant::layout::*;
    use idatacool::plant::TickOutput;
    use idatacool::runtime::{BackendKind, PlantBackend};

    let cfg = build_config(args)?;
    let ticks = args.usize_or("ticks", 40);
    println!("validate: comparing hlo vs native over {ticks} ticks ...");

    let man = Manifest::load(&cfg.artifacts_dir);
    let n = match &man {
        Ok(m) => m
            .entries
            .iter()
            .map(|e| e.n_nodes)
            .min()
            .unwrap_or(cfg.n_nodes),
        Err(e) => {
            println!("no artifacts ({e}); skipping hlo comparison");
            return cmd_validate_faults(args, &cfg);
        }
    };
    let mut hlo = PlantBackend::create(
        BackendKind::Hlo, &cfg.artifacts_dir, n, &cfg.pp, cfg.seed, 20.0)?;
    // Validate against the node-major reference kernel — the oracle —
    // regardless of the SoA default or env override (SoA-vs-reference
    // parity is covered by proptests::prop_kernel_parity).
    let mut nat = PlantBackend::create_with_kernel(
        BackendKind::Native, idatacool::plant::PlantKernel::Reference,
        &cfg.artifacts_dir, n, &cfg.pp, cfg.seed, 20.0)?;
    let npad = hlo.n_padded();
    let controls = vec![0.0, 1.0, 18.0, 8.0, 9000.0, 0.75, 0.0, 0.0];
    let util = vec![1.0f32; npad * NC];
    let mut oh = TickOutput::new(npad);
    let mut on = TickOutput::new(npad);
    let mut max_dt = 0.0f32;
    let mut max_dsc = 0.0f32;
    for _ in 0..ticks {
        hlo.tick(&controls, &util, &mut oh)?;
        nat.tick(&controls, &util, &mut on)?;
        for (a, b) in hlo.node_state().iter().zip(nat.node_state()) {
            max_dt = max_dt.max((a - b).abs());
        }
        for i in 0..NS {
            let denom = oh.scalars[i].abs().max(1.0);
            max_dsc = max_dsc.max((oh.scalars[i] - on.scalars[i]).abs() / denom);
        }
    }
    println!(
        "max |node_state| divergence: {max_dt:.4} degC; \
         max relative scalar divergence: {max_dsc:.5}"
    );
    anyhow::ensure!(max_dt < 0.5, "backends diverged");
    println!("backends agree OK");
    cmd_validate_faults(args, &cfg)
}

fn cmd_validate_faults(args: &Args, cfg: &SimConfig) -> Result<()> {
    if !args.has("faults") {
        return Ok(());
    }
    println!("fault injection: chiller failure + recovery ...");
    let opts = SweepOptions::quick();
    let series = figures::fault_injection(cfg, &opts)?;
    for n in &series.notes {
        println!("  {n}");
    }
    println!("fault scenarios pass OK");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    println!("idatacool {} — three-layer digital twin", env!("CARGO_PKG_VERSION"));
    match xla::PjRtClient::cpu() {
        Ok(c) => println!(
            "pjrt: platform={} devices={}",
            c.platform_name(),
            c.device_count()
        ),
        Err(e) => println!("pjrt: unavailable ({e})"),
    }
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} (tile={}, seed={:#x})",
                     dir.display(), m.tile, m.seed);
            for e in &m.entries {
                println!(
                    "  n={} padded={} substeps={} hlo={}",
                    e.n_nodes, e.n_padded, e.substeps_per_tick, e.hlo
                );
            }
        }
        Err(e) => println!("artifacts: none ({e})"),
    }
    Ok(())
}
