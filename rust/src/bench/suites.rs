//! Registered benchmark suites for the `idatacool bench` subcommand.
//!
//! Suites are artifact-independent (native backend) so they run anywhere,
//! including CI's `perf-smoke` job. The HLO-backend cases stay in
//! `rust/benches/hotpath.rs`, which layers them on top of the `hotpath`
//! suite when artifacts exist.

use std::path::Path;

use anyhow::Result;

use crate::config::constants::PlantParams;
use crate::config::SimConfig;
use crate::coordinator::telemetry::{SensorSpec, Telemetry};
use crate::coordinator::SimulationDriver;
use crate::figures::sweep::{self, SweepOptions};
use crate::fleet::scenario::Scenario;
use crate::fleet::{FleetConfig, FleetDriver};
use crate::plant::hydraulics::{Manifold, ManifoldKind};
use crate::plant::layout::{G_ADV, IDX_SINK, IDX_WATER, NC, NG, S};
use crate::plant::native::NativePlant;
use crate::plant::node::{self, NodeScratch};
use crate::plant::operators::Operators;
use crate::plant::soa::{self, SoaState};
use crate::plant::{PlantKernel, PlantStatic, TickOutput};
use crate::runtime::{BackendKind, PlantBackend};
use crate::variability::ChipLottery;
use crate::workload::scheduler::BatchScheduler;
use crate::workload::{UtilPlan, WorkloadSource};

use super::record::{config_fingerprint, BenchReport};
use super::{fast_mode, Bench};

/// A registered suite.
pub struct SuiteEntry {
    pub name: &'static str,
    pub description: &'static str,
    runner: fn(&mut Bench) -> Result<()>,
    /// Fingerprint of everything that changes what *this* suite
    /// measures; the comparator disarms when it differs from the
    /// baseline's, so each suite must hash its own knobs.
    fingerprint: fn() -> u64,
}

/// The suite catalog.
pub const SUITES: &[SuiteEntry] = &[
    SuiteEntry {
        name: "hotpath",
        description: "per-layer hot paths: plant tick, coordinator tick, \
                      scheduler, telemetry, manifold solve, lottery draw",
        runner: hotpath,
        fingerprint: hotpath_fingerprint,
    },
    SuiteEntry {
        name: "fleet",
        description: "meso benchmarks: sharded fleet runs and the \
                      serial-vs-parallel setpoint sweep",
        runner: fleet,
        fingerprint: fleet_fingerprint,
    },
    SuiteEntry {
        name: "optimize",
        description: "closed-loop search: one physical candidate eval, \
                      the fingerprint-cache hit path, and a small grid \
                      search end to end",
        runner: optimize,
        fingerprint: optimize_fingerprint,
    },
    SuiteEntry {
        name: "serve",
        description: "sim-as-a-service: loopback request latency \
                      (healthz, cache hit) and full-simulation misses",
        runner: serve,
        fingerprint: serve_fingerprint,
    },
    SuiteEntry {
        name: "serve_batched",
        description: "continuous request batching: concurrent \
                      heterogeneous misses through one shared lane \
                      arena vs the same load with batching off",
        runner: serve_batched,
        fingerprint: serve_batched_fingerprint,
    },
];

pub fn by_name(name: &str) -> Result<&'static SuiteEntry> {
    SUITES.iter().find(|s| s.name == name).ok_or_else(|| {
        let names: Vec<&str> = SUITES.iter().map(|s| s.name).collect();
        anyhow::anyhow!("unknown bench suite '{name}' (have {names:?})")
    })
}

/// Run one suite and package the results as a machine-readable report.
pub fn run_suite(name: &str) -> Result<BenchReport> {
    run_suite_filtered(name, None)
}

/// `run_suite` restricted to benches whose id contains `filter` (the
/// `idatacool bench --filter` path). Suite setup still runs; skipped
/// benches are simply absent from the report, which the baseline
/// comparator treats as a warning, never a gate failure.
pub fn run_suite_filtered(name: &str, filter: Option<&str>)
                          -> Result<BenchReport> {
    let entry = by_name(name)?;
    match filter {
        Some(f) => println!(
            "suite '{}' (filter '{f}'): {}", entry.name, entry.description
        ),
        None => println!("suite '{}': {}", entry.name, entry.description),
    }
    println!("{}", Bench::header());
    let mut b = Bench::from_env();
    b.filter = filter.map(String::from);
    (entry.runner)(&mut b)?;
    Ok(BenchReport::from_results(
        entry.name,
        &reference_config().backend,
        (entry.fingerprint)(),
        fast_mode(),
        &b.results,
    ))
}

/// The full-cluster preset pinned to the native backend — the config the
/// hotpath coordinator bench and the sweep benches actually run.
fn reference_config() -> SimConfig {
    let mut cfg = SimConfig::idatacool_full();
    cfg.backend = "native".into();
    cfg.pp = PlantParams::from_artifacts(&cfg.artifacts_dir);
    cfg
}

fn hotpath_fingerprint() -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
    }
    // The env-resolved kernel changes what plant_tick/coordinator_tick
    // measure, so an IDATACOOL_KERNEL=reference run must not be gated
    // against an SoA baseline.
    let mut h = config_fingerprint(&reference_config());
    let kernel = PlantKernel::from_env()
        .map(|k| k.name())
        .unwrap_or("invalid");
    for b in kernel.bytes() {
        h = mix(h, b as u64);
    }
    h
}

fn fleet_fingerprint() -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
    }
    // Everything the fleet suite measures: the per-plant base config,
    // the fleet shape, the sweep config and its timing knobs. The
    // env-resolved megabatch flag changes what fleet_run measures, so
    // an IDATACOOL_FLEET_MEGABATCH=0 run must not be gated against a
    // megabatch-on baseline (results are bitwise identical, wall time
    // is not).
    let mut h = config_fingerprint(&fleet_base());
    h = mix(h, config_fingerprint(&reference_config()));
    h = mix(h, FLEET_PLANTS as u64);
    let megabatch = match crate::fleet::default_megabatch() {
        Ok(true) => 1u64,
        Ok(false) => 0u64,
        Err(_) => 99u64,
    };
    h = mix(h, megabatch);
    let o = fleet_sweep_opts();
    for v in [o.settle_s, o.measure_s, o.settle_tol, o.max_extra_settle_s] {
        h = mix(h, v.to_bits());
    }
    for sp in SWEEP_SETPOINTS {
        h = mix(h, sp.to_bits());
    }
    h
}

const FLEET_PLANTS: usize = 4;
const SWEEP_SETPOINTS: &[f64] = &[50.0, 59.0, 68.0];

/// Per-plant base of the fleet benches (shared with `fleet_fingerprint`).
fn fleet_base() -> SimConfig {
    let mut base = SimConfig::test_small();
    base.duration_s = 600.0;
    base
}

/// Sweep sizing of the fleet benches (shared with `fleet_fingerprint`).
fn fleet_sweep_opts() -> SweepOptions {
    SweepOptions {
        settle_s: 150.0,
        measure_s: 120.0,
        settle_tol: 3.0,
        max_extra_settle_s: 300.0,
        histogram_samples: 2,
        equilibrium_s: 2000.0,
    }
}

/// Micro/meso hot paths (native mirror of `benches/hotpath.rs`).
fn hotpath(b: &mut Bench) -> Result<()> {
    let art = Path::new("artifacts");
    let pp = PlantParams::from_artifacts(art);

    for &n in &[13usize, 216] {
        let controls = vec![0.0f32, 1.0, 18.0, 8.0, 9000.0, 0.75, 0.0, 0.0];
        let mut nat = PlantBackend::create(
            BackendKind::Native, art, n, &pp, 0x1DA7AC001, 20.0)?;
        let util = vec![1.0f32; nat.n_padded() * NC];
        let mut out = TickOutput::new(nat.n_padded());
        let node_substeps = (n * nat.substeps()) as f64;
        b.run_with_units(
            &format!("plant_tick/native/n{n}"), node_substeps,
            "node-substeps", &mut || {
                nat.tick(&controls, &util, &mut out).unwrap();
            });
    }

    // SoA vs reference kernel head-to-head at n=64 — one full Pallas
    // tile, every lane fully occupied (the fairest layout comparison).
    {
        let n = 64usize;
        let lot = ChipLottery::draw(n, &pp, 0x50A_64);
        let st = PlantStatic::from_lottery(&lot, &pp, 64);
        let ops = Operators::build(&pp);
        let npad = st.n_padded;
        let controls = vec![0.0f32, 1.0, 18.0, 8.0, 9000.0, 0.75, 0.0, 0.0];
        let util = vec![1.0f32; npad * NC];

        // raw substep: identical inputs for both kernels
        let mut t = vec![45.0f32; npad * S];
        let mut g_eff = st.g.clone();
        for i in 0..npad {
            g_eff[i * NG + G_ADV] *= 0.75;
        }
        let mut q = vec![0.0f32; npad * S];
        // same sink + advective-inlet forcing SoaState::new/set_inlet build
        let q_sink = ((pp.p_node_base + pp.ua_node_air * pp.t_room)
            * ops.inv_c[IDX_SINK] as f64) as f32;
        for i in 0..n {
            q[i * S + IDX_SINK] = q_sink;
        }
        for i in 0..npad {
            q[i * S + IDX_WATER] =
                g_eff[i * NG + G_ADV] * 55.0 * ops.inv_c[IDX_WATER];
        }
        let mut scratch = NodeScratch::new(npad);
        b.run_with_units(
            "ref_substep/n64", n as f64, "node-substeps", &mut || {
                std::hint::black_box(node::fused_substep(
                    &mut t, &g_eff, &util, &st.p_dyn, &st.p_idle,
                    &st.active, &q, &ops, &pp, &mut scratch, n));
            });
        let mut sst = SoaState::new(&st, &ops, &pp);
        let t0 = vec![45.0f32; npad * S];
        sst.load(&t0, &util);
        sst.set_flow(0.75);
        sst.set_inlet(55.0, ops.inv_c[IDX_WATER]);
        b.run_with_units(
            "soa_substep/n64", n as f64, "node-substeps", &mut || {
                std::hint::black_box(
                    soa::soa_substep(&mut sst, &pp, n));
            });

        // whole plant tick (substeps + circuits + observe epilogue)
        for (kname, kernel) in [
            ("ref", PlantKernel::Reference),
            ("soa", PlantKernel::Soa),
        ] {
            let mut plant = NativePlant::with_kernel(
                pp.clone(), ops.clone(), st.clone(), 20.0, kernel);
            let mut out = TickOutput::new(npad);
            let node_substeps = (n * plant.substeps) as f64;
            b.run_with_units(
                &format!("{kname}_plant_tick/n64"), node_substeps,
                "node-substeps", &mut || {
                    plant.tick(&controls, &util, &mut out);
                });
        }

        // Resident lanes (PR 5): `soa_plant_tick` above *is* the
        // resident steady-state loop now — zero node-major transposes
        // per tick. `resident_tick` registers that contract under its
        // own id; `materialize_tick` adds a forced `node_state()` read
        // per tick, so the resident/materialize delta prices exactly
        // the transpose the resident contract removed (the PR 3 path
        // paid it — plus a transpose-in — on every tick; compare
        // soa_plant_tick against the PR 3 baseline for the full win).
        {
            let mut plant = NativePlant::with_kernel(
                pp.clone(), ops.clone(), st.clone(), 20.0,
                PlantKernel::Soa);
            let mut out = TickOutput::new(npad);
            let node_substeps = (n * plant.substeps) as f64;
            b.run_with_units(
                "resident_tick/n64", node_substeps, "node-substeps",
                &mut || {
                    plant.tick(&controls, &util, &mut out);
                });
            b.run_with_units(
                "materialize_tick/n64", node_substeps, "node-substeps",
                &mut || {
                    plant.tick(&controls, &util, &mut out);
                    std::hint::black_box(plant.node_state());
                });
        }
    }

    // Full coordinator tick around the plant, allocation-free path.
    let mut cfg = reference_config();
    cfg.t_water_init = 63.0;
    let mut driver = SimulationDriver::new(cfg)?;
    let tick_s = driver.backend.tick_seconds(&driver.cfg.pp);
    let mut out = TickOutput::new(driver.backend.n_padded());
    b.run_with_units(
        "coordinator_tick/native/n216", tick_s, "sim-seconds", &mut || {
            driver.tick_into(&mut out).unwrap();
        });

    let mut sched = BatchScheduler::new(216, 0.92, 7);
    let mut plan = UtilPlan::idle(256);
    b.run("scheduler_advance/n216", || {
        sched.advance(5.0, &mut plan);
    });

    let mut tel = Telemetry::new(SensorSpec::default(), 3);
    b.run("telemetry_sample/256-cores", || {
        let mut acc = 0.0;
        for _ in 0..256 {
            acc += tel.core_temp(84.0);
        }
        std::hint::black_box(acc);
    });

    let man = Manifold::from_params(&pp, 72, ManifoldKind::Tichelmann);
    let mut flows = Vec::new();
    b.run("manifold_solve/72-branches", || {
        man.solve_flows_into(43.2, &mut flows);
        std::hint::black_box(&flows);
    });

    b.run("lottery_draw/n216", || {
        std::hint::black_box(ChipLottery::draw(216, &pp, 1));
    });
    Ok(())
}

/// Fleet engine + figure-sweep meso benchmarks.
fn fleet(b: &mut Bench) -> Result<()> {
    let base = fleet_base();
    let scenario = Scenario::by_name("mixed")?;
    // fleet_run follows the env-resolved megabatch flag (CI runs the
    // suite under both values; the suite fingerprint mixes the flag so
    // the two never gate against each other's baseline).
    let megabatch = crate::fleet::default_megabatch()?;
    for shards in [1usize, 4] {
        let driver = FleetDriver::new(FleetConfig {
            n_plants: FLEET_PLANTS,
            shards,
            base: base.clone(),
            fleet_seed: 0x1DA7,
            scenario,
            megabatch,
        })?;
        b.run_with_units(
            &format!("fleet_run/p4s{shards}/n13"),
            FLEET_PLANTS as f64 * base.duration_s,
            "plant-sim-seconds", &mut || {
                driver.run().unwrap();
            });
    }

    // One lockstep megabatch tick over the whole 4-plant bucket: the
    // single arena sweep per substep that replaces 4 per-plant kernel
    // calls — the megabatch primitive itself. Skipped (not a fatal
    // error) when the env pins a configuration that cannot lockstep
    // (IDATACOOL_KERNEL=reference): the fleet_run benches above remain
    // measurable there, and the missing bench is a comparator warning,
    // never a gate failure.
    if crate::fleet::megabatch::precheck(&base) {
        use crate::fleet::megabatch::{build_ctxs, LockstepFleet};
        let driver = FleetDriver::new(FleetConfig {
            n_plants: FLEET_PLANTS,
            shards: 1,
            base: base.clone(),
            fleet_seed: 0x1DA7,
            scenario,
            megabatch: true,
        })?;
        let mut ls = LockstepFleet::new(build_ctxs(driver.specs())?)
            .ok()
            .ok_or_else(|| anyhow::anyhow!(
                "fleet bench bucket must be lockstep-eligible"
            ))?;
        let tick_s = base.pp.dt_substep * base.pp.substeps_per_tick as f64;
        b.run_with_units(
            "fleet_megabatch_tick/p4/n13",
            FLEET_PLANTS as f64 * tick_s,
            "plant-sim-seconds", &mut || {
                ls.tick();
                // keep the bench loop memory-bounded; capacity is kept,
                // so no reallocation lands in the timed window
                ls.discard_history();
            });
    } else {
        println!(
            "fleet_megabatch_tick/p4/n13: skipped (base config cannot \
             lockstep — non-SoA kernel or hlo backend)"
        );
    }

    // The Fig. 4-7 setpoint sweep, serial vs sharded (the two must stay
    // bitwise identical — tests/sweep_parallel.rs is the gate; this pair
    // tracks the speedup).
    let cfg = reference_config();
    let opts = fleet_sweep_opts();
    let sps = SWEEP_SETPOINTS;
    let sim_s = (opts.settle_s + opts.measure_s) * sps.len() as f64;
    b.run_with_units(
        "sweep_serial/3-setpoints", sim_s, "sim-seconds", &mut || {
            sweep::run_sweep_sharded(&cfg, sps, &opts, 1).unwrap();
        });
    let shards = sweep::default_sweep_shards(sps.len())?;
    b.run_with_units(
        &format!("sweep_parallel/3-setpoints/s{shards}"), sim_s,
        "sim-seconds", &mut || {
            sweep::run_sweep_sharded(&cfg, sps, &opts, shards).unwrap();
        });
    Ok(())
}

const OPT_PLANTS: usize = 2;
const OPT_BUDGET: usize = 6;

/// Per-candidate base of the optimize benches (shared with
/// `optimize_fingerprint`): 13 nodes, 300 simulated seconds per
/// candidate fleet evaluation.
fn optimize_base() -> SimConfig {
    let mut base = SimConfig::test_small();
    base.duration_s = 300.0;
    base
}

/// Closed-loop search benchmarks: the candidate-eval primitive (one
/// small fleet run + objective scoring), the fingerprint-cache hit
/// path that repeated points ride, and a budgeted grid search end to
/// end (the `idatacool optimize` hot loop).
fn optimize(b: &mut Bench) -> Result<()> {
    use crate::economics::CostModel;
    use crate::optimize::driver::{self, DriverKind};
    use crate::optimize::eval::Evaluator;
    use crate::optimize::objective::Weights;
    use crate::optimize::space::Space;

    let base = optimize_base();
    let scenario = Scenario::by_name("mixed")?;
    let megabatch = crate::fleet::default_megabatch()?;
    let space = Space::default();
    let center = space.center();
    let weights = Weights::preset("ere")?;
    let make = |fleet_seed: u64, budget: usize| -> Result<Evaluator> {
        Evaluator::new(
            base.clone(),
            space.clone(),
            weights,
            CostModel::default(),
            OPT_PLANTS,
            scenario,
            fleet_seed,
            megabatch,
            1,
            budget,
        )
    };

    // One physical candidate evaluation per iteration: a fresh seed
    // makes every point a cache miss, so this prices the eval primitive
    // (fleet run + facility pass + objective scoring).
    let mut seed = 0u64;
    b.run_with_units(
        "optimize_eval/p2/n13",
        OPT_PLANTS as f64 * base.duration_s,
        "plant-sim-seconds",
        &mut || {
            seed += 1;
            let mut ev = make(seed, 1).unwrap();
            std::hint::black_box(ev.eval_batch(&[center]));
        },
    );

    // The same point through a warm evaluator: fingerprint + cache
    // lookup only — the path every repeated candidate rides.
    let mut warm = make(0x1DA7, 1)?;
    let _ = warm.eval_batch(&[center]);
    b.run_with_units("optimize_cache_hit", 1.0, "evals", &mut || {
        std::hint::black_box(warm.eval_batch(&[center]));
    });

    // A budgeted grid search end to end (fresh seed per iteration so
    // the eval cache never carries over between iterations).
    let mut gseed = 0x900_0000u64;
    b.run_with_units(
        &format!("optimize_grid/b{OPT_BUDGET}"),
        (OPT_BUDGET * OPT_PLANTS) as f64 * base.duration_s,
        "plant-sim-seconds",
        &mut || {
            gseed += 1;
            let mut ev = make(gseed, OPT_BUDGET).unwrap();
            let out =
                driver::search(DriverKind::Grid, &mut ev, 4, gseed).unwrap();
            std::hint::black_box(out);
        },
    );
    Ok(())
}

fn optimize_fingerprint() -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
    }
    // What the suite measures: the per-candidate base, the fleet shape
    // per candidate, the search budget, and the env-resolved megabatch
    // flag (execution shape with a real wall-time effect, like the
    // fleet suite's).
    let mut h = config_fingerprint(&optimize_base());
    h = mix(h, OPT_PLANTS as u64);
    h = mix(h, OPT_BUDGET as u64);
    let megabatch = match crate::fleet::default_megabatch() {
        Ok(true) => 1u64,
        Ok(false) => 0u64,
        Err(_) => 99u64,
    };
    h = mix(h, megabatch);
    h
}

/// Base config behind the serve-suite simulations (shared with
/// `serve_fingerprint`): 13 nodes, 60 simulated seconds (12 ticks).
fn serve_base() -> SimConfig {
    let mut c = SimConfig::test_small();
    c.duration_s = 60.0;
    c
}

const SERVE_WORKERS: usize = 2;

/// Serving-layer benchmarks: a real server on an ephemeral loopback
/// port, measured through the same `http_roundtrip` client the
/// integration tests use. `healthz` prices pure HTTP + dispatch,
/// `cache_hit` prices the LRU fast path end to end, `miss` prices a
/// full simulation per request (unique seed per iteration).
fn serve(b: &mut Bench) -> Result<()> {
    use crate::server::{ServeOptions, Server};
    use crate::util::http::http_roundtrip;

    let mut opts = ServeOptions::new(serve_base());
    opts.cfg.addr = "127.0.0.1:0".into();
    opts.cfg.workers = SERVE_WORKERS;
    opts.cfg.cache_cap = 64;
    opts.cfg.queue_cap = 32;
    let handle = Server::bind(opts)?.spawn();
    let addr = handle.addr.to_string();

    b.run_with_units("serve_healthz/roundtrip", 1.0, "requests", &mut || {
        let r = http_roundtrip(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(r.status, 200);
        std::hint::black_box(r);
    });

    // Unique seed per iteration: every request is a fresh cache miss
    // and therefore a full 12-tick simulation behind the endpoint.
    let mut seed = 0u64;
    b.run_with_units("serve_simulate/miss", 1.0, "requests", &mut || {
        seed += 1;
        let body = format!("{{\"seed\": {seed}}}");
        let r = http_roundtrip(
            &addr, "POST", "/simulate", Some(body.as_bytes()),
        )
        .unwrap();
        assert_eq!(r.status, 200);
        std::hint::black_box(r);
    });

    // Identical request repeated: after priming, every response is the
    // stored bytes — this is the cache-hit throughput headline.
    let body: &[u8] = br#"{"seed": 424242}"#;
    let prime = http_roundtrip(&addr, "POST", "/simulate", Some(body))?;
    anyhow::ensure!(prime.status == 200, "prime request failed");
    b.run_with_units("serve_simulate/cache_hit", 1.0, "requests", &mut || {
        let r =
            http_roundtrip(&addr, "POST", "/simulate", Some(body)).unwrap();
        assert_eq!(r.header("x-cache"), Some("hit"));
        std::hint::black_box(r);
    });

    handle.stop()?;
    Ok(())
}

fn serve_fingerprint() -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
    }
    // What the suite measures: the base config the endpoint simulates
    // and the serving shape (worker count).
    let mut h = config_fingerprint(&serve_base());
    h = mix(h, SERVE_WORKERS as u64);
    h
}

const BATCH_CONCURRENCY: usize = 4;
const BATCH_WINDOW_MS: usize = 4;

/// Continuous-batching benchmarks. Both benches push the same load —
/// `BATCH_CONCURRENCY` concurrent unique-seed `/v1/simulate` misses per
/// iteration — through two servers that differ only in the admission
/// window, so the pair prices exactly what batching buys: one arena
/// sweep per round instead of one full simulation per request.
fn serve_batched(b: &mut Bench) -> Result<()> {
    use crate::server::{ServeOptions, Server};
    use crate::util::http::http_roundtrip;

    let boot = |window_ms: usize| -> Result<_> {
        let mut opts = ServeOptions::new(serve_base());
        opts.cfg.addr = "127.0.0.1:0".into();
        opts.cfg.workers = BATCH_CONCURRENCY;
        opts.cfg.cache_cap = 64;
        opts.cfg.queue_cap = 32;
        opts.cfg.batch_window_ms = window_ms;
        opts.cfg.batch_max_plants = 16;
        Ok(Server::bind(opts)?.spawn())
    };

    // Unique seeds per iteration keep every request a genuine miss; the
    // counter continues across benches so the two legs never share keys.
    let mut seed = 0u64;
    let volley = |addr: &str, seed: &mut u64| {
        let joins: Vec<_> = (0..BATCH_CONCURRENCY)
            .map(|_| {
                *seed += 1;
                let body = format!("{{\"seed\": {seed}}}");
                let addr = addr.to_string();
                std::thread::spawn(move || {
                    http_roundtrip(
                        &addr, "POST", "/v1/simulate",
                        Some(body.as_bytes()),
                    )
                    .unwrap()
                })
            })
            .collect();
        for j in joins {
            let r = j.join().unwrap();
            assert_eq!(r.status, 200);
            std::hint::black_box(r);
        }
    };

    for (id, window_ms) in [
        ("serve_batched/concurrent4/window_on", BATCH_WINDOW_MS),
        ("serve_batched/concurrent4/window_off", 0),
    ] {
        let handle = boot(window_ms)?;
        let addr = handle.addr.to_string();
        b.run_with_units(
            id, BATCH_CONCURRENCY as f64, "requests", &mut || {
                volley(&addr, &mut seed);
            });
        handle.stop()?;
    }
    Ok(())
}

fn serve_batched_fingerprint() -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
    }
    let mut h = config_fingerprint(&serve_base());
    h = mix(h, BATCH_CONCURRENCY as u64);
    h = mix(h, BATCH_WINDOW_MS as u64);
    h
}
