//! Machine-readable benchmark records: the `idatacool-bench/1` schema.
//!
//! One `BenchReport` per suite, serialized to `BENCH_<suite>.json` with a
//! stable field set (suite, bench id, ns/iter, units/sec, git rev,
//! backend, config fingerprint) so CI can diff runs across commits. The
//! JSON is built on `crate::util::json` (the vendored crate set has no
//! serde): reports render through the `Json` value tree, whose object
//! keys are `BTreeMap`-ordered — the emitted key order is alphabetical
//! and therefore stable across runs and platforms.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::SimConfig;
use crate::util::json::Json;

use super::BenchResult;

/// Schema identifier carried by every report.
pub const SCHEMA: &str = "idatacool-bench/1";

/// One benchmark case in the machine-readable report.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    /// Stable bench id, e.g. `plant_tick/native/n216`.
    pub id: String,
    pub ns_per_iter: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub p95_ns: f64,
    pub iters: usize,
    /// Throughput (0 when the case has no unit).
    pub units_per_sec: f64,
    pub unit: String,
    /// Per-bench regression threshold override for the comparator
    /// (baselines only; `None` uses the gate's `--max-regress` default).
    pub max_regress_pct: Option<f64>,
    /// Optional per-phase breakdown, `(span name, ns/iter)`, emitted as
    /// the `phase_ns_per_iter` object when the run was traced; empty
    /// records omit the field. Name-sorted (it rides a `BTreeMap`).
    pub phases: Vec<(String, f64)>,
}

impl BenchRecord {
    pub fn from_result(r: &BenchResult) -> Self {
        BenchRecord {
            id: r.name.clone(),
            ns_per_iter: r.mean_s * 1e9,
            std_ns: r.std_s * 1e9,
            min_ns: r.min_s * 1e9,
            p95_ns: r.p95_s * 1e9,
            iters: r.iters,
            units_per_sec: r.throughput(),
            unit: r.unit_name.clone(),
            max_regress_pct: None,
            phases: r.phases.clone(),
        }
    }
}

/// A full suite run: metadata + one record per bench.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    pub schema: String,
    pub suite: String,
    pub git_rev: String,
    pub backend: String,
    /// FNV-mixed hash of the reference config (hex string: u64 does not
    /// survive a round trip through JSON f64 numbers).
    pub config_fingerprint: String,
    /// True when the run used `BENCH_FAST=1` sizing.
    pub fast_mode: bool,
    /// Placeholder baselines gate nothing; see `compare`.
    pub placeholder: bool,
    pub benches: Vec<BenchRecord>,
}

impl BenchReport {
    pub fn from_results(
        suite: &str,
        backend: &str,
        config_fingerprint: u64,
        fast: bool,
        results: &[BenchResult],
    ) -> Self {
        BenchReport {
            schema: SCHEMA.to_string(),
            suite: suite.to_string(),
            git_rev: git_rev(),
            backend: backend.to_string(),
            config_fingerprint: format!("{config_fingerprint:#018x}"),
            fast_mode: fast,
            placeholder: false,
            benches: results.iter().map(BenchRecord::from_result).collect(),
        }
    }

    pub fn get(&self, id: &str) -> Option<&BenchRecord> {
        self.benches.iter().find(|b| b.id == id)
    }

    pub fn to_json_value(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("schema".into(), Json::Str(self.schema.clone()));
        m.insert("suite".into(), Json::Str(self.suite.clone()));
        m.insert("git_rev".into(), Json::Str(self.git_rev.clone()));
        m.insert("backend".into(), Json::Str(self.backend.clone()));
        m.insert(
            "config_fingerprint".into(),
            Json::Str(self.config_fingerprint.clone()),
        );
        m.insert("fast_mode".into(), Json::Bool(self.fast_mode));
        m.insert("placeholder".into(), Json::Bool(self.placeholder));
        let benches = self
            .benches
            .iter()
            .map(|b| {
                let mut e = BTreeMap::new();
                e.insert("id".into(), Json::Str(b.id.clone()));
                e.insert("ns_per_iter".into(), Json::Num(b.ns_per_iter));
                e.insert("std_ns".into(), Json::Num(b.std_ns));
                e.insert("min_ns".into(), Json::Num(b.min_ns));
                e.insert("p95_ns".into(), Json::Num(b.p95_ns));
                e.insert("iters".into(), Json::Num(b.iters as f64));
                e.insert("units_per_sec".into(), Json::Num(b.units_per_sec));
                e.insert("unit".into(), Json::Str(b.unit.clone()));
                if let Some(t) = b.max_regress_pct {
                    e.insert("max_regress_pct".into(), Json::Num(t));
                }
                if !b.phases.is_empty() {
                    let phases: BTreeMap<String, Json> = b
                        .phases
                        .iter()
                        .map(|(name, ns)| (name.clone(), Json::Num(*ns)))
                        .collect();
                    e.insert(
                        "phase_ns_per_iter".into(),
                        Json::Obj(phases),
                    );
                }
                Json::Obj(e)
            })
            .collect();
        m.insert("benches".into(), Json::Arr(benches));
        Json::Obj(m)
    }

    pub fn to_json(&self) -> String {
        self.to_json_value().to_string()
    }

    pub fn from_json_value(j: &Json) -> Result<Self> {
        let s = |k: &str| -> Result<String> {
            Ok(j.get(k)
                .and_then(Json::as_str)
                .with_context(|| format!("bench report: field '{k}'"))?
                .to_string())
        };
        let schema = s("schema")?;
        anyhow::ensure!(
            schema == SCHEMA,
            "unsupported bench schema '{schema}' (want '{SCHEMA}')"
        );
        let mut benches = Vec::new();
        for (i, e) in j
            .get("benches")
            .and_then(Json::as_arr)
            .context("bench report: field 'benches'")?
            .iter()
            .enumerate()
        {
            let f = |k: &str| -> Result<f64> {
                e.get(k)
                    .and_then(Json::as_f64)
                    .with_context(|| format!("bench #{i}: field '{k}'"))
            };
            benches.push(BenchRecord {
                id: e
                    .get("id")
                    .and_then(Json::as_str)
                    .with_context(|| format!("bench #{i}: field 'id'"))?
                    .to_string(),
                ns_per_iter: f("ns_per_iter")?,
                std_ns: f("std_ns")?,
                min_ns: f("min_ns")?,
                p95_ns: f("p95_ns")?,
                iters: f("iters")? as usize,
                units_per_sec: f("units_per_sec")?,
                unit: e
                    .get("unit")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                max_regress_pct: e.get("max_regress_pct").and_then(Json::as_f64),
                phases: match e.get("phase_ns_per_iter") {
                    Some(Json::Obj(m)) => m
                        .iter()
                        .filter_map(|(name, v)| {
                            v.as_f64().map(|ns| (name.clone(), ns))
                        })
                        .collect(),
                    _ => Vec::new(),
                },
            });
        }
        Ok(BenchReport {
            schema,
            suite: s("suite")?,
            git_rev: s("git_rev")?,
            backend: s("backend")?,
            config_fingerprint: s("config_fingerprint")?,
            fast_mode: j.get("fast_mode").and_then(Json::as_bool).unwrap_or(false),
            placeholder: j
                .get("placeholder")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            benches,
        })
    }

    pub fn from_json(text: &str) -> Result<Self> {
        Self::from_json_value(&Json::parse(text)?)
    }
}

/// A baseline file: one or more suite reports (`bench/baseline.json` is a
/// JSON array; a bare report object is accepted too).
#[derive(Debug, Clone)]
pub struct BaselineFile {
    pub reports: Vec<BenchReport>,
}

impl BaselineFile {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read baseline {}", path.display()))?;
        Self::from_json(&text)
            .with_context(|| format!("parse baseline {}", path.display()))
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let reports = match &j {
            Json::Arr(items) => items
                .iter()
                .map(BenchReport::from_json_value)
                .collect::<Result<Vec<_>>>()?,
            _ => vec![BenchReport::from_json_value(&j)?],
        };
        Ok(BaselineFile { reports })
    }

    pub fn find(&self, suite: &str) -> Option<&BenchReport> {
        self.reports.iter().find(|r| r.suite == suite)
    }

    pub fn to_json(&self) -> String {
        Json::Arr(self.reports.iter().map(BenchReport::to_json_value).collect())
            .to_string()
    }
}

/// Best-effort git revision: `IDATACOOL_GIT_REV` env override, then
/// `git rev-parse`, then `"unknown"` (benches must run outside checkouts).
pub fn git_rev() -> String {
    if let Ok(v) = std::env::var("IDATACOOL_GIT_REV") {
        if !v.is_empty() {
            return v;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// FNV-mixed fingerprint of the configuration knobs that change what a
/// bench measures; reports with different fingerprints are not comparable.
pub fn config_fingerprint(cfg: &SimConfig) -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
    }
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    h = mix(h, cfg.n_nodes as u64);
    h = mix(h, cfg.seed);
    h = mix(h, cfg.t_out_setpoint.to_bits());
    h = mix(h, cfg.pump_speed.to_bits());
    h = mix(h, cfg.production_load.to_bits());
    h = mix(h, cfg.pp.substeps_per_tick as u64);
    h = mix(h, cfg.pp.dt_substep.to_bits());
    for b in cfg.backend.bytes() {
        h = mix(h, b as u64);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> BenchReport {
        BenchReport {
            schema: SCHEMA.into(),
            suite: "hotpath".into(),
            git_rev: "abc123def456".into(),
            backend: "native".into(),
            config_fingerprint: "0x00000000deadbeef".into(),
            fast_mode: true,
            placeholder: false,
            benches: vec![
                BenchRecord {
                    id: "plant_tick/native/n216".into(),
                    ns_per_iter: 123456.789,
                    std_ns: 1000.5,
                    min_ns: 120000.0,
                    p95_ns: 130000.25,
                    iters: 12,
                    units_per_sec: 4320.0,
                    unit: "node-substeps".into(),
                    max_regress_pct: None,
                    phases: vec![
                        ("control".into(), 1500.25),
                        ("soa_substep".into(), 98000.5),
                    ],
                },
                BenchRecord {
                    id: "manifold_solve/72-branches".into(),
                    ns_per_iter: 0.0625,
                    std_ns: 0.001,
                    min_ns: 0.05,
                    p95_ns: 0.08,
                    iters: 3,
                    units_per_sec: 0.0,
                    unit: "".into(),
                    max_regress_pct: Some(40.0),
                    phases: vec![],
                },
            ],
        }
    }

    #[test]
    fn report_round_trips_exactly() {
        let r = sample_report();
        let text = r.to_json();
        // Traced record carries the breakdown; untraced one omits it.
        assert!(text.contains("phase_ns_per_iter"));
        assert_eq!(text.matches("phase_ns_per_iter").count(), 1);
        let back = BenchReport::from_json(&text).unwrap();
        assert_eq!(r, back);
        // f64 Display emits the shortest round-trip representation, so
        // numeric fields survive bit-exactly.
        assert_eq!(
            r.benches[0].ns_per_iter.to_bits(),
            back.benches[0].ns_per_iter.to_bits()
        );
        assert_eq!(
            r.benches[1].max_regress_pct.unwrap().to_bits(),
            back.benches[1].max_regress_pct.unwrap().to_bits()
        );
    }

    #[test]
    fn baseline_accepts_array_and_single_object() {
        let r = sample_report();
        let arr = format!("[{}]", r.to_json());
        let b = BaselineFile::from_json(&arr).unwrap();
        assert_eq!(b.reports.len(), 1);
        assert!(b.find("hotpath").is_some());
        assert!(b.find("fleet").is_none());
        let single = BaselineFile::from_json(&r.to_json()).unwrap();
        assert_eq!(single.reports.len(), 1);
        let back = BaselineFile::from_json(&b.to_json()).unwrap();
        assert_eq!(back.reports[0], r);
    }

    #[test]
    fn wrong_schema_rejected() {
        let text = sample_report().to_json().replace(SCHEMA, "bogus/9");
        assert!(BenchReport::from_json(&text).is_err());
    }

    #[test]
    fn fingerprint_tracks_config_knobs() {
        let a = SimConfig::test_small();
        let mut b = SimConfig::test_small();
        assert_eq!(config_fingerprint(&a), config_fingerprint(&b));
        b.n_nodes = 216;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
        let mut c = SimConfig::test_small();
        c.backend = "hlo".into();
        assert_ne!(config_fingerprint(&a), config_fingerprint(&c));
    }

    #[test]
    fn from_results_converts_units() {
        let r = BenchResult {
            name: "x".into(),
            iters: 5,
            mean_s: 2e-6,
            std_s: 1e-7,
            min_s: 1.8e-6,
            p50_s: 2e-6,
            p95_s: 2.4e-6,
            units_per_iter: 10.0,
            unit_name: "items".into(),
            phases: vec![("tick".into(), 1800.0)],
        };
        let rep = BenchReport::from_results("s", "native", 7, false, &[r]);
        assert_eq!(rep.suite, "s");
        assert!((rep.benches[0].ns_per_iter - 2000.0).abs() < 1e-9);
        assert!((rep.benches[0].units_per_sec - 5e6).abs() < 1.0);
        assert!(rep.config_fingerprint.starts_with("0x"));
        assert_eq!(rep.benches[0].phases, vec![("tick".to_string(), 1800.0)]);
    }
}
