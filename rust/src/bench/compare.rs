//! Baseline comparison: the perf-regression gate behind
//! `idatacool bench --compare bench/baseline.json --max-regress PCT`.
//!
//! Every bench present in the baseline with a recorded time is gated:
//! the run fails when `ns/iter` regresses more than the threshold (the
//! per-bench `max_regress_pct` override when present, else the gate's
//! default). Benches missing on either side are reported but never fail
//! the gate — suites are allowed to evolve. A baseline marked
//! `placeholder` gates nothing; it exists so the file can be checked in
//! before a reference machine has recorded real numbers.

use std::fmt::Write as _;

use super::record::BenchReport;

/// One gated bench: baseline vs current.
#[derive(Debug, Clone)]
pub struct BenchDelta {
    pub id: String,
    pub base_ns: f64,
    pub cur_ns: f64,
    /// Relative change in ns/iter, percent (positive = slower).
    pub delta_pct: f64,
    pub threshold_pct: f64,
    pub regressed: bool,
}

/// Outcome of comparing a suite run against its baseline.
#[derive(Debug, Clone)]
pub struct Comparison {
    pub suite: String,
    pub deltas: Vec<BenchDelta>,
    /// Baseline benches absent from the current run (warn only).
    pub missing: Vec<String>,
    /// Current benches absent from the baseline (info only).
    pub added: Vec<String>,
    pub baseline_placeholder: bool,
    /// Metadata mismatches (config fingerprint, fast_mode, backend) that
    /// make the timings incomparable; when non-empty the gate is off and
    /// the report says so loudly — refresh the baseline instead.
    pub incomparable: Vec<String>,
}

impl Comparison {
    pub fn build(
        baseline: &BenchReport,
        current: &BenchReport,
        default_threshold_pct: f64,
    ) -> Self {
        let mut deltas = Vec::new();
        let mut missing = Vec::new();
        for rec in &baseline.benches {
            match current.get(&rec.id) {
                None => missing.push(rec.id.clone()),
                Some(cur) => {
                    let delta_pct = if rec.ns_per_iter > 0.0 {
                        100.0 * (cur.ns_per_iter - rec.ns_per_iter)
                            / rec.ns_per_iter
                    } else {
                        0.0
                    };
                    let threshold_pct =
                        rec.max_regress_pct.unwrap_or(default_threshold_pct);
                    deltas.push(BenchDelta {
                        id: rec.id.clone(),
                        base_ns: rec.ns_per_iter,
                        cur_ns: cur.ns_per_iter,
                        delta_pct,
                        threshold_pct,
                        regressed: delta_pct > threshold_pct,
                    });
                }
            }
        }
        let added = current
            .benches
            .iter()
            .filter(|b| baseline.get(&b.id).is_none())
            .map(|b| b.id.clone())
            .collect();
        let mut incomparable = Vec::new();
        if !baseline.placeholder {
            for (what, base, cur) in [
                (
                    "config_fingerprint",
                    &baseline.config_fingerprint,
                    &current.config_fingerprint,
                ),
                ("backend", &baseline.backend, &current.backend),
            ] {
                if base != cur {
                    incomparable
                        .push(format!("{what}: baseline {base} vs run {cur}"));
                }
            }
            if baseline.fast_mode != current.fast_mode {
                incomparable.push(format!(
                    "fast_mode: baseline {} vs run {} (BENCH_FAST sizing)",
                    baseline.fast_mode, current.fast_mode
                ));
            }
        }
        Comparison {
            suite: current.suite.clone(),
            deltas,
            missing,
            added,
            baseline_placeholder: baseline.placeholder,
            incomparable,
        }
    }

    pub fn regressions(&self) -> Vec<&BenchDelta> {
        if self.baseline_placeholder || !self.incomparable.is_empty() {
            return Vec::new();
        }
        self.deltas.iter().filter(|d| d.regressed).collect()
    }

    pub fn passed(&self) -> bool {
        self.regressions().is_empty()
    }

    /// Human-readable comparison table + notes.
    pub fn report(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "compare '{}' vs baseline ({} gated):",
            self.suite,
            self.deltas.len()
        );
        if self.baseline_placeholder {
            let _ = writeln!(
                s,
                "  baseline is a placeholder — nothing gated; record one \
                 with `idatacool bench --suite all --baseline-out \
                 bench/baseline.json`"
            );
        }
        for m in &self.incomparable {
            let _ = writeln!(
                s,
                "  WARNING: incomparable with baseline ({m}) — nothing \
                 gated; refresh the baseline"
            );
        }
        let _ = writeln!(
            s,
            "  {:<44} {:>12} {:>12} {:>9} {:>7}",
            "benchmark", "baseline", "current", "delta", "gate"
        );
        for d in &self.deltas {
            let _ = writeln!(
                s,
                "  {:<44} {:>12} {:>12} {:>+8.1}% {:>7}",
                d.id,
                super::fmt_s(d.base_ns * 1e-9),
                super::fmt_s(d.cur_ns * 1e-9),
                d.delta_pct,
                if self.baseline_placeholder || !self.incomparable.is_empty()
                {
                    "-"
                } else if d.regressed {
                    "FAIL"
                } else {
                    "ok"
                },
            );
        }
        for id in &self.missing {
            let _ = writeln!(s, "  missing in current run (warn): {id}");
        }
        for id in &self.added {
            let _ = writeln!(s, "  new bench (not in baseline): {id}");
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::record::{BenchRecord, SCHEMA};

    fn report(suite: &str, cases: &[(&str, f64, Option<f64>)]) -> BenchReport {
        BenchReport {
            schema: SCHEMA.into(),
            suite: suite.into(),
            git_rev: "test".into(),
            backend: "native".into(),
            config_fingerprint: "0x0".into(),
            fast_mode: true,
            placeholder: false,
            benches: cases
                .iter()
                .map(|(id, ns, thr)| BenchRecord {
                    id: id.to_string(),
                    ns_per_iter: *ns,
                    std_ns: 0.0,
                    min_ns: *ns,
                    p95_ns: *ns,
                    iters: 3,
                    units_per_sec: 0.0,
                    unit: String::new(),
                    max_regress_pct: *thr,
                    phases: vec![],
                })
                .collect(),
        }
    }

    #[test]
    fn gate_fires_above_threshold_only() {
        let base = report("s", &[("a", 100.0, None), ("b", 100.0, None)]);
        let cur = report("s", &[("a", 130.0, None), ("b", 110.0, None)]);
        let cmp = Comparison::build(&base, &cur, 25.0);
        assert!(!cmp.passed());
        let regs = cmp.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].id, "a");
        assert!((regs[0].delta_pct - 30.0).abs() < 1e-9);
        // 10 % is under the 25 % gate
        assert!(!cmp.deltas.iter().find(|d| d.id == "b").unwrap().regressed);
    }

    #[test]
    fn per_bench_threshold_overrides_default() {
        let base = report("s", &[("a", 100.0, Some(50.0))]);
        let cur = report("s", &[("a", 130.0, None)]);
        let cmp = Comparison::build(&base, &cur, 25.0);
        assert!(cmp.passed(), "50% override must win over the 25% default");
        let tight = report("s", &[("a", 100.0, Some(10.0))]);
        let cmp = Comparison::build(&tight, &cur, 25.0);
        assert!(!cmp.passed(), "10% override must tighten the 25% default");
    }

    #[test]
    fn speedups_and_missing_benches_never_fail() {
        let base = report("s", &[("a", 100.0, None), ("gone", 50.0, None)]);
        let cur = report("s", &[("a", 40.0, None), ("new", 9.0, None)]);
        let cmp = Comparison::build(&base, &cur, 25.0);
        assert!(cmp.passed());
        assert_eq!(cmp.missing, vec!["gone".to_string()]);
        assert_eq!(cmp.added, vec!["new".to_string()]);
        assert!(cmp.report().contains("missing in current run"));
    }

    #[test]
    fn mismatched_metadata_disarms_the_gate_loudly() {
        let base = report("s", &[("a", 100.0, None)]);
        let cur = report("s", &[("a", 1e9, None)]);
        for tweak in ["fingerprint", "fast_mode", "backend"] {
            let mut c = cur.clone();
            match tweak {
                "fingerprint" => c.config_fingerprint = "0xff".into(),
                "fast_mode" => c.fast_mode = false,
                _ => c.backend = "hlo".into(),
            }
            let cmp = Comparison::build(&base, &c, 25.0);
            assert!(cmp.passed(), "{tweak}: incomparable must not gate");
            assert!(!cmp.incomparable.is_empty(), "{tweak}");
            assert!(cmp.report().contains("incomparable"), "{tweak}");
        }
        // identical metadata stays armed
        let cmp = Comparison::build(&base, &cur, 25.0);
        assert!(!cmp.passed());
    }

    #[test]
    fn placeholder_baseline_gates_nothing() {
        let mut base = report("s", &[("a", 1.0, None)]);
        base.placeholder = true;
        let cur = report("s", &[("a", 1e9, None)]);
        let cmp = Comparison::build(&base, &cur, 25.0);
        assert!(cmp.passed());
        assert!(cmp.report().contains("placeholder"));
    }
}
