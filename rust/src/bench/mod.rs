//! First-class benchmarking subsystem.
//!
//! Layers:
//!  * the measurement runner (`Bench`/`BenchResult`) — criterion-style
//!    warmup + timed iterations with trimmed-mean/std/min/p50/p95 stats
//!    (no criterion in the vendored crate set);
//!  * `record` — the machine-readable result schema (`idatacool-bench/1`
//!    JSON: suite, bench id, ns/iter, units/sec, git rev, backend,
//!    config fingerprint) written to `BENCH_<suite>.json`;
//!  * `compare` — the baseline comparator behind CI's perf-regression
//!    gate (`bench/baseline.json`, per-bench thresholds);
//!  * `suites` — the registered suites the `idatacool bench` subcommand
//!    runs (`hotpath`, `fleet`).
//!
//! `crate::util::bench` re-exports the runner for older call sites
//! (`rust/benches/*.rs`, `examples/perf_scan.rs`).

pub mod compare;
pub mod record;
pub mod suites;

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    /// Work units per iteration (for throughput reporting).
    pub units_per_iter: f64,
    pub unit_name: String,
    /// Per-phase attribution, `(span name, ns/iter)`, captured from the
    /// flight recorder's cumulative phase totals when tracing is enabled
    /// during the measurement loop; empty otherwise. Name-sorted.
    pub phases: Vec<(String, f64)>,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        if self.mean_s > 0.0 {
            self.units_per_iter / self.mean_s
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        let tp = if self.units_per_iter > 0.0 {
            format!("  {:>12.1} {}/s", self.throughput(), self.unit_name)
        } else {
            String::new()
        };
        format!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}{}",
            self.name,
            fmt_s(self.mean_s),
            fmt_s(self.std_s),
            fmt_s(self.min_s),
            fmt_s(self.p95_s),
            tp
        )
    }
}

/// Pretty time formatting.
pub fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// True when `BENCH_FAST=1` (CI-sized runs).
pub fn fast_mode() -> bool {
    std::env::var("BENCH_FAST").ok().as_deref() == Some("1")
}

/// Benchmark runner.
pub struct Bench {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    pub results: Vec<BenchResult>,
    /// When set, only benches whose name contains this substring run;
    /// the rest are skipped (no warmup, no measurement, no result).
    pub filter: Option<String>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            measure_iters: 12,
            results: Vec::new(),
            filter: None,
        }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup_iters: warmup, measure_iters: iters, ..Bench::default() }
    }

    /// Honor `BENCH_FAST=1` for CI-sized runs. Fast sizing keeps 5
    /// measure iterations — the minimum at which the trimmed mean drops
    /// a sample, so one OS scheduling spike cannot move the mean that
    /// CI's regression gate compares.
    pub fn from_env() -> Self {
        if fast_mode() {
            Bench::new(1, 5)
        } else {
            Bench::default()
        }
    }

    pub fn header() -> String {
        format!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}",
            "benchmark", "mean", "std", "min", "p95"
        )
    }

    /// Time `f` (which should perform one full iteration of the case).
    /// Returns `None` when the case is filtered out.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F)
                           -> Option<&BenchResult> {
        self.run_with_units(name, 0.0, "", &mut f)
    }

    /// Time with throughput units (e.g. simulated seconds, node-substeps).
    /// Mean/std are computed with the slowest ~5 % of samples trimmed —
    /// at least one sample once there are >= 5 (robust against OS
    /// scheduling spikes); min/p50/p95 always use every sample.
    /// Returns `None` when the case is filtered out.
    pub fn run_with_units<F: FnMut()>(
        &mut self,
        name: &str,
        units_per_iter: f64,
        unit_name: &str,
        f: &mut F,
    ) -> Option<&BenchResult> {
        if let Some(pat) = &self.filter {
            if !name.contains(pat.as_str()) {
                return None;
            }
        }
        for _ in 0..self.warmup_iters {
            f();
        }
        // Phase attribution: the recorder's cumulative per-name totals
        // are never evicted (unlike the event ring), so deltas around
        // the measurement loop stay exact even when the ring wraps.
        let phases_before = if crate::obs::enabled() {
            Some(crate::obs::trace::phase_totals())
        } else {
            None
        };
        let mut times = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        let phases = match phases_before {
            Some(before) if crate::obs::enabled() => {
                let after = crate::obs::trace::phase_totals();
                let iters = self.measure_iters.max(1) as f64;
                after
                    .into_iter()
                    .filter_map(|(name, (count, total_us))| {
                        let (c0, us0) =
                            before.get(&name).copied().unwrap_or((0, 0.0));
                        if count > c0 {
                            // µs summed over the loop -> ns per iteration.
                            Some((name, (total_us - us0) * 1e3 / iters))
                        } else {
                            None
                        }
                    })
                    .collect()
            }
            _ => Vec::new(),
        };
        times.sort_by(|a, b| a.total_cmp(b));
        // Trimmed mean: drop the slowest ~5 % of samples — at least one
        // once there are >= 5 — to damp OS scheduling spikes (min/p50/p95
        // still use every sample).
        let drop = if times.len() >= 5 {
            (times.len() / 20).max(1)
        } else {
            0
        };
        let kept = &times[..times.len() - drop];
        let n = kept.len() as f64;
        let mean = kept.iter().sum::<f64>() / n;
        let var = kept.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
        let res = BenchResult {
            name: name.to_string(),
            iters: times.len(),
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: times[0],
            p50_s: times[times.len() / 2],
            p95_s: times[(times.len() * 95 / 100).min(times.len() - 1)],
            units_per_iter,
            unit_name: unit_name.to_string(),
            phases,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut b = Bench::new(1, 5);
        let r = b
            .run("noop-spin", || {
                let mut x = 0u64;
                for i in 0..10_000 {
                    x = x.wrapping_add(i);
                }
                std::hint::black_box(x);
            })
            .unwrap()
            .clone();
        assert_eq!(r.iters, 5);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s);
        assert!(r.p95_s >= r.p50_s);
        assert!(r.report().contains("noop-spin"));
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bench::new(0, 3);
        let r = b
            .run_with_units("units", 100.0, "items", &mut || {
                std::thread::sleep(std::time::Duration::from_millis(1));
            })
            .unwrap()
            .clone();
        assert!(r.throughput() > 1000.0 && r.throughput() < 200_000.0);
    }

    #[test]
    fn filter_skips_non_matching_cases() {
        let mut b = Bench::new(0, 1);
        b.filter = Some("tick".into());
        let mut ran = 0usize;
        assert!(b.run("plant_tick/n64", || ran += 1).is_some());
        assert!(b.run("lottery_draw/n216", || ran += 1).is_none());
        assert_eq!(ran, 1, "filtered closure must not execute");
        assert_eq!(b.results.len(), 1);
        assert_eq!(b.results[0].name, "plant_tick/n64");
    }

    #[test]
    fn fmt_human() {
        assert_eq!(fmt_s(2.5), "2.500s");
        assert_eq!(fmt_s(0.0025), "2.500ms");
        assert_eq!(fmt_s(2.5e-6), "2.500us");
    }

    #[test]
    fn trimmed_mean_ignores_one_spike() {
        // At the default 12-iteration sizing, one huge scheduling spike
        // lands in the trimmed tail and the mean stays near the fast
        // samples (this is what keeps the CI regression gate stable).
        let mut b = Bench::new(0, 12);
        let mut i = 0usize;
        let r = b
            .run("spiky", || {
                i += 1;
                if i == 7 {
                    std::thread::sleep(std::time::Duration::from_millis(30));
                }
            })
            .unwrap()
            .clone();
        assert!(r.mean_s < 0.010, "trimmed mean {} absorbed spike", r.mean_s);
        assert_eq!(r.iters, 12);
        assert!(r.p95_s >= 0.020, "p95 must still see the spike");
    }

    #[test]
    fn tiny_sample_counts_are_not_trimmed() {
        // Below 5 samples every one stays in the mean.
        let mut b = Bench::new(0, 3);
        let r = b
            .run("tiny", || {
                std::thread::sleep(std::time::Duration::from_millis(2));
            })
            .unwrap()
            .clone();
        assert!(r.mean_s >= 0.002 * 0.9, "mean {} lost samples", r.mean_s);
    }

    #[test]
    fn fast_sizing_still_trims_one_sample() {
        // `BENCH_FAST` runs 5 iterations, so the trim drops exactly one:
        // one spike cannot move the gated mean.
        let mut b = Bench::new(0, 5);
        let mut i = 0usize;
        let r = b
            .run("fast-spiky", || {
                i += 1;
                if i == 3 {
                    std::thread::sleep(std::time::Duration::from_millis(30));
                }
            })
            .unwrap()
            .clone();
        assert!(r.mean_s < 0.010, "trimmed mean {} absorbed spike", r.mean_s);
    }
}
