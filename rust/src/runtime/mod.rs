//! Runtime: load + execute AOT plant artifacts via PJRT (`xla` crate),
//! with a pure-Rust native fallback for artifact-less environments.
//!
//! The coordinator talks to `PlantBackend`, which dispatches to either:
//!  * `Hlo` — the JAX/Pallas plant lowered by aot.py, compiled once on the
//!    PJRT CPU client, executed on every tick (the production path), or
//!  * `Native` — `plant::native::NativePlant`, the Rust mirror (used for
//!    cross-validation, fallback, and baseline benches). The native
//!    plant itself steps through one of two kernels
//!    (`plant::PlantKernel`): the lane-major SoA default or the
//!    node-major reference oracle — selected per config (`--kernel`,
//!    `cluster.kernel`) or via `IDATACOOL_KERNEL`.

pub mod manifest;
pub mod pjrt;

use std::path::Path;

use anyhow::{Context, Result};

use crate::config::constants::PlantParams;
use crate::plant::layout::*;
use crate::plant::native::NativePlant;
use crate::plant::operators::Operators;
use crate::plant::{PlantKernel, PlantStatic, TickOutput};
use crate::variability::ChipLottery;
use manifest::Manifest;
use pjrt::HloPlant;

/// Which backend to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO via PJRT (requires `make artifacts`).
    Hlo,
    /// Pure-Rust mirror.
    Native,
    /// HLO if artifacts exist, else native.
    Auto,
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "hlo" => Ok(BackendKind::Hlo),
            "native" => Ok(BackendKind::Native),
            "auto" => Ok(BackendKind::Auto),
            _ => anyhow::bail!("unknown backend '{s}' (hlo|native|auto)"),
        }
    }
}

impl BackendKind {
    /// Resolve `Auto` by artifact presence — the single detection rule,
    /// shared by backend construction (`PlantBackend::create_with_kernel`)
    /// and the fleet megabatch eligibility precheck
    /// (`fleet::megabatch::precheck`). Never returns `Auto`.
    pub fn resolve_auto(self, artifacts_dir: &Path) -> BackendKind {
        match self {
            BackendKind::Auto => {
                if artifacts_dir.join("manifest.json").exists() {
                    BackendKind::Hlo
                } else {
                    BackendKind::Native
                }
            }
            k => k,
        }
    }
}

/// The plant as seen by the coordinator.
pub enum PlantBackend {
    Hlo(HloPlant),
    Native(NativePlant),
}

impl PlantBackend {
    /// Construct for a cluster size, resolving `Auto` by artifact
    /// presence and the native kernel from the `IDATACOOL_KERNEL`
    /// environment override (default: SoA).
    ///
    /// `pp` should come from `PlantParams::from_artifacts` so both backends
    /// use the constants the HLO was lowered with.
    pub fn create(
        kind: BackendKind,
        artifacts_dir: &Path,
        n_nodes: usize,
        pp: &PlantParams,
        seed: u64,
        t_water: f32,
    ) -> Result<Self> {
        Self::create_with_kernel(
            kind,
            PlantKernel::from_env()?,
            artifacts_dir,
            n_nodes,
            pp,
            seed,
            t_water,
        )
    }

    /// `create` with an explicit native-kernel selection (the HLO
    /// backend ignores it — kernels only exist on the native side).
    #[allow(clippy::too_many_arguments)]
    pub fn create_with_kernel(
        kind: BackendKind,
        kernel: PlantKernel,
        artifacts_dir: &Path,
        n_nodes: usize,
        pp: &PlantParams,
        seed: u64,
        t_water: f32,
    ) -> Result<Self> {
        let kind = kind.resolve_auto(artifacts_dir);
        match kind {
            BackendKind::Hlo => {
                let man = Manifest::load(artifacts_dir)?;
                let entry = man.entry(n_nodes).with_context(|| {
                    format!(
                        "no artifact for n_nodes={n_nodes}; rebuild with \
                         `make artifacts` (have: {:?})",
                        man.entries.iter().map(|e| e.n_nodes).collect::<Vec<_>>()
                    )
                })?;
                // Use the lottery dumped at AOT time: identical floats.
                let lot_text =
                    std::fs::read_to_string(man.lottery_path(entry))?;
                let lot = ChipLottery::from_json(
                    &crate::util::json::Json::parse(&lot_text)?,
                )?;
                let st = PlantStatic::from_lottery(&lot, pp, man.tile);
                anyhow::ensure!(
                    st.n_padded == entry.n_padded,
                    "padding mismatch: built {} vs manifest {}",
                    st.n_padded,
                    entry.n_padded
                );
                let client = xla::PjRtClient::cpu()
                    .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
                let plant = HloPlant::load(
                    &client,
                    &man.hlo_path(entry),
                    &st,
                    entry.substeps_per_tick,
                    t_water,
                )?;
                Ok(PlantBackend::Hlo(plant))
            }
            BackendKind::Native => {
                let lot = ChipLottery::draw(n_nodes, pp, seed);
                let st = PlantStatic::from_lottery(&lot, pp, 64);
                let ops = Operators::build(pp);
                Ok(PlantBackend::Native(NativePlant::with_kernel(
                    pp.clone(),
                    ops,
                    st,
                    t_water,
                    kernel,
                )))
            }
            BackendKind::Auto => unreachable!(),
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self {
            PlantBackend::Hlo(_) => "hlo",
            PlantBackend::Native(_) => "native",
        }
    }

    /// The substep kernel actually in use ("hlo" for the HLO backend).
    pub fn kernel_name(&self) -> &'static str {
        match self {
            PlantBackend::Hlo(_) => "hlo",
            PlantBackend::Native(p) => p.kernel.name(),
        }
    }

    pub fn n_nodes(&self) -> usize {
        match self {
            PlantBackend::Hlo(p) => p.n_nodes,
            PlantBackend::Native(p) => p.st.n_nodes,
        }
    }

    pub fn n_padded(&self) -> usize {
        match self {
            PlantBackend::Hlo(p) => p.n_padded,
            PlantBackend::Native(p) => p.st.n_padded,
        }
    }

    pub fn substeps(&self) -> usize {
        match self {
            PlantBackend::Hlo(p) => p.substeps,
            PlantBackend::Native(p) => p.substeps,
        }
    }

    /// Advance one tick. `util` is [n_padded * NC]; `controls` is [CT].
    pub fn tick(&mut self, controls: &[f32], util: &[f32],
                out: &mut TickOutput) -> Result<()> {
        match self {
            PlantBackend::Hlo(p) => p.tick(controls, util, out),
            PlantBackend::Native(p) => {
                p.tick(controls, util, out);
                Ok(())
            }
        }
    }

    /// Full node thermal state [n_padded * S] (per-core temps for
    /// Fig. 4b). Takes `&mut self`: the native SoA kernel keeps its
    /// lanes resident and materializes the node-major view lazily on
    /// first read after a tick (`NativePlant::node_state`) — steady-state
    /// runs that never call this do zero state transposes.
    pub fn node_state(&mut self) -> &[f32] {
        match self {
            PlantBackend::Hlo(p) => &p.node_state,
            PlantBackend::Native(p) => p.node_state(),
        }
    }

    /// The native plant, if this backend is native (the fleet megabatch
    /// engine drives native plants' circuit state directly).
    pub fn native(&self) -> Option<&NativePlant> {
        match self {
            PlantBackend::Native(p) => Some(p),
            PlantBackend::Hlo(_) => None,
        }
    }

    /// Mutable variant of `native`.
    pub fn native_mut(&mut self) -> Option<&mut NativePlant> {
        match self {
            PlantBackend::Native(p) => Some(p),
            PlantBackend::Hlo(_) => None,
        }
    }

    pub fn circuit_state(&self) -> &[f32] {
        match self {
            PlantBackend::Hlo(p) => &p.circuit_state,
            PlantBackend::Native(p) => &p.circuit_state,
        }
    }

    pub fn reset(&mut self, t_water: f32) {
        match self {
            PlantBackend::Hlo(p) => p.reset(t_water),
            PlantBackend::Native(p) => p.reset(t_water),
        }
    }

    /// Simulated seconds advanced per tick.
    pub fn tick_seconds(&self, pp: &PlantParams) -> f64 {
        self.substeps() as f64 * pp.dt_substep
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!("hlo".parse::<BackendKind>().unwrap(), BackendKind::Hlo);
        assert_eq!("auto".parse::<BackendKind>().unwrap(), BackendKind::Auto);
        assert!("x".parse::<BackendKind>().is_err());
    }

    #[test]
    fn native_backend_without_artifacts() {
        let pp = PlantParams::default();
        let mut b = PlantBackend::create(
            BackendKind::Native,
            Path::new("/nonexistent"),
            13,
            &pp,
            1,
            20.0,
        )
        .unwrap();
        assert_eq!(b.n_nodes(), 13);
        assert_eq!(b.n_padded(), 64);
        let mut out = TickOutput::new(b.n_padded());
        let controls = vec![0.0, 1.0, 18.0, 8.0, 9000.0, 0.75, 0.0, 0.0];
        let util = vec![1.0f32; b.n_padded() * NC];
        b.tick(&controls, &util, &mut out).unwrap();
        assert!(out.scalars[SC_P_DC] > 1000.0);
    }

    #[test]
    fn explicit_kernel_selection_sticks() {
        let pp = PlantParams::default();
        for (kernel, name) in [
            (PlantKernel::Reference, "reference"),
            (PlantKernel::Soa, "soa"),
        ] {
            let mut b = PlantBackend::create_with_kernel(
                BackendKind::Native,
                kernel,
                Path::new("/nonexistent"),
                13,
                &pp,
                1,
                20.0,
            )
            .unwrap();
            assert_eq!(b.kernel_name(), name);
            let mut out = TickOutput::new(b.n_padded());
            let controls = vec![0.0, 1.0, 18.0, 8.0, 9000.0, 0.75, 0.0, 0.0];
            let util = vec![1.0f32; b.n_padded() * NC];
            b.tick(&controls, &util, &mut out).unwrap();
            assert!(out.scalars[SC_P_DC] > 1000.0);
        }
    }

    #[test]
    fn auto_falls_back_to_native() {
        let pp = PlantParams::default();
        let b = PlantBackend::create(
            BackendKind::Auto,
            Path::new("/nonexistent"),
            13,
            &pp,
            1,
            20.0,
        )
        .unwrap();
        assert_eq!(b.kind_name(), "native");
    }
}
