//! Artifact manifest: shapes and file names emitted by `aot.py`.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One lowered plant executable (a cluster size).
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub n_nodes: usize,
    pub n_padded: usize,
    pub hlo: String,
    pub lottery: String,
    pub substeps_per_tick: usize,
    pub dt_substep: f64,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub tile: usize,
    pub seed: u64,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let j = Json::parse(&text)?;
        Self::from_json(dir, &j)
    }

    pub fn from_json(dir: &Path, j: &Json) -> anyhow::Result<Self> {
        anyhow::ensure!(
            j.get("format").and_then(Json::as_str) == Some("hlo-text"),
            "manifest: unsupported format"
        );
        let tile = j.get("tile").and_then(Json::as_usize).unwrap_or(64);
        let seed = j.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest: no entries"))?
        {
            entries.push(ManifestEntry {
                n_nodes: e
                    .get("n_nodes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("entry: n_nodes"))?,
                n_padded: e
                    .get("n_padded")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("entry: n_padded"))?,
                hlo: e
                    .get("hlo")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("entry: hlo"))?
                    .to_string(),
                lottery: e
                    .get("lottery")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string(),
                substeps_per_tick: e
                    .get("substeps_per_tick")
                    .and_then(Json::as_usize)
                    .unwrap_or(20),
                dt_substep: e
                    .get("dt_substep")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.25),
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), tile, seed, entries })
    }

    /// Find the entry for a cluster size.
    pub fn entry(&self, n_nodes: usize) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.n_nodes == n_nodes)
    }

    pub fn hlo_path(&self, e: &ManifestEntry) -> PathBuf {
        self.dir.join(&e.hlo)
    }

    pub fn lottery_path(&self, e: &ManifestEntry) -> PathBuf {
        self.dir.join(&e.lottery)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let j = Json::parse(
            r#"{"format": "hlo-text", "tile": 64, "seed": 1,
                "entries": [{"n_nodes": 13, "n_padded": 64,
                             "hlo": "plant_step_n13.hlo.txt",
                             "lottery": "lottery_n13.json",
                             "substeps_per_tick": 20,
                             "dt_substep": 0.25}]}"#,
        )
        .unwrap();
        let m = Manifest::from_json(Path::new("/tmp/a"), &j).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.entry(13).unwrap();
        assert_eq!(e.n_padded, 64);
        assert!(m.entry(99).is_none());
        assert_eq!(m.hlo_path(e), Path::new("/tmp/a/plant_step_n13.hlo.txt"));
    }

    #[test]
    fn rejects_wrong_format() {
        let j = Json::parse(r#"{"format": "proto", "entries": []}"#).unwrap();
        assert!(Manifest::from_json(Path::new("."), &j).is_err());
    }
}
