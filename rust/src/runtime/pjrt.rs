//! PJRT execution of the AOT-lowered plant (the request-path hot loop).
//!
//! Loads `artifacts/plant_step_n{N}.hlo.txt` (HLO *text* — see aot.py for
//! why not serialized protos), compiles it once on the PJRT CPU client,
//! and executes it every coordinator tick. Python never runs here.
//!
//! Hot-path notes (EXPERIMENTS.md §Perf): the static lottery arrays
//! (g/p_dyn/p_idle/active) are uploaded to device buffers once and reused
//! via `execute_b`; only the state + util + controls change per tick.

use std::path::Path;

use anyhow::{Context, Result};

use crate::plant::layout::*;
use crate::plant::{PlantStatic, TickOutput};

/// A compiled plant executable bound to a PJRT client.
pub struct HloPlant {
    exe: xla::PjRtLoadedExecutable,
    pub n_nodes: usize,
    pub n_padded: usize,
    pub substeps: usize,
    /// Device-resident static inputs (g, p_dyn, p_idle, active).
    static_bufs: Vec<xla::PjRtBuffer>,
    /// Host-side state mirrors.
    pub node_state: Vec<f32>,
    pub circuit_state: Vec<f32>,
    /// Reusable host literals for the per-tick uploads.
    client: xla::PjRtClient,
    /// Executions since construction (telemetry).
    pub ticks_executed: u64,
}

impl HloPlant {
    /// Load + compile an HLO text file.
    pub fn load(
        client: &xla::PjRtClient,
        hlo_path: &Path,
        st: &PlantStatic,
        substeps: usize,
        t_water: f32,
    ) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .context("hlo path not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", hlo_path.display()))?;

        let npad = st.n_padded;
        let dev = client
            .addressable_devices()
            .into_iter()
            .next()
            .context("no pjrt device")?;
        let up = |data: &[f32], rows: usize, cols: usize| -> Result<xla::PjRtBuffer> {
            client
                .buffer_from_host_buffer(data, &[rows, cols], Some(&dev))
                .map_err(|e| anyhow::anyhow!("upload: {e}"))
        };
        let static_bufs = vec![
            up(&st.g, npad, NG)?,
            up(&st.p_dyn, npad, NC)?,
            up(&st.p_idle, npad, NC)?,
            up(&st.active, npad, NC)?,
        ];

        Ok(HloPlant {
            exe,
            n_nodes: st.n_nodes,
            n_padded: npad,
            substeps,
            static_bufs,
            node_state: vec![t_water; npad * S],
            circuit_state: crate::plant::circuits::initial_circuit_state(
                t_water,
                &crate::config::constants::PlantParams::default(),
            ),
            client: client.clone(),
            ticks_executed: 0,
        })
    }

    pub fn reset(&mut self, t_water: f32) {
        self.node_state.fill(t_water);
        self.circuit_state = crate::plant::circuits::initial_circuit_state(
            t_water,
            &crate::config::constants::PlantParams::default(),
        );
    }

    /// Execute one tick: uploads state/util/controls, runs the executable,
    /// downloads the 4-tuple (node_state', circuit_state', node_obs,
    /// scalars) and refreshes the host mirrors.
    pub fn tick(&mut self, controls: &[f32], util: &[f32],
                out: &mut TickOutput) -> Result<()> {
        let npad = self.n_padded;
        debug_assert_eq!(util.len(), npad * NC);
        debug_assert_eq!(controls.len(), CT);

        let dev = self
            .client
            .addressable_devices()
            .into_iter()
            .next()
            .context("no pjrt device")?;
        let b_state = self
            .client
            .buffer_from_host_buffer(&self.node_state, &[npad, S], Some(&dev))
            .map_err(|e| anyhow::anyhow!("upload state: {e}"))?;
        let b_cs = self
            .client
            .buffer_from_host_buffer(&self.circuit_state, &[CS], Some(&dev))
            .map_err(|e| anyhow::anyhow!("upload circuit: {e}"))?;
        let b_util = self
            .client
            .buffer_from_host_buffer(util, &[npad, NC], Some(&dev))
            .map_err(|e| anyhow::anyhow!("upload util: {e}"))?;
        let b_ctl = self
            .client
            .buffer_from_host_buffer(controls, &[CT], Some(&dev))
            .map_err(|e| anyhow::anyhow!("upload controls: {e}"))?;

        // Parameter order matches model.plant_step:
        //   node_state, circuit_state, util, controls, g, p_dyn, p_idle, active
        let args: Vec<&xla::PjRtBuffer> = vec![
            &b_state,
            &b_cs,
            &b_util,
            &b_ctl,
            &self.static_bufs[0],
            &self.static_bufs[1],
            &self.static_bufs[2],
            &self.static_bufs[3],
        ];
        let result = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        anyhow::ensure!(parts.len() == 4, "expected 4 outputs, got {}",
                        parts.len());

        parts[0]
            .copy_raw_to(&mut self.node_state)
            .map_err(|e| anyhow::anyhow!("state out: {e}"))?;
        parts[1]
            .copy_raw_to(&mut self.circuit_state)
            .map_err(|e| anyhow::anyhow!("circuit out: {e}"))?;
        if out.node_obs.len() != npad * OBS_N {
            out.node_obs.resize(npad * OBS_N, 0.0);
        }
        parts[2]
            .copy_raw_to(&mut out.node_obs)
            .map_err(|e| anyhow::anyhow!("obs out: {e}"))?;
        let mut scalars = vec![0.0f32; NS];
        parts[3]
            .copy_raw_to(&mut scalars)
            .map_err(|e| anyhow::anyhow!("scalars out: {e}"))?;
        out.scalars.copy_from_slice(&scalars);
        self.ticks_executed += 1;
        Ok(())
    }
}
