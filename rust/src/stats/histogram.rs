//! Fixed-bin histogram (the paper's Figs. 4b and 5b).

/// A histogram over [lo, hi) with uniform bins.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
            total: 0,
        }
    }

    pub fn bins(&self) -> usize {
        self.counts.len()
    }

    pub fn bin_width(&self) -> f64 {
        (self.hi - self.lo) / self.bins() as f64
    }

    pub fn push(&mut self, x: f64) {
        self.total += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let i = ((x - self.lo) / self.bin_width()) as usize;
            let i = i.min(self.bins() - 1);
            self.counts[i] += 1;
        }
    }

    pub fn push_all<I: IntoIterator<Item = f64>>(&mut self, xs: I) {
        for x in xs {
            self.push(x);
        }
    }

    /// Bin centers.
    pub fn centers(&self) -> Vec<f64> {
        let w = self.bin_width();
        (0..self.bins()).map(|i| self.lo + (i as f64 + 0.5) * w).collect()
    }

    /// Normalized densities (sum * bin_width = 1 over in-range mass).
    pub fn densities(&self) -> Vec<f64> {
        let in_range = (self.total - self.underflow - self.overflow) as f64;
        if in_range == 0.0 {
            return vec![0.0; self.bins()];
        }
        let w = self.bin_width();
        self.counts
            .iter()
            .map(|&c| c as f64 / (in_range * w))
            .collect()
    }

    /// Nearest-rank quantile over the binned sample, reported as a bin
    /// center (the serve layer's p50/p99 latency view). Underflow mass
    /// maps to `lo`, overflow mass to `hi`; NaN when the histogram is
    /// empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.total as f64).ceil() as u64).max(1);
        let mut cum = self.underflow;
        if cum >= target {
            return self.lo;
        }
        let w = self.bin_width();
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return self.lo + (i as f64 + 0.5) * w;
            }
        }
        self.hi
    }

    /// The mode's bin center.
    pub fn mode(&self) -> f64 {
        let (i, _) = self
            .counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .unwrap();
        self.centers()[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_and_flows() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push_all([-1.0, 0.0, 0.5, 5.5, 9.99, 10.0, 42.0]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 2);
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[5], 1);
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.total, 7);
    }

    #[test]
    fn densities_integrate_to_one() {
        let mut h = Histogram::new(-3.0, 3.0, 60);
        let mut rng = crate::variability::rng::Rng::new(3);
        for _ in 0..10_000 {
            h.push(rng.normal());
        }
        let mass: f64 =
            h.densities().iter().map(|d| d * h.bin_width()).sum();
        assert!((mass - 1.0).abs() < 1e-9);
    }

    #[test]
    fn quantiles_walk_the_cdf() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        // 90 samples in bin 0, 10 in bin 9: p50 sits in bin 0, p99 in
        // bin 9 (bin centers 0.5 and 9.5).
        for _ in 0..90 {
            h.push(0.2);
        }
        for _ in 0..10 {
            h.push(9.2);
        }
        assert_eq!(h.quantile(0.5), 0.5);
        assert_eq!(h.quantile(0.9), 0.5);
        assert_eq!(h.quantile(0.91), 9.5);
        assert_eq!(h.quantile(0.99), 9.5);
        assert_eq!(h.quantile(0.0), 0.5);
        assert_eq!(h.quantile(1.0), 9.5);
    }

    #[test]
    fn quantile_edges() {
        let h = Histogram::new(0.0, 1.0, 4);
        assert!(h.quantile(0.5).is_nan());
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.push(-5.0); // underflow maps to lo
        assert_eq!(h.quantile(0.5), 0.0);
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.push(5.0); // overflow maps to hi
        assert_eq!(h.quantile(0.5), 1.0);
    }

    #[test]
    fn mode_of_gaussian_near_mean() {
        let mut h = Histogram::new(50.0, 110.0, 60);
        let mut rng = crate::variability::rng::Rng::new(9);
        for _ in 0..50_000 {
            h.push(84.0 + 2.8 * rng.normal());
        }
        assert!((h.mode() - 84.0).abs() < 1.5, "{}", h.mode());
    }
}
