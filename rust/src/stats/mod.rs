//! Statistics substrate: the analysis the paper applies to its
//! measurements — histograms with Gaussian fits (Figs. 4b, 5b), averages
//! with standard-deviation error bars (Figs. 4a, 5a, 6a), linear
//! interpolation of per-node power to a reference temperature (Fig. 5b),
//! and error propagation for the flow-meter accuracies (Figs. 6b, 7).

pub mod gauss;
pub mod histogram;
pub mod interp;

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::MAX, max: f64::MIN }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Running) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n;
        let m2 = self.m2
            + other.m2
            + d * d * self.n as f64 * other.n as f64 / n;
        self.n += other.n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Mean and population std of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let mut r = Running::new();
    for &x in xs {
        r.push(x);
    }
    (r.mean(), r.std())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let (m, s) = mean_std(&xs);
        assert!((m - 4.0).abs() < 1e-12);
        let var: f64 =
            xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 5.0;
        assert!((s - var.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 5.0).collect();
        let mut a = Running::new();
        let mut b = Running::new();
        for &x in &xs[..40] {
            a.push(x);
        }
        for &x in &xs[40..] {
            b.push(x);
        }
        a.merge(&b);
        let mut all = Running::new();
        for &x in &xs {
            all.push(x);
        }
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.std() - all.std()).abs() < 1e-10);
        assert_eq!(a.count(), 100);
    }

    #[test]
    fn empty_running_is_safe() {
        let r = Running::new();
        assert_eq!(r.var(), 0.0);
        assert_eq!(r.count(), 0);
    }
}
