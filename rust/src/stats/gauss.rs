//! Gaussian fitting for the paper's histograms.
//!
//! Fig. 4(b): "The solid line is a Gaussian fit centered at 84 degC with
//! sigma = 2.8 degC"; Fig. 5(b): "Gaussian fit centered at 206 W with
//! sigma = 5.4 W". The paper's histograms have contamination (the idle
//! bump at the low end of Fig. 4b), so we fit by iterated trimmed moments
//! (sigma-clipping), which recovers the dominant Gaussian component, and
//! verify against a least-squares refinement on the histogram densities.

use super::histogram::Histogram;

/// A fitted Gaussian component.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Gaussian {
    pub mu: f64,
    pub sigma: f64,
    /// Mixture weight of the fitted component (1.0 = all samples).
    pub weight: f64,
}

impl Gaussian {
    pub fn pdf(&self, x: f64) -> f64 {
        let z = (x - self.mu) / self.sigma;
        (-0.5 * z * z).exp() / (self.sigma * (2.0 * std::f64::consts::PI).sqrt())
    }
}

/// Sigma-clipped moment fit: robust to a minority contamination such as
/// the idle-node bump in Fig. 4(b).
pub fn fit_sigma_clipped(xs: &[f64], clip: f64, iters: usize) -> Gaussian {
    assert!(!xs.is_empty());
    let (mut mu, mut sigma) = super::mean_std(xs);
    let mut kept = xs.len();
    for _ in 0..iters {
        let lo = mu - clip * sigma;
        let hi = mu + clip * sigma;
        let mut r = super::Running::new();
        for &x in xs {
            if x >= lo && x <= hi {
                r.push(x);
            }
        }
        if r.count() == 0 {
            break;
        }
        kept = r.count() as usize;
        let new_mu = r.mean();
        let new_sigma = r.std().max(1e-9);
        if (new_mu - mu).abs() < 1e-12 && (new_sigma - sigma).abs() < 1e-12 {
            mu = new_mu;
            sigma = new_sigma;
            break;
        }
        mu = new_mu;
        sigma = new_sigma;
    }
    // Correct the clipped variance: truncating at +-c sigma underestimates
    // sigma by a known factor for a true Gaussian.
    let corr = truncated_sigma_correction(clip);
    Gaussian { mu, sigma: sigma / corr, weight: kept as f64 / xs.len() as f64 }
}

/// sqrt of the variance of a standard normal truncated to [-c, c].
fn truncated_sigma_correction(c: f64) -> f64 {
    // Var = 1 - 2 c phi(c) / (2 Phi(c) - 1)
    let phi = (-0.5 * c * c).exp() / (2.0 * std::f64::consts::PI).sqrt();
    let cdf = 0.5 * (1.0 + erf(c / std::f64::consts::SQRT_2));
    let z = 2.0 * cdf - 1.0;
    (1.0 - 2.0 * c * phi / z).sqrt()
}

/// Error function (Abramowitz & Stegun 7.1.26, |err| < 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
            - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Least-squares refinement of (mu, sigma, amplitude) on histogram
/// densities via coordinate descent. Returns the refined Gaussian.
pub fn refine_on_histogram(h: &Histogram, init: Gaussian) -> Gaussian {
    let xs = h.centers();
    let ys = h.densities();
    let sse = |mu: f64, sigma: f64, a: f64| -> f64 {
        let g = Gaussian { mu, sigma, weight: 1.0 };
        xs.iter()
            .zip(&ys)
            .map(|(&x, &y)| {
                let e = a * g.pdf(x) - y;
                e * e
            })
            .sum()
    };
    let (mut mu, mut sigma, mut a) = (init.mu, init.sigma, init.weight);
    let mut best = sse(mu, sigma, a);
    for _ in 0..60 {
        let mut improved = false;
        for (dm, ds, da) in [
            (0.05, 0.0, 0.0),
            (-0.05, 0.0, 0.0),
            (0.0, 0.02, 0.0),
            (0.0, -0.02, 0.0),
            (0.0, 0.0, 0.01),
            (0.0, 0.0, -0.01),
        ] {
            let cand = sse(mu + dm, (sigma + ds).max(1e-6), (a + da).clamp(0.0, 1.5));
            if cand < best {
                best = cand;
                mu += dm;
                sigma = (sigma + ds).max(1e-6);
                a = (a + da).clamp(0.0, 1.5);
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    Gaussian { mu, sigma, weight: a }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::variability::rng::Rng;

    #[test]
    fn clean_gaussian_recovered() {
        let mut rng = Rng::new(4);
        let xs: Vec<f64> = (0..30_000).map(|_| 84.0 + 2.8 * rng.normal()).collect();
        let g = fit_sigma_clipped(&xs, 2.5, 8);
        assert!((g.mu - 84.0).abs() < 0.1, "mu {}", g.mu);
        assert!((g.sigma - 2.8).abs() < 0.15, "sigma {}", g.sigma);
    }

    #[test]
    fn contaminated_gaussian_recovered() {
        // Fig. 4b shape: dominant Gaussian at 84, idle bump near 55.
        let mut rng = Rng::new(5);
        let mut xs: Vec<f64> =
            (0..20_000).map(|_| 84.0 + 2.8 * rng.normal()).collect();
        xs.extend((0..1500).map(|_| 55.0 + 1.5 * rng.normal()));
        let g = fit_sigma_clipped(&xs, 2.5, 10);
        assert!((g.mu - 84.0).abs() < 0.4, "mu {}", g.mu);
        assert!((g.sigma - 2.8).abs() < 0.4, "sigma {}", g.sigma);
    }

    #[test]
    fn erf_reference_values() {
        assert!((erf(0.0)).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(3.0) - 0.9999779095).abs() < 1e-6);
    }

    #[test]
    fn histogram_refinement_improves_or_holds() {
        let mut rng = Rng::new(6);
        let mut h = crate::stats::histogram::Histogram::new(60.0, 110.0, 50);
        for _ in 0..30_000 {
            h.push(84.0 + 2.8 * rng.normal());
        }
        let init = Gaussian { mu: 82.0, sigma: 4.0, weight: 1.0 };
        let g = refine_on_histogram(&h, init);
        assert!((g.mu - 84.0).abs() < 0.6, "mu {}", g.mu);
        assert!((g.sigma - 2.8).abs() < 0.6, "sigma {}", g.sigma);
    }
}
