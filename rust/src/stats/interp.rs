//! Linear regression / interpolation.
//!
//! Fig. 5(b) protocol: "we measure the DC power on most six-core nodes for
//! various temperatures, interpolate to 80 degC, and then construct a
//! histogram of the interpolated node power."

/// Ordinary least-squares line fit y = a + b x.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Line {
    pub a: f64,
    pub b: f64,
    /// Coefficient of determination.
    pub r2: f64,
}

impl Line {
    pub fn at(&self, x: f64) -> f64 {
        self.a + self.b * x
    }
}

/// Fit a line through (x, y) samples. Returns None for < 2 points or
/// degenerate x.
pub fn fit_line(xs: &[f64], ys: &[f64]) -> Option<Line> {
    if xs.len() != ys.len() || xs.len() < 2 {
        return None;
    }
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    if sxx < 1e-12 {
        return None;
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy < 1e-12 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    Some(Line { a, b, r2 })
}

/// Piecewise-linear interpolation of y at `x` over sorted xs.
/// Extrapolates with the end segments (as the paper's protocol needs when
/// 80 degC lies beyond a node's measured band).
pub fn interp_at(xs: &[f64], ys: &[f64], x: f64) -> Option<f64> {
    if xs.len() != ys.len() || xs.is_empty() {
        return None;
    }
    if xs.len() == 1 {
        return Some(ys[0]);
    }
    debug_assert!(xs.windows(2).all(|w| w[0] <= w[1]));
    let i = match xs.iter().position(|&xi| xi >= x) {
        Some(0) => 1,
        Some(i) => i,
        None => xs.len() - 1,
    };
    let (x0, x1) = (xs[i - 1], xs[i]);
    let (y0, y1) = (ys[i - 1], ys[i]);
    if (x1 - x0).abs() < 1e-12 {
        return Some(0.5 * (y0 + y1));
    }
    Some(y0 + (y1 - y0) * (x - x0) / (x1 - x0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let l = fit_line(&xs, &ys).unwrap();
        assert!((l.a - 1.0).abs() < 1e-12);
        assert!((l.b - 2.0).abs() < 1e-12);
        assert!((l.r2 - 1.0).abs() < 1e-12);
        assert!((l.at(80.0) - 161.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_rejected() {
        assert!(fit_line(&[1.0], &[2.0]).is_none());
        assert!(fit_line(&[2.0, 2.0], &[1.0, 5.0]).is_none());
    }

    #[test]
    fn interp_interior_and_extrapolation() {
        let xs = [60.0, 70.0, 75.0];
        let ys = [190.0, 200.0, 205.0];
        assert!((interp_at(&xs, &ys, 65.0).unwrap() - 195.0).abs() < 1e-9);
        // extrapolate to 80 with the last segment (slope 1 W/K)
        assert!((interp_at(&xs, &ys, 80.0).unwrap() - 210.0).abs() < 1e-9);
        // and below with the first segment
        assert!((interp_at(&xs, &ys, 55.0).unwrap() - 185.0).abs() < 1e-9);
    }

    #[test]
    fn noisy_line_r2_below_one() {
        let mut rng = crate::variability::rng::Rng::new(8);
        let xs: Vec<f64> = (0..200).map(|i| i as f64 / 10.0).collect();
        let ys: Vec<f64> =
            xs.iter().map(|&x| 2.0 + 0.5 * x + rng.normal()).collect();
        let l = fit_line(&xs, &ys).unwrap();
        assert!((l.b - 0.5).abs() < 0.05);
        assert!(l.r2 > 0.7 && l.r2 < 1.0);
    }
}
