//! Hydraulic network substrate: the rack manifold of Sect. 2.
//!
//! The paper: "The manifold is designed using the Tichelmann principle to
//! ensure that the distance covered by the water flow, and therefore the
//! pressure drop, is equal for all nodes. Thus the water flow rates
//! balance themselves automatically."
//!
//! This module solves the parallel-branch flow distribution with explicit
//! supply/return headers so the self-balancing claim can be quantified
//! against a conventional direct-return manifold (ablation bench
//! `figures.rs::manifold`). Segment and branch pressure drops follow the
//! turbulent law dp = r * q^2; header segments carry the cumulative flow
//! of all downstream branches.

/// Manifold topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ManifoldKind {
    /// Reverse-return (equal path length for every branch) — iDataCool.
    Tichelmann,
    /// Direct-return (first branch has the shortest path).
    DirectReturn,
}

/// A rack manifold with `n` identical node branches.
#[derive(Debug, Clone)]
pub struct Manifold {
    pub kind: ManifoldKind,
    /// Node (branch) hydraulic resistance [bar/(l/min)^2].
    pub r_branch: f64,
    /// Per-segment header resistance [bar/(l/min)^2].
    pub r_segment: f64,
    pub n: usize,
}

impl Manifold {
    /// Build from the plant parameters: branch resistance sized so the
    /// nominal per-node flow (0.6 l/min) produces the paper's <0.1 bar
    /// drop; header segments sized so the whole manifold adds
    /// ~`manifold_dp_bar` at nominal total flow.
    pub fn from_params(
        pp: &crate::config::constants::PlantParams,
        n: usize,
        kind: ManifoldKind,
    ) -> Self {
        let r_branch = pp.node_dp_bar / (pp.node_flow_lpm * pp.node_flow_lpm);
        let total_q = pp.node_flow_lpm * n as f64;
        let avg_header_flow = total_q / 2.0;
        let r_segment = pp.manifold_dp_bar
            / (n as f64 * avg_header_flow * avg_header_flow);
        Manifold { kind, r_branch, r_segment, n }
    }

    /// Pressure drop of branch path i given the current flow split.
    fn path_dp(&self, q: &[f64], i: usize) -> f64 {
        let n = self.n;
        // Supply header: segment j (0-based, before branch j) carries the
        // flow still headed to branches j..n.
        let mut remaining: f64 = q.iter().sum();
        let mut dp = 0.0;
        for qj in q.iter().take(i + 1) {
            dp += self.r_segment * remaining * remaining;
            remaining -= qj;
        }
        dp += self.r_branch * q[i] * q[i];
        match self.kind {
            ManifoldKind::DirectReturn => {
                // Return header exits at the supply end: the segment
                // between branch j and j-1 carries the collected flow of
                // branches j..n, so branch i's return path traverses
                // segments i, i-1, ..., 1.
                for j in (1..=i).rev() {
                    let seg_flow: f64 = q.iter().skip(j).sum::<f64>();
                    dp += self.r_segment * seg_flow * seg_flow;
                }
                dp
            }
            ManifoldKind::Tichelmann => {
                // Reverse return: exits at the far end; the segment between
                // branch j and j+1 carries the collected flow of 0..=j.
                for j in i..n - 1 {
                    let seg_flow: f64 = q.iter().take(j + 1).sum::<f64>();
                    dp += self.r_segment * seg_flow * seg_flow;
                }
                dp
            }
        }
    }

    /// All branch-path pressure drops at once in O(n): one prefix-sum
    /// pass over the branch flows, then cumulative supply/return header
    /// sweeps. Mirrors the O(n^2)-per-branch reference `path_dp` (kept
    /// for validation and one-shot callers) up to float summation order.
    fn path_dps_into(&self, q: &[f64], prefix: &mut [f64], dps: &mut [f64]) {
        let n = self.n;
        prefix[0] = 0.0;
        for (j, &qj) in q.iter().enumerate() {
            prefix[j + 1] = prefix[j] + qj;
        }
        let total = prefix[n];
        // Supply header (segments 0..=i, segment j carrying the flow
        // still headed downstream) + the branch term.
        let mut supply = 0.0;
        for i in 0..n {
            let remaining = total - prefix[i];
            supply += self.r_segment * remaining * remaining;
            dps[i] = supply + self.r_branch * q[i] * q[i];
        }
        match self.kind {
            ManifoldKind::DirectReturn => {
                // Return segments i, i-1, ..., 1; segment j carries the
                // collected flow of branches j..n.
                let mut ret = 0.0;
                for i in 1..n {
                    let seg = total - prefix[i];
                    ret += self.r_segment * seg * seg;
                    dps[i] += ret;
                }
            }
            ManifoldKind::Tichelmann => {
                // Reverse return: segments i..n-1; segment j carries the
                // collected flow of branches 0..=j.
                let mut ret = 0.0;
                for i in (0..n).rev() {
                    dps[i] += ret;
                    ret += self.r_segment * prefix[i] * prefix[i];
                }
            }
        }
    }

    /// Solve branch flows [l/min] for a given total rack flow by fixed-
    /// point iteration on equal path pressure drops.
    pub fn solve_flows(&self, total_flow_lpm: f64) -> Vec<f64> {
        let mut q = Vec::new();
        self.solve_flows_into(total_flow_lpm, &mut q);
        q
    }

    /// `solve_flows` into a caller-owned buffer; the scratch vectors are
    /// hoisted out of the fixed-point loop (previously one `dps`
    /// allocation per iteration, each filled by an O(n^2)-per-branch
    /// sweep), so a solve is two scratch allocations + O(n) per
    /// iteration.
    pub fn solve_flows_into(&self, total_flow_lpm: f64, q: &mut Vec<f64>) {
        let n = self.n;
        q.clear();
        q.resize(n, total_flow_lpm / n as f64);
        let mut prefix = vec![0.0f64; n + 1];
        let mut dps = vec![0.0f64; n];
        for _ in 0..300 {
            self.path_dps_into(q, &mut prefix, &mut dps);
            let dp_mean = dps.iter().sum::<f64>() / n as f64;
            let mut changed = 0.0f64;
            for (qi, dp) in q.iter_mut().zip(&dps) {
                let adj = (dp_mean / dp).sqrt().clamp(0.5, 2.0);
                let new_q = *qi * (1.0 + 0.5 * (adj - 1.0));
                changed = changed.max((new_q - *qi).abs());
                *qi = new_q;
            }
            // renormalize to the total
            let sum: f64 = q.iter().sum();
            for qi in q.iter_mut() {
                *qi *= total_flow_lpm / sum;
            }
            if changed < 1e-12 {
                break;
            }
        }
    }

    /// Relative flow imbalance: (max - min) / mean.
    pub fn imbalance(&self, total_flow_lpm: f64) -> f64 {
        let q = self.solve_flows(total_flow_lpm);
        let mean = total_flow_lpm / self.n as f64;
        let max = q.iter().cloned().fold(f64::MIN, f64::max);
        let min = q.iter().cloned().fold(f64::MAX, f64::min);
        (max - min) / mean
    }

    /// Pump pressure needed at the given total flow [bar] (= equalized
    /// branch-path drop after the solve).
    pub fn pressure_drop(&self, total_flow_lpm: f64) -> f64 {
        let q = self.solve_flows(total_flow_lpm);
        self.path_dp(&q, 0)
    }

    /// Per-node flow error translated to a water-outlet temperature error
    /// at the given node heat [W]: dT_node = Q / (m_dot c_p), so a flow
    /// deficit raises the node's local outlet temperature.
    pub fn outlet_temp_spread(&self, total_flow_lpm: f64, q_node_w: f64,
                              pp: &crate::config::constants::PlantParams)
                              -> f64 {
        let flows = self.solve_flows(total_flow_lpm);
        let dts: Vec<f64> = flows
            .iter()
            .map(|&f_lpm| {
                let mcp = f_lpm / 60.0 * pp.rho_water * pp.cp_water;
                q_node_w / mcp
            })
            .collect();
        let max = dts.iter().cloned().fold(f64::MIN, f64::max);
        let min = dts.iter().cloned().fold(f64::MAX, f64::min);
        max - min
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::constants::PlantParams;

    #[test]
    fn tichelmann_balances_flows() {
        let pp = PlantParams::default();
        let m = Manifold::from_params(&pp, 72, ManifoldKind::Tichelmann);
        let imb = m.imbalance(72.0 * 0.6);
        // Second-order (quadratic-header) imbalance only: small.
        assert!(imb < 0.05, "Tichelmann imbalance {imb}");
    }

    #[test]
    fn direct_return_is_imbalanced() {
        let pp = PlantParams::default();
        let d = Manifold::from_params(&pp, 72, ManifoldKind::DirectReturn);
        let t = Manifold::from_params(&pp, 72, ManifoldKind::Tichelmann);
        let imb_d = d.imbalance(72.0 * 0.6);
        let imb_t = t.imbalance(72.0 * 0.6);
        assert!(imb_d > 0.06, "direct-return imbalance only {imb_d}");
        assert!(imb_d > imb_t * 2.0, "d={imb_d} t={imb_t}");
    }

    #[test]
    fn flows_sum_to_total() {
        let pp = PlantParams::default();
        for kind in [ManifoldKind::Tichelmann, ManifoldKind::DirectReturn] {
            let m = Manifold::from_params(&pp, 72, kind);
            let q = m.solve_flows(43.2);
            let sum: f64 = q.iter().sum();
            assert!((sum - 43.2).abs() < 1e-9);
        }
    }

    #[test]
    fn direct_return_favors_first_branch() {
        let pp = PlantParams::default();
        let m = Manifold::from_params(&pp, 72, ManifoldKind::DirectReturn);
        let q = m.solve_flows(43.2);
        assert!(q[0] > q[71], "q0={} q71={}", q[0], q[71]);
    }

    #[test]
    fn nominal_pressure_drop_near_paper_limit() {
        // Sect. 2: branch drop < 0.1 bar at 0.6 l/min; headers add a little.
        let pp = PlantParams::default();
        let m = Manifold::from_params(&pp, 72, ManifoldKind::Tichelmann);
        let dp = m.pressure_drop(72.0 * 0.6);
        assert!(dp > 0.05 && dp < 0.16, "dp {dp}");
    }

    #[test]
    fn equalized_path_drops_after_solve() {
        let pp = PlantParams::default();
        for kind in [ManifoldKind::Tichelmann, ManifoldKind::DirectReturn] {
            let m = Manifold::from_params(&pp, 24, kind);
            let q = m.solve_flows(24.0 * 0.6);
            let dps: Vec<f64> = (0..24).map(|i| m.path_dp(&q, i)).collect();
            let mean = dps.iter().sum::<f64>() / dps.len() as f64;
            for dp in dps {
                assert!((dp / mean - 1.0).abs() < 0.01, "dp {dp} mean {mean}");
            }
        }
    }

    #[test]
    fn fast_path_dps_match_reference() {
        // The O(n) prefix-sum evaluation must agree with the O(n^2)
        // reference `path_dp` to float-summation-order accuracy.
        let pp = PlantParams::default();
        for kind in [ManifoldKind::Tichelmann, ManifoldKind::DirectReturn] {
            let m = Manifold::from_params(&pp, 48, kind);
            let q = m.solve_flows(48.0 * 0.6);
            let mut prefix = vec![0.0; 49];
            let mut dps = vec![0.0; 48];
            m.path_dps_into(&q, &mut prefix, &mut dps);
            for (i, &dp) in dps.iter().enumerate() {
                let reference = m.path_dp(&q, i);
                assert!(
                    (dp - reference).abs() <= 1e-12 * reference.abs().max(1e-9),
                    "{kind:?} branch {i}: fast {dp} vs reference {reference}"
                );
            }
        }
    }

    #[test]
    fn solve_flows_into_reuses_buffer() {
        let pp = PlantParams::default();
        let m = Manifold::from_params(&pp, 24, ManifoldKind::Tichelmann);
        let mut q = vec![99.0; 7]; // wrong size + stale contents
        m.solve_flows_into(24.0 * 0.6, &mut q);
        assert_eq!(q.len(), 24);
        assert_eq!(q, m.solve_flows(24.0 * 0.6));
    }

    #[test]
    fn outlet_temp_spread_larger_for_direct_return() {
        let pp = PlantParams::default();
        let d = Manifold::from_params(&pp, 72, ManifoldKind::DirectReturn);
        let t = Manifold::from_params(&pp, 72, ManifoldKind::Tichelmann);
        let sd = d.outlet_temp_spread(43.2, 180.0, &pp);
        let st = t.outlet_temp_spread(43.2, 180.0, &pp);
        assert!(sd > st * 2.0, "direct {sd} vs tichelmann {st}");
    }
}
