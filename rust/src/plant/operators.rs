//! Shared linear operators of the node RC network — the Rust mirror of
//! `python/compile/params.py::build_operators`.
//!
//! The substep is `T' = T + dt * (T A0^T + ((T E1^T) * g) E2^T + q)`.
//! When artifacts are present, `Operators::from_artifacts` loads the exact
//! float matrices the Pallas kernel was lowered with (params.json carries
//! them), guaranteeing HLO-vs-native agreement to f32 rounding.

use super::layout::*;
use crate::config::constants::PlantParams;
use crate::util::json::Json;

/// Row-major operator matrices (f32, matching the kernel).
#[derive(Debug, Clone)]
pub struct Operators {
    /// [S, S] shared terms (sink air loss; advection sits in G_ADV).
    pub a0: Vec<f32>,
    /// [NG, S] difference operator rows.
    pub e1: Vec<f32>,
    /// [S, NG] flux scatter scaled by 1/C.
    pub e2: Vec<f32>,
    /// [S, NC] power scatter scaled by 1/C.
    pub ec: Vec<f32>,
    /// [S] inverse heat capacities.
    pub inv_c: Vec<f32>,
}

impl Operators {
    pub fn build(pp: &PlantParams) -> Self {
        let mut inv_c = vec![0.0f64; S];
        for c in 0..NC {
            inv_c[c] = 1.0 / pp.c_core;
        }
        inv_c[IDX_PKG0] = 1.0 / pp.c_pkg;
        inv_c[IDX_PKG1] = 1.0 / pp.c_pkg;
        inv_c[IDX_SINK] = 1.0 / pp.c_sink;
        inv_c[IDX_WATER] = 1.0 / pp.c_water;

        let mut a0 = vec![0.0f64; S * S];
        a0[IDX_SINK * S + IDX_SINK] -= pp.ua_node_air * inv_c[IDX_SINK];

        let mut e1 = vec![0.0f64; NG * S];
        let mut e2 = vec![0.0f64; S * NG];
        for c in 0..NC {
            let pkg = if c < 6 { IDX_PKG0 } else { IDX_PKG1 };
            e1[c * S + c] = 1.0;
            e1[c * S + pkg] = -1.0;
            e2[c * NG + c] = -inv_c[c];
            e2[pkg * NG + c] = inv_c[pkg];
        }
        for (ch, pkg) in [(G_SP0, IDX_PKG0), (G_SP1, IDX_PKG1)] {
            e1[ch * S + pkg] = 1.0;
            e1[ch * S + IDX_SINK] = -1.0;
            e2[pkg * NG + ch] = -inv_c[pkg];
            e2[IDX_SINK * NG + ch] = inv_c[IDX_SINK];
        }
        e1[G_SW * S + IDX_SINK] = 1.0;
        e1[G_SW * S + IDX_WATER] = -1.0;
        e2[IDX_SINK * NG + G_SW] = -inv_c[IDX_SINK];
        e2[IDX_WATER * NG + G_SW] = inv_c[IDX_WATER];
        // advection outflow channel (inlet term is in q)
        e1[G_ADV * S + IDX_WATER] = 1.0;
        e2[IDX_WATER * NG + G_ADV] = -inv_c[IDX_WATER];

        let mut ec = vec![0.0f64; S * NC];
        for c in 0..NC {
            ec[c * NC + c] = inv_c[c];
        }

        let f32v = |v: Vec<f64>| v.into_iter().map(|x| x as f32).collect();
        Operators {
            a0: f32v(a0),
            e1: f32v(e1),
            e2: f32v(e2),
            ec: f32v(ec),
            inv_c: f32v(inv_c),
        }
    }

    /// Load the operator matrices dumped by aot.py (params.json
    /// `operators` key) for bit-equal agreement with the HLO plant.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let ops = j
            .get("operators")
            .ok_or_else(|| anyhow::anyhow!("params.json: no operators"))?;
        let mat = |k: &str, rows: usize, cols: usize| -> anyhow::Result<Vec<f32>> {
            let (flat, r, c) = ops
                .get(k)
                .and_then(Json::as_mat_f64)
                .ok_or_else(|| anyhow::anyhow!("operators: bad {k}"))?;
            anyhow::ensure!(r == rows && c == cols,
                            "operators: {k} is {r}x{c}, want {rows}x{cols}");
            Ok(flat.into_iter().map(|x| x as f32).collect())
        };
        let inv_c = ops
            .get("inv_c")
            .and_then(Json::as_vec_f64)
            .ok_or_else(|| anyhow::anyhow!("operators: bad inv_c"))?
            .into_iter()
            .map(|x| x as f32)
            .collect();
        Ok(Operators {
            a0: mat("a0", S, S)?,
            e1: mat("e1", NG, S)?,
            e2: mat("e2", S, NG)?,
            ec: mat("ec", S, NC)?,
            inv_c,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let ops = Operators::build(&PlantParams::default());
        assert_eq!(ops.a0.len(), S * S);
        assert_eq!(ops.e1.len(), NG * S);
        assert_eq!(ops.e2.len(), S * NG);
        assert_eq!(ops.ec.len(), S * NC);
    }

    #[test]
    fn e1_rows_sum_zero_except_advection() {
        let ops = Operators::build(&PlantParams::default());
        for ch in 0..NG {
            let sum: f32 = ops.e1[ch * S..(ch + 1) * S].iter().sum();
            if ch == G_ADV {
                assert!((sum - 1.0).abs() < 1e-6);
            } else {
                assert!(sum.abs() < 1e-6, "channel {ch} sums to {sum}");
            }
        }
    }

    #[test]
    fn junction_flux_conserves_energy() {
        // sum_i C_i * (E2 f)_i == 0 for every interior channel.
        let pp = PlantParams::default();
        let ops = Operators::build(&pp);
        for ch in 0..G_ADV {
            let mut total = 0.0f64;
            for s in 0..S {
                let c = 1.0 / ops.inv_c[s] as f64;
                total += c * ops.e2[s * NG + ch] as f64;
            }
            assert!(total.abs() < 1e-6, "channel {ch}: {total}");
        }
    }
}
