//! Native (pure-Rust) mirror of the fused Pallas thermal substep, in
//! node-major (AoS) layout — the *reference* kernel.
//!
//! Semantically identical to `python/compile/kernels/thermal_step.py`:
//! per-core power model (leakage + throttling) fused with one explicit
//! Euler step of the batched node RC network. Used (a) as the
//! cross-check oracle for both the HLO executable
//! (`tests/hlo_vs_native.rs`) and the lane-major SoA kernel
//! (`super::soa`, the default backend;
//! `tests/proptests.rs::prop_kernel_parity`), (b) as the fallback when
//! artifacts are absent, and (c) by the native bench baselines
//! (EXPERIMENTS.md §Perf).

use super::layout::*;
use super::operators::Operators;
use crate::config::constants::PlantParams;

/// Operator matrices as fixed-size rows: lets LLVM fully unroll and
/// vectorize the 16-wide dot products without per-iteration bounds
/// checks (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct FixedOps {
    pub a0: [[f32; S]; S],
    pub e1: [[f32; S]; NG],
    pub e2: [[f32; NG]; S],
    pub ec: [[f32; NC]; S],
}

impl FixedOps {
    pub fn from_ops(ops: &Operators) -> Self {
        let mut f = FixedOps {
            a0: [[0.0; S]; S],
            e1: [[0.0; S]; NG],
            e2: [[0.0; NG]; S],
            ec: [[0.0; NC]; S],
        };
        for s in 0..S {
            f.a0[s].copy_from_slice(&ops.a0[s * S..(s + 1) * S]);
            f.e2[s].copy_from_slice(&ops.e2[s * NG..(s + 1) * NG]);
            f.ec[s].copy_from_slice(&ops.ec[s * NC..(s + 1) * NC]);
        }
        for ch in 0..NG {
            f.e1[ch].copy_from_slice(&ops.e1[ch * S..(ch + 1) * S]);
        }
        f
    }
}

/// Scratch buffers reused across substeps (hot-path: zero allocation).
#[derive(Debug, Default)]
pub struct NodeScratch {
    diffs: Vec<f32>,   // [n, NG]
    p_cores: Vec<f32>, // [n, NC]
    t_next: Vec<f32>,  // [n, S]
    fixed: Option<FixedOps>,
}

impl NodeScratch {
    pub fn new(n: usize) -> Self {
        NodeScratch {
            diffs: vec![0.0; n * NG],
            p_cores: vec![0.0; n * NC],
            t_next: vec![0.0; n * S],
            fixed: None,
        }
    }
}

/// Precomputed f32 constants of the per-core power model. Every kernel
/// and observe epilogue (AoS `fused_substep`/`NativePlant::observe`,
/// SoA `soa_substep`/`soa_observe`) inlines `core_power` from here, so
/// the four call sites stay term-for-term identical by construction —
/// the SoA-vs-reference parity contract
/// (`tests/proptests.rs::prop_kernel_parity`).
#[derive(Debug, Clone, Copy)]
pub struct PowerCoeffs {
    pub t_thr: f32,
    pub inv_band: f32,
    pub leak_fb: f32,
    pub leak_t0: f32,
}

impl PowerCoeffs {
    pub fn new(pp: &PlantParams) -> Self {
        PowerCoeffs {
            t_thr: pp.t_throttle as f32,
            inv_band: 1.0 / pp.throttle_band as f32,
            leak_fb: (pp.leak_frac * pp.leak_beta) as f32,
            leak_t0: pp.leak_t0 as f32,
        }
    }

    /// Per-core power with leakage feedback and thermal throttling.
    #[inline(always)]
    pub fn core_power(&self, t_core: f32, util: f32, p_dyn: f32,
                      p_idle: f32, active: f32) -> f32 {
        let headroom =
            ((self.t_thr - t_core) * self.inv_band).clamp(0.0, 1.0);
        let base = p_idle + util * headroom * p_dyn;
        let leak =
            (1.0 + self.leak_fb * (t_core - self.leak_t0)).max(0.05);
        active * base * leak
    }
}

/// Per-core power with leakage feedback and thermal throttling
/// (convenience wrapper; hot paths hoist `PowerCoeffs::new` out of
/// their loops).
#[inline]
pub fn core_power(
    t_core: f32,
    util: f32,
    p_dyn: f32,
    p_idle: f32,
    active: f32,
    pp: &PlantParams,
) -> f32 {
    PowerCoeffs::new(pp).core_power(t_core, util, p_dyn, p_idle, active)
}

/// One fused substep over `n` nodes.
///
/// `t` [n*S] is updated in place; `g_eff` [n*NG] must already have the
/// advection channel scaled by the pump speed. `q_base` [n*S] carries the
/// advective-inlet + base-power + air-loss constants. Returns total node
/// DC power (cores + base) of the *valid* prefix `n_valid`.
#[allow(clippy::too_many_arguments)]
pub fn fused_substep(
    t: &mut [f32],
    g_eff: &[f32],
    util: &[f32],
    p_dyn: &[f32],
    p_idle: &[f32],
    active: &[f32],
    q_base: &[f32],
    ops: &Operators,
    pp: &PlantParams,
    scratch: &mut NodeScratch,
    n_valid: usize,
) -> f64 {
    let n = t.len() / S;
    debug_assert_eq!(g_eff.len(), n * NG);
    let dt = pp.dt_substep as f32;
    let mut p_total = 0.0f64;

    // Fixed-size operator rows (cached in scratch) let LLVM fully unroll
    // and vectorize the 16-wide dot products (EXPERIMENTS.md §Perf).
    // Split-borrow the scratch fields so the cached FixedOps can be read
    // in place while the work buffers are written (no per-substep clone).
    let NodeScratch { diffs, p_cores, t_next, fixed } = scratch;
    if fixed.is_none() {
        *fixed = Some(FixedOps::from_ops(ops));
    }
    let fx = fixed.as_ref().unwrap();
    let coeffs = PowerCoeffs::new(pp);

    for i in 0..n {
        let mut ts = [0.0f32; S];
        ts.copy_from_slice(&t[i * S..(i + 1) * S]);
        let mut gi = [0.0f32; NG];
        gi.copy_from_slice(&g_eff[i * NG..(i + 1) * NG]);

        // --- power model (elementwise, vectorizable) ------------------------
        let mut ui = [0.0f32; NC];
        ui.copy_from_slice(&util[i * NC..(i + 1) * NC]);
        let mut di = [0.0f32; NC];
        di.copy_from_slice(&p_dyn[i * NC..(i + 1) * NC]);
        let mut pi = [0.0f32; NC];
        pi.copy_from_slice(&p_idle[i * NC..(i + 1) * NC]);
        let mut av = [0.0f32; NC];
        av.copy_from_slice(&active[i * NC..(i + 1) * NC]);
        let mut pc = [0.0f32; NC];
        let mut p_node = 0.0f32;
        for c in 0..NC {
            let p = coeffs.core_power(ts[c], ui[c], di[c], pi[c], av[c]);
            pc[c] = p;
            p_node += p;
        }
        p_cores[i * NC..(i + 1) * NC].copy_from_slice(&pc);
        if i < n_valid {
            p_total += p_node as f64 + pp.p_node_base;
        }

        // --- diffs = T @ E1^T -----------------------------------------------
        let mut dvec = [0.0f32; NG];
        for ch in 0..NG {
            let row = &fx.e1[ch];
            let mut acc = 0.0f32;
            for s in 0..S {
                acc += ts[s] * row[s];
            }
            dvec[ch] = acc * gi[ch];
        }
        diffs[i * NG..(i + 1) * NG].copy_from_slice(&dvec);

        // --- T' = T + dt * (T A0^T + diffs E2^T + P Ec^T + q) ----------------
        let mut qi = [0.0f32; S];
        qi.copy_from_slice(&q_base[i * S..(i + 1) * S]);
        let mut tn = [0.0f32; S];
        for s in 0..S {
            let mut acc = qi[s];
            let a0row = &fx.a0[s];
            for k in 0..S {
                acc += ts[k] * a0row[k];
            }
            let e2row = &fx.e2[s];
            for ch in 0..NG {
                acc += dvec[ch] * e2row[ch];
            }
            let ecrow = &fx.ec[s];
            for c in 0..NC {
                acc += pc[c] * ecrow[c];
            }
            tn[s] = ts[s] + dt * acc;
        }
        t_next[i * S..(i + 1) * S].copy_from_slice(&tn);
    }
    t.copy_from_slice(t_next);
    p_total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = crate::variability::rng::Rng::new(11);
        let t: Vec<f32> =
            (0..n * S).map(|_| rng.uniform_in(20.0, 90.0) as f32).collect();
        let g: Vec<f32> =
            (0..n * NG).map(|_| rng.uniform_in(1.0, 30.0) as f32).collect();
        let util: Vec<f32> =
            (0..n * NC).map(|_| rng.uniform() as f32).collect();
        let p_dyn: Vec<f32> =
            (0..n * NC).map(|_| rng.uniform_in(8.0, 14.0) as f32).collect();
        let p_idle: Vec<f32> =
            (0..n * NC).map(|_| rng.uniform_in(1.0, 3.0) as f32).collect();
        let active: Vec<f32> = (0..n * NC)
            .map(|_| if rng.uniform() > 0.2 { 1.0 } else { 0.0 })
            .collect();
        let q: Vec<f32> =
            (0..n * S).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        (t, g, util, p_dyn, p_idle, active, q)
    }

    #[test]
    fn hot_core_cools_toward_package() {
        let pp = PlantParams::default();
        let ops = Operators::build(&pp);
        let n = 2;
        let mut t = vec![40.0f32; n * S];
        t[0] = 90.0;
        let g = vec![5.0f32; n * NG];
        let zero = vec![0.0f32; n * NC];
        let q = vec![0.0f32; n * S];
        let mut scratch = NodeScratch::new(n);
        fused_substep(&mut t, &g, &zero, &zero, &zero, &zero, &q, &ops, &pp,
                      &mut scratch, n);
        assert!(t[0] < 90.0);
        assert!(t[IDX_PKG0] > 40.0);
    }

    #[test]
    fn power_total_counts_only_valid_prefix() {
        let pp = PlantParams::default();
        let ops = Operators::build(&pp);
        let n = 4;
        let (mut t, g, _u, p_dyn, p_idle, _a, q) = setup(n);
        let util = vec![1.0f32; n * NC];
        let active = vec![1.0f32; n * NC];
        let mut scratch = NodeScratch::new(n);
        let p2 = fused_substep(&mut t.clone(), &g, &util, &p_dyn, &p_idle,
                               &active, &q, &ops, &pp, &mut scratch, 2);
        let p4 = fused_substep(&mut t, &g, &util, &p_dyn, &p_idle, &active,
                               &q, &ops, &pp, &mut scratch, 4);
        assert!(p4 > p2 * 1.5, "p2={p2} p4={p4}");
    }

    #[test]
    fn deterministic() {
        let pp = PlantParams::default();
        let ops = Operators::build(&pp);
        let (t0, g, u, pd, pi, a, q) = setup(8);
        let mut t1 = t0.clone();
        let mut t2 = t0;
        let mut s1 = NodeScratch::new(8);
        let mut s2 = NodeScratch::new(8);
        fused_substep(&mut t1, &g, &u, &pd, &pi, &a, &q, &ops, &pp, &mut s1, 8);
        fused_substep(&mut t2, &g, &u, &pd, &pi, &a, &q, &ops, &pp, &mut s2, 8);
        assert_eq!(t1, t2);
    }

    #[test]
    fn stress_converges_to_physical_steady_state() {
        // Single node, fixed inlet: core temps must settle 10..30 K above
        // the water temperature (Fig. 4a band) and stay below throttle.
        let pp = PlantParams::default();
        let ops = Operators::build(&pp);
        let n = 1;
        let lot = crate::variability::ChipLottery::draw(n, &pp, 3);
        let mut g = lot.g_var(&pp);
        // pump at 0.55 nominal
        g[G_ADV] *= 0.55;
        let util = vec![1.0f32; NC];
        let t_in = 60.0f32;
        let mut q = vec![0.0f32; S];
        q[IDX_WATER] = g[G_ADV] * t_in * ops.inv_c[IDX_WATER];
        q[IDX_SINK] = ((pp.p_node_base + pp.ua_node_air * pp.t_room)
            * ops.inv_c[IDX_SINK] as f64) as f32;
        let mut t = vec![t_in; S];
        let mut scratch = NodeScratch::new(n);
        for _ in 0..40_000 {
            fused_substep(&mut t, &g, &util, &lot.p_dyn, &lot.p_idle,
                          &lot.active, &q, &ops, &pp, &mut scratch, 1);
        }
        let core_mean: f32 = t[..NC].iter().sum::<f32>() / NC as f32;
        let dt_core_water = core_mean - t[IDX_WATER];
        assert!((8.0..28.0).contains(&dt_core_water), "{dt_core_water}");
        assert!(t[..NC].iter().all(|&x| x < pp.t_throttle as f32));
        // water outlet must sit above the inlet (it carries the heat away)
        assert!(t[IDX_WATER] > t_in + 2.0);
    }
}
