//! State-vector layouts shared with the Python compile path
//! (`python/compile/params.py`). Keep in lockstep — the cross-layer
//! integration tests (`tests/hlo_vs_native.rs`) fail loudly on drift.

/// Core slots per node (E5645: 12 active, E5630: 8 active).
pub const NC: usize = 12;
/// Per-node thermal states.
pub const S: usize = 16;
pub const IDX_CORE0: usize = 0;
pub const IDX_PKG0: usize = 12;
pub const IDX_PKG1: usize = 13;
pub const IDX_SINK: usize = 14;
pub const IDX_WATER: usize = 15;

/// Variable-conductance channels: 12 junctions + 2 pkg->sink + sink->water
/// + water advection.
pub const G_SP0: usize = NC;
pub const G_SP1: usize = NC + 1;
pub const G_SW: usize = NC + 2;
pub const G_ADV: usize = NC + 3;
pub const NG: usize = NC + 4;

/// Circuit-level state (see Fig. 3 of the paper).
pub const CS: usize = 12;
pub const C_T_RACK_IN: usize = 0;
pub const C_T_TANK: usize = 1;
pub const C_T_PRIMARY: usize = 2;
pub const C_T_RECOOL: usize = 3;
pub const C_CHILLER_ON: usize = 4;
pub const C_CYCLE_PHASE: usize = 5;
pub const C_P_D: usize = 6;
pub const C_P_C: usize = 7;
pub const C_P_ADD: usize = 8;
pub const C_P_LOSS: usize = 9;
pub const C_T_RACK_OUT: usize = 10;
pub const C_P_CENTRAL: usize = 11;

/// Control vector set by the coordinator every tick.
pub const CT: usize = 8;
pub const U_VALVE: usize = 0;
pub const U_CHILLER_EN: usize = 1;
pub const U_T_AMBIENT: usize = 2;
pub const U_T_CENTRAL: usize = 3;
pub const U_GPU_LOAD: usize = 4;
pub const U_FLOW_SCALE: usize = 5;
pub const U_PUMP_FAIL: usize = 6;
pub const U_SPARE: usize = 7;

/// Per-node observations.
pub const OBS_N: usize = 4;
pub const O_NODE_POWER: usize = 0;
pub const O_CORE_MEAN: usize = 1;
pub const O_CORE_MAX: usize = 2;
pub const O_WATER_OUT: usize = 3;

/// Plant-level scalar observations (model.py layout).
pub const NS: usize = 16;
pub const SC_P_DC: usize = 0;
pub const SC_P_AC: usize = 1;
pub const SC_P_R: usize = 2;
pub const SC_P_D: usize = 3;
pub const SC_P_C: usize = 4;
pub const SC_P_ADD: usize = 5;
pub const SC_P_LOSS: usize = 6;
pub const SC_T_RACK_IN: usize = 7;
pub const SC_T_RACK_OUT: usize = 8;
pub const SC_T_TANK: usize = 9;
pub const SC_T_PRIMARY: usize = 10;
pub const SC_CHILLER_ON: usize = 11;
pub const SC_P_CENTRAL: usize = 12;
pub const SC_T_RECOOL: usize = 13;
pub const SC_THROTTLE: usize = 14;
pub const SC_CORE_MAX: usize = 15;

/// Pad a node count up to a multiple of the Pallas tile.
pub const fn pad_nodes(n: usize, tile: usize) -> usize {
    n.div_ceil(tile) * tile
}

/// Transpose a node-major `[n][w]` buffer into lane-major `[w][n]`
/// (the SoA kernel's layout: one contiguous `n`-length lane per state /
/// channel / core slot, so a scalar-broadcast FMA sweeps all nodes).
pub fn transpose_to_lanes(src: &[f32], dst: &mut [f32], n: usize, w: usize) {
    debug_assert_eq!(src.len(), n * w);
    debug_assert_eq!(dst.len(), n * w);
    for i in 0..n {
        for s in 0..w {
            dst[s * n + i] = src[i * w + s];
        }
    }
}

/// Inverse of `transpose_to_lanes`: lane-major `[w][n]` back to
/// node-major `[n][w]`.
pub fn transpose_from_lanes(src: &[f32], dst: &mut [f32], n: usize, w: usize) {
    debug_assert_eq!(src.len(), n * w);
    debug_assert_eq!(dst.len(), n * w);
    for i in 0..n {
        for s in 0..w {
            dst[i * w + s] = src[s * n + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_consistency() {
        assert_eq!(NG, 16);
        assert_eq!(S, 16);
        assert_eq!(G_ADV, 15);
        assert_eq!(pad_nodes(13, 64), 64);
        assert_eq!(pad_nodes(216, 64), 256);
        assert_eq!(pad_nodes(64, 64), 64);
    }

    #[test]
    fn transpose_round_trips_and_places_lanes() {
        let (n, w) = (5, 3);
        let src: Vec<f32> = (0..n * w).map(|x| x as f32).collect();
        let mut lanes = vec![0.0; n * w];
        transpose_to_lanes(&src, &mut lanes, n, w);
        // node i, slot s lands in lane s at offset i
        for i in 0..n {
            for s in 0..w {
                assert_eq!(lanes[s * n + i], src[i * w + s]);
            }
        }
        let mut back = vec![0.0; n * w];
        transpose_from_lanes(&lanes, &mut back, n, w);
        assert_eq!(back, src);
    }
}
