//! State-vector layouts shared with the Python compile path
//! (`python/compile/params.py`). Keep in lockstep — the cross-layer
//! integration tests (`tests/hlo_vs_native.rs`) fail loudly on drift.

/// Core slots per node (E5645: 12 active, E5630: 8 active).
pub const NC: usize = 12;
/// Per-node thermal states.
pub const S: usize = 16;
pub const IDX_CORE0: usize = 0;
pub const IDX_PKG0: usize = 12;
pub const IDX_PKG1: usize = 13;
pub const IDX_SINK: usize = 14;
pub const IDX_WATER: usize = 15;

/// Variable-conductance channels: 12 junctions + 2 pkg->sink + sink->water
/// + water advection.
pub const G_SP0: usize = NC;
pub const G_SP1: usize = NC + 1;
pub const G_SW: usize = NC + 2;
pub const G_ADV: usize = NC + 3;
pub const NG: usize = NC + 4;

/// Circuit-level state (see Fig. 3 of the paper).
pub const CS: usize = 12;
pub const C_T_RACK_IN: usize = 0;
pub const C_T_TANK: usize = 1;
pub const C_T_PRIMARY: usize = 2;
pub const C_T_RECOOL: usize = 3;
pub const C_CHILLER_ON: usize = 4;
pub const C_CYCLE_PHASE: usize = 5;
pub const C_P_D: usize = 6;
pub const C_P_C: usize = 7;
pub const C_P_ADD: usize = 8;
pub const C_P_LOSS: usize = 9;
pub const C_T_RACK_OUT: usize = 10;
pub const C_P_CENTRAL: usize = 11;

/// Control vector set by the coordinator every tick.
pub const CT: usize = 8;
pub const U_VALVE: usize = 0;
pub const U_CHILLER_EN: usize = 1;
pub const U_T_AMBIENT: usize = 2;
pub const U_T_CENTRAL: usize = 3;
pub const U_GPU_LOAD: usize = 4;
pub const U_FLOW_SCALE: usize = 5;
pub const U_PUMP_FAIL: usize = 6;
pub const U_SPARE: usize = 7;

/// Per-node observations.
pub const OBS_N: usize = 4;
pub const O_NODE_POWER: usize = 0;
pub const O_CORE_MEAN: usize = 1;
pub const O_CORE_MAX: usize = 2;
pub const O_WATER_OUT: usize = 3;

/// Plant-level scalar observations (model.py layout).
pub const NS: usize = 16;
pub const SC_P_DC: usize = 0;
pub const SC_P_AC: usize = 1;
pub const SC_P_R: usize = 2;
pub const SC_P_D: usize = 3;
pub const SC_P_C: usize = 4;
pub const SC_P_ADD: usize = 5;
pub const SC_P_LOSS: usize = 6;
pub const SC_T_RACK_IN: usize = 7;
pub const SC_T_RACK_OUT: usize = 8;
pub const SC_T_TANK: usize = 9;
pub const SC_T_PRIMARY: usize = 10;
pub const SC_CHILLER_ON: usize = 11;
pub const SC_P_CENTRAL: usize = 12;
pub const SC_T_RECOOL: usize = 13;
pub const SC_THROTTLE: usize = 14;
pub const SC_CORE_MAX: usize = 15;

/// Pad a node count up to a multiple of the Pallas tile.
pub const fn pad_nodes(n: usize, tile: usize) -> usize {
    n.div_ceil(tile) * tile
}

/// One plant's contiguous slice of a lane arena (`plant::soa`).
///
/// A lane arena packs several plants into shared `[slot][total]` lanes;
/// plant `p` owns offsets `[offset, offset + npad)` of every lane, of
/// which the first `n_valid` are real nodes (the rest is tile padding,
/// so every range starts and ends on a vector-width boundary). A
/// single-plant `SoaState` is the degenerate arena: one range at offset
/// 0 spanning the whole lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneRange {
    /// First lane offset of this plant.
    pub offset: usize,
    /// Valid (non-padding) node count.
    pub n_valid: usize,
    /// Padded width of the plant's slice (its `PlantStatic::n_padded`).
    pub npad: usize,
}

/// Transpose a node-major `[n][w]` buffer into lane-major `[w][n]`
/// (the SoA kernel's layout: one contiguous `n`-length lane per state /
/// channel / core slot, so a scalar-broadcast FMA sweeps all nodes).
pub fn transpose_to_lanes(src: &[f32], dst: &mut [f32], n: usize, w: usize) {
    transpose_to_lanes_at(src, dst, n, w, n, 0);
}

/// Inverse of `transpose_to_lanes`: lane-major `[w][n]` back to
/// node-major `[n][w]`.
pub fn transpose_from_lanes(src: &[f32], dst: &mut [f32], n: usize, w: usize) {
    transpose_from_lanes_at(src, dst, n, w, n, 0);
}

/// Transpose node-major `[n][w]` into a slice of an arena whose lanes
/// are `stride` long: node `i`, slot `s` lands at
/// `dst[s * stride + offset + i]`. With `stride == n`, `offset == 0`
/// this is the plain single-plant transpose.
pub fn transpose_to_lanes_at(src: &[f32], dst: &mut [f32], n: usize,
                             w: usize, stride: usize, offset: usize) {
    debug_assert_eq!(src.len(), n * w);
    debug_assert_eq!(dst.len(), stride * w);
    debug_assert!(offset + n <= stride);
    for i in 0..n {
        for s in 0..w {
            dst[s * stride + offset + i] = src[i * w + s];
        }
    }
}

/// Inverse of `transpose_to_lanes_at`: one plant's slice of an arena
/// back to node-major `[n][w]`.
pub fn transpose_from_lanes_at(src: &[f32], dst: &mut [f32], n: usize,
                               w: usize, stride: usize, offset: usize) {
    debug_assert_eq!(src.len(), stride * w);
    debug_assert_eq!(dst.len(), n * w);
    debug_assert!(offset + n <= stride);
    for i in 0..n {
        for s in 0..w {
            dst[i * w + s] = src[s * stride + offset + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_consistency() {
        assert_eq!(NG, 16);
        assert_eq!(S, 16);
        assert_eq!(G_ADV, 15);
        assert_eq!(pad_nodes(13, 64), 64);
        assert_eq!(pad_nodes(216, 64), 256);
        assert_eq!(pad_nodes(64, 64), 64);
    }

    #[test]
    fn transpose_round_trips_and_places_lanes() {
        let (n, w) = (5, 3);
        let src: Vec<f32> = (0..n * w).map(|x| x as f32).collect();
        let mut lanes = vec![0.0; n * w];
        transpose_to_lanes(&src, &mut lanes, n, w);
        // node i, slot s lands in lane s at offset i
        for i in 0..n {
            for s in 0..w {
                assert_eq!(lanes[s * n + i], src[i * w + s]);
            }
        }
        let mut back = vec![0.0; n * w];
        transpose_from_lanes(&lanes, &mut back, n, w);
        assert_eq!(back, src);
    }

    #[test]
    fn strided_transpose_targets_the_arena_slice() {
        // Two plants (n=3 and n=2) in one stride-5 arena, w=2 slots.
        let (w, stride) = (2usize, 5usize);
        let a: Vec<f32> = (0..3 * w).map(|x| x as f32).collect();
        let b: Vec<f32> = (0..2 * w).map(|x| 100.0 + x as f32).collect();
        let mut arena = vec![-1.0; stride * w];
        transpose_to_lanes_at(&a, &mut arena, 3, w, stride, 0);
        transpose_to_lanes_at(&b, &mut arena, 2, w, stride, 3);
        for i in 0..3 {
            for s in 0..w {
                assert_eq!(arena[s * stride + i], a[i * w + s]);
            }
        }
        for i in 0..2 {
            for s in 0..w {
                assert_eq!(arena[s * stride + 3 + i], b[i * w + s]);
            }
        }
        // round-trip each slice independently
        let mut back_a = vec![0.0; 3 * w];
        let mut back_b = vec![0.0; 2 * w];
        transpose_from_lanes_at(&arena, &mut back_a, 3, w, stride, 0);
        transpose_from_lanes_at(&arena, &mut back_b, 2, w, stride, 3);
        assert_eq!(back_a, a);
        assert_eq!(back_b, b);
    }
}
