//! The physics plant: per-node RC thermal networks + the five water
//! circuits of the paper's Fig. 3.
//!
//! Two interchangeable implementations exist (see `runtime::PlantBackend`):
//! the AOT-compiled HLO executable (JAX/Pallas, runtime::pjrt) and the
//! pure-Rust mirror in this module (`native::NativePlant`), used for
//! cross-validation, fallback, and baseline benches.

pub mod circuits;
pub mod hydraulics;
pub mod layout;
pub mod native;
pub mod node;
pub mod operators;
pub mod soa;

use layout::*;

/// Which native substep kernel steps the node thermal state.
///
/// Both kernels implement the same physics; they differ only in memory
/// layout. `Reference` is the node-major (AoS) oracle (`node::
/// fused_substep`, one node at a time, 16-wide dot products). `Soa` is
/// the lane-major kernel (`soa::soa_substep`): state transposed to
/// `[S][n_padded]` lanes so every operator contraction becomes a
/// scalar-broadcast FMA over a contiguous lane that LLVM vectorizes
/// across nodes. See DESIGN.md §5 and EXPERIMENTS.md §Perf.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlantKernel {
    /// Node-major AoS reference kernel — the cross-check oracle.
    Reference,
    /// Lane-major SoA kernel — the default backend.
    #[default]
    Soa,
}

impl std::str::FromStr for PlantKernel {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "reference" | "ref" | "aos" => Ok(PlantKernel::Reference),
            "soa" | "lanes" => Ok(PlantKernel::Soa),
            // "auto" is accepted everywhere a kernel can be named
            // (CLI/TOML resolve it via the env; a literal parse — e.g.
            // IDATACOOL_KERNEL=auto — means the default).
            "auto" => Ok(PlantKernel::default()),
            _ => anyhow::bail!(
                "unknown plant kernel '{s}' (soa|reference|auto)"
            ),
        }
    }
}

impl PlantKernel {
    pub fn name(&self) -> &'static str {
        match self {
            PlantKernel::Reference => "reference",
            PlantKernel::Soa => "soa",
        }
    }

    /// Resolve the `IDATACOOL_KERNEL` environment override; unset or
    /// empty means the default (SoA). An unparseable value is an error,
    /// not a silent fall-back.
    pub fn from_env() -> anyhow::Result<Self> {
        match std::env::var("IDATACOOL_KERNEL") {
            Ok(v) if !v.is_empty() => v.parse().map_err(|e| {
                anyhow::anyhow!("IDATACOOL_KERNEL: {e}")
            }),
            _ => Ok(PlantKernel::default()),
        }
    }

    /// Resolve a config/CLI selector: `"auto"` defers to the
    /// environment (then the default), anything else parses strictly.
    pub fn resolve(s: &str) -> anyhow::Result<Self> {
        if s == "auto" {
            Self::from_env()
        } else {
            s.parse()
        }
    }
}

/// Static per-run plant inputs (the silicon lottery, padded node-major).
#[derive(Debug, Clone)]
pub struct PlantStatic {
    pub n_nodes: usize,
    pub n_padded: usize,
    pub g: Vec<f32>,      // [npad, NG]
    pub p_dyn: Vec<f32>,  // [npad, NC]
    pub p_idle: Vec<f32>, // [npad, NC]
    pub active: Vec<f32>, // [npad, NC]
}

impl PlantStatic {
    /// Pad a lottery up to `n_padded` (inactive filler nodes).
    pub fn from_lottery(
        lot: &crate::variability::ChipLottery,
        pp: &crate::config::constants::PlantParams,
        tile: usize,
    ) -> Self {
        let n = lot.n_nodes;
        let npad = pad_nodes(n, tile);
        let mut s = PlantStatic {
            n_nodes: n,
            n_padded: npad,
            g: vec![0.0; npad * NG],
            p_dyn: vec![0.0; npad * NC],
            p_idle: vec![0.0; npad * NC],
            active: vec![0.0; npad * NC],
        };
        let g = lot.g_var(pp);
        s.g[..n * NG].copy_from_slice(&g);
        // Padded nodes: tiny conductances keep the system well-posed.
        for i in n * NG..npad * NG {
            s.g[i] = 1e-3;
        }
        s.p_dyn[..n * NC].copy_from_slice(&lot.p_dyn);
        s.p_idle[..n * NC].copy_from_slice(&lot.p_idle);
        s.active[..n * NC].copy_from_slice(&lot.active);
        s
    }
}

/// Per-tick plant outputs.
#[derive(Debug, Clone, Default)]
pub struct TickOutput {
    /// [npad, OBS_N] node observations (power, core mean/max, water out).
    pub node_obs: Vec<f32>,
    /// [NS] plant-level scalars (model.py layout).
    pub scalars: [f32; NS],
}

impl TickOutput {
    pub fn new(n_padded: usize) -> Self {
        TickOutput { node_obs: vec![0.0; n_padded * OBS_N], scalars: [0.0; NS] }
    }

    /// Re-arm a possibly reused buffer for a fresh run: size it for
    /// `n_padded` and zero everything — equivalent to `TickOutput::new`
    /// without the allocation (the serve path keeps one per worker).
    pub fn reset(&mut self, n_padded: usize) {
        self.node_obs.clear();
        self.node_obs.resize(n_padded * OBS_N, 0.0);
        self.scalars = [0.0; NS];
    }

    #[inline]
    pub fn node(&self, i: usize) -> &[f32] {
        &self.node_obs[i * OBS_N..(i + 1) * OBS_N]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_parses_and_defaults_to_soa() {
        assert_eq!(PlantKernel::default(), PlantKernel::Soa);
        assert_eq!("soa".parse::<PlantKernel>().unwrap(), PlantKernel::Soa);
        assert_eq!(
            "reference".parse::<PlantKernel>().unwrap(),
            PlantKernel::Reference
        );
        assert_eq!(
            "ref".parse::<PlantKernel>().unwrap(),
            PlantKernel::Reference
        );
        assert!("bogus".parse::<PlantKernel>().is_err());
        // "auto" parses to the default (IDATACOOL_KERNEL=auto must work)
        assert_eq!("auto".parse::<PlantKernel>().unwrap(),
                   PlantKernel::default());
        assert_eq!(PlantKernel::resolve("soa").unwrap(), PlantKernel::Soa);
        assert!(PlantKernel::resolve("nope").is_err());
    }
}
