//! The physics plant: per-node RC thermal networks + the five water
//! circuits of the paper's Fig. 3.
//!
//! Two interchangeable implementations exist (see `runtime::PlantBackend`):
//! the AOT-compiled HLO executable (JAX/Pallas, runtime::pjrt) and the
//! pure-Rust mirror in this module (`native::NativePlant`), used for
//! cross-validation, fallback, and baseline benches.

pub mod circuits;
pub mod hydraulics;
pub mod layout;
pub mod native;
pub mod node;
pub mod operators;

use layout::*;

/// Static per-run plant inputs (the silicon lottery, padded node-major).
#[derive(Debug, Clone)]
pub struct PlantStatic {
    pub n_nodes: usize,
    pub n_padded: usize,
    pub g: Vec<f32>,      // [npad, NG]
    pub p_dyn: Vec<f32>,  // [npad, NC]
    pub p_idle: Vec<f32>, // [npad, NC]
    pub active: Vec<f32>, // [npad, NC]
}

impl PlantStatic {
    /// Pad a lottery up to `n_padded` (inactive filler nodes).
    pub fn from_lottery(
        lot: &crate::variability::ChipLottery,
        pp: &crate::config::constants::PlantParams,
        tile: usize,
    ) -> Self {
        let n = lot.n_nodes;
        let npad = pad_nodes(n, tile);
        let mut s = PlantStatic {
            n_nodes: n,
            n_padded: npad,
            g: vec![0.0; npad * NG],
            p_dyn: vec![0.0; npad * NC],
            p_idle: vec![0.0; npad * NC],
            active: vec![0.0; npad * NC],
        };
        let g = lot.g_var(pp);
        s.g[..n * NG].copy_from_slice(&g);
        // Padded nodes: tiny conductances keep the system well-posed.
        for i in n * NG..npad * NG {
            s.g[i] = 1e-3;
        }
        s.p_dyn[..n * NC].copy_from_slice(&lot.p_dyn);
        s.p_idle[..n * NC].copy_from_slice(&lot.p_idle);
        s.active[..n * NC].copy_from_slice(&lot.active);
        s
    }
}

/// Per-tick plant outputs.
#[derive(Debug, Clone, Default)]
pub struct TickOutput {
    /// [npad, OBS_N] node observations (power, core mean/max, water out).
    pub node_obs: Vec<f32>,
    /// [NS] plant-level scalars (model.py layout).
    pub scalars: [f32; NS],
}

impl TickOutput {
    pub fn new(n_padded: usize) -> Self {
        TickOutput { node_obs: vec![0.0; n_padded * OBS_N], scalars: [0.0; NS] }
    }

    #[inline]
    pub fn node(&self, i: usize) -> &[f32] {
        &self.node_obs[i * OBS_N..(i + 1) * OBS_N]
    }
}
