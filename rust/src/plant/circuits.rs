//! Circuit-level plant physics — the Rust mirror of
//! `python/compile/plant.py::circuit_substep` (the five water circuits of
//! the paper's Fig. 3, the InvenSor LTC 09 adsorption chiller, the 3-way
//! valve, buffer tank, CoolTrans support and dry recooler).

use super::layout::*;
use crate::config::constants::PlantParams;

/// Chiller standby hysteresis (Sect. 3): on above t_on, off below t_off.
pub fn chiller_hysteresis(t_drive: f32, on_prev: f32, enable: f32,
                          pp: &PlantParams) -> f32 {
    let on = if t_drive > pp.chiller_t_on as f32 {
        1.0
    } else if t_drive < pp.chiller_t_off as f32 {
        0.0
    } else {
        on_prev
    };
    on * enable
}

/// Advance the circuit state `cs` [CS] by one dt substep (in place).
///
/// `t_rack_out_raw` is the flow-weighted mean node water-outlet temperature,
/// `p_nodes_total` the total node DC power this substep (unused by the
/// physics but kept for signature parity with the JAX side).
pub fn circuit_substep(
    cs: &mut [f32],
    controls: &[f32],
    t_rack_out_raw: f32,
    _p_nodes_total: f64,
    n_nodes: usize,
    pp: &PlantParams,
) {
    debug_assert_eq!(cs.len(), CS);
    debug_assert_eq!(controls.len(), CT);
    let dt = pp.dt_substep as f32;
    let mcp = (pp.rack_mcp(n_nodes) as f32
        * controls[U_FLOW_SCALE].max(1e-3)
        * (1.0 - controls[U_PUMP_FAIL]))
        .max(1.0);

    let t_tank = cs[C_T_TANK];
    let t_primary = cs[C_T_PRIMARY];
    let t_recool = cs[C_T_RECOOL];
    let t_ambient = controls[U_T_AMBIENT];
    let t_room = pp.t_room as f32;

    // rack outlet after hot-side plumbing loss — exponential
    // (effectiveness) form, bounded for any flow incl. pump failure
    let decay_hot = (-pp.ua_pipe_env as f32 / mcp).exp();
    let t_rack_out = t_room + (t_rack_out_raw - t_room) * decay_hot;
    let pipe_loss_hot = mcp * (t_rack_out_raw - t_rack_out);

    // chiller state machine + adsorption cycle
    let on = chiller_hysteresis(t_tank, cs[C_CHILLER_ON],
                                controls[U_CHILLER_EN], pp);
    let phase =
        (cs[C_CYCLE_PHASE] + dt / pp.cycle_period_s as f32).rem_euclid(1.0);
    let cycle_mod = 1.0
        + pp.cycle_amp as f32 * (2.0 * std::f32::consts::PI * phase).sin();

    // rack -> driving heat exchanger
    let p_hx_d =
        pp.eps_hx_drive as f32 * mcp * (t_rack_out - t_tank).max(0.0);
    let t_after_drive = t_rack_out - p_hx_d / mcp;

    // 3-way valve: route remaining heat to the primary circuit
    let u = controls[U_VALVE].clamp(0.0, 1.0);
    let p_add = u
        * pp.eps_hx_primary as f32
        * mcp
        * (t_after_drive - t_primary).max(0.0);
    let mut t_rack_in = t_after_drive - p_add / mcp;

    // cold-side plumbing loss (can be a gain below room temperature)
    let decay_cold =
        (-(pp.ua_pipe_env * pp.ua_pipe_cold_frac) as f32 / mcp).exp();
    let t_rack_in_post = t_room + (t_rack_in - t_room) * decay_cold;
    let pipe_loss_cold = mcp * (t_rack_in - t_rack_in_post);
    t_rack_in = t_rack_in_post;

    // chiller draw from the tank
    let (pd_max, cop) = chiller_curves(t_tank, on, cycle_mod, pp);
    let p_d_abs = pd_max;
    let p_c = cop * p_d_abs;
    let p_reject = p_d_abs + p_c;

    // tank (driving circuit)
    let tank_loss = pp.ua_tank_env as f32 * (t_tank - t_room);
    let t_tank_next =
        t_tank + dt * (p_hx_d - p_d_abs - tank_loss) / pp.c_tank as f32;

    // primary circuit
    let p_central = if t_primary > pp.t_primary_support as f32 {
        pp.ua_cooltrans as f32 * (t_primary - controls[U_T_CENTRAL])
    } else {
        0.0
    };
    let t_primary_next = t_primary
        + dt * (controls[U_GPU_LOAD] + p_add - p_c - p_central)
            / pp.c_primary as f32;

    // recooling circuit (fan speed auto-optimized by the chiller, Sect. 3)
    let fan = ((t_recool - t_ambient) / 12.0)
        .clamp(pp.recool_fan_min as f32, 1.0);
    let p_recool = pp.ua_recool_max as f32 * fan * (t_recool - t_ambient);
    let t_recool_next =
        t_recool + dt * (p_reject - p_recool) / pp.c_recool as f32;

    let p_loss = pipe_loss_hot + pipe_loss_cold + tank_loss;

    cs[C_T_RACK_IN] = t_rack_in;
    cs[C_T_TANK] = t_tank_next;
    cs[C_T_PRIMARY] = t_primary_next;
    cs[C_T_RECOOL] = t_recool_next;
    cs[C_CHILLER_ON] = on;
    cs[C_CYCLE_PHASE] = phase;
    cs[C_P_D] = p_hx_d;
    cs[C_P_C] = p_c;
    cs[C_P_ADD] = p_add;
    cs[C_P_LOSS] = p_loss;
    cs[C_T_RACK_OUT] = t_rack_out;
    cs[C_P_CENTRAL] = p_central;
}

/// (P_d^max * cycle_mod, COP) at the given driving temperature.
/// Mirrors plant.py::chiller_pd_max / chiller_cop exactly (f32 math).
fn chiller_curves(t_tank: f32, on: f32, cycle_mod: f32,
                  pp: &PlantParams) -> (f32, f32) {
    let cop_raw = (pp.cop_at_57 as f32
        + pp.cop_slope as f32 * (t_tank - 57.0))
        .clamp(0.0, pp.cop_max as f32);
    let cop = on * cop_raw;
    let pc = on
        * (pp.pc_max_at_57 as f32 + pp.pc_max_slope as f32 * (t_tank - 57.0))
            .clamp(0.0, pp.pc_max_cap as f32)
        * cycle_mod;
    let pd = if cop > 1e-6 { pc / cop.max(1e-6) } else { 0.0 };
    (pd, cop)
}

/// Initial circuit state (cold start).
pub fn initial_circuit_state(t_water: f32, pp: &PlantParams) -> Vec<f32> {
    let mut cs = vec![0.0f32; CS];
    cs[C_T_RACK_IN] = t_water;
    cs[C_T_TANK] = t_water;
    cs[C_T_PRIMARY] = 16.0;
    cs[C_T_RECOOL] = pp.t_room as f32;
    cs[C_T_RACK_OUT] = t_water;
    cs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controls(valve: f32) -> Vec<f32> {
        vec![valve, 1.0, 18.0, 8.0, 9000.0, 0.75, 0.0, 0.0]
    }

    fn cs_at(t: f32) -> Vec<f32> {
        let pp = PlantParams::default();
        let mut cs = initial_circuit_state(t, &pp);
        cs[C_T_TANK] = t;
        cs[C_T_RACK_OUT] = t;
        cs
    }

    #[test]
    fn valve_lowers_inlet_temperature() {
        let pp = PlantParams::default();
        let mut closed = cs_at(60.0);
        let mut opened = cs_at(60.0);
        circuit_substep(&mut closed, &controls(0.0), 65.0, 40e3, 216, &pp);
        circuit_substep(&mut opened, &controls(1.0), 65.0, 40e3, 216, &pp);
        assert!(opened[C_T_RACK_IN] < closed[C_T_RACK_IN]);
        assert!(opened[C_P_ADD] > 0.0);
        assert_eq!(closed[C_P_ADD], 0.0);
    }

    #[test]
    fn hysteresis_band() {
        let pp = PlantParams::default();
        assert_eq!(chiller_hysteresis(56.0, 0.0, 1.0, &pp), 1.0);
        assert_eq!(chiller_hysteresis(54.0, 1.0, 1.0, &pp), 1.0);
        assert_eq!(chiller_hysteresis(52.9, 1.0, 1.0, &pp), 0.0);
        assert_eq!(chiller_hysteresis(60.0, 1.0, 0.0, &pp), 0.0);
    }

    #[test]
    fn tank_tracks_rack_outlet() {
        // Footnote 2: driving temperature ~ rack outlet temperature.
        let pp = PlantParams::default();
        let mut cs = cs_at(67.0);
        for _ in 0..4000 {
            circuit_substep(&mut cs, &controls(0.0), 68.0, 44e3, 216, &pp);
        }
        // Steady-state gap = P_d_abs / (eps * mcp) ~ 4 K at pump 0.55;
        // "virtually no temperature loss" holds at full pump speed.
        let gap = 68.0 - cs[C_T_TANK];
        assert!((0.0..5.0).contains(&gap), "gap {gap}");
    }

    #[test]
    fn central_supports_primary_above_20() {
        let pp = PlantParams::default();
        let mut cs = cs_at(60.0);
        cs[C_T_PRIMARY] = 24.0;
        circuit_substep(&mut cs, &controls(0.0), 65.0, 40e3, 216, &pp);
        assert!(cs[C_P_CENTRAL] > 0.0);
        let mut cs2 = cs_at(60.0);
        cs2[C_T_PRIMARY] = 18.0;
        circuit_substep(&mut cs2, &controls(0.0), 65.0, 40e3, 216, &pp);
        assert_eq!(cs2[C_P_CENTRAL], 0.0);
    }

    #[test]
    fn pump_failure_kills_transfer() {
        let pp = PlantParams::default();
        let mut cs = cs_at(60.0);
        let mut ctl = controls(0.0);
        ctl[U_PUMP_FAIL] = 1.0;
        circuit_substep(&mut cs, &ctl, 65.0, 40e3, 216, &pp);
        assert!(cs[C_P_D] < 100.0, "{}", cs[C_P_D]);
    }

    #[test]
    fn cycle_phase_wraps() {
        let pp = PlantParams::default();
        let mut cs = cs_at(60.0);
        cs[C_CYCLE_PHASE] = 0.999;
        for _ in 0..10 {
            circuit_substep(&mut cs, &controls(0.0), 65.0, 40e3, 216, &pp);
        }
        assert!(cs[C_CYCLE_PHASE] >= 0.0 && cs[C_CYCLE_PHASE] < 1.0);
    }
}
