//! `NativePlant`: the pure-Rust whole-plant step, mirroring
//! `python/compile/model.py::make_plant_step` (K fused substeps + circuit
//! physics + observation extraction).
//!
//! Two interchangeable substep kernels implement the node physics (see
//! `PlantKernel`): the node-major reference kernel (`node`) — the
//! cross-check oracle `tests/hlo_vs_native.rs` also validates the HLO
//! executable against — and the lane-major SoA kernel (`soa`), the
//! default. `tests/proptests.rs::prop_kernel_parity` pins the two to
//! tight f32 tolerance.

use super::circuits;
use super::layout::*;
use super::node::{self, NodeScratch};
use super::operators::Operators;
use super::soa::{self, SoaState};
use super::{PlantKernel, PlantStatic, TickOutput};
use crate::config::constants::PlantParams;

/// Which copy of the node thermal state is current.
///
/// The reference kernel always keeps the node-major buffer
/// authoritative (`NodeMajor`). The SoA kernel keeps its lanes
/// **resident**: after a tick the lanes are authoritative and the
/// node-major buffer is stale (`LanesDirty`) until a consumer calls
/// `NativePlant::node_state()`, which materializes it lazily
/// (`InSync`). Steady-state runs that never read node-major state do
/// zero state transposes after warm-up.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LaneSync {
    /// node-major is authoritative; lanes must be loaded before a tick.
    NodeMajor,
    /// Lanes are authoritative; the node-major buffer is stale.
    LanesDirty,
    /// Lanes are authoritative and the node-major buffer matches them.
    InSync,
}

/// Effective pump flow from the control vector: the nominal flow scale
/// derated by pump failure, floored away from zero. The single
/// definition shared by `NativePlant::tick` and the fleet megabatch
/// engine — the megabatch bitwise-identity contract depends on the two
/// paths computing this term-for-term identically.
pub(crate) fn effective_flow(controls: &[f32]) -> f32 {
    (controls[U_FLOW_SCALE] * (1.0 - controls[U_PUMP_FAIL])).max(1e-3)
}

/// Pure-Rust plant simulation state + stepper.
#[derive(Debug)]
pub struct NativePlant {
    pub pp: PlantParams,
    pub ops: Operators,
    pub st: PlantStatic,
    pub substeps: usize,
    pub kernel: PlantKernel,
    /// [npad * S] node thermal state, node-major. Authoritative for the
    /// reference kernel; for the SoA kernel it is a lazily-materialized
    /// view of the resident lanes — read it through `node_state()`.
    node_major: Vec<f32>,
    /// Which buffer is current (see `LaneSync`).
    sync: LaneSync,
    /// [CS] circuit state
    pub circuit_state: Vec<f32>,
    scratch: NodeScratch,
    g_eff: Vec<f32>,
    q_base: Vec<f32>,
    /// Effective flow of the last tick: the g_eff rebuild is skipped
    /// while the pump controls are unchanged.
    last_flow: Option<f32>,
    /// Resident lane state (SoA kernel only), allocated lazily on the
    /// first tick — a plant driven externally through a megabatch arena
    /// (`fleet::megabatch`) never carries its own lanes.
    soa: Option<SoaState>,
}

impl NativePlant {
    pub fn new(pp: PlantParams, ops: Operators, st: PlantStatic,
               t_water: f32) -> Self {
        Self::with_kernel(pp, ops, st, t_water, PlantKernel::default())
    }

    pub fn with_kernel(pp: PlantParams, ops: Operators, st: PlantStatic,
                       t_water: f32, kernel: PlantKernel) -> Self {
        let npad = st.n_padded;
        let n = st.n_nodes;
        let substeps = pp.substeps_per_tick;
        let circuit_state = circuits::initial_circuit_state(t_water, &pp);
        // Each kernel owns its working set; the other's stays empty so
        // a fleet of SoA plants does not carry dead AoS buffers (and
        // vice versa). The SoA lanes allocate lazily on the first tick
        // (see the `soa` field).
        let (scratch, g_eff, q_base) = match kernel {
            PlantKernel::Reference => {
                // q_base has exactly two live entries per node: the
                // advective inlet (updated every substep) and the sink
                // constant, which depends only on plant parameters —
                // set once here so the tick loop never refills the
                // buffer. (SoaState fills its own lane-major mirror.)
                let mut q_base = vec![0.0; npad * S];
                let q_sink_const =
                    ((pp.p_node_base + pp.ua_node_air * pp.t_room)
                        * ops.inv_c[IDX_SINK] as f64) as f32;
                for i in 0..n {
                    q_base[i * S + IDX_SINK] = q_sink_const;
                }
                (NodeScratch::new(npad), vec![0.0; npad * NG], q_base)
            }
            PlantKernel::Soa => {
                (NodeScratch::new(0), Vec::new(), Vec::new())
            }
        };
        NativePlant {
            scratch,
            g_eff,
            q_base,
            node_major: vec![t_water; npad * S],
            sync: LaneSync::NodeMajor,
            circuit_state,
            last_flow: None,
            soa: None,
            kernel,
            pp,
            ops,
            st,
            substeps,
        }
    }

    pub fn reset(&mut self, t_water: f32) {
        self.node_major.fill(t_water);
        // The node-major buffer is the edited copy; lanes reload on the
        // next tick.
        self.sync = LaneSync::NodeMajor;
        self.circuit_state =
            circuits::initial_circuit_state(t_water, &self.pp);
        self.last_flow = None;
    }

    /// Node thermal state `[npad * S]`, node-major. For the SoA kernel
    /// this is the **lazy** transpose of the resident lanes: the first
    /// call after a tick pays one materialization, repeat calls are
    /// free, and runs that never call it do zero state transposes.
    pub fn node_state(&mut self) -> &[f32] {
        self.sync_node_major();
        &self.node_major
    }

    /// Materialize the node-major view if the lanes are newer.
    fn sync_node_major(&mut self) {
        if self.sync == LaneSync::LanesDirty {
            let _span = crate::obs::span("transpose");
            if crate::obs::enabled() {
                crate::obs::metrics::lane_sync_transitions().inc();
            }
            let soa = self.soa.as_ref().expect("dirty lanes without state");
            soa.materialize(&mut self.node_major);
            self.sync = LaneSync::InSync;
        }
    }

    /// Overwrite the node-major state from an external source — the
    /// fleet megabatch engine hands each plant its final arena slice
    /// back at run end, so a driver that was lockstep-driven reports
    /// the real thermal state (not the warm-up fill) to any later
    /// consumer. Invalidates the (untouched) internal lanes; a
    /// subsequent tick reloads them from this buffer.
    pub(crate) fn adopt_node_state(&mut self, state: &[f32]) {
        self.node_major.copy_from_slice(state);
        self.sync = LaneSync::NodeMajor;
    }

    /// Corrupt the plant's entire dynamic state with NaN — the chaos
    /// injector's `poison_nan` action (`resilience::inject`). Both the
    /// node-major buffer and any resident lanes are poisoned so the
    /// fault survives whichever copy the next tick reads, and the
    /// circuit state is poisoned so it reaches the scalar observations
    /// on the very next tick. The fleet quarantine sweep detects the
    /// resulting non-finite reductions and evicts the plant.
    pub fn poison_state(&mut self) {
        self.node_major.fill(f32::NAN);
        if let Some(soa) = self.soa.as_mut() {
            let r = LaneRange {
                offset: 0,
                n_valid: self.st.n_nodes,
                npad: self.st.n_padded,
            };
            soa.poison_state_range(r);
            // Both copies now hold the same NaN fill.
            self.sync = LaneSync::InSync;
        } else {
            self.sync = LaneSync::NodeMajor;
        }
        for v in self.circuit_state.iter_mut() {
            *v = f32::NAN;
        }
    }

    /// Rebuild the kernel's derived state after an external edit to the
    /// static inputs (`st` is `pub`): the SoA lane mirrors and the
    /// flow-derived `g_eff` cache both copy from `st` and would
    /// otherwise keep serving stale values until the pump control
    /// changes. The current thermal state is preserved (materialized
    /// first if the lanes are newer); the lanes themselves are dropped
    /// and rebuilt from the edited statics on the next tick.
    pub fn refresh_static(&mut self) {
        self.sync_node_major();
        if self.kernel == PlantKernel::Soa {
            self.soa = None;
            self.sync = LaneSync::NodeMajor;
        }
        self.last_flow = None;
    }

    /// One coordinator tick = `substeps` fused substeps (model.py parity).
    pub fn tick(&mut self, controls: &[f32], util: &[f32],
                out: &mut TickOutput) {
        let n = self.st.n_nodes;
        let flow = effective_flow(controls);
        // g_eff depends only on the static conductances and the pump
        // flow; skip the rebuild while the controls keep it unchanged.
        let flow_changed = self.last_flow != Some(flow);
        self.last_flow = Some(flow);
        let inv_c_w = self.ops.inv_c[IDX_WATER];

        match self.kernel {
            PlantKernel::Reference => {
                let npad = self.st.n_padded;
                if flow_changed {
                    // advection channel scaled by pump speed
                    self.g_eff.copy_from_slice(&self.st.g);
                    for i in 0..npad {
                        self.g_eff[i * NG + G_ADV] *= flow;
                    }
                }
                let _substep_span = crate::obs::span("ref_substep");
                for _ in 0..self.substeps {
                    // q_base: only the advective-inlet entry varies
                    // within a tick; the sink constant and the zero
                    // entries were set at construction. g_eff's
                    // advection channel already carries flow * g (f32
                    // multiplication commutes bitwise), so this
                    // reproduces flow * g * t_in * inv_c_w exactly.
                    let t_in = self.circuit_state[C_T_RACK_IN];
                    for i in 0..npad {
                        self.q_base[i * S + IDX_WATER] =
                            self.g_eff[i * NG + G_ADV] * t_in * inv_c_w;
                    }
                    let p_dc = node::fused_substep(
                        &mut self.node_major, &self.g_eff, util,
                        &self.st.p_dyn, &self.st.p_idle, &self.st.active,
                        &self.q_base, &self.ops, &self.pp,
                        &mut self.scratch, n,
                    );
                    // Equal branch flows (Tichelmann): arithmetic mean
                    // over the valid prefix.
                    let mut t_out_raw = 0.0f32;
                    for i in 0..n {
                        t_out_raw += self.node_major[i * S + IDX_WATER];
                    }
                    t_out_raw /= n as f32;
                    circuits::circuit_substep(
                        &mut self.circuit_state, controls, t_out_raw,
                        p_dc, n, &self.pp);
                }
                drop(_substep_span);
                let _obs_span = crate::obs::span("observe");
                self.observe(controls, util, out);
            }
            PlantKernel::Soa => {
                if self.soa.is_none() {
                    self.soa =
                        Some(SoaState::new(&self.st, &self.ops, &self.pp));
                }
                let soa = self.soa.as_mut().expect("just allocated");
                let r = LaneRange {
                    offset: 0,
                    n_valid: n,
                    npad: self.st.n_padded,
                };
                if flow_changed {
                    soa.set_flow_range(flow, r);
                }
                // Resident lanes: the state transpose-in runs only when
                // the node-major buffer was edited (construction, reset,
                // refresh_static) — not per tick. Utilization is a
                // genuine per-tick input.
                if self.sync == LaneSync::NodeMajor {
                    let _span = crate::obs::span("transpose");
                    if crate::obs::enabled() {
                        crate::obs::metrics::lane_sync_transitions().inc();
                    }
                    soa.load_state_range(&self.node_major, r);
                }
                soa.load_util_range(util, r);
                let _substep_span = crate::obs::span("soa_substep");
                for _ in 0..self.substeps {
                    let t_in = self.circuit_state[C_T_RACK_IN];
                    soa.set_inlet_range(t_in, inv_c_w, r);
                    let (p_dc, t_out_sum) =
                        soa::soa_substep(soa, &self.pp, n);
                    let t_out_raw = t_out_sum / n as f32;
                    circuits::circuit_substep(
                        &mut self.circuit_state, controls, t_out_raw,
                        p_dc, n, &self.pp);
                }
                drop(_substep_span);
                // Fused epilogue straight from the lanes; no node-major
                // write-back — node_state() materializes lazily.
                let _obs_span = crate::obs::span("observe");
                let (p_dc, throttling, core_max_all) =
                    soa::soa_observe_range(soa, &self.pp, r,
                                           &mut out.node_obs);
                self.sync = LaneSync::LanesDirty;
                self.fill_scalars(controls, p_dc, throttling,
                                  core_max_all, out);
            }
        }
    }

    /// Observation extraction, mirroring model.py's epilogue (the
    /// reference-kernel path; the SoA kernel fuses this into its final
    /// substep pass — `soa::soa_observe`).
    fn observe(&self, controls: &[f32], util: &[f32], out: &mut TickOutput) {
        let npad = self.st.n_padded;
        let n = self.st.n_nodes;
        let pp = &self.pp;
        let coeffs = node::PowerCoeffs::new(pp);
        let mut p_dc = 0.0f64;
        let mut throttling = 0.0f32;
        let mut core_max_all = f32::MIN;

        for i in 0..npad {
            let ts = &self.node_major[i * S..(i + 1) * S];
            let mut p_node = 0.0f32;
            let mut tsum = 0.0f32;
            let mut tmax = -1e9f32;
            let mut n_active = 0.0f32;
            for c in 0..NC {
                let a = self.st.active[i * NC + c];
                let p = coeffs.core_power(
                    ts[c], util[i * NC + c], self.st.p_dyn[i * NC + c],
                    self.st.p_idle[i * NC + c], a);
                p_node += p;
                if a > 0.0 {
                    tsum += ts[c];
                    n_active += 1.0;
                    if ts[c] > tmax {
                        tmax = ts[c];
                    }
                    if ts[c] > (pp.t_throttle - pp.throttle_band) as f32 {
                        throttling += 1.0;
                    }
                }
            }
            // Zero active cores: report the water temperature, not the
            // accumulator sentinels (-1e9 / 0.0) — padded filler nodes
            // and fully-binned chips would otherwise leak them into the
            // observations and SC_CORE_MAX.
            let (tmax, tmean) = if n_active > 0.0 {
                (tmax, tsum / n_active)
            } else {
                (ts[IDX_WATER], ts[IDX_WATER])
            };
            if i < n {
                p_node += pp.p_node_base as f32;
                p_dc += p_node as f64;
                if tmax > core_max_all {
                    core_max_all = tmax;
                }
            }
            let o = &mut out.node_obs[i * OBS_N..(i + 1) * OBS_N];
            o[O_NODE_POWER] = p_node;
            o[O_CORE_MEAN] = tmean;
            o[O_CORE_MAX] = tmax;
            o[O_WATER_OUT] = ts[IDX_WATER];
        }

        self.fill_scalars(controls, p_dc, throttling, core_max_all, out);
    }

    /// Scalar block shared by both kernels' epilogues (and by the fleet
    /// megabatch engine, which runs the SoA epilogue externally).
    pub(crate) fn fill_scalars(&self, controls: &[f32], p_dc: f64,
                               throttling: f32, core_max_all: f32,
                               out: &mut TickOutput) {
        let pp = &self.pp;
        let cs = &self.circuit_state;
        let mcp = (pp.rack_mcp(self.st.n_nodes) as f32
            * controls[U_FLOW_SCALE].max(1e-3)
            * (1.0 - controls[U_PUMP_FAIL]))
            .max(1.0);
        let sc = &mut out.scalars;
        sc[SC_P_DC] = p_dc as f32;
        sc[SC_P_AC] =
            (p_dc / pp.psu_efficiency + pp.p_switches) as f32;
        sc[SC_P_R] = mcp * (cs[C_T_RACK_OUT] - cs[C_T_RACK_IN]);
        sc[SC_P_D] = cs[C_P_D];
        sc[SC_P_C] = cs[C_P_C];
        sc[SC_P_ADD] = cs[C_P_ADD];
        sc[SC_P_LOSS] = cs[C_P_LOSS];
        sc[SC_T_RACK_IN] = cs[C_T_RACK_IN];
        sc[SC_T_RACK_OUT] = cs[C_T_RACK_OUT];
        sc[SC_T_TANK] = cs[C_T_TANK];
        sc[SC_T_PRIMARY] = cs[C_T_PRIMARY];
        sc[SC_CHILLER_ON] = cs[C_CHILLER_ON];
        sc[SC_P_CENTRAL] = cs[C_P_CENTRAL];
        sc[SC_T_RECOOL] = cs[C_T_RECOOL];
        sc[SC_THROTTLE] = throttling;
        sc[SC_CORE_MAX] = core_max_all;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::variability::ChipLottery;

    fn make_with(n: usize, kernel: PlantKernel)
                 -> (NativePlant, Vec<f32>, Vec<f32>) {
        let pp = PlantParams::default();
        let ops = Operators::build(&pp);
        let lot = ChipLottery::draw(n, &pp, crate::variability::DEFAULT_SEED);
        let st = PlantStatic::from_lottery(&lot, &pp, 64);
        let npad = st.n_padded;
        let plant = NativePlant::with_kernel(pp, ops, st, 20.0, kernel);
        let controls = vec![0.0, 1.0, 18.0, 8.0, 9000.0, 0.75, 0.0, 0.0];
        let util = vec![1.0f32; npad * NC];
        (plant, controls, util)
    }

    /// Default kernel (SoA) — what `NativePlant::new` builds.
    fn make(n: usize) -> (NativePlant, Vec<f32>, Vec<f32>) {
        make_with(n, PlantKernel::default())
    }

    #[test]
    fn stress_heats_and_reaches_equilibrium_band() {
        let (mut plant, controls, util) = make(13);
        let mut out = TickOutput::new(plant.st.n_padded);
        // 13 nodes -> much lower load; equilibrium far below chiller band.
        for _ in 0..600 {
            plant.tick(&controls, &util, &mut out);
        }
        let sc = &out.scalars;
        assert!(sc[SC_T_RACK_OUT] > 21.0);
        assert!(sc[SC_P_DC] > 13.0 * 150.0);
        // core temps must exceed water temps
        assert!(sc[SC_CORE_MAX] > sc[SC_T_RACK_OUT]);
    }

    #[test]
    fn idle_stays_cool() {
        let (mut plant, controls, _util) = make(13);
        let util = vec![0.0f32; plant.st.n_padded * NC];
        let mut out = TickOutput::new(plant.st.n_padded);
        for _ in 0..600 {
            plant.tick(&controls, &util, &mut out);
        }
        assert!(out.scalars[SC_CORE_MAX] < 45.0,
                "{}", out.scalars[SC_CORE_MAX]);
    }

    #[test]
    fn valve_regulates_inlet() {
        let (mut plant, mut controls, util) = make(13);
        let mut out = TickOutput::new(plant.st.n_padded);
        for _ in 0..400 {
            plant.tick(&controls, &util, &mut out);
        }
        let before = out.scalars[SC_T_RACK_IN];
        controls[U_VALVE] = 1.0;
        for _ in 0..100 {
            plant.tick(&controls, &util, &mut out);
        }
        assert!(out.scalars[SC_T_RACK_IN] < before);
        assert!(out.scalars[SC_P_ADD] > 0.0);
    }

    #[test]
    fn reset_restores_cold_state() {
        let (mut plant, controls, util) = make(13);
        let mut out = TickOutput::new(plant.st.n_padded);
        for _ in 0..50 {
            plant.tick(&controls, &util, &mut out);
        }
        plant.reset(20.0);
        assert!(plant.node_state().iter().all(|&t| t == 20.0));
        assert_eq!(plant.circuit_state[C_T_RACK_IN], 20.0);
    }

    #[test]
    fn kernels_agree_over_a_trajectory() {
        // Quick cross-kernel smoke (the exhaustive randomized version
        // lives in tests/proptests.rs::prop_kernel_parity).
        let (mut refp, controls, util) = make_with(13, PlantKernel::Reference);
        let (mut soap, _, _) = make_with(13, PlantKernel::Soa);
        let mut or = TickOutput::new(refp.st.n_padded);
        let mut os = TickOutput::new(soap.st.n_padded);
        for _ in 0..80 {
            refp.tick(&controls, &util, &mut or);
            soap.tick(&controls, &util, &mut os);
        }
        let ns_ref = refp.node_state().to_vec();
        for (a, b) in ns_ref.iter().zip(soap.node_state()) {
            assert!((a - b).abs() < 1e-3, "state: ref {a} vs soa {b}");
        }
        for i in 0..NS {
            let denom = or.scalars[i].abs().max(1.0);
            let rel = (or.scalars[i] - os.scalars[i]).abs() / denom;
            assert!(rel < 1e-4, "scalar {i}: {} vs {}", or.scalars[i],
                    os.scalars[i]);
        }
    }

    #[test]
    fn idle_cores_report_water_temperature_not_sentinel() {
        // Regression: a node with zero active cores used to report
        // O_CORE_MAX = -1e9, and an all-idle plant leaked the sentinel
        // into SC_CORE_MAX. Both must clamp to the node water temp.
        for kernel in [PlantKernel::Reference, PlantKernel::Soa] {
            let (mut plant, controls, util) = make_with(13, kernel);
            // Fully bin node 0 (the paper's chip lottery can disable
            // cores; force the extreme case).
            for c in 0..NC {
                plant.st.active[c] = 0.0;
            }
            plant.refresh_static();
            let mut out = TickOutput::new(plant.st.n_padded);
            for _ in 0..10 {
                plant.tick(&controls, &util, &mut out);
            }
            let o = out.node(0);
            assert_eq!(o[O_CORE_MAX], o[O_WATER_OUT], "{kernel:?}");
            assert_eq!(o[O_CORE_MEAN], o[O_WATER_OUT], "{kernel:?}");
            assert!(o[O_CORE_MAX] > 0.0, "{kernel:?}");
            // padded filler nodes never had active cores either
            let pad = out.node(plant.st.n_nodes);
            assert_eq!(pad[O_CORE_MAX], pad[O_WATER_OUT], "{kernel:?}");

            // all-idle plant: SC_CORE_MAX is a water temperature, not
            // f32::MIN / -1e9
            plant.st.active.fill(0.0);
            plant.refresh_static();
            plant.tick(&controls, &util, &mut out);
            assert!(out.scalars[SC_CORE_MAX] > 0.0, "{kernel:?}");
            assert!(out.scalars[SC_CORE_MAX] < 100.0, "{kernel:?}");
        }
    }

    #[test]
    fn flow_cache_tracks_control_changes() {
        for kernel in [PlantKernel::Reference, PlantKernel::Soa] {
            let (mut plant, mut controls, util) = make_with(13, kernel);
            let mut out = TickOutput::new(plant.st.n_padded);
            let g_adv = |p: &NativePlant, i: usize| match p.kernel {
                PlantKernel::Reference => p.g_eff[i * NG + G_ADV],
                PlantKernel::Soa => {
                    let s = p.soa.as_ref().unwrap();
                    s.g_eff[G_ADV * s.npad + i]
                }
            };
            for &flow in &[0.75f32, 0.75, 0.4, 0.75] {
                controls[U_FLOW_SCALE] = flow;
                plant.tick(&controls, &util, &mut out);
                assert_eq!(plant.last_flow, Some(flow));
                for i in 0..3 {
                    assert_eq!(g_adv(&plant, i),
                               plant.st.g[i * NG + G_ADV] * flow,
                               "{kernel:?} flow {flow}");
                }
            }
        }
    }

    #[test]
    fn resident_lanes_materialize_lazily_and_exactly() {
        // The resident-state contract: node_state() after a lazy
        // materialization is bitwise equal to an eager twin that
        // materializes after every tick, repeat reads are stable, and
        // reading the view does not perturb the subsequent evolution.
        let (mut lazy, controls, util) = make_with(13, PlantKernel::Soa);
        let (mut eager, _, _) = make_with(13, PlantKernel::Soa);
        let mut ol = TickOutput::new(lazy.st.n_padded);
        let mut oe = TickOutput::new(eager.st.n_padded);
        for _ in 0..30 {
            lazy.tick(&controls, &util, &mut ol);
            eager.tick(&controls, &util, &mut oe);
            let _ = eager.node_state(); // eager per-tick write-back
        }
        let a = lazy.node_state().to_vec();
        let b = eager.node_state().to_vec();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "lazy vs eager");
        }
        // repeat reads are free and identical (InSync)
        assert_eq!(lazy.node_state(), &a[..]);
        // the materialized view matches the lanes exactly
        let mut direct = vec![0.0f32; lazy.st.n_padded * S];
        lazy.soa.as_ref().unwrap().materialize(&mut direct);
        assert_eq!(lazy.node_state(), &direct[..]);
        // ticking on continues from the resident lanes, in lockstep
        lazy.tick(&controls, &util, &mut ol);
        eager.tick(&controls, &util, &mut oe);
        let a = lazy.node_state().to_vec();
        for (x, y) in a.iter().zip(eager.node_state()) {
            assert_eq!(x.to_bits(), y.to_bits(), "post-read divergence");
        }
    }

    #[test]
    fn adopted_state_is_served_and_reloaded() {
        // The megabatch hand-back path: adopt_node_state must replace
        // the node-major view immediately and the next tick must reload
        // the lanes from it (not from the stale resident lanes).
        let (mut plant, controls, util) = make_with(13, PlantKernel::Soa);
        let mut out = TickOutput::new(plant.st.n_padded);
        for _ in 0..5 {
            plant.tick(&controls, &util, &mut out);
        }
        let external = vec![33.5f32; plant.st.n_padded * S];
        plant.adopt_node_state(&external);
        assert_eq!(plant.node_state(), &external[..]);
        // the next tick evolves from the adopted state: a twin started
        // from the same state + circuits must match bitwise
        let (mut twin, _, _) = make_with(13, PlantKernel::Soa);
        twin.adopt_node_state(&external);
        twin.circuit_state.copy_from_slice(&plant.circuit_state);
        plant.tick(&controls, &util, &mut out);
        let mut out2 = TickOutput::new(twin.st.n_padded);
        twin.tick(&controls, &util, &mut out2);
        for (a, b) in out.scalars.iter().zip(&out2.scalars) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let a = plant.node_state().to_vec();
        for (x, y) in a.iter().zip(twin.node_state()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn energy_is_not_created() {
        // Node enthalpy cannot rise faster than electrical input allows.
        let (mut plant, controls, util) = make(13);
        let mut out = TickOutput::new(plant.st.n_padded);
        let c: Vec<f32> =
            plant.ops.inv_c.iter().map(|&ic| 1.0 / ic).collect();
        let n_states = plant.st.n_nodes * S;
        for _ in 0..50 {
            let before: f64 = {
                let ns = plant.node_state();
                (0..n_states).map(|i| ns[i] as f64 * c[i % S] as f64).sum()
            };
            plant.tick(&controls, &util, &mut out);
            let after: f64 = {
                let ns = plant.node_state();
                (0..n_states).map(|i| ns[i] as f64 * c[i % S] as f64).sum()
            };
            let dt = plant.substeps as f64 * plant.pp.dt_substep;
            let de = (after - before) / dt;
            assert!(de < out.scalars[SC_P_DC] as f64 + 5_000.0,
                    "enthalpy rate {de} vs P_dc {}", out.scalars[SC_P_DC]);
        }
    }
}
